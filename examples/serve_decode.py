"""End-to-end serving driver (the paper's workload): batched prefill + long
decode with the FP8 quantized KV cache, on a reduced MLA model.

    PYTHONPATH=src python examples/serve_decode.py [--arch mla-7b] [--gen 32]

Reports decode tokens/s (CPU, interpret-scale) and token agreement vs BF16.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import generate
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mla-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "ref", "kernel"],
                    help="decode-attention backend: 'kernel' runs the Pallas "
                         "split-KV kernels inside the jitted decode step")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    cfg = dataclasses.replace(cfg, decode_backend=args.backend,
                              use_kernels=args.backend == "kernel")
    key = jax.random.PRNGKey(0)
    params = T.init_model(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    aux = (jax.random.normal(key, (args.batch, cfg.n_aux_tokens, cfg.d_model))
           if cfg.n_aux_tokens else None)

    results = {}
    for fmt in ("fp8_e4m3", "int8", "none"):
        c = dataclasses.replace(cfg, kv_fmt=fmt)
        toks, tps = generate(c, params, prompts, args.gen, aux_embed=aux)
        results[fmt] = (np.asarray(toks), tps)
        print(f"[{fmt:9s}] {tps:8.1f} tok/s (CPU interpret-scale)")

    for fmt in ("fp8_e4m3", "int8"):
        agree = (results[fmt][0] == results["none"][0]).mean()
        print(f"token agreement {fmt} vs bf16: {agree * 100:.1f}%")


if __name__ == "__main__":
    main()
