"""Quantization-config ablation (paper Appendix G, Table 3 + Figure 5).

    PYTHONPATH=src python examples/quantization_ablation.py

Compares SnapMLA's RoPE-aware per-token quantization against Configs A-D on
synthetic MLA KV distributions with heavy-tailed RoPE components.
"""
import sys
import pathlib
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.numerics import attention_fidelity, value_range_analysis


def main():
    print("== Fig 3 analogue: dynamic range & FP8 sensitivity ==")
    for r in value_range_analysis():
        print(f"  {r['part']:8s} |x| in [{r['abs_min']:.2e}, {r['abs_max']:.1f}] "
              f"per-token FP8 MSE {r['fp8_per_token_mse']:.3e}")
    print("\n== Fig 5 analogue: attention-output fidelity per config ==")
    print(f"  {'config':10s} {'MSE':>12s} {'max rel err':>12s} {'cos sim':>10s}")
    for r in attention_fidelity():
        print(f"  {r['config']:10s} {r['mse']:12.3e} {r['max_rel_err']:12.4f} "
              f"{r['cos_sim']:10.6f}")
    print("\nExpected ordering (paper): snapmla < config_d < config_c/b, and "
          "config_a (RoPE-unaware) catastrophically worse.")


if __name__ == "__main__":
    main()
