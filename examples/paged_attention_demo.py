"""TPU-native PagedAttention demo: the SnapMLA decode kernel driven by a
scalar-prefetched page table (the paper's Fused-K-Append / PagedAttention
analogue on TPU — see DESIGN.md §2).

    PYTHONPATH=src python examples/paged_attention_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kvcache import CacheConfig, init_mla_cache, mla_prefill
from repro.kernels.mla_decode import ref as R
from repro.kernels.mla_decode.kernel import (mla_decode_paged_pallas,
                                             mla_decode_paged_splitkv_pallas)
from repro.kernels.mla_decode.ops import snapmla_decode


def main():
    B, H, d_c, d_r, page, P = 2, 8, 64, 16, 64, 4
    N, S = page * P, 200
    key = jax.random.PRNGKey(0)
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=page)
    ks = jax.random.split(key, 4)
    cache = mla_prefill(init_mla_cache(cfg, B, N, d_c, d_r), cfg,
                        jax.random.normal(ks[0], (B, S, d_c)) * 2,
                        jax.random.normal(ks[1], (B, S, d_r)) * 20)
    q_c8, q_r, sq = R.prepare_q(jax.random.normal(ks[2], (B, H, d_c)),
                                jax.random.normal(ks[3], (B, H, d_r)) * 4)
    scale = 1.0 / np.sqrt(128 + d_r)

    o_contig, _ = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=scale,
                                 block_n=page)

    # scatter the pages into a shuffled global pool + page table
    rng = np.random.RandomState(0)
    n_pool = B * P + 4
    perm = rng.permutation(n_pool)[: B * P].reshape(B, P)
    pool_c = np.zeros((n_pool, page, d_c), np.asarray(cache.content).dtype)
    pool_r = np.zeros((n_pool, page, d_r), np.float32)
    pool_s = np.ones((n_pool, page), np.float32)
    for b in range(B):
        for j in range(P):
            sl = slice(j * page, (j + 1) * page)
            pool_c[perm[b, j]] = np.asarray(cache.content[b, sl])
            pool_r[perm[b, j]] = np.asarray(cache.rope[b, sl], np.float32)
            pool_s[perm[b, j]] = np.asarray(cache.scale[b, sl])

    o_paged, _ = mla_decode_paged_pallas(
        q_c8, q_r, sq, jnp.asarray(pool_c), jnp.asarray(pool_r),
        jnp.asarray(pool_s), jnp.asarray(perm, jnp.int32), cache.seq_lens,
        softmax_scale=scale)
    print("page table:", perm.tolist())
    print("max |paged - contiguous| =", float(np.abs(o_paged - o_contig).max()))
    assert np.allclose(o_paged, o_contig, atol=1e-5)
    print("paged == contiguous: the page table drives the BlockSpec index map.")

    # paged split-KV: sequence parallelism over the same pool (flash-decoding
    # grid + LSE combine + block-level early exit, page-table addressed)
    o_split, _ = mla_decode_paged_splitkv_pallas(
        q_c8, q_r, sq, jnp.asarray(pool_c), jnp.asarray(pool_r),
        jnp.asarray(pool_s), jnp.asarray(perm, jnp.int32), cache.seq_lens,
        softmax_scale=scale, num_splits=2)
    print("max |paged split-KV - contiguous| =",
          float(np.abs(o_split - o_contig).max()))
    assert np.allclose(o_split, o_contig, atol=1e-4)
    print("paged split-KV == contiguous within quantization rounding.")


if __name__ == "__main__":
    main()
