"""Train a small MLA+MoE model end-to-end on the synthetic pipeline with
checkpoint/restart (fault-tolerance drill included).

    PYTHONPATH=src python examples/train_small_mla.py [--steps 60]

Demonstrates the full production loop at CPU scale: sharded train step,
deterministic resumable data, atomic checkpoints, preemption-safe exit.
"""
import argparse
import tempfile

from repro.configs import get_smoke_config
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="deepseek-v3-mla")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    with tempfile.TemporaryDirectory() as ckpt:
        print(f"== phase 1: train {args.steps // 2} steps, checkpoint ==")
        out1 = train_loop(cfg, steps=args.steps // 2, batch=8, seq=32,
                          ckpt_dir=ckpt, ckpt_every=10, lr=1e-3)
        print(f"== phase 2: 'restart' resumes from checkpoint ==")
        out2 = train_loop(cfg, steps=args.steps, batch=8, seq=32,
                          ckpt_dir=ckpt, ckpt_every=50, lr=1e-3)
        print(f"loss: {out1['losses'][0]:.4f} -> {out2['losses'][-1]:.4f} "
              f"(resumed at step {args.steps // 2 - args.steps % 2})")


if __name__ == "__main__":
    main()
