"""Quickstart: the SnapMLA FP8 decoding pipeline on a small MLA model.

    PYTHONPATH=src python examples/quickstart.py

Builds a small MLA attention layer, prefills a prompt into the quantized
latent KV cache (RoPE-aware per-token FP8), runs a few decode steps through
the scale-fused FP8 pipeline, and compares against the BF16 baseline.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mla as M
from repro.core.kvcache import CacheConfig
from repro.core.snapmla import SnapMLAConfig, decode_step, init_cache, prefill


def main():
    key = jax.random.PRNGKey(0)
    mla_cfg = M.MLAConfig(d_model=256, n_heads=8, d_head=32, d_rope=16, d_c=64)
    params = M.init_mla_params(key, mla_cfg)

    B, S, steps = 2, 64, 8
    h_prompt = jax.random.normal(jax.random.PRNGKey(1), (B, S, 256))
    h_steps = jax.random.normal(jax.random.PRNGKey(2), (steps, B, 256))

    outs = {}
    for fmt in ("fp8_e4m3", "none"):
        cfg = SnapMLAConfig(mla=mla_cfg, cache=CacheConfig(fmt=fmt, page_size=64))
        cache = init_cache(cfg, B, 256)
        _, cache = prefill(params, cfg, h_prompt, cache)
        ys = []
        for t in range(steps):
            y, cache = decode_step(params, cfg, h_steps[t], cache)
            ys.append(y)
        outs[fmt] = np.asarray(jnp.stack(ys))
        bytes_per_tok = (cache.content.dtype.itemsize * mla_cfg.d_c
                         + 2 * mla_cfg.d_rope + 4)
        print(f"[{fmt:9s}] decoded {steps} steps; cache {bytes_per_tok} B/token")

    rel = np.abs(outs["fp8_e4m3"] - outs["none"]).max() / np.abs(outs["none"]).max()
    print(f"FP8 vs BF16 pipeline max relative difference: {rel:.4f}")
    print("(paper claim: near-parity — small per-step divergence)")


if __name__ == "__main__":
    main()
