"""Kernel-level performance (paper Figures 6 & 7 analogues).

Paper App. H derives an effective FP8 peak on Hopper:
    Peak_eff = 148 x 17/9 ~ 279.6 TFLOPS
(16 content tiles at FP8 half-cost + 1 RoPE tile at BF16).

v5e translation (DESIGN.md §2): the content GEMMs can use the int8 MXU path
(2x bf16 peak) while the RoPE tile stays bf16:
    d_c = 512 -> 8 "tiles" of 64 + 1 rope tile of 64+... using the paper's
    17-tile accounting (d_c+d_r = 576 = 9 x 64; QK+PV -> 16 content + 1 rope):
    Peak_eff(v5e) = 197 x 17 / (16/2 + 1) = 197 x 17/9 ~ 372 TFLOPS.

For each (context x heads x mtp) we report the *achievable* TFLOPS =
min(Peak_eff, intensity x HBM_bw) — the roofline position of the kernel —
for BF16-storage FlashMLA-equivalent vs SnapMLA FP8 storage, plus measured
CPU interpret-mode wall time of the real Pallas kernel at reduced size
(correctness-bearing, not TPU-time-bearing).
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

V5E_BF16 = 197e12
V5E_INT8 = 394e12
V5E_HBM = 819e9
PEAK_EFF_SNAP = V5E_BF16 * 17 / 9          # int8 content + bf16 rope
PEAK_EFF_BF16 = V5E_BF16

D_C, D_R = 512, 64


def kernel_roofline(context: int, heads: int, mtp: int, fmt: str) -> dict:
    """Per-token-step decode attention kernel roofline on v5e."""
    # bytes per cached token
    if fmt == "none":
        b_tok = (D_C + D_R) * 2
        peak = PEAK_EFF_BF16
    else:
        b_tok = D_C * 1 + D_R * 2 + 4
        peak = PEAK_EFF_SNAP
    flops_tok = (2 * (D_C + D_R) + 2 * D_C) * heads * mtp     # QK + PV per head
    intensity = flops_tok / b_tok                              # FLOP / byte
    achievable = min(peak, intensity * V5E_HBM)
    t = context * max(b_tok / V5E_HBM, flops_tok / peak)
    return {"intensity": intensity, "achievable_tflops": achievable / 1e12,
            "peak_tflops": peak / 1e12, "t_us": t * 1e6,
            "bound": "mem" if b_tok / V5E_HBM > flops_tok / peak else "comp"}


def figure6(fmt_pairs=(("bf16", "none"), ("snapmla", "fp8_e4m3"))):
    rows = []
    for ctx in [16384, 32768, 65536, 131072]:
        row = {"context": ctx}
        for label, fmt in fmt_pairs:
            r = kernel_roofline(ctx, heads=128, mtp=1, fmt=fmt)
            row[label] = r
        row["speedup"] = row["bf16"]["t_us"] / row["snapmla"]["t_us"]
        rows.append(row)
    return rows


def figure7():
    rows = []
    for mtp in (1, 2):
        for heads in (16, 32, 64, 128):
            r = kernel_roofline(32768, heads, mtp, "fp8_e4m3")
            b = kernel_roofline(32768, heads, mtp, "none")
            rows.append({"heads": heads, "mtp": mtp,
                         "fp8_tflops": r["achievable_tflops"],
                         "bf16_tflops": b["achievable_tflops"],
                         "pct_of_eff_peak": 100 * r["achievable_tflops"] / r["peak_tflops"],
                         "speedup": b["t_us"] / r["t_us"]})
    return rows


def paged_splitkv_sweep(pool_capacities=(32768, 131072),
                        seq_len=8192, splits=(1, 2, 4, 8), page=128):
    """Early-exit accounting for the PAGED split-KV kernel: effective blocks
    visited must scale with seq_lens, NOT with the per-sequence page-table
    span (pool capacity) — the acceptance property of the paged path. The
    seed paged kernel scanned all capacity/page pages serially."""
    b_tok = D_C * 1 + D_R * 2 + 4
    rows = []
    for cap in pool_capacities:
        total_pages = -(-cap // page)
        visited = -(-seq_len // page)
        for s in splits:
            rows.append({
                "pool_capacity": cap, "num_splits": s, "seq_len": seq_len,
                "blocks_visited": visited, "total_blocks": total_pages,
                "early_exit_savings": 1.0 - visited / total_pages,
                "critical_path_blocks": -(-visited // s),
                "t_us": visited * page * b_tok / V5E_HBM * 1e6,
            })
    return rows


def splitkv_sweep(contexts=(8192, 32768, 65536, 131072),
                  splits=(1, 2, 4, 8), fill=0.5, block_n=128):
    """num_splits × context sweep for the split-KV (flash-decoding) kernel.

    Per point, from the roofline model at v5e constants:
      * blocks_visited      — KV blocks actually DMA'd. With block-level early
        exit this scales with seq_len (= fill * context), NOT with the padded
        cache capacity; the seed kernel always visited context/block_n.
      * critical_path_blocks — longest per-split sequential chain
        ceil(visited / num_splits): the latency term that sequence
        parallelism shortens when splits map onto parallel units.
      * t_us — modeled HBM-bound step time over the visited bytes.
    """
    b_tok = D_C * 1 + D_R * 2 + 4                     # fp8 content+bf16 rope+scale
    rows = []
    for ctx in contexts:
        seq_len = int(ctx * fill)
        total_blocks = -(-ctx // block_n)
        visited = -(-seq_len // block_n)
        for s in splits:
            chain = -(-visited // s)
            t = visited * block_n * b_tok / V5E_HBM
            rows.append({
                "context": ctx, "num_splits": s, "seq_len": seq_len,
                "blocks_visited": visited, "total_blocks": total_blocks,
                "early_exit_savings": 1.0 - visited / total_blocks,
                "critical_path_blocks": chain,
                "t_us": t * 1e6,
            })
    return rows


def _splitkv_inputs(B, H, d_c, d_r, N, bn, seed=0):
    """Shared bench/parity fixture: quantized cache with ragged lengths in
    (N/3, N] so early exit is exercised per row, plus prepared queries."""
    from repro.core.kvcache import CacheConfig, init_mla_cache, mla_prefill
    from repro.kernels.mla_decode import ref as kref

    key = jax.random.PRNGKey(seed)
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=bn)
    cache = init_mla_cache(cfg, B, N, d_c, d_r)
    ks = jax.random.split(key, 4)
    cache = mla_prefill(cache, cfg, jax.random.normal(ks[0], (B, N, d_c)),
                        jax.random.normal(ks[1], (B, N, d_r)))
    lens = np.linspace(N // 3, N, B).round().astype(np.int32)
    cache = cache._replace(seq_lens=jnp.asarray(lens))
    q_c8, q_r, sq = kref.prepare_q(jax.random.normal(ks[2], (B, H, d_c)),
                                   jax.random.normal(ks[3], (B, H, d_r)))
    return cache, (q_c8, q_r, sq), 1.0 / float(np.sqrt(d_c + d_r))


def _scatter_to_pool(cache, page, n_extra=3, seed=0):
    """Scatter a contiguous cache into a shuffled page pool + page table."""
    B, N = np.asarray(cache.scale).shape
    P = N // page
    rng = np.random.RandomState(seed)
    n_pool = B * P + n_extra
    perm = rng.permutation(n_pool)[: B * P].reshape(B, P)
    pool_c = np.zeros((n_pool, page) + cache.content.shape[2:],
                      np.asarray(cache.content).dtype)
    pool_r = np.zeros((n_pool, page) + cache.rope.shape[2:], np.float32)
    pool_s = np.ones((n_pool, page), np.float32)
    for b in range(B):
        for j in range(P):
            sl = slice(j * page, (j + 1) * page)
            pool_c[perm[b, j]] = np.asarray(cache.content[b, sl])
            pool_r[perm[b, j]] = np.asarray(cache.rope[b, sl], np.float32)
            pool_s[perm[b, j]] = np.asarray(cache.scale[b, sl])
    return (jnp.asarray(pool_c), jnp.asarray(pool_r), jnp.asarray(pool_s),
            jnp.asarray(perm, jnp.int32))


def parity_gate_splitkv(B=2, H=8, d_c=64, d_r=16, N=512, bn=64,
                        splits=(1, 2, 4)) -> float:
    """Kernel-vs-oracle parity for the contiguous split-KV path (the gate the
    bench numbers sit behind; also run directly by `pytest -m parity`).
    Returns the max abs error across split counts; asserts < 1e-4."""
    from repro.kernels.mla_decode.ops import snapmla_decode
    from repro.kernels.mla_decode import ref as kref

    cache, (q_c8, q_r, sq), scale = _splitkv_inputs(B, H, d_c, d_r, N, bn)
    worst = 0.0
    for s in splits:
        o, _ = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=scale,
                              block_n=bn, num_splits=s)
        o_ref, _ = kref.snapmla_decode_splitkv_ref(
            q_c8, q_r, sq, cache.content, cache.rope.astype(jnp.float32),
            cache.scale, cache.seq_lens, softmax_scale=scale,
            num_splits=s, block_n=bn)
        err = float(jnp.max(jnp.abs(o - o_ref)))
        assert err < 1e-4, (s, err)
        worst = max(worst, err)
    return worst


def parity_gate_paged_splitkv(B=2, H=8, d_c=64, d_r=16, N=512, page=64,
                              splits=(1, 2, 4)) -> float:
    """Kernel-vs-oracle parity for the PAGED split-KV path over a shuffled
    page pool. Returns the max abs error; asserts < 1e-4."""
    from repro.kernels.mla_decode.kernel import mla_decode_paged_splitkv_pallas
    from repro.kernels.mla_decode import ref as kref

    cache, (q_c8, q_r, sq), scale = _splitkv_inputs(B, H, d_c, d_r, N, page,
                                                    seed=1)
    pool_c, pool_r, pool_s, pt = _scatter_to_pool(cache, page)
    worst = 0.0
    for s in splits:
        o, _ = mla_decode_paged_splitkv_pallas(
            q_c8, q_r, sq, pool_c, pool_r, pool_s, pt, cache.seq_lens,
            softmax_scale=scale, num_splits=s)
        o_ref, _ = kref.snapmla_decode_paged_splitkv_ref(
            q_c8, q_r, sq, pool_c, pool_r, pool_s, pt, cache.seq_lens,
            softmax_scale=scale, num_splits=s)
        err = float(jnp.max(jnp.abs(o - o_ref)))
        assert err < 1e-4, (s, err)
        worst = max(worst, err)
    return worst


def amla_sweep(B=2, H=8, d_c=64, d_r=16, shapes=((512, 64), (1024, 128)),
               splits=(1, 2, 4)):
    """AMLA-vs-FMA rescale sweep through the REAL kernels (interpret mode).

    Per (context, num_splits) point, both rescale modes run the same
    quantized inputs:
      * ``amla_vs_fma_rel`` — max rel difference between the two modes'
        outputs. AMLA snaps (m, sigma_p) to the power-of-two grid, so the
        modes differ only at P-quantization rounding level (~2% under FP8);
        ``within_tol`` pins it at 5%.
      * ``kernel_vs_ref`` — kernel-AMLA vs ref-AMLA parity (< 1e-4): the
        exponent-add trick is EXACT, so the combine-free kernel must match
        its jnp twin to interpret-mode float tolerance.
    """
    from repro.kernels.mla_decode.ops import snapmla_decode

    rows = []
    for N, bn in shapes:
        cache, (q_c8, q_r, sq), scale = _splitkv_inputs(B, H, d_c, d_r, N, bn)
        for s in splits:
            o_f, _ = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=scale,
                                    block_n=bn, num_splits=s, rescale="fma")
            o_a, _ = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=scale,
                                    block_n=bn, num_splits=s, rescale="amla")
            o_ra, _ = snapmla_decode(q_c8, q_r, sq, cache,
                                     softmax_scale=scale, block_n=bn,
                                     num_splits=s, use_kernel=False,
                                     rescale="amla")
            rel = float(jnp.max(jnp.abs(o_a - o_f))
                        / (jnp.max(jnp.abs(o_f)) + 1e-12))
            kr = float(jnp.max(jnp.abs(o_a - o_ra)))
            rows.append({"context": N, "block_n": bn, "num_splits": s,
                         "amla_vs_fma_rel": rel, "within_tol": rel < 0.05,
                         "kernel_vs_ref": kr, "parity_ok": kr < 1e-4})
    return rows


def fetch_bound_sweep(B=2, d_c=32, d_r=16, page=32,
                      capacities_pages=(4, 8),
                      chunk_starts=(0, 17, 64, 256)):
    """Bounded-vs-full-span prefix fetch grid (DMA accounting + parity).

    ``bounded_pages`` = ceil(chunk_start / page) is the page traffic the
    chunk_start-prefetched index maps actually issue (dead pages clamp to
    the last live page, whose DMA the unchanged-index rule elides);
    ``full_pages`` is what the span fetch streamed every chunk. The counts
    are pure accounting — deterministic on any machine — and each point
    also runs the REAL kernel against its ref twin (``parity_ok``)."""
    from repro.core.kvcache import (CacheConfig, init_paged_mla_cache,
                                    paged_mla_prefill)
    from repro.kernels.quantize import fetch_dequant as FD

    rows = []
    for P in capacities_pages:
        N = P * page
        cfg = CacheConfig(fmt="fp8_e4m3", page_size=page)
        pool = init_paged_mla_cache(cfg, B, N, d_c, d_r)
        ks = jax.random.split(jax.random.PRNGKey(2), 2)
        pool = paged_mla_prefill(pool, cfg,
                                 jax.random.normal(ks[0], (B, N, d_c)),
                                 jax.random.normal(ks[1], (B, N, d_r)))
        for cs_val in chunk_starts:
            cs_val = min(cs_val, N)
            cs = jnp.full((B,), cs_val, jnp.int32)
            kv_k = FD.paged_fetch_dequant_pallas(pool, chunk_start=cs)
            kv_r = FD.paged_fetch_dequant_ref(pool, chunk_start=cs)
            err = float(jnp.max(jnp.abs(kv_k.astype(jnp.float32)
                                        - kv_r.astype(jnp.float32))))
            bounded = -(-cs_val // page)
            rows.append({"capacity_pages": P, "chunk_start": cs_val,
                         "bounded_pages": bounded, "full_pages": P,
                         "dma_savings": 1.0 - bounded / P,
                         "parity_err": err, "parity_ok": err < 2e-5})
    return rows


def measured_splitkv_cpu(B=2, H=8, d_c=64, d_r=16, N=512, bn=64,
                         splits=(1, 2, 4), iters=3):
    """Interpret-mode wall time + parity of the split-KV decode path through
    the jitted public wrapper (comparable with measured_kernel_cpu, which
    benches the same wrapper; correctness-bearing, not TPU-time-bearing)."""
    from repro.kernels.mla_decode.ops import snapmla_decode

    # parity gate: bench numbers are only recorded for a correct kernel
    parity_gate_splitkv(B, H, d_c, d_r, N, bn, splits)
    cache, (q_c8, q_r, sq), scale = _splitkv_inputs(B, H, d_c, d_r, N, bn)
    out = {}
    for s in splits:
        o, _ = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=scale,
                              block_n=bn, num_splits=s)          # compile
        jax.block_until_ready(o)
        t0 = time.time()
        for _ in range(iters):
            o, _ = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=scale,
                                  block_n=bn, num_splits=s)
        jax.block_until_ready(o)
        out[s] = (time.time() - t0) / iters * 1e6
    return out


def measured_paged_splitkv_cpu(B=2, H=8, d_c=64, d_r=16, N=512, page=64,
                               splits=(1, 2, 4), iters=3):
    """Interpret-mode wall time + parity of the paged split-KV kernel over a
    shuffled page pool (the multi-tenant layout the kernel is built for)."""
    from repro.kernels.mla_decode.kernel import mla_decode_paged_splitkv_pallas

    parity_gate_paged_splitkv(B, H, d_c, d_r, N, page, splits)
    cache, (q_c8, q_r, sq), scale = _splitkv_inputs(B, H, d_c, d_r, N, page,
                                                    seed=1)
    pool_c, pool_r, pool_s, pt = _scatter_to_pool(cache, page)
    out = {}
    for s in splits:
        o, _ = mla_decode_paged_splitkv_pallas(
            q_c8, q_r, sq, pool_c, pool_r, pool_s, pt, cache.seq_lens,
            softmax_scale=scale, num_splits=s)                   # compile
        jax.block_until_ready(o)
        t0 = time.time()
        for _ in range(iters):
            o, _ = mla_decode_paged_splitkv_pallas(
                q_c8, q_r, sq, pool_c, pool_r, pool_s, pt, cache.seq_lens,
                softmax_scale=scale, num_splits=s)
        jax.block_until_ready(o)
        out[s] = (time.time() - t0) / iters * 1e6
    return out


def emit_split_profile(path=None,
                       shapes=((512, 64, 2), (1024, 64, 2), (1024, 128, 4)),
                       paged_shapes=((512, 64, 2),),
                       config_shapes=((512, 2),),
                       amla_config_shapes=((512, 2),),
                       iters=2):
    """Run the autotuner's measured sweep over a few (capacity, block_n,
    batch) shapes — contiguous AND paged layouts, each timed on its own
    kernel — and persist the split profile: the JSON artifact that
    ``ops.resolve_num_splits`` consults before falling back to the
    heuristic. On TPU rerun with production shapes; CPU interpret-mode
    ordering seeds the cache at reduced size (paged interpret is slow, so
    its default shape list is shorter). ``path=None`` writes to the
    resolver's own default (repo root / SNAPMLA_SPLIT_PROFILE override)."""
    from repro.kernels.mla_decode import autotune

    profile = autotune.SplitProfile()
    for capacity, block_n, batch in shapes:
        autotune.measure_split_sweep(capacity, block_n, batch,
                                     profile=profile, iters=iters)
    for capacity, block_n, batch in paged_shapes:
        autotune.measure_split_sweep(capacity, block_n, batch,
                                     profile=profile, iters=iters,
                                     layout="paged")
    # joint 2D (num_splits, block_n) sweep: one v2 entry per candidate
    # block_n, each carrying best_us so lookup_config can compare across
    # block sizes at the same (capacity, batch, layout)
    for capacity, batch in config_shapes:
        autotune.measure_config_sweep(capacity, batch, profile=profile,
                                      iters=iters)
    # AMLA-rescale entries ("/amla" keys): the combine-free emission shifts
    # the split/combine trade-off, so its plans are timed on the AMLA kernel
    # itself (compiled on TPU via interpret=None) and never borrow FMA
    # timings. FMA stays the default — these keys only drive callers that
    # opt into rescale="amla".
    for capacity, batch in amla_config_shapes:
        autotune.measure_config_sweep(capacity, batch, profile=profile,
                                      iters=iters, rescale="amla")
    out = profile.save(path)
    autotune.reset(profile)          # freshly measured profile wins in-process
    return out


def write_bench_splitkv(path="BENCH_splitkv.json"):
    """Persist the split-KV sweep so the perf trajectory starts recording."""
    payload = {
        "sweep": splitkv_sweep(),
        "paged_sweep": paged_splitkv_sweep(),
        "amla_sweep": amla_sweep(),
        "fetch_bound": fetch_bound_sweep(),
        "measured_cpu_interpret_us": {
            str(k): v for k, v in measured_splitkv_cpu().items()},
        "measured_paged_cpu_interpret_us": {
            str(k): v for k, v in measured_paged_splitkv_cpu().items()},
        "notes": "modeled v5e roofline (fill=0.5) + CPU interpret-mode wall "
                 "time of the real Pallas kernels at reduced size",
    }
    pathlib.Path(path).write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def measured_kernel_cpu(B=2, H=16, d_c=128, d_r=32, N=1024, iters=3):
    """Wall time of the actual Pallas kernel in interpret mode (CPU)."""
    from repro.core.kvcache import CacheConfig, init_mla_cache, mla_prefill
    from repro.kernels.mla_decode.ops import snapmla_decode
    from repro.kernels.mla_decode import ref as kref

    key = jax.random.PRNGKey(0)
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=128)
    cache = init_mla_cache(cfg, B, N, d_c, d_r)
    ks = jax.random.split(key, 4)
    cache = mla_prefill(cache, cfg, jax.random.normal(ks[0], (B, N, d_c)),
                        jax.random.normal(ks[1], (B, N, d_r)))
    q_c8, q_r, sq = kref.prepare_q(jax.random.normal(ks[2], (B, H, d_c)),
                                   jax.random.normal(ks[3], (B, H, d_r)))
    scale = 1.0 / np.sqrt(d_c + d_r)
    o, _ = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=scale)  # compile
    jax.block_until_ready(o)
    t0 = time.time()
    for _ in range(iters):
        o, _ = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=scale)
    jax.block_until_ready(o)
    return (time.time() - t0) / iters * 1e6


def main(csv=True):
    out = []
    for row in figure6():
        name = f"fig6_ctx{row['context']//1024}k"
        out.append((name, row["snapmla"]["t_us"],
                    f"speedup={row['speedup']:.2f}x "
                    f"fp8={row['snapmla']['achievable_tflops']:.0f}TF/"
                    f"{row['snapmla']['peak_tflops']:.0f}TF-eff-peak "
                    f"({row['snapmla']['bound']}-bound)"))
    for row in figure7():
        name = f"fig7_h{row['heads']}_mtp{row['mtp']}"
        out.append((name, 0.0,
                    f"fp8={row['fp8_tflops']:.0f}TF ({row['pct_of_eff_peak']:.0f}% eff-peak) "
                    f"speedup={row['speedup']:.2f}x"))
    payload = write_bench_splitkv()
    for row in payload["sweep"]:
        name = f"splitkv_ctx{row['context']//1024}k_s{row['num_splits']}"
        out.append((name, row["t_us"],
                    f"visited={row['blocks_visited']}/{row['total_blocks']}blk "
                    f"(early-exit {row['early_exit_savings']*100:.0f}%) "
                    f"chain={row['critical_path_blocks']}blk"))
    for row in payload["paged_sweep"]:
        name = (f"paged_splitkv_cap{row['pool_capacity']//1024}k"
                f"_s{row['num_splits']}")
        out.append((name, row["t_us"],
                    f"visited={row['blocks_visited']}/{row['total_blocks']}pg "
                    f"(early-exit {row['early_exit_savings']*100:.0f}%) "
                    f"chain={row['critical_path_blocks']}pg"))
    for row in payload["amla_sweep"]:
        name = f"amla_ctx{row['context']}_s{row['num_splits']}"
        out.append((name, 0.0,
                    f"amla-vs-fma rel={row['amla_vs_fma_rel']:.3e} "
                    f"(tol ok={row['within_tol']}) "
                    f"kernel-vs-ref={row['kernel_vs_ref']:.1e} "
                    f"(parity ok={row['parity_ok']})"))
    for row in payload["fetch_bound"]:
        name = (f"fetch_bound_cap{row['capacity_pages']}pg"
                f"_cs{row['chunk_start']}")
        out.append((name, 0.0,
                    f"bounded={row['bounded_pages']}/{row['full_pages']}pg "
                    f"(dma savings {row['dma_savings']*100:.0f}%) "
                    f"parity ok={row['parity_ok']}"))
    for s, us_m in payload["measured_cpu_interpret_us"].items():
        out.append((f"splitkv_cpu_interpret_s{s}", us_m,
                    "pallas interpret mode on CPU (reduced size)"))
    for s, us_m in payload["measured_paged_cpu_interpret_us"].items():
        out.append((f"paged_splitkv_cpu_interpret_s{s}", us_m,
                    "pallas interpret mode on CPU (reduced size)"))
    profile_path = emit_split_profile()
    out.append(("split_profile", 0.0,
                f"autotuner split profile written to {profile_path}"))
    us = measured_kernel_cpu()
    out.append(("kernel_cpu_interpret_us", us, "pallas interpret mode on CPU"))
    if csv:
        for name, t, derived in out:
            print(f"{name},{t:.1f},{derived}")
    return out


if __name__ == "__main__":
    main()
