"""Kernel-level performance (paper Figures 6 & 7 analogues).

Paper App. H derives an effective FP8 peak on Hopper:
    Peak_eff = 148 x 17/9 ~ 279.6 TFLOPS
(16 content tiles at FP8 half-cost + 1 RoPE tile at BF16).

v5e translation (DESIGN.md §2): the content GEMMs can use the int8 MXU path
(2x bf16 peak) while the RoPE tile stays bf16:
    d_c = 512 -> 8 "tiles" of 64 + 1 rope tile of 64+... using the paper's
    17-tile accounting (d_c+d_r = 576 = 9 x 64; QK+PV -> 16 content + 1 rope):
    Peak_eff(v5e) = 197 x 17 / (16/2 + 1) = 197 x 17/9 ~ 372 TFLOPS.

For each (context x heads x mtp) we report the *achievable* TFLOPS =
min(Peak_eff, intensity x HBM_bw) — the roofline position of the kernel —
for BF16-storage FlashMLA-equivalent vs SnapMLA FP8 storage, plus measured
CPU interpret-mode wall time of the real Pallas kernel at reduced size
(correctness-bearing, not TPU-time-bearing).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

V5E_BF16 = 197e12
V5E_INT8 = 394e12
V5E_HBM = 819e9
PEAK_EFF_SNAP = V5E_BF16 * 17 / 9          # int8 content + bf16 rope
PEAK_EFF_BF16 = V5E_BF16

D_C, D_R = 512, 64


def kernel_roofline(context: int, heads: int, mtp: int, fmt: str) -> dict:
    """Per-token-step decode attention kernel roofline on v5e."""
    # bytes per cached token
    if fmt == "none":
        b_tok = (D_C + D_R) * 2
        peak = PEAK_EFF_BF16
    else:
        b_tok = D_C * 1 + D_R * 2 + 4
        peak = PEAK_EFF_SNAP
    flops_tok = (2 * (D_C + D_R) + 2 * D_C) * heads * mtp     # QK + PV per head
    intensity = flops_tok / b_tok                              # FLOP / byte
    achievable = min(peak, intensity * V5E_HBM)
    t = context * max(b_tok / V5E_HBM, flops_tok / peak)
    return {"intensity": intensity, "achievable_tflops": achievable / 1e12,
            "peak_tflops": peak / 1e12, "t_us": t * 1e6,
            "bound": "mem" if b_tok / V5E_HBM > flops_tok / peak else "comp"}


def figure6(fmt_pairs=(("bf16", "none"), ("snapmla", "fp8_e4m3"))):
    rows = []
    for ctx in [16384, 32768, 65536, 131072]:
        row = {"context": ctx}
        for label, fmt in fmt_pairs:
            r = kernel_roofline(ctx, heads=128, mtp=1, fmt=fmt)
            row[label] = r
        row["speedup"] = row["bf16"]["t_us"] / row["snapmla"]["t_us"]
        rows.append(row)
    return rows


def figure7():
    rows = []
    for mtp in (1, 2):
        for heads in (16, 32, 64, 128):
            r = kernel_roofline(32768, heads, mtp, "fp8_e4m3")
            b = kernel_roofline(32768, heads, mtp, "none")
            rows.append({"heads": heads, "mtp": mtp,
                         "fp8_tflops": r["achievable_tflops"],
                         "bf16_tflops": b["achievable_tflops"],
                         "pct_of_eff_peak": 100 * r["achievable_tflops"] / r["peak_tflops"],
                         "speedup": b["t_us"] / r["t_us"]})
    return rows


def measured_kernel_cpu(B=2, H=16, d_c=128, d_r=32, N=1024, iters=3):
    """Wall time of the actual Pallas kernel in interpret mode (CPU)."""
    from repro.core.kvcache import CacheConfig, init_mla_cache, mla_prefill
    from repro.kernels.mla_decode.ops import snapmla_decode
    from repro.kernels.mla_decode import ref as kref

    key = jax.random.PRNGKey(0)
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=128)
    cache = init_mla_cache(cfg, B, N, d_c, d_r)
    ks = jax.random.split(key, 4)
    cache = mla_prefill(cache, cfg, jax.random.normal(ks[0], (B, N, d_c)),
                        jax.random.normal(ks[1], (B, N, d_r)))
    q_c8, q_r, sq = kref.prepare_q(jax.random.normal(ks[2], (B, H, d_c)),
                                   jax.random.normal(ks[3], (B, H, d_r)))
    scale = 1.0 / np.sqrt(d_c + d_r)
    o, _ = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=scale)  # compile
    jax.block_until_ready(o)
    t0 = time.time()
    for _ in range(iters):
        o, _ = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=scale)
    jax.block_until_ready(o)
    return (time.time() - t0) / iters * 1e6


def main(csv=True):
    out = []
    for row in figure6():
        name = f"fig6_ctx{row['context']//1024}k"
        out.append((name, row["snapmla"]["t_us"],
                    f"speedup={row['speedup']:.2f}x "
                    f"fp8={row['snapmla']['achievable_tflops']:.0f}TF/"
                    f"{row['snapmla']['peak_tflops']:.0f}TF-eff-peak "
                    f"({row['snapmla']['bound']}-bound)"))
    for row in figure7():
        name = f"fig7_h{row['heads']}_mtp{row['mtp']}"
        out.append((name, 0.0,
                    f"fp8={row['fp8_tflops']:.0f}TF ({row['pct_of_eff_peak']:.0f}% eff-peak) "
                    f"speedup={row['speedup']:.2f}x"))
    us = measured_kernel_cpu()
    out.append(("kernel_cpu_interpret_us", us, "pallas interpret mode on CPU"))
    if csv:
        for name, t, derived in out:
            print(f"{name},{t:.1f},{derived}")
    return out


if __name__ == "__main__":
    main()
