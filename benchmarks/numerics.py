"""Numerical-accuracy benchmarks (paper Table 1 proxy + Figure 3 + Figure 5).

The paper's Table 1 runs full eval suites on 600B models; the CPU-scale
equivalent here measures what those scores are a downstream proxy *for*: the
attention-output fidelity of the FP8 pipeline vs the BF16 baseline, per
quantization configuration (paper Appendix G, Table 3):

  SnapMLA   per-token RoPE-aware          (content per-token FP8, RoPE BF16)
  Config A  per-token RoPE-UNaware        (RoPE quantized too)
  Config B  per-tensor static RoPE-aware  (fixed scale 1.0)
  Config C  per-tensor dynamic RoPE-aware
  Config D  per-block RoPE-aware

Also reproduces Fig. 3: dynamic-range split between content and RoPE parts
and their per-config quantization MSE. The KV distributions are synthetic
(content tight around 0, RoPE heavy-tailed to +-1e3 — matching the paper's
measured LongCat-Flash-Thinking statistics) since no pretrained MLA weights
ship in this container; the *relative ordering* of configs is the claim
under test.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.attention import mla_decode_dequant_ref
from repro.core.kvcache import CacheConfig, MLACache
from repro.kernels.mla_decode import ref as kref


def synth_mla_kv(key, B, N, d_c, d_r):
    """Content ~ tight near zero with rare outlier TOKENS (massive-activation
    tokens, cf. KVSink/massive-activations refs in the paper); RoPE ~
    heavy-tailed entries reaching +-10^3 (paper Fig. 3a)."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    content = jax.random.normal(k1, (B, N, d_c)) * 2.0            # +-10^1
    tok_out = jax.random.bernoulli(k5, 0.01, (B, N, 1))           # outlier tokens
    content = jnp.where(tok_out, content * 60.0, content)         # amax ~ 5e2
    base = jax.random.normal(k2, (B, N, d_r)) * 20.0
    outlier_mask = jax.random.bernoulli(k3, 0.02, (B, N, d_r))
    outliers = jax.random.normal(k4, (B, N, d_r)) * 400.0         # +-10^3 tails
    rope = jnp.where(outlier_mask, outliers, base)
    return content, rope


def build_cache(config_name: str, content, rope, fmt="fp8_e4m3") -> MLACache:
    B, N, d_c = content.shape
    seq = jnp.full((B,), N, jnp.int32)
    if config_name == "f32ref":     # exact reference (fair to all configs)
        return MLACache(content.astype(jnp.float32), rope.astype(jnp.float32),
                        jnp.ones((B, N), jnp.float32), seq)
    if config_name == "bf16":
        return MLACache(content.astype(jnp.bfloat16), rope.astype(jnp.bfloat16),
                        jnp.ones((B, N), jnp.float32), seq)
    if config_name == "snapmla":        # per-token RoPE-aware
        q = quant.quantize_rope_aware(content, rope, fmt)
        return MLACache(q.q_content, q.rope_scaled, q.scale[..., 0], seq)
    if config_name == "config_a":       # per-token RoPE-UNaware
        q = quant.quantize_rope_unaware(content, rope, fmt)
        return MLACache(q.q_content, q.rope_scaled.astype(jnp.bfloat16),
                        q.scale[..., 0], seq)
    if config_name == "config_b":       # per-tensor static (scale 1.0)
        qc = quant.quantize_per_tensor(content, fmt, static_scale=1.0)
        scale = jnp.broadcast_to(qc.scale.reshape(1, 1), (B, N))
        return MLACache(qc.q, (rope / 1.0).astype(jnp.bfloat16), scale, seq)
    if config_name == "config_c":       # per-tensor dynamic
        qc = quant.quantize_per_tensor(content, fmt)
        scale = jnp.broadcast_to(qc.scale.reshape(1, 1), (B, N))
        return MLACache(qc.q, (rope / qc.scale.reshape(1, 1, 1)).astype(jnp.bfloat16),
                        scale, seq)
    if config_name == "config_d":       # per-block (64x64) RoPE-aware
        qc = quant.quantize_per_block(content, (64, 64), fmt)
        # per-token effective scale for the shared container: use the row max
        # of the block scales (exact dequant still uses qc.scale internally)
        row_scale = jnp.max(qc.scale, axis=-1)
        content_rt = qc.dequant()
        requant = quant._cast(content_rt / row_scale[..., None], fmt)
        return MLACache(requant, (rope / row_scale[..., None]).astype(jnp.bfloat16),
                        row_scale, seq)
    raise ValueError(config_name)


CONFIGS = ["snapmla", "config_a", "config_b", "config_c", "config_d"]


def attention_fidelity(seed=0, B=4, N=2048, H=16, d_c=512, d_r=64):
    """Fig. 5 analogue: attention-output error per quantization config."""
    key = jax.random.PRNGKey(seed)
    content, rope = synth_mla_kv(key, B, N, d_c, d_r)
    kq = jax.random.split(key, 3)
    q_lat = jax.random.normal(kq[0], (B, H, d_c))
    q_rope = jax.random.normal(kq[1], (B, H, d_r)) * 2.0
    scale = 1.0 / np.sqrt(128 + d_r)

    # exact f32 reference: every config pays its true representation error
    # (a bf16 reference would be bit-identical to configs that store raw bf16
    # rope, hiding their error — an unfair comparison)
    ref_cache = build_cache("f32ref", content, rope)
    o_ref = mla_decode_dequant_ref(q_lat, q_rope, ref_cache, scale)
    rows = [{"config": "bf16_baseline", **_err(
        mla_decode_dequant_ref(q_lat, q_rope, build_cache("bf16", content, rope),
                               scale), o_ref)}]

    for name in CONFIGS:
        cache = build_cache(name, content, rope)
        q_c8, q_r_s, sq = kref.prepare_q(q_lat, q_rope, "fp8_e4m3")
        o, _ = kref.snapmla_decode_pipeline_ref(
            q_c8, q_r_s, sq, cache.content, cache.rope.astype(jnp.float32),
            cache.scale, cache.seq_lens, softmax_scale=scale, block_n=128)
        rows.append({"config": name, **_err(o, o_ref)})
    return rows


def sink_guard_grid(seed=0, B=2, H=8, d_c=256, d_r=32, sink_tokens=4,
                    contexts=(512, 2048)):
    """P-Cast sink guard grid (context x sink-presence): attention-output
    error of the FP8 pipeline with and without the first-tokens guard
    (``CacheConfig.sink_tokens``), against the exact f32 oracle.

    The synthetic sink is a massive-activation token at position 0 (content
    norm ~100x a normal token — the KVSink statistic): roughly half the
    heads lock onto it, so its FP8 representation error passes straight
    through the softmax into the output AND into the logits (LSE). Queries
    are exact and P-quantization is off so the grid isolates the CACHE
    representation error — the one thing the guard changes. ``guard_ok``
    requires (a) the guard never makes things worse anywhere on the grid,
    and (b) with a sink present it strictly reduces both the max output
    error and the max logit (LSE) error.
    """
    from repro.core.kvcache import MLACache as _MLACache
    from repro.core.kvcache import sink_patched_content
    rows = []
    for N in contexts:
        for sink_present in (False, True):
            key = jax.random.PRNGKey(seed + N + int(sink_present))
            k1, k2, k_sink = jax.random.split(key, 3)
            # content-dominated KV: mild rope (no +-1e3 tails) so the grid
            # measures the channel the guard changes — synth_mla_kv's rope
            # outliers would swamp the sink's content error in every metric
            content = jax.random.normal(k1, (B, N, d_c)) * 2.0
            rope = jax.random.normal(k2, (B, N, d_r)) * 5.0
            if sink_present:
                content = content.at[:, 0].set(
                    jax.random.normal(k_sink, (B, d_c)) * 300.0)
            kq = jax.random.split(key, 3)
            q_lat = jax.random.normal(kq[0], (B, H, d_c))
            q_rope = jax.random.normal(kq[1], (B, H, d_r)) * 2.0
            scale = 1.0 / np.sqrt(128 + d_r)
            seq = jnp.full((B,), N, jnp.int32)
            q_c8, q_r_s, sq = kref.prepare_q(q_lat, q_rope, "none")

            def run(cache):
                return kref.snapmla_decode_pipeline_ref(
                    q_c8, q_r_s, sq, sink_patched_content(cache),
                    cache.rope.astype(jnp.float32), cache.scale,
                    cache.seq_lens, softmax_scale=scale, block_n=128,
                    fmt="none")

            o_ref, lse_ref = run(build_cache("f32ref", content, rope))
            q_raq = quant.quantize_rope_aware(content, rope, "fp8_e4m3")
            unguarded = _MLACache(q_raq.q_content, q_raq.rope_scaled,
                                  q_raq.scale[..., 0], seq)
            guarded = unguarded._replace(
                sink=content[:, :sink_tokens].astype(jnp.float32))
            o_u, lse_u = run(unguarded)
            o_g, lse_g = run(guarded)
            err_u = _err(o_u, o_ref)["max_rel_err"]
            err_g = _err(o_g, o_ref)["max_rel_err"]
            logit_u = float(jnp.max(jnp.abs(lse_u - lse_ref)))
            logit_g = float(jnp.max(jnp.abs(lse_g - lse_ref)))
            ok = (err_g <= err_u * 1.05 + 1e-7
                  and logit_g <= logit_u * 1.05 + 1e-6)
            if sink_present:
                ok = ok and err_g < err_u and logit_g < logit_u
            rows.append({"context": int(N), "sink_present": sink_present,
                         "sink_tokens": sink_tokens,
                         "max_rel_err_unguarded": err_u,
                         "max_rel_err_guarded": err_g,
                         "max_logit_err_unguarded": logit_u,
                         "max_logit_err_guarded": logit_g,
                         "guard_ok": bool(ok)})
    return rows


def _err(o, o_ref):
    err = np.asarray(o - o_ref, np.float64)
    refn = np.asarray(o_ref, np.float64)
    return {
        "mse": float((err ** 2).mean()),
        "max_rel_err": float(np.abs(err).max() / (np.abs(refn).max() + 1e-12)),
        "cos_sim": float((refn * np.asarray(o, np.float64)).sum()
                         / (np.linalg.norm(refn)
                            * np.linalg.norm(np.asarray(o)) + 1e-12)),
    }


def value_range_analysis(seed=0, B=2, N=1024, d_c=512, d_r=64):
    """Fig. 3 analogue: dynamic range + per-part FP8 MSE."""
    content, rope = synth_mla_kv(jax.random.PRNGKey(seed), B, N, d_c, d_r)
    rows = []
    for part, x in [("content", content), ("rope", rope)]:
        lo, hi = quant.dynamic_range(x)
        mse_pt = float(quant.quant_mse(x.reshape(-1, x.shape[-1]), "fp8_e4m3",
                                       "per_token"))
        rows.append({"part": part, "abs_min": float(lo), "abs_max": float(hi),
                     "fp8_per_token_mse": mse_pt})
    return rows


def main(csv=True):
    out = []
    for r in value_range_analysis():
        out.append(("fig3_range_" + r["part"], 0.0,
                    f"absmax={r['abs_max']:.1f} fp8_mse={r['fp8_per_token_mse']:.3e}"))
    for r in attention_fidelity():
        out.append(("fig5_fidelity_" + r["config"], 0.0,
                    f"mse={r['mse']:.3e} cos={r['cos_sim']:.6f}"))
    for r in sink_guard_grid():
        tag = f"sink_guard_N{r['context']}_" \
              f"{'sink' if r['sink_present'] else 'nosink'}"
        out.append((tag, 0.0,
                    f"unguarded={r['max_rel_err_unguarded']:.3e} "
                    f"guarded={r['max_rel_err_guarded']:.3e} "
                    f"ok={r['guard_ok']}"))
    if csv:
        for name, us, derived in out:
            print(f"{name},{us:.1f},{derived}")
    return out


if __name__ == "__main__":
    main()
