"""Numerical-accuracy benchmarks (paper Table 1 proxy + Figure 3 + Figure 5).

The paper's Table 1 runs full eval suites on 600B models; the CPU-scale
equivalent here measures what those scores are a downstream proxy *for*: the
attention-output fidelity of the FP8 pipeline vs the BF16 baseline, per
quantization configuration (paper Appendix G, Table 3):

  SnapMLA   per-token RoPE-aware          (content per-token FP8, RoPE BF16)
  Config A  per-token RoPE-UNaware        (RoPE quantized too)
  Config B  per-tensor static RoPE-aware  (fixed scale 1.0)
  Config C  per-tensor dynamic RoPE-aware
  Config D  per-block RoPE-aware

Also reproduces Fig. 3: dynamic-range split between content and RoPE parts
and their per-config quantization MSE. The KV distributions are synthetic
(content tight around 0, RoPE heavy-tailed to +-1e3 — matching the paper's
measured LongCat-Flash-Thinking statistics) since no pretrained MLA weights
ship in this container; the *relative ordering* of configs is the claim
under test.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.attention import mla_decode_dequant_ref
from repro.core.kvcache import CacheConfig, MLACache
from repro.kernels.mla_decode import ref as kref


def synth_mla_kv(key, B, N, d_c, d_r):
    """Content ~ tight near zero with rare outlier TOKENS (massive-activation
    tokens, cf. KVSink/massive-activations refs in the paper); RoPE ~
    heavy-tailed entries reaching +-10^3 (paper Fig. 3a)."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    content = jax.random.normal(k1, (B, N, d_c)) * 2.0            # +-10^1
    tok_out = jax.random.bernoulli(k5, 0.01, (B, N, 1))           # outlier tokens
    content = jnp.where(tok_out, content * 60.0, content)         # amax ~ 5e2
    base = jax.random.normal(k2, (B, N, d_r)) * 20.0
    outlier_mask = jax.random.bernoulli(k3, 0.02, (B, N, d_r))
    outliers = jax.random.normal(k4, (B, N, d_r)) * 400.0         # +-10^3 tails
    rope = jnp.where(outlier_mask, outliers, base)
    return content, rope


def build_cache(config_name: str, content, rope, fmt="fp8_e4m3") -> MLACache:
    B, N, d_c = content.shape
    seq = jnp.full((B,), N, jnp.int32)
    if config_name == "f32ref":     # exact reference (fair to all configs)
        return MLACache(content.astype(jnp.float32), rope.astype(jnp.float32),
                        jnp.ones((B, N), jnp.float32), seq)
    if config_name == "bf16":
        return MLACache(content.astype(jnp.bfloat16), rope.astype(jnp.bfloat16),
                        jnp.ones((B, N), jnp.float32), seq)
    if config_name == "snapmla":        # per-token RoPE-aware
        q = quant.quantize_rope_aware(content, rope, fmt)
        return MLACache(q.q_content, q.rope_scaled, q.scale[..., 0], seq)
    if config_name == "config_a":       # per-token RoPE-UNaware
        q = quant.quantize_rope_unaware(content, rope, fmt)
        return MLACache(q.q_content, q.rope_scaled.astype(jnp.bfloat16),
                        q.scale[..., 0], seq)
    if config_name == "config_b":       # per-tensor static (scale 1.0)
        qc = quant.quantize_per_tensor(content, fmt, static_scale=1.0)
        scale = jnp.broadcast_to(qc.scale.reshape(1, 1), (B, N))
        return MLACache(qc.q, (rope / 1.0).astype(jnp.bfloat16), scale, seq)
    if config_name == "config_c":       # per-tensor dynamic
        qc = quant.quantize_per_tensor(content, fmt)
        scale = jnp.broadcast_to(qc.scale.reshape(1, 1), (B, N))
        return MLACache(qc.q, (rope / qc.scale.reshape(1, 1, 1)).astype(jnp.bfloat16),
                        scale, seq)
    if config_name == "config_d":       # per-block (64x64) RoPE-aware
        qc = quant.quantize_per_block(content, (64, 64), fmt)
        # per-token effective scale for the shared container: use the row max
        # of the block scales (exact dequant still uses qc.scale internally)
        row_scale = jnp.max(qc.scale, axis=-1)
        content_rt = qc.dequant()
        requant = quant._cast(content_rt / row_scale[..., None], fmt)
        return MLACache(requant, (rope / row_scale[..., None]).astype(jnp.bfloat16),
                        row_scale, seq)
    raise ValueError(config_name)


CONFIGS = ["snapmla", "config_a", "config_b", "config_c", "config_d"]


def attention_fidelity(seed=0, B=4, N=2048, H=16, d_c=512, d_r=64):
    """Fig. 5 analogue: attention-output error per quantization config."""
    key = jax.random.PRNGKey(seed)
    content, rope = synth_mla_kv(key, B, N, d_c, d_r)
    kq = jax.random.split(key, 3)
    q_lat = jax.random.normal(kq[0], (B, H, d_c))
    q_rope = jax.random.normal(kq[1], (B, H, d_r)) * 2.0
    scale = 1.0 / np.sqrt(128 + d_r)

    # exact f32 reference: every config pays its true representation error
    # (a bf16 reference would be bit-identical to configs that store raw bf16
    # rope, hiding their error — an unfair comparison)
    ref_cache = build_cache("f32ref", content, rope)
    o_ref = mla_decode_dequant_ref(q_lat, q_rope, ref_cache, scale)
    rows = [{"config": "bf16_baseline", **_err(
        mla_decode_dequant_ref(q_lat, q_rope, build_cache("bf16", content, rope),
                               scale), o_ref)}]

    for name in CONFIGS:
        cache = build_cache(name, content, rope)
        q_c8, q_r_s, sq = kref.prepare_q(q_lat, q_rope, "fp8_e4m3")
        o, _ = kref.snapmla_decode_pipeline_ref(
            q_c8, q_r_s, sq, cache.content, cache.rope.astype(jnp.float32),
            cache.scale, cache.seq_lens, softmax_scale=scale, block_n=128)
        rows.append({"config": name, **_err(o, o_ref)})
    return rows


def _err(o, o_ref):
    err = np.asarray(o - o_ref, np.float64)
    refn = np.asarray(o_ref, np.float64)
    return {
        "mse": float((err ** 2).mean()),
        "max_rel_err": float(np.abs(err).max() / (np.abs(refn).max() + 1e-12)),
        "cos_sim": float((refn * np.asarray(o, np.float64)).sum()
                         / (np.linalg.norm(refn)
                            * np.linalg.norm(np.asarray(o)) + 1e-12)),
    }


def value_range_analysis(seed=0, B=2, N=1024, d_c=512, d_r=64):
    """Fig. 3 analogue: dynamic range + per-part FP8 MSE."""
    content, rope = synth_mla_kv(jax.random.PRNGKey(seed), B, N, d_c, d_r)
    rows = []
    for part, x in [("content", content), ("rope", rope)]:
        lo, hi = quant.dynamic_range(x)
        mse_pt = float(quant.quant_mse(x.reshape(-1, x.shape[-1]), "fp8_e4m3",
                                       "per_token"))
        rows.append({"part": part, "abs_min": float(lo), "abs_max": float(hi),
                     "fp8_per_token_mse": mse_pt})
    return rows


def main(csv=True):
    out = []
    for r in value_range_analysis():
        out.append(("fig3_range_" + r["part"], 0.0,
                    f"absmax={r['abs_max']:.1f} fp8_mse={r['fp8_per_token_mse']:.3e}"))
    for r in attention_fidelity():
        out.append(("fig5_fidelity_" + r["config"], 0.0,
                    f"mse={r['mse']:.3e} cos={r['cos_sim']:.6f}"))
    if csv:
        for name, us, derived in out:
            print(f"{name},{us:.1f},{derived}")
    return out


if __name__ == "__main__":
    main()
