"""Benchmark driver — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,fig3,...]

Prints ``name,us_per_call,derived`` CSV rows:
  * numerics   — Table 1 / Fig 3 / Fig 5 analogues (quantization fidelity)
  * throughput — Fig 1 analogue (modeled v5e decode throughput + CPU measured)
  * kernel     — Fig 6 / Fig 7 analogues (kernel roofline + CPU interpret time)
  * roofline   — §Roofline summary if a dry-run sweep exists
"""
from __future__ import annotations

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: numerics,throughput,kernel,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    print("name,us_per_call,derived")
    if want("numerics"):
        from benchmarks import numerics
        numerics.main(csv=True)
    if want("throughput"):
        from benchmarks import throughput
        throughput.main(csv=True)
    if want("kernel"):
        from benchmarks import kernel_perf
        kernel_perf.main(csv=True)
    if want("roofline"):
        sweep = pathlib.Path("results/dryrun/sweep.json")
        if sweep.exists():
            from benchmarks import roofline
            rows = roofline.table(roofline.load_sweep(str(sweep)))
            for r in rows:
                if r.get("dominant") == "SKIP":
                    print(f"roofline_{r['arch']}_{r['shape']},0.0,skipped")
                else:
                    dom_us = r.get(r["dominant"] + "_s", 0)
                    print(f"roofline_{r['arch']}_{r['shape']},{dom_us},"
                          f"dominant={r['dominant']} frac={r['roofline_frac']}")
        else:
            print("roofline,0.0,no sweep.json (run repro.launch.dryrun_sweep)")


if __name__ == "__main__":
    main()
