"""Roofline analysis (§Roofline): three terms per (arch x shape) cell from the
dry-run sweep artifacts.

    compute_s    = HLO_FLOPs / (chips x 197 TF/s bf16)
    memory_s     = HLO_bytes / (chips x 819 GB/s HBM)
    collective_s = collective_bytes / (chips x 50 GB/s/link x links)

HLO_FLOPs / HLO_bytes come from the *cost-exact* (unrolled) lowering;
collective bytes from the partitioned HLO of the same pass. cost_analysis
reports per-device program totals for the SPMD module, i.e. already per-chip;
collective bytes are summed over the module (per chip as well).

MODEL_FLOPS: 6·N(_active)·D for train, 2·N_active per generated token (+
attention cache term) for decode — the "useful"-compute yardstick.

Usage:  python -m benchmarks.roofline --sweep results/dryrun/sweep.json
"""
from __future__ import annotations

import argparse
import json
import pathlib

V5E_BF16 = 197e12
V5E_HBM = 819e9
V5E_ICI_LINK = 50e9      # GB/s per link
ICI_LINKS = 3            # usable links/chip on a 2-D torus axis pair (v5e: 4
                         # neighbors; 3 effective after bisection discount)

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,          # one token per sequence
    "long_500k": 1,
}


def model_flops(rec: dict) -> float:
    """Global useful FLOPs for the cell's step."""
    n_act = rec["active_param_count"]
    shape = rec["shape"]
    if rec["kind"] == "train":
        return 6.0 * n_act * SHAPE_TOKENS[shape]
    if rec["kind"] == "prefill":
        return 2.0 * n_act * SHAPE_TOKENS[shape]
    return 2.0 * n_act * SHAPE_TOKENS[shape]      # decode: per new token


def analytic_memory_bytes(rec: dict) -> float:
    """Minimum-HBM-traffic model per chip per step (the fused lower bound —
    what a TPU compilation approaches; the unfused HLO bytes are an upper
    bound). Terms documented in EXPERIMENTS.md §Roofline.
    """
    from repro.configs import get_config
    cfg = get_config(rec["arch"])
    if rec.get("kv_fmt") and rec["kv_fmt"] != cfg.kv_fmt:
        cfg = cfg.scaled(kv_fmt=rec["kv_fmt"])
    chips = rec["n_chips"]
    n = rec["param_count"]
    n_act = rec["active_param_count"]
    shape = rec["shape"]
    seq = {"train_4k": 4096, "prefill_32k": 32768, "decode_32k": 32768,
           "long_500k": 524288}[shape]
    gb = {"train_4k": 256, "prefill_32k": 32, "decode_32k": 128,
          "long_500k": 1}[shape]
    tokens = gb * (seq if rec["kind"] != "decode" else 1)

    if rec["kind"] == "train":
        # weights: fwd read + bwd read + remat read (bf16) + grad write/read
        # (bf16) + adam m,v read/write (f32) + param write
        w_traffic = n * 2 * 3 + n * 2 * 2 + n * 4 * 4 + n * 2
        # activations: save + reload at superblock boundaries (remat) in bf16,
        # x2 for the recompute writes
        act = tokens * cfg.d_model * cfg.n_layers * 2 * 2
        return (w_traffic + act) / chips
    # serving: active weights read once per step; KV cache traffic
    if cfg.mla is not None:
        entry = cfg.mla.d_c + cfg.mla.d_rope * 2 + 4
        cache_layers = cfg.n_layers
    else:
        entry = 2 * cfg.n_kv_heads * cfg.d_head + 2 * cfg.n_kv_heads * 4
        cache_layers = sum(1 for i in range(cfg.n_layers)
                           if cfg._kind(i) in ("attn", "swa", "dec"))
    if cfg.kv_fmt == "none":
        entry = entry * 2 if cfg.mla is None else (cfg.mla.d_c + cfg.mla.d_rope) * 2
    eff_seq = seq
    if cfg.window:
        # windowed layers cap their cache
        n_full = sum(1 for i in range(cfg.n_layers) if cfg._kind(i) == "attn")
        n_win = max(cache_layers - n_full, 0)
        cache_bytes = gb * entry * (n_full * seq + n_win * min(seq, cfg.window))
    else:
        cache_bytes = gb * entry * cache_layers * eff_seq
    if rec["kind"] == "prefill":
        acts = tokens * cfg.d_model * cfg.n_layers * 2
        return (n_act * 2 + cache_bytes + acts) / chips
    return (n_act * 2 + cache_bytes) / chips


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok" or "flops" not in rec:
        return None
    if not rec.get("cost_pass", {}).get("exact", False):
        return None     # wave-1-only record: FLOPs undercount scan bodies
    chips = rec["n_chips"]
    flops_chip = rec["flops"]                       # global/chips (cost-exact)
    bytes_chip_analytic = analytic_memory_bytes(rec)
    bytes_chip_unfused = rec.get("bytes_global_unfused", 0.0) / chips
    coll_chip = rec["collectives"]["total_bytes"]   # per-chip partitioned HLO

    compute_s = flops_chip / V5E_BF16
    memory_s = bytes_chip_analytic / V5E_HBM
    collective_s = coll_chip / (V5E_ICI_LINK * ICI_LINKS)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    useful_ratio = mf / (flops_chip * chips) if flops_chip else 0.0
    t_useful = mf / chips / V5E_BF16
    frac = t_useful / terms[dominant] if terms[dominant] > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"], "chips": chips,
        **{k: round(v * 1e6, 2) for k, v in terms.items()},   # in us
        "memory_unfused_s": round(bytes_chip_unfused / V5E_HBM * 1e6, 2),
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_chip": flops_chip,
        "useful_ratio": round(useful_ratio, 4),
        "roofline_frac": round(frac, 4),
        "collective_breakdown": rec["collectives"].get("bytes", {}),
        "peak_bytes_chip": rec["memory"]["peak_bytes"],
        "arg_bytes_chip": rec["memory"]["argument_bytes"],
    }


def load_sweep(path: str):
    return json.loads(pathlib.Path(path).read_text())


def table(sweep, mesh="pod"):
    rows = []
    for rec in sweep:
        if rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": mesh, "dominant": "SKIP",
                         "reason": rec.get("reason", "")})
            continue
        a = analyze(rec)
        if a:
            rows.append(a)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", default="results/dryrun/sweep.json")
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--md", action="store_true", help="markdown table output")
    args = ap.parse_args()
    rows = table(load_sweep(args.sweep), args.mesh)
    if args.md:
        print("| arch | shape | compute us | memory us | collective us | "
              "dominant | useful | roofline frac |")
        print("|---|---|---|---|---|---|---|---|")
        for r in rows:
            if r["dominant"] == "SKIP":
                print(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — |")
            else:
                print(f"| {r['arch']} | {r['shape']} | {r['compute_s']} | "
                      f"{r['memory_s']} | {r['collective_s']} | {r['dominant']} | "
                      f"{r['useful_ratio']} | {r['roofline_frac']} |")
    else:
        for r in rows:
            print(json.dumps(r))


if __name__ == "__main__":
    main()
