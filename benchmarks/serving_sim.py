"""Continuous-batching serving simulation -> BENCH_serving.json.

A seeded synthetic-arrival workload driven through the serving engine
(``repro.serving``): Poisson-ish arrivals (exponential inter-arrival gaps in
*virtual engine steps* — arrival times are generated host-side and passed
in; no wall-clock enters traced code, so a fixed ``--seed`` reproduces the
exact schedule and, under greedy decoding, the exact tokens run-to-run).

The sweep crosses request rate x prefix-sharing ratio. ``share_ratio`` is
the fraction of requests whose prompt begins with a workload-common prefix
(two full pages of it), so the allocator's refcounted prefix sharing can map
the same physical pages across concurrent requests; each cell is also run
with sharing disabled to report pages saved.

Emitted series per cell (the ``BENCH_serving.json`` schema — see README
"Serving engine"):
    throughput      decode tokens/s (wall) + tokens-per-engine-step
    latency         p50/p99 request latency and TTFT, in virtual steps
    pages           peak/capacity, utilization series, saved_by_sharing,
                    unshared_peak (same workload, sharing off), evictions
"""
from __future__ import annotations

import argparse
import json
import pathlib

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.kvcache import page_aligned_capacity
from repro.models import transformer as T
from repro.serving import EngineConfig, Request, ServingEngine


def make_workload(seed: int, n_requests: int, rate: float, share_ratio: float,
                  prompt_lens: tuple[int, ...], gen_lens: tuple[int, ...],
                  page_size: int, vocab: int) -> list[Request]:
    """Seeded synthetic workload: exponential inter-arrival gaps at
    ``rate`` requests/step; ``share_ratio`` of prompts start with a common
    two-page prefix (the prefix the allocator can share)."""
    rng = np.random.default_rng(seed)
    shared_prefix = rng.integers(0, vocab, size=2 * page_size,
                                 dtype=np.int32)
    t = 0.0
    reqs = []
    for rid in range(n_requests):
        t += rng.exponential(1.0 / max(rate, 1e-9))
        S = int(rng.choice(prompt_lens))
        body = rng.integers(0, vocab, size=S, dtype=np.int32)
        if rng.random() < share_ratio:
            # clamp: prompts shorter than the prefix just share what fits
            n = min(S, len(shared_prefix))
            body[:n] = shared_prefix[:n]
        reqs.append(Request(rid=rid, prompt=body,
                            max_new=int(rng.choice(gen_lens)),
                            arrival=float(np.floor(t))))
    return reqs


def _pct(xs: list[int], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else -1.0


def run_cell(cfg, params, seed: int, n_requests: int, rate: float,
             share_ratio: float, max_batch: int, pool_pages: int,
             prompt_lens, gen_lens, prefix_sharing: bool = True) -> dict:
    span = page_aligned_capacity(max(prompt_lens) + max(gen_lens),
                                 cfg.page_size) // cfg.page_size
    reqs = make_workload(seed, n_requests, rate, share_ratio, prompt_lens,
                         gen_lens, cfg.page_size, cfg.vocab_size)
    engine = ServingEngine(cfg, params, EngineConfig(
        max_batch=max_batch, max_pages_per_seq=span, n_pages=pool_pages,
        prefix_sharing=prefix_sharing, seed=seed))
    results = engine.run(reqs)
    m = engine.metrics()
    done = [r for r in results if r.status == "done"]
    lat = [r.latency_steps for r in done]
    ttft = [r.ttft_steps for r in done]
    return {
        "rate_req_per_step": rate,
        "share_ratio": share_ratio,
        "prefix_sharing": prefix_sharing,
        "n_requests": n_requests,
        "completed": len(done),
        "evicted": sum(1 for r in results if r.status == "evicted"),
        "steps": m["steps"],
        "throughput": {
            "decode_tok_per_s": m["decode_tok_per_s"],
            "decode_tokens": m["decode_tokens"],
            "tok_per_step": m["decode_tokens"] / max(m["steps"], 1),
        },
        "latency_steps": {"p50": _pct(lat, 50), "p99": _pct(lat, 99)},
        "ttft_steps": {"p50": _pct(ttft, 50), "p99": _pct(ttft, 99)},
        "pages": {
            **m["pages"],
            "mean_utilization": float(np.mean(m["utilization_series"]))
            if m["utilization_series"] else 0.0,
            "utilization_series": [round(u, 4)
                                   for u in m["utilization_series"]],
        },
    }


def write_bench_serving(path: str = "BENCH_serving.json", *, seed: int = 0,
                        arch: str = "mla-7b", n_requests: int = 8,
                        max_batch: int = 4,
                        rates=(0.25, 1.0), share_ratios=(0.0, 0.75)) -> dict:
    cfg = get_smoke_config(arch)
    params = T.init_model(jax.random.PRNGKey(seed), cfg)
    page = cfg.page_size
    prompt_lens = (2 * page + page // 2, 3 * page)   # mixed, prefix-shareable
    gen_lens = (page // 2, page)
    span = page_aligned_capacity(max(prompt_lens) + max(gen_lens), page) \
        // page
    pool_pages = max_batch * span + 1
    cells = []
    for rate in rates:
        for share in share_ratios:
            cell = run_cell(cfg, params, seed, n_requests, rate, share,
                            max_batch, pool_pages, prompt_lens, gen_lens)
            # sharing-off twin of the same workload: the pages the free-list
            # allocator saved are the headline of the prefix-sharing
            # feature. At share_ratio 0 sharing cannot save anything, so
            # the twin run (a full extra engine + compile) is skipped and
            # the cell is its own baseline.
            off = cell if share == 0.0 else run_cell(
                cfg, params, seed, n_requests, rate, share, max_batch,
                pool_pages, prompt_lens, gen_lens, prefix_sharing=False)
            cell["pages"]["unshared_peak_in_use"] = \
                off["pages"]["peak_in_use"]
            cell["pages"]["unshared_total_allocs"] = \
                off["pages"]["total_allocs"]
            cells.append(cell)
    payload = {
        "bench": "serving_sim",
        "arch": cfg.name,
        "seed": seed,
        "page_size": page,
        "max_batch": max_batch,
        "pool_pages": pool_pages,
        "prompt_lens": list(prompt_lens),
        "gen_lens": list(gen_lens),
        "cells": cells,
    }
    pathlib.Path(path).write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arch", default="mla-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()
    payload = write_bench_serving(args.out, seed=args.seed, arch=args.arch,
                                  n_requests=args.requests,
                                  max_batch=args.max_batch)
    for c in payload["cells"]:
        saved = c["pages"]["saved_by_sharing"]
        print(f"[serving_sim] rate={c['rate_req_per_step']:<5} "
              f"share={c['share_ratio']:<5} "
              f"tok/s={c['throughput']['decode_tok_per_s']:8.1f} "
              f"p50={c['latency_steps']['p50']:5.1f} "
              f"p99={c['latency_steps']['p99']:5.1f} "
              f"peak_pages={c['pages']['peak_in_use']}"
              f"/{c['pages']['unshared_peak_in_use']} (shared/unshared) "
              f"saved={saved} evicted={c['evicted']}")
    print(f"[serving_sim] wrote {args.out} ({len(payload['cells'])} cells)")


if __name__ == "__main__":
    main()
