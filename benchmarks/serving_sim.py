"""Continuous-batching serving simulation -> BENCH_serving.json.

A seeded synthetic-arrival workload driven through the serving engine
(``repro.serving``): Poisson-ish arrivals (exponential inter-arrival gaps in
*virtual engine steps* — arrival times are generated host-side and passed
in; no wall-clock enters traced code, so a fixed ``--seed`` reproduces the
exact schedule and, under greedy decoding, the exact tokens run-to-run).

The sweep crosses request rate x prefix-sharing ratio. ``share_ratio`` is
the fraction of requests whose prompt begins with a workload-common prefix
(two full pages of it), so the allocator's refcounted prefix sharing can map
the same physical pages across concurrent requests; each cell is also run
with sharing disabled to report pages saved.

Emitted series per cell (the ``BENCH_serving.json`` schema — see README
"Serving engine"):
    throughput      decode tokens/s (wall) + tokens-per-engine-step
    latency         p50/p99 request latency and TTFT, in virtual steps
    pages           peak/capacity, utilization series, saved_by_sharing,
                    unshared_peak (same workload, sharing off), evictions

Two further sections compare this PR's perf levers against their twins on
identical workloads:

  * ``chunked_prefill`` — a mixed long+short arrival workload run through
    the engine TWICE (``prefill_chunk`` on vs monolithic admission).
    Latency is compared in deterministic WORK UNITS (tokens of prefill +
    decode compute processed between a request's submission and its first
    token — wall clock on a shared CI runner is noise, work units are not):
    p50/p99 TTFT overall and per class (short = interactive requests, the
    ones a monolithic long prefill makes wait), plus decode-stall tokens
    (prefill work done in steps with decodes in flight — the ITL-spike
    metric) per step max/p99/total. The ``delta`` block is the headline:
    chunked admission must cut the per-step decode stall and the short-class
    p99 TTFT.
  * ``fused_eos_gating`` — ``make_fused_decode(gate_finished=...)`` twins
    on an EOS-heavy batch: identical tokens, and the gated run's frozen
    ``seq_lens`` quantify the cache appends + KV blocks the split-KV early
    exit no longer touches for finished rows.
  * ``speculative`` — self-speculative (n-gram draft + q_len>1 verify)
    decoding twins on a greedy mixed random+repetitive workload:
    token-identical output, acceptance rate, committed tokens per
    slot-step (> 1.0 = real multi-token commits), and engine steps saved.
  * ``telemetry`` — the tiered shared-prefix workload with the span tracer
    and quant-health probe armed, run twice on the same seed: registry
    work-metric values for bench_gate pinning plus byte-identical
    trace/registry determinism booleans.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.kvcache import page_aligned_capacity
from repro.launch import steps as ST
from repro.models import transformer as T
from repro.serving import (EngineConfig, FaultEvent, FaultPlan, Request,
                           ServingEngine)


def make_workload(seed: int, n_requests: int, rate: float, share_ratio: float,
                  prompt_lens: tuple[int, ...], gen_lens: tuple[int, ...],
                  page_size: int, vocab: int) -> list[Request]:
    """Seeded synthetic workload: exponential inter-arrival gaps at
    ``rate`` requests/step; ``share_ratio`` of prompts start with a common
    two-page prefix (the prefix the allocator can share)."""
    rng = np.random.default_rng(seed)
    shared_prefix = rng.integers(0, vocab, size=2 * page_size,
                                 dtype=np.int32)
    t = 0.0
    reqs = []
    for rid in range(n_requests):
        t += rng.exponential(1.0 / max(rate, 1e-9))
        S = int(rng.choice(prompt_lens))
        body = rng.integers(0, vocab, size=S, dtype=np.int32)
        if rng.random() < share_ratio:
            # clamp: prompts shorter than the prefix just share what fits
            n = min(S, len(shared_prefix))
            body[:n] = shared_prefix[:n]
        reqs.append(Request(rid=rid, prompt=body,
                            max_new=int(rng.choice(gen_lens)),
                            arrival=float(np.floor(t))))
    return reqs


def _pct(xs: list[int], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else -1.0


def run_cell(cfg, params, seed: int, n_requests: int, rate: float,
             share_ratio: float, max_batch: int, pool_pages: int,
             prompt_lens, gen_lens, prefix_sharing: bool = True) -> dict:
    span = page_aligned_capacity(max(prompt_lens) + max(gen_lens),
                                 cfg.page_size) // cfg.page_size
    reqs = make_workload(seed, n_requests, rate, share_ratio, prompt_lens,
                         gen_lens, cfg.page_size, cfg.vocab_size)
    engine = ServingEngine(cfg, params, EngineConfig(
        max_batch=max_batch, max_pages_per_seq=span, n_pages=pool_pages,
        prefix_sharing=prefix_sharing, seed=seed))
    results = engine.run(reqs)
    m = engine.metrics()
    done = [r for r in results if r.status == "done"]
    lat = [r.latency_steps for r in done]
    ttft = [r.ttft_steps for r in done]
    return {
        "rate_req_per_step": rate,
        "share_ratio": share_ratio,
        "prefix_sharing": prefix_sharing,
        "n_requests": n_requests,
        "completed": len(done),
        "evicted": m["requeues"],       # evictions are requeues now (no loss)
        "steps": m["steps"],
        "throughput": {
            "decode_tok_per_s": m["wall"]["decode_tok_per_s"],
            "decode_tokens": m["decode_tokens"],
            "tok_per_step": m["decode_tokens"] / max(m["steps"], 1),
        },
        "latency_steps": {"p50": _pct(lat, 50), "p99": _pct(lat, 99)},
        "ttft_steps": {"p50": _pct(ttft, 50), "p99": _pct(ttft, 99)},
        "pages": {
            **m["pages"],
            "mean_utilization": float(np.mean(m["utilization_series"]))
            if m["utilization_series"] else 0.0,
            "utilization_series": [round(u, 4)
                                   for u in m["utilization_series"]],
        },
    }


def _mixed_workload(seed: int, page: int, chunk: int, vocab: int,
                    n_short: int = 6) -> list[Request]:
    """Mixed long+short arrivals: two long prompts (several chunks each)
    with short interactive requests arriving at and just after them — the
    regime where a monolithic prefill stalls every in-flight decode."""
    rng = np.random.default_rng(seed)
    long_len, short_len = 6 * chunk, page
    reqs = []
    rid = 0
    for arrival, length in (
            [(0.0, long_len)]
            + [(float(i % 3), short_len) for i in range(n_short // 2)]
            + [(4.0, long_len)]
            + [(4.0 + i % 3, short_len) for i in range(n_short - n_short // 2)]):
        reqs.append(Request(
            rid=rid, prompt=rng.integers(0, vocab, size=length,
                                         dtype=np.int32),
            max_new=page // 2, arrival=arrival))
        rid += 1
    return reqs


def _ttft_stats(results, short_cutoff: int) -> dict:
    def pcts(xs):
        return {"p50": _pct(xs, 50), "p99": _pct(xs, 99)}
    works = [r.ttft_work for r in results if r.ttft_work >= 0]
    shorts = [r.ttft_work for r in results
              if r.ttft_work >= 0 and r.prompt_len <= short_cutoff]
    longs = [r.ttft_work for r in results
             if r.ttft_work >= 0 and r.prompt_len > short_cutoff]
    return {"all": pcts(works), "short": pcts(shorts), "long": pcts(longs)}


def run_chunked_twin(cfg, params, seed: int, chunk: int, budget: int,
                     max_batch: int = 4) -> dict:
    """The SAME mixed long+short workload through the engine twice:
    chunked admission vs the monolithic twin. Returns the comparison the
    ISSUE's acceptance criterion reads — work-unit TTFT + decode-stall
    deltas."""
    import dataclasses as _dc
    page = cfg.page_size
    n_requests = len(_mixed_workload(seed, page, chunk, cfg.vocab_size))
    span = page_aligned_capacity(6 * chunk + page // 2, page) // page
    runs = {}
    for mode, pchunk in (("monolithic", 0), ("chunked", chunk)):
        engine = ServingEngine(
            _dc.replace(cfg, prefill_chunk=pchunk), params,
            EngineConfig(max_batch=max_batch, max_pages_per_seq=span,
                         prefill_budget=budget if pchunk else 0, seed=seed))
        # requests carry mutable run state — each twin gets a fresh workload
        # (same seed -> identical prompts/arrivals)
        results = engine.run(_mixed_workload(seed, page, chunk,
                                             cfg.vocab_size))
        m = engine.metrics()
        stalls = m["work"]["stall_tokens_series"]
        runs[mode] = {
            "completed": len(results),
            "steps": m["steps"],
            "prefill_traces": m["prefill"]["traces"],
            "ttft_work": _ttft_stats(results, short_cutoff=2 * chunk),
            "ttft_steps_p99": _pct([r.ttft_steps for r in results
                                    if r.ttft_steps >= 0], 99),
            "stall": {
                "tokens_total": int(sum(stalls)),
                "tokens_per_step_max": int(max(stalls, default=0)),
                "tokens_per_step_p99": _pct([s for s in stalls], 99),
                "seconds": m["wall"]["stall_seconds"],
            },
            "wall": {
                "ttft_s_p99": _pct([r.ttft_s for r in results], 99),
                "decode_tok_per_s": m["wall"]["decode_tok_per_s"],
            },
            "fetch_work": m["fetch_work"],
            "tokens": {r.rid: r.tokens for r in results},
        }
    # capacity-independence twin: the SAME chunked workload on a pool with
    # twice the page-table span. The bounded prefix fetch's page traffic
    # tracks chunk_start, so pages_fetched_bounded must NOT move when the
    # capacity doubles (a full-span fetch would double with it).
    engine2x = ServingEngine(
        _dc.replace(cfg, prefill_chunk=chunk), params,
        EngineConfig(max_batch=max_batch, max_pages_per_seq=2 * span,
                     prefill_budget=budget, seed=seed))
    engine2x.run(_mixed_workload(seed, page, chunk, cfg.vocab_size))
    fetch_2x = engine2x.metrics()["fetch_work"]
    mono, chk = runs["monolithic"], runs["chunked"]
    tokens_equal = mono.pop("tokens") == chk.pop("tokens")
    fw = chk["fetch_work"]
    fetch_bound = {
        "pages_fetched_bounded": fw["pages_fetched_bounded"],
        "pages_fetched_full": fw["pages_fetched_full"],
        "fetch_savings": fw["fetch_savings"],
        "bounded_at_2x_capacity": fetch_2x["pages_fetched_bounded"],
        "full_at_2x_capacity": fetch_2x["pages_fetched_full"],
        "capacity_independent": (fw["pages_fetched_bounded"]
                                 == fetch_2x["pages_fetched_bounded"]),
    }
    return {
        "prefill_chunk": chunk,
        "prefill_budget": budget,
        "n_requests": n_requests,
        "tokens_equal": tokens_equal,
        "monolithic": mono,
        "chunked": chk,
        "fetch_bound": fetch_bound,
        # the acceptance headline: positive = chunked is better
        "delta": {
            "stall_tokens_per_step_max":
                mono["stall"]["tokens_per_step_max"]
                - chk["stall"]["tokens_per_step_max"],
            "stall_tokens_per_step_p99":
                mono["stall"]["tokens_per_step_p99"]
                - chk["stall"]["tokens_per_step_p99"],
            "ttft_work_p99_short":
                mono["ttft_work"]["short"]["p99"]
                - chk["ttft_work"]["short"]["p99"],
            "ttft_s_p99": mono["wall"]["ttft_s_p99"]
                - chk["wall"]["ttft_s_p99"],
        },
    }


def run_fused_gating_twin(cfg, params, seed: int, gen: int = 12) -> dict:
    """``make_fused_decode`` finished-row gating vs the always-append twin
    on an EOS-heavy batch: tokens must be identical; the gated run's frozen
    ``seq_lens`` measure the appends (and early-exit KV blocks) saved."""
    B, S = 4, 2 * cfg.page_size
    key = jax.random.PRNGKey(seed)
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    max_len = page_aligned_capacity(S + gen, cfg.page_size)
    prefill = jax.jit(ST.make_prefill_step(cfg))
    state0 = T.init_decode_state(cfg, B, max_len)
    logits, _ = prefill(params, prompts, state0)
    first = jnp.argmax(logits, -1).astype(jnp.int32)
    eos = int(np.asarray(first)[0])      # row 0 finishes immediately
    out = {}
    for gate in (True, False):
        state = T.init_decode_state(cfg, B, max_len)
        _, state = prefill(params, prompts, state)
        fused = jax.jit(ST.make_fused_decode(cfg, gen - 1, eos_id=eos,
                                             gate_finished=gate),
                        donate_argnums=(2,))
        args = (params, first, state, jnp.full((B,), S, jnp.int32))
        compiled = fused.lower(*args).compile()
        t0 = time.time()
        toks, state_out, ok = compiled(*args)
        jax.block_until_ready(toks)
        dt = time.time() - t0
        lens = np.asarray(state_out["scanned"][0].seq_lens).reshape(-1, B)[0] \
            if state_out.get("scanned") is not None \
            else np.asarray(state_out["tail"][0].seq_lens)
        out[gate] = {"tokens": np.asarray(toks).tolist(),
                     "seconds": dt,
                     "final_seq_lens": [int(x) for x in lens],
                     "finite": bool(ok)}
    gated, ungated = out[True], out[False]
    return {
        "eos_id": eos,
        "gen_steps": gen,
        "tokens_equal": gated["tokens"] == ungated["tokens"],
        "gated": {k: v for k, v in gated.items() if k != "tokens"},
        "ungated": {k: v for k, v in ungated.items() if k != "tokens"},
        # appends (== split-KV blocks the early exit keeps streaming)
        # skipped for finished rows by the gate:
        "appends_saved": int(sum(ungated["final_seq_lens"])
                             - sum(gated["final_seq_lens"])),
    }


def _prefix_workload(seed: int, page: int, vocab: int, n_requests: int,
                     gap: int, shared_pages: int = 3,
                     suffix: int | None = None) -> list[Request]:
    """'Shared system prompt, long-tail user turns': every prompt starts
    with the SAME ``shared_pages`` full pages (the system prompt) followed
    by a unique per-request suffix; arrivals are spaced ``gap`` steps apart
    so requests never overlap live — any page reuse must come from the
    refcount-0 retained cache, not from live refcount sharing."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, size=shared_pages * page, dtype=np.int32)
    if suffix is None:
        suffix = page + page // 2
    reqs = []
    for rid in range(n_requests):
        body = np.concatenate([
            shared, rng.integers(0, vocab, size=suffix, dtype=np.int32)])
        reqs.append(Request(rid=rid, prompt=body, max_new=page // 2,
                            arrival=float(rid * gap)))
    return reqs


def run_prefix_cache_workload(cfg, params, seed: int, n_requests: int = 4,
                              shared_pages: int = 3) -> dict:
    """The radix-cache headline: the SAME shared-system-prompt workload
    through the engine three times — cache off (cold), device-only retained
    cache, and a tiny device budget backed by the host tier (forcing
    offload + restore). Chunked prefill, so a cache hit skips whole chunks:
    the acceptance criterion is hit TTFT (work units, requests 2..N) below
    the cold run's, with token-identical output across all three runs."""
    import dataclasses as _dc
    page = cfg.page_size
    suffix = page + page // 2
    S = shared_pages * page + suffix
    gen = page // 2
    # arrivals spaced past the worst-case cold lifetime of one request:
    # every prefill chunk + every decode step + admission slack
    gap = S // page + 2 + gen + 8
    span = page_aligned_capacity(S + gen, page) // page
    pool_pages = 2 * span + 1
    ccfg = _dc.replace(cfg, prefill_chunk=page)
    modes = {
        "cold": dict(prefix_cache_pages=0, host_tier_pages=0),
        "cached": dict(prefix_cache_pages=pool_pages - 1, host_tier_pages=0),
        # device budget below the shared-prefix size: retained pages spill
        # to host, so later hits exercise the restore path too
        "tiered": dict(prefix_cache_pages=max(shared_pages - 1, 1),
                       host_tier_pages=pool_pages),
    }
    runs = {}
    for mode, kw in modes.items():
        engine = ServingEngine(ccfg, params, EngineConfig(
            max_batch=2, max_pages_per_seq=span, n_pages=pool_pages,
            prefill_budget=2 * page, seed=seed, **kw))
        results = engine.run(_prefix_workload(seed, page, cfg.vocab_size,
                                              n_requests, gap, shared_pages,
                                              suffix))
        m = engine.metrics()
        pc = m["prefix_cache"]
        hits = [r.ttft_work for r in results if r.rid > 0 and r.ttft_work >= 0]
        runs[mode] = {
            "completed": sum(r.status == "done" for r in results),
            # rid 0 warms the cache; rids 1..N-1 are the hit candidates
            "ttft_work_first": next((r.ttft_work for r in results
                                     if r.rid == 0), -1),
            "ttft_work_rest_mean": float(np.mean(hits)) if hits else -1.0,
            "ttft_work_rest_max": max(hits, default=-1),
            "prefill_skipped_tokens": pc["prefill_skipped_tokens"],
            "pages_reused_cached": pc["reused_cached"],
            "pages_restored_host": pc["restored_host"],
            "host_offloads": pc["offloads"],
            "hbm_peak_resident_pages": pc["peak_resident"],
            "tokens": {r.rid: r.tokens for r in results},
        }
    cold, cached, tiered = runs["cold"], runs["cached"], runs["tiered"]
    toks = cold.pop("tokens")
    tokens_equal = toks == cached.pop("tokens") \
        and toks == tiered.pop("tokens")
    return {
        "n_requests": n_requests,
        "shared_prefix_pages": shared_pages,
        "prompt_len": S,
        "pool_pages": pool_pages,
        # token-identity across cold / cached / tiered runs — cache hits
        # must not change a single sampled token
        "tokens_equal": tokens_equal,
        "cold": cold,
        "cached": cached,
        "tiered": tiered,
        # acceptance headline: positive = cache hits beat cold TTFT
        "delta": {
            "hit_ttft_work_mean": cold["ttft_work_rest_mean"]
                - cached["ttft_work_rest_mean"],
            "tiered_hit_ttft_work_mean": cold["ttft_work_rest_mean"]
                - tiered["ttft_work_rest_mean"],
        },
    }


_TELEMETRY_GATED = (
    # single-value work metrics bench_gate pins (deterministic for a seed)
    "snapmla_cache_reused_pages",
    "snapmla_tier_offload_pages",
    "snapmla_tier_restore_pages",
    "snapmla_fetch_pages_bounded_total",
    "snapmla_fetch_pages_full_total",
    "snapmla_engine_prefill_skipped_tokens_total",
    "snapmla_engine_decode_tokens_total",
    "snapmla_roofline_model_bytes_total",
)


def run_telemetry_probe(cfg, params, seed: int, n_requests: int = 4,
                        shared_pages: int = 3) -> dict:
    """Observability headline: the tiered shared-prefix chunked workload
    with EVERY probe armed (span tracer, quant-health sampler) run twice on
    the same seed. Reports the registry's single-value work metrics for
    bench_gate pinning plus the determinism cross-checks — byte-identical
    Chrome trace and registry snapshot across the twin runs, and a
    validated trace (one terminal instant per request track)."""
    import dataclasses as _dc
    from repro.obs import SpanTracer, validate_chrome_trace
    page = cfg.page_size
    suffix = page + page // 2
    S = shared_pages * page + suffix
    gen = page // 2
    gap = S // page + 2 + gen + 8
    span = page_aligned_capacity(S + gen, page) // page
    pool_pages = 2 * span + 1
    ccfg = _dc.replace(cfg, prefill_chunk=page)

    def one_run():
        tracer = SpanTracer()
        engine = ServingEngine(ccfg, params, EngineConfig(
            max_batch=2, max_pages_per_seq=span, n_pages=pool_pages,
            prefill_budget=2 * page, seed=seed,
            prefix_cache_pages=max(shared_pages - 1, 1),
            host_tier_pages=pool_pages, quant_health_every=4),
            tracer=tracer)
        engine.run(_prefix_workload(seed, page, cfg.vocab_size, n_requests,
                                    gap, shared_pages, suffix))
        return engine, tracer

    engine, tracer = one_run()
    engine2, tracer2 = one_run()
    payload = tracer.chrome_payload()
    stats = validate_chrome_trace(payload, expect_requests=n_requests)
    dump = json.dumps(payload, sort_keys=True)
    work = engine.telemetry()["work"]
    metrics = {}
    for name in _TELEMETRY_GATED:
        vals = work[name]["values"]
        metrics[name] = vals[""]
    faults = work["snapmla_engine_faults_total"]["values"]
    probe = engine.quant_probe
    return {
        "n_requests": n_requests,
        "metrics": metrics,
        "faults_total": int(sum(faults.values())),
        "trace": {
            "events": stats["events"],
            "spans": stats["spans"],
            "request_tracks": stats["requests"],
            "deterministic": dump == json.dumps(tracer2.chrome_payload(),
                                                sort_keys=True),
        },
        "registry_deterministic": (engine.telemetry()["work"]
                                   == engine2.telemetry()["work"]),
        "quant_health": {
            "samples": len(probe.samples) if probe else 0,
            "last_clip_rate_max": (probe.samples[-1]["clip_rate_max"]
                                   if probe and probe.samples else -1.0),
        },
    }


def run_speculative_twin(cfg, params, seed: int, spec_draft: int = 3,
                         n_random: int = 2, n_repeat: int = 2,
                         max_batch: int = 2) -> dict:
    """Self-speculative decoding twin: the SAME greedy mixed
    random+repetitive workload through the engine twice
    (``spec_draft_len`` 0 vs N). Speculation is rollback-by-rewind over
    the existing verify kernel, so it must be a PURE throughput
    optimization — per-request token dicts identical — while the
    repetitive traffic (the regime n-gram drafting wins on) pushes
    committed tokens per slot-step above the sequential-decode ceiling
    of exactly 1.0. Engine steps saved is the wall-free headline: the
    same tokens in fewer verify dispatches."""
    rng = np.random.default_rng(seed)
    S, gen = 24, 16
    span = page_aligned_capacity(S + gen, cfg.page_size) // cfg.page_size
    prompts = [rng.integers(0, cfg.vocab_size, size=S, dtype=np.int32)
               for _ in range(n_random)]
    patterns = ((5, 9, 2, 7), (13, 4, 6), (3, 8))
    prompts += [np.asarray((list(patterns[j % len(patterns)]) * S)[:S],
                           np.int32) for j in range(n_repeat)]

    def run(draft):
        engine = ServingEngine(cfg, params, EngineConfig(
            max_batch=max_batch, max_pages_per_seq=span,
            spec_draft_len=draft, seed=seed))
        results = engine.run([Request(rid=i, prompt=p, max_new=gen,
                                      arrival=0.0)
                              for i, p in enumerate(prompts)])
        m = engine.metrics()
        assert m["pages"]["free"] == m["pages"]["capacity"], "leaked pages"
        return {r.rid: r.tokens for r in results}, m

    base_toks, m0 = run(0)
    spec_toks, m1 = run(spec_draft)
    sp = m1["speculative"]
    return {
        "spec_draft_len": spec_draft,
        "n_requests": len(prompts),
        "gen_len": gen,
        # token-identity is the whole contract: a draft that survives an
        # incorrect verify would show up here before anywhere else
        "tokens_equal": base_toks == spec_toks,
        "baseline": {
            "steps": m0["steps"],
            "decode_tokens": m0["decode_tokens"],
        },
        "spec": {
            "steps": m1["steps"],
            "decode_tokens": m1["decode_tokens"],
            "verify_steps": sp["verify_steps"],
            "drafted_tokens": sp["drafted_tokens"],
            "accepted_tokens": sp["accepted_tokens"],
            "accept_rate": sp["accept_rate"],
            "accepted_tokens_per_step": sp["accepted_tokens_per_step"],
        },
        # positive = the speculative run drained the same workload in
        # fewer engine steps (virtual, seeded — deterministic)
        "delta": {"steps_saved": m0["steps"] - m1["steps"]},
    }


def run_fault_sweep(cfg, params, seed: int, n_requests: int = 8,
                    max_batch: int = 4) -> dict:
    """Survival metrics under deterministic fault injection: the SAME
    seeded workload run fault-free and then under each FaultPlan scenario.
    Per scenario: completed / failed-by-reason / rejected counts, recovery
    metrics (quarantines recovered via the jnp_ref retry, backend-fault
    fallback steps), whether every page drained, and — the isolation
    headline — whether every surviving request's tokens are identical to
    its fault-free twin."""
    page = cfg.page_size
    prompt_lens = (2 * page, 3 * page)
    gen_lens = (page // 2, page)
    span = page_aligned_capacity(max(prompt_lens) + max(gen_lens), page) \
        // page
    pool_pages = max_batch * span + 1

    def run_with(plan, max_queue=0, deadline=None):
        reqs = make_workload(seed, n_requests, 1.0, 0.5, prompt_lens,
                             gen_lens, page, cfg.vocab_size)
        if deadline is not None:
            for r in reqs:
                r.ttft_deadline = deadline
        engine = ServingEngine(cfg, params, EngineConfig(
            max_batch=max_batch, max_pages_per_seq=span, n_pages=pool_pages,
            max_queue=max_queue, seed=seed), fault_plan=plan)
        results = engine.run(reqs)
        return results, engine.metrics()

    clean, _ = run_with(None)
    clean_toks = {r.rid: r.tokens for r in clean}
    scenarios = {
        "nan_recovered": FaultPlan([FaultEvent("nan_logits", 4, slot=1)]),
        "nan_sticky": FaultPlan([FaultEvent("nan_logits", 4, slot=1,
                                            sticky=True)]),
        "backend_raise": FaultPlan([FaultEvent("backend_raise", 3)]),
        "alloc_storm": FaultPlan([FaultEvent("alloc_fail", 2, count=3)]),
        "random_storm": FaultPlan.random(seed, n_steps=16, n_faults=4,
                                         max_batch=max_batch,
                                         sticky_ratio=0.5),
    }
    out = {"n_requests": n_requests,
           "clean_completed": sum(r.status == "done" for r in clean)}
    for name, plan in scenarios.items():
        kw = {"max_queue": 2, "deadline": 64} if name == "random_storm" \
            else {}
        results, m = run_with(plan, **kw)
        f = m["faults"]
        done = [r for r in results if r.status == "done"]
        # survivors must be untouched by the injected faults (and a
        # recovered quarantine reproduces its fault-free token, because the
        # jnp_ref retry recomputes the same position on the same cache)
        survivors_identical = all(r.tokens == clean_toks[r.rid]
                                  for r in done)
        by_reason: dict[str, int] = {}
        for r in results:
            if r.status != "done":
                by_reason[r.fail_reason] = by_reason.get(r.fail_reason, 0) + 1
        out[name] = {
            "injected": len(f["injected"]),
            "completed": len(done),
            "failed_by_reason": by_reason,
            "rejected": f["rejected"],
            "quarantined": f["nonfinite_rows"],
            "recovered_ref": f["recovered_ref"],
            "backend_fallback_steps": f["ref_fallback_steps"],
            "deadline_cancelled": f["deadline_cancelled"],
            "requeues": m["requeues"],
            "pages_drained": m["pages"]["free"] == m["pages"]["capacity"],
            "survivors_token_identical": survivors_identical,
        }
    return out


def write_bench_serving(path: str = "BENCH_serving.json", *, seed: int = 0,
                        arch: str = "mla-7b", n_requests: int = 8,
                        max_batch: int = 4,
                        rates=(0.25, 1.0), share_ratios=(0.0, 0.75)) -> dict:
    cfg = get_smoke_config(arch)
    params = T.init_model(jax.random.PRNGKey(seed), cfg)
    page = cfg.page_size
    prompt_lens = (2 * page + page // 2, 3 * page)   # mixed, prefix-shareable
    gen_lens = (page // 2, page)
    span = page_aligned_capacity(max(prompt_lens) + max(gen_lens), page) \
        // page
    pool_pages = max_batch * span + 1
    cells = []
    for rate in rates:
        for share in share_ratios:
            cell = run_cell(cfg, params, seed, n_requests, rate, share,
                            max_batch, pool_pages, prompt_lens, gen_lens)
            # sharing-off twin of the same workload: the pages the free-list
            # allocator saved are the headline of the prefix-sharing
            # feature. At share_ratio 0 sharing cannot save anything, so
            # the twin run (a full extra engine + compile) is skipped and
            # the cell is its own baseline.
            off = cell if share == 0.0 else run_cell(
                cfg, params, seed, n_requests, rate, share, max_batch,
                pool_pages, prompt_lens, gen_lens, prefix_sharing=False)
            cell["pages"]["unshared_peak_in_use"] = \
                off["pages"]["peak_in_use"]
            cell["pages"]["unshared_total_allocs"] = \
                off["pages"]["total_allocs"]
            cells.append(cell)
    payload = {
        "bench": "serving_sim",
        "arch": cfg.name,
        "seed": seed,
        "page_size": page,
        "max_batch": max_batch,
        "pool_pages": pool_pages,
        "prompt_lens": list(prompt_lens),
        "gen_lens": list(gen_lens),
        "cells": cells,
        # budget = 3 chunks/step: the long request plus two interactive
        # requests advance every step, which is what moves BOTH headline
        # deltas (per-step decode stall AND short-class p99 TTFT) positive
        "chunked_prefill": run_chunked_twin(cfg, params, seed,
                                            chunk=page, budget=3 * page),
        "fused_eos_gating": run_fused_gating_twin(cfg, params, seed),
        # shared-system-prompt long-tail workload: cold vs retained-cache vs
        # host-tiered runs of identical requests — hit TTFT, pages
        # recomputed-vs-restored, HBM high-water
        "prefix_cache": run_prefix_cache_workload(cfg, params, seed),
        # all probes armed on the tiered shared-prefix workload: registry
        # work metrics for gating + trace/registry determinism cross-checks
        "telemetry": run_telemetry_probe(cfg, params, seed),
        # self-speculative decoding twin: greedy token-identity plus the
        # accepted-tokens-per-slot-step headline (> 1.0 = real multi-token
        # commits through the q_len>1 verify kernel)
        "speculative": run_speculative_twin(cfg, params, seed),
        "fault_sweep": run_fault_sweep(cfg, params, seed,
                                       n_requests=n_requests,
                                       max_batch=max_batch),
    }
    pathlib.Path(path).write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arch", default="mla-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()
    payload = write_bench_serving(args.out, seed=args.seed, arch=args.arch,
                                  n_requests=args.requests,
                                  max_batch=args.max_batch)
    for c in payload["cells"]:
        saved = c["pages"]["saved_by_sharing"]
        print(f"[serving_sim] rate={c['rate_req_per_step']:<5} "
              f"share={c['share_ratio']:<5} "
              f"tok/s={c['throughput']['decode_tok_per_s']:8.1f} "
              f"p50={c['latency_steps']['p50']:5.1f} "
              f"p99={c['latency_steps']['p99']:5.1f} "
              f"peak_pages={c['pages']['peak_in_use']}"
              f"/{c['pages']['unshared_peak_in_use']} (shared/unshared) "
              f"saved={saved} evicted={c['evicted']}")
    cp = payload["chunked_prefill"]
    print(f"[serving_sim] chunked twin: stall/step max "
          f"{cp['monolithic']['stall']['tokens_per_step_max']} -> "
          f"{cp['chunked']['stall']['tokens_per_step_max']} tokens, "
          f"short-class p99 TTFT "
          f"{cp['monolithic']['ttft_work']['short']['p99']:.0f} -> "
          f"{cp['chunked']['ttft_work']['short']['p99']:.0f} work units, "
          f"tokens_equal={cp['tokens_equal']}")
    fg = payload["fused_eos_gating"]
    print(f"[serving_sim] fused EOS gating: appends saved "
          f"{fg['appends_saved']}, tokens_equal={fg['tokens_equal']}")
    pcw = payload["prefix_cache"]
    print(f"[serving_sim] prefix cache: hit TTFT "
          f"{pcw['cold']['ttft_work_rest_mean']:.0f} (cold) -> "
          f"{pcw['cached']['ttft_work_rest_mean']:.0f} (cached) / "
          f"{pcw['tiered']['ttft_work_rest_mean']:.0f} (tiered) work units, "
          f"skipped {pcw['cached']['prefill_skipped_tokens']} tokens, "
          f"restored {pcw['tiered']['pages_restored_host']} pages from host, "
          f"HBM peak {pcw['cached']['hbm_peak_resident_pages']} pages, "
          f"tokens_equal={pcw['tokens_equal']}")
    tel = payload["telemetry"]
    print(f"[serving_sim] telemetry: trace {tel['trace']['events']} events/"
          f"{tel['trace']['spans']} spans over "
          f"{tel['trace']['request_tracks']} tracks, "
          f"trace_deterministic={tel['trace']['deterministic']} "
          f"registry_deterministic={tel['registry_deterministic']} "
          f"reused_pages={tel['metrics']['snapmla_cache_reused_pages']} "
          f"tier_restore={tel['metrics']['snapmla_tier_restore_pages']} "
          f"quant_samples={tel['quant_health']['samples']}")
    sv = payload["speculative"]
    print(f"[serving_sim] speculative twin: draft={sv['spec_draft_len']} "
          f"accept_rate={sv['spec']['accept_rate']:.3f} "
          f"tokens/slot-step={sv['spec']['accepted_tokens_per_step']:.3f} "
          f"steps {sv['baseline']['steps']} -> {sv['spec']['steps']} "
          f"(saved {sv['delta']['steps_saved']}), "
          f"tokens_equal={sv['tokens_equal']}")
    fs = payload["fault_sweep"]
    for name in ("nan_recovered", "nan_sticky", "backend_raise",
                 "alloc_storm", "random_storm"):
        s = fs[name]
        print(f"[serving_sim] fault {name:<14} completed="
              f"{s['completed']}/{fs['n_requests']} "
              f"recovered={s['recovered_ref']} "
              f"failed={s['failed_by_reason']} rejected={s['rejected']} "
              f"drained={s['pages_drained']} "
              f"survivors_identical={s['survivors_token_identical']}")
    print(f"[serving_sim] wrote {args.out} ({len(payload['cells'])} cells)")


if __name__ == "__main__":
    main()
