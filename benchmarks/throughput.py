"""End-to-end decode throughput (paper Figure 1 analogue).

Two outputs per (parallelism config x context length) point:

1. *Modeled* decode step time on TPU v5e from the roofline terms —
   bytes/step (weights + KV cache reads, FP8 vs BF16) over HBM bandwidth vs
   FLOPs/step over peak — the Figure-1 claim transported to v5e constants.
   This is the honest CPU-container substitute for wall-clock GPU numbers.
2. *Measured* CPU wall time of the actual pipeline at small scale (smoke
   config), FP8 vs BF16, demonstrating the full code path end-to-end.

The modeled speedup saturates near the paper's 1.91x where decode is
HBM-bound and the cache dominates bytes (long contexts), and shrinks when
weights dominate (short contexts / huge models) — same qualitative shape as
Figure 1.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config

V5E_BF16_FLOPS = 197e12
V5E_HBM_BPS = 819e9


def decode_step_model(cfg, context: int, batch_per_chip: float, tp: int,
                      fmt: str) -> dict:
    """Analytic per-chip decode-step roofline for an MLA arch on v5e."""
    m = cfg.mla
    bytes_per_param = 2.0
    n_active = cfg.active_param_count()
    # weights read once per step (batch amortizes), sharded over tp
    weight_bytes = n_active * bytes_per_param / tp
    # per sequence: latent cache content + rope + scales per layer
    cache_entry = (m.d_c * (1 if fmt != "none" else 2)
                   + m.d_rope * 2 + (4 if fmt != "none" else 0))
    cache_bytes = batch_per_chip * cfg.n_layers * context * cache_entry
    # flops: 2*N_active per token + attention (2*(d_c+d_r)*H + 2*d_c*H per tok)
    attn_flops = (2 * (m.d_c + m.d_rope) + 2 * m.d_c) * cfg.n_heads \
        * context * cfg.n_layers * batch_per_chip
    flops = 2 * n_active * batch_per_chip / tp + attn_flops / tp
    t_mem = (weight_bytes + cache_bytes / tp) / V5E_HBM_BPS
    t_comp = flops / V5E_BF16_FLOPS
    t_step = max(t_mem, t_comp)
    return {"t_mem": t_mem, "t_comp": t_comp, "t_step": t_step,
            "tok_per_s_chip": batch_per_chip / t_step}


def figure1_model(arch="deepseek-v3-mla"):
    """Modeled throughput, BF16 vs FP8, DP/TP configs x context lengths."""
    cfg = get_config(arch)
    rows = []
    for dp, tp in [(1, 8), (4, 2), (8, 1)]:
        for ctx in [16384, 32768, 65536, 131072]:
            # per-rank batch chosen to fill ~12GB of cache per chip at bf16,
            # matched across formats (paper: matched per-rank input shapes)
            entry_bf16 = (cfg.mla.d_c + cfg.mla.d_rope) * 2
            b = max(1.0, 12e9 / (cfg.n_layers * ctx * entry_bf16) * tp)
            bf16 = decode_step_model(cfg, ctx, b, tp, "none")
            fp8 = decode_step_model(cfg, ctx, b, tp, "fp8_e4m3")
            rows.append({
                "dp": dp, "tp": tp, "context": ctx, "batch_per_rank": round(b, 1),
                "bf16_tok_s": bf16["tok_per_s_chip"],
                "fp8_tok_s": fp8["tok_per_s_chip"],
                "speedup": fp8["tok_per_s_chip"] / bf16["tok_per_s_chip"],
                "bf16_bound": "mem" if bf16["t_mem"] > bf16["t_comp"] else "comp",
                "fp8_bound": "mem" if fp8["t_mem"] > fp8["t_comp"] else "comp",
            })
    return rows


def figure1_capacity(arch="deepseek-v3-mla", hbm_budget=9e9):
    """Capacity-mediated speedup: at a fixed per-chip HBM cache budget the FP8
    cache fits ~1.79x more sequences; with step time ~ total bytes/BW the
    throughput gain approaches the byte ratio. This is the serving-throughput
    regime of the paper's Fig. 1 (their Hopper + FP8-weight deployment keeps
    the weight term small; on v5e with BF16 weights the weight term damps the
    matched-shape speedup — both modes reported, DESIGN.md §2)."""
    cfg = get_config(arch)
    m = cfg.mla
    entry_bf16 = (m.d_c + m.d_rope) * 2
    entry_fp8 = m.d_c + 2 * m.d_rope + 4
    rows = []
    for tp in (8, 16):
        w_chip = cfg.active_param_count() * 2 / tp
        for ctx in [16384, 32768, 65536, 131072]:
            per_seq = cfg.n_layers * ctx
            out = {"tp": tp, "context": ctx}
            for label, entry in [("bf16", entry_bf16), ("fp8", entry_fp8)]:
                batch = hbm_budget / (per_seq * entry / tp)
                t = (w_chip + hbm_budget) / V5E_HBM_BPS
                out[label + "_batch"] = batch
                out[label + "_tok_s"] = batch / t / tp
            out["speedup"] = out["fp8_tok_s"] / out["bf16_tok_s"]
            rows.append(out)
    return rows


def early_exit_report(arch="deepseek-v3-mla", contexts=(16384, 32768, 65536, 131072),
                      fills=(0.25, 0.5, 0.75)):
    """Effective-blocks-visited under split-KV block-level early exit.

    Serving batches are ragged: sequences share a cache padded to max_len, so
    the seed kernel read max_len/block_n KV blocks per sequence per step. The
    split-KV kernel's clamped index maps + pl.when guards make blocks-visited
    scale with each sequence's own seq_len instead — the per-step HBM saving
    reported here is (1 - mean_seq_len / max_len) of the cache read, which at
    long contexts is most of the decode step's bytes.
    """
    cfg = get_config(arch)
    bn = cfg.page_size
    rows = []
    for ctx in contexts:
        total = -(-ctx // bn)
        for fill in fills:
            # ragged batch: uniform lengths in (0, fill*2*ctx] capped at ctx
            # (the cap shifts the realized mean below the nominal fill at
            # fill > 0.5 — report the realized occupancy, not the nominal)
            lens = np.minimum((np.arange(1, 33) / 32.0) * 2 * fill * ctx, ctx)
            visited = np.ceil(lens / bn)
            rows.append({
                "context": ctx, "nominal_fill": fill,
                "mean_fill": float(lens.mean() / ctx),
                "blocks_visited_mean": float(visited.mean()),
                "blocks_total": total,
                "early_exit_savings": float(1.0 - visited.mean() / total),
            })
    return rows


def measured_cpu(arch="mla-7b", B=4, prompt=32, gen=8):
    """Measured wall time of the real pipeline at smoke scale (CPU)."""
    from repro.launch.serve import generate
    from repro.models import transformer as T

    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_model(key, cfg)
    prompts = jax.random.randint(key, (B, prompt), 0, cfg.vocab_size, jnp.int32)
    out = {}
    for fmt in ["none", "fp8_e4m3"]:
        c = dataclasses.replace(cfg, kv_fmt=fmt)
        _, tps = generate(c, params, prompts, gen)
        out[fmt] = tps
    return out


def main(csv=True):
    out = []
    for r in figure1_model():
        name = f"fig1_dp{r['dp']}tp{r['tp']}_ctx{r['context']//1024}k"
        us = 1e6 / r["fp8_tok_s"]
        out.append((name, us,
                    f"speedup={r['speedup']:.2f}x bf16={r['bf16_tok_s']:.1f} "
                    f"fp8={r['fp8_tok_s']:.1f} tok/s/chip ({r['fp8_bound']}-bound)"))
    for r in figure1_capacity():
        name = f"fig1cap_tp{r['tp']}_ctx{r['context']//1024}k"
        out.append((name, 1e6 / max(r["fp8_tok_s"], 1e-9),
                    f"capacity-speedup={r['speedup']:.2f}x "
                    f"batch {r['bf16_batch']:.0f}->{r['fp8_batch']:.0f} per chip-group"))
    for r in early_exit_report():
        name = f"earlyexit_ctx{r['context']//1024}k_fill{int(r['mean_fill']*100)}"
        out.append((name, 0.0,
                    f"blocks={r['blocks_visited_mean']:.0f}/{r['blocks_total']} "
                    f"(early-exit saves {r['early_exit_savings']*100:.0f}% of "
                    f"cache reads at {r['mean_fill']*100:.0f}% mean occupancy)"))
    cpu = measured_cpu()
    ratio = cpu["fp8_e4m3"] / max(cpu["none"], 1e-9)
    out.append(("fig1_cpu_smoke_measured", 1e6 / max(cpu['fp8_e4m3'], 1e-9),
                f"cpu_fp8_vs_bf16={ratio:.2f}x (interpret-mode, not TPU-indicative)"))
    if csv:
        for name, us, derived in out:
            print(f"{name},{us:.1f},{derived}")
    return out


if __name__ == "__main__":
    main()
