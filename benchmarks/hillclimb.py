import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: run a chosen (arch, shape) cell under a sequence of
variants and record the roofline-term deltas.

    PYTHONPATH=src python -m benchmarks.hillclimb \
        --arch deepseek-v3-mla --shape decode_32k \
        --variants baseline serve_ws --out results/perf/<name>.json

Variants:
  baseline   FSDP x TP shardings everywhere (training layout reused)
  serve_ws   weight-stationary DP x TP for serving kinds (the paper's Fig-1
             serving layout: weights replicated over DP, sharded over TP)
  noremat    train only: no activation recomputation (flops down, memory up)
  bf16cache  kv_fmt=none (the FlashMLA-equivalent BF16 baseline pipeline)
  int8cache  kv_fmt=int8 (beyond-paper TPU-native content format)
"""
import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))


def run_variant(arch, shape, mesh, variant):
    from repro.launch.dryrun import run_cell
    kwargs = {}
    vname = variant
    if variant == "noremat":
        kwargs["remat"] = False
        vname = "baseline"
    elif variant == "bf16cache":
        kwargs["extra"] = {"kv_fmt": "none"}
        vname = "baseline"
    elif variant == "int8cache":
        kwargs["extra"] = {"kv_fmt": "int8"}
        vname = "baseline"
    return run_cell(arch, shape, mesh, variant=vname, **kwargs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--variants", nargs="+", default=["baseline", "serve_ws"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from benchmarks.roofline import analyze
    results = []
    for v in args.variants:
        rec = run_variant(args.arch, args.shape, args.mesh, v)
        rec["variant_label"] = v
        row = analyze(rec) if rec.get("status") == "ok" else None
        results.append({"variant": v, "raw": rec, "roofline": row})
        if row:
            print(f"{v:12s} compute={row['compute_s']}us memory={row['memory_s']}us "
                  f"collective={row['collective_s']}us dominant={row['dominant']} "
                  f"frac={row['roofline_frac']}", flush=True)
        else:
            print(f"{v:12s} status={rec.get('status')}", flush=True)

    if args.out:
        pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        pathlib.Path(args.out).write_text(json.dumps(results, indent=1,
                                                     default=str))


if __name__ == "__main__":
    main()
