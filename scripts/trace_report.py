#!/usr/bin/env python
"""Summarize a SnapMLA Chrome trace (``serve --trace-out``) in the terminal.

Validates the file first (``repro.obs.validate_chrome_trace``; pass
``--expect-requests`` to also pin the request-track count, as ci_smoke
does), then prints three tables derived purely from the trace:

  * per-request lifecycle — queued/admitted/first-token/terminal steps,
    TTFT and latency in engine steps (virtual clock: ``ts //
    ticks_per_step`` recovers the exact step, so these EQUAL the engine's
    own reported numbers), prefill chunk count, outcome;
  * decode-stall — engine steps whose prefill window ran while decodes
    were in flight (the ITL-spike steps), with per-step token maxima;
  * page occupancy — min/mean/peak of the per-step pool counter samples;
  * speculative decoding (``serve --spec-draft``) — verify steps, drafted
    vs accepted totals, and the acceptance rate, from the verify-flagged
    decode phase spans.

Exit code is non-zero on validation failure, so CI can gate on it.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.obs import validate_chrome_trace  # noqa: E402

_TERMINAL = ("DONE", "FAILED", "REJECTED")


def _fmt_table(headers: list[str], rows: list[list[str]]) -> str:
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    def line(cells):
        return "  ".join(c.rjust(w) for c, w in zip(cells, widths))
    return "\n".join([line(headers), line(["-" * w for w in widths])]
                     + [line(r) for r in rows])


def summarize(payload: dict) -> dict:
    """Pure extraction (no printing): the per-request, stall, and occupancy
    summaries as plain dicts — tests consume this, main() renders it."""
    from repro.obs.trace import REQUEST_PID, ENGINE_PID
    meta = payload.get("metadata", {})
    virtual = meta.get("clock", "virtual") == "virtual"
    ticks = int(meta.get("ticks_per_step", 1000))

    def step_of(ts: int) -> int:
        return ts // ticks if virtual else ts

    reqs: dict[int, dict] = {}
    stall_steps: dict[int, dict] = {}
    pages: list[dict] = []
    spec = {"verify_steps": 0, "drafted": 0, "accepted": 0, "rows": 0}
    for e in payload["traceEvents"]:
        ph = e.get("ph")
        if ph == "M":
            continue
        if e.get("pid") == ENGINE_PID:
            if ph == "C" and e.get("name") == "pages":
                pages.append(e["args"])
            elif ph == "X" and e.get("name") == "prefill" \
                    and e["args"].get("stalled_decodes", 0) > 0:
                stall_steps[e["args"]["step"]] = {
                    "tokens": e["args"].get("tokens", 0),
                    "stalled_decodes": e["args"]["stalled_decodes"]}
            elif ph == "X" and e.get("name") == "decode" \
                    and e.get("args", {}).get("verify"):
                spec["verify_steps"] += 1
                spec["drafted"] += e["args"].get("drafted", 0)
                spec["accepted"] += e["args"].get("accepted", 0)
                spec["rows"] += e["args"].get("rows", 0)
            continue
        rid = e.get("tid")
        r = reqs.setdefault(rid, {"rid": rid, "queued": None, "admit": None,
                                  "first_token": None, "end": None,
                                  "outcome": "?", "chunks": 0,
                                  "prompt_len": None, "evictions": 0})
        name, ts = e.get("name", ""), e["ts"]
        if ph == "X":
            if name == "QUEUED" and r["queued"] is None:
                r["queued"] = step_of(ts)
                r["prompt_len"] = e["args"].get("prompt_len")
            elif name == "PREFILL" and r["admit"] is None:
                r["admit"] = step_of(ts)
            elif name.startswith("PREFILL(chunk"):
                r["chunks"] += 1
        elif ph == "i":
            if name == "FIRST_TOKEN" and r["first_token"] is None:
                r["first_token"] = step_of(ts)
            elif name == "EVICTED":
                r["evictions"] += 1
            elif any(name.startswith(t) for t in _TERMINAL):
                r["end"], r["outcome"] = step_of(ts), name
    for r in reqs.values():
        q, ft, end = r["queued"], r["first_token"], r["end"]
        r["ttft"] = ft - q if virtual and None not in (q, ft) else None
        r["latency"] = end - q if virtual and None not in (q, end) else None
    occupancy = {}
    if pages:
        in_use = [p["in_use"] for p in pages]
        cap = [p["in_use"] + p["free"] for p in pages]
        occupancy = {
            "samples": len(pages),
            "in_use_min": min(in_use),
            "in_use_mean": sum(in_use) / len(in_use),
            "in_use_peak": max(in_use),
            "cached_peak": max(p.get("cached", 0) for p in pages),
            "capacity": max(cap),
        }
    stalls = sorted(stall_steps.items())
    spec["accept_rate"] = (spec["accepted"] / spec["drafted"]
                           if spec["drafted"] else 0.0)
    return {
        "clock": meta.get("clock", "virtual"),
        "requests": [reqs[rid] for rid in sorted(reqs)],
        "stall": {
            "steps": len(stalls),
            "tokens_total": sum(s["tokens"] for _, s in stalls),
            "tokens_per_step_max": max((s["tokens"] for _, s in stalls),
                                       default=0),
            "by_step": stalls,
        },
        "occupancy": occupancy,
        "speculative": spec,
    }


def render(summary: dict, stats: dict) -> str:
    unit = "step" if summary["clock"] == "virtual" else "us"
    out = [f"trace: {stats['events']} events, {stats['spans']} spans, "
           f"{stats['requests']} request tracks "
           f"({summary['clock']} clock, times in {unit}s)", ""]
    rows = []
    for r in summary["requests"]:
        def s(v):
            return "-" if v is None else str(v)
        rows.append([s(r["rid"]), s(r["prompt_len"]), s(r["queued"]),
                     s(r["admit"]), s(r["first_token"]), s(r["ttft"]),
                     s(r["end"]), s(r["latency"]), s(r["chunks"]),
                     s(r["evictions"]), r["outcome"]])
    out.append(_fmt_table(
        ["rid", "prompt", "queued", "admit", "first_tok", "ttft", "end",
         "latency", "chunks", "evict", "outcome"], rows))
    st = summary["stall"]
    out += ["", f"decode stall: {st['steps']} stalled steps, "
            f"{st['tokens_total']} prefill tokens alongside live decodes, "
            f"max {st['tokens_per_step_max']} tokens/step"]
    occ = summary["occupancy"]
    if occ:
        out += ["", f"pages: peak {occ['in_use_peak']}/{occ['capacity']} "
                f"in use (mean {occ['in_use_mean']:.1f}, "
                f"min {occ['in_use_min']}, cached peak "
                f"{occ['cached_peak']}) over {occ['samples']} step samples"]
    sp = summary["speculative"]
    if sp["verify_steps"]:
        out += ["", f"speculative: {sp['verify_steps']} verify steps over "
                f"{sp['rows']} slot-steps, drafted {sp['drafted']} / "
                f"accepted {sp['accepted']} "
                f"(accept rate {sp['accept_rate']:.3f})"]
    return "\n".join(out)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace JSON from serve --trace-out")
    ap.add_argument("--expect-requests", type=int, default=None,
                    help="fail unless the trace has exactly this many "
                    "request tracks, each with one terminal instant")
    args = ap.parse_args()
    payload = json.loads(pathlib.Path(args.trace).read_text())
    try:
        stats = validate_chrome_trace(payload,
                                      expect_requests=args.expect_requests)
    except ValueError as err:
        print(f"[trace_report] INVALID {args.trace}: {err}",
              file=sys.stderr)
        return 1
    print(render(summarize(payload), stats))
    return 0


if __name__ == "__main__":
    sys.exit(main())
