"""Regenerate the data-driven sections of EXPERIMENTS.md from artifacts.

    PYTHONPATH=src python scripts/fill_experiments.py
"""
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT))
sys.path.insert(0, str(ROOT / "src"))

from benchmarks import roofline as RL          # noqa: E402
from benchmarks.throughput import figure1_capacity, figure1_model  # noqa: E402


def load_cells():
    cells = []
    for p in sorted((ROOT / "results/dryrun").glob("*.json")):
        if p.name == "sweep.json":
            continue
        try:
            cells.append(json.loads(p.read_text()))
        except json.JSONDecodeError:
            pass
    return cells


def dryrun_summary(cells):
    lines = ["| arch | shape | pod compile | multipod compile | peak GB/chip (mp) | status |",
             "|---|---|---|---|---|---|"]
    by_key = {}
    for c in cells:
        by_key[(c["arch"], c["shape"], c["mesh"])] = c
    archs = sorted({c["arch"] for c in cells})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    n_ok = n_skip = n_other = 0
    for a in archs:
        for s in shapes:
            pod = by_key.get((a, s, "pod"))
            mp = by_key.get((a, s, "multipod"))
            if pod is None and mp is None:
                continue
            st = (pod or mp).get("status")
            if st == "skipped":
                n_skip += 1
                lines.append(f"| {a} | {s} | — | — | — | skipped ({(pod or mp).get('reason','')[:48]}…) |")
                continue
            ok = (pod or {}).get("status") == "ok" and (mp or {}).get("status") == "ok"
            n_ok += ok
            n_other += not ok
            peak = (mp or {}).get("memory", {}).get("peak_bytes")
            peak_gb = f"{peak/1e9:.2f}" if peak else "?"
            lines.append(
                f"| {a} | {s} | {(pod or {}).get('compile_s','?')}s | "
                f"{(mp or {}).get('compile_s','?')}s | {peak_gb} | "
                f"{'ok' if ok else 'INCOMPLETE'} |")
    lines.append("")
    lines.append(f"**{n_ok} cells compile on both meshes, {n_skip} skipped per "
                 f"the assignment rules, {n_other} incomplete.**")
    return "\n".join(lines)


def roofline_md(cells):
    rows = RL.table(cells, mesh="pod")
    lines = ["| arch | shape | compute µs | memory µs | collective µs | dominant | useful | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("dominant") == "SKIP":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — |")
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['compute_s']} | {r['memory_s']} | "
                f"{r['collective_s']} | **{r['dominant']}** | {r['useful_ratio']} | "
                f"{r['roofline_frac']} |")
    return "\n".join(lines)


def fig1_md():
    lines = ["**Matched per-rank shapes** (same batch — weight reads damp the v5e gain):",
             "",
             "| DP×TP | context | batch/rank | bf16 tok/s/chip | fp8 tok/s/chip | speedup | bound |",
             "|---|---|---|---|---|---|---|"]
    for r in figure1_model():
        lines.append(
            f"| {r['dp']}×{r['tp']} | {r['context']//1024}k | {r['batch_per_rank']} | "
            f"{r['bf16_tok_s']:.1f} | {r['fp8_tok_s']:.1f} | **{r['speedup']:.2f}×** | "
            f"{r['fp8_bound']} |")
    lines += ["", "**Capacity-mediated** (fixed HBM cache budget — the serving regime; "
              "FP8 fits ~1.79× more sequences):", "",
              "| TP | context | bf16→fp8 batch | speedup |", "|---|---|---|---|"]
    for r in figure1_capacity():
        lines.append(f"| {r['tp']} | {r['context']//1024}k | "
                     f"{r['bf16_batch']:.0f}→{r['fp8_batch']:.0f} | **{r['speedup']:.2f}×** |")
    return "\n".join(lines)


def splice(text, marker, payload):
    if marker not in text:
        print(f"marker {marker} missing!", file=sys.stderr)
        return text
    return text.replace(marker, payload)


def main():
    cells = load_cells()
    (ROOT / "results/dryrun/sweep.json").write_text(
        json.dumps(cells, indent=1, default=str))
    exp = (ROOT / "EXPERIMENTS.md").read_text()
    exp = splice(exp, "<!-- DRYRUN_SUMMARY -->", dryrun_summary(cells))
    exp = splice(exp, "<!-- ROOFLINE_TABLE -->", roofline_md(cells))
    exp = splice(exp, "<!-- FIG1_TABLE -->", fig1_md())
    (ROOT / "EXPERIMENTS.md").write_text(exp)
    print("EXPERIMENTS.md updated;",
          sum(1 for c in cells if c.get("status") == "ok"), "ok cells,",
          sum(1 for c in cells if c.get("status") == "skipped"), "skipped")


if __name__ == "__main__":
    main()
