#!/usr/bin/env bash
# Fast end-to-end CI gate: tier-1 test suite + real serving smoke runs
# (prefill -> quantized decode -> greedy generation) across the decode
# configurations that exercise distinct kernel paths:
#   * per-step decode loop and the fused scan-based path
#   * contiguous and paged (page-table) KV caches
#   * auto and fixed (--kv-splits 4) split-KV parallelism
#   * ref (einsum-twin), kernel (Pallas split-KV, interpret-mode on the
#     CPU runner), and shard-map (collective-free host-mesh region) decode
#     backends — `--backend kernel` runs the actual kernels inside the
#     jitted model decode
#   * temperature/top-k sampling through the fused scan
# The serve driver exits non-zero on non-finite logits (serve._check_finite),
# so a NaN anywhere in the quantized pipeline fails this script loudly.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

python -m repro.launch.serve --smoke --gen 4
python -m repro.launch.serve --smoke --gen 4 --fused
python -m repro.launch.serve --smoke --gen 4 --paged
python -m repro.launch.serve --smoke --gen 4 --paged --fused --kv-splits 4
python -m repro.launch.serve --smoke --gen 4 --kv-splits 4
python -m repro.launch.serve --smoke --gen 4 --backend kernel
python -m repro.launch.serve --smoke --gen 4 --backend kernel --paged
python -m repro.launch.serve --smoke --gen 4 --backend kernel --fused
python -m repro.launch.serve --smoke --gen 4 --backend shard-map
python -m repro.launch.serve --smoke --gen 4 --fused \
    --temperature 0.8 --top-k 8

echo "[ci_smoke] OK"
