#!/usr/bin/env bash
# Fast end-to-end CI gate: tier-1 test suite + real serving smoke runs
# (prefill -> quantized decode -> greedy generation) across the decode
# configurations that exercise distinct kernel paths:
#   * per-step decode loop and the fused scan-based path
#   * contiguous and paged (page-table) KV caches
#   * auto and fixed (--kv-splits 4) split-KV parallelism
#   * ref (einsum-twin), kernel (Pallas split-KV, interpret-mode on the
#     CPU runner), and shard-map (collective-free host-mesh region) decode
#     backends — `--backend kernel` runs the actual kernels inside the
#     jitted model decode
#   * temperature/top-k/top-p (nucleus) sampling through the fused scan
#   * the continuous-batching serving engine (--engine): staggered
#     arrivals over fewer slots than requests, prefix sharing on — the
#     driver exits non-zero on token divergence from the static-batch
#     generate oracle or on leaked pool pages after drain
#   * CHUNKED prefill admission (--prefill-chunk): a mixed long+short
#     prompt workload (--prompt-lens) with a per-step token budget —
#     parity-gated per prompt-length group against the generate oracle,
#     and the driver additionally fails if the engine compiled more
#     prefill variants than the power-of-two bucket count
#   * self-speculative decoding (--spec-draft): n-gram drafts verified by
#     one q_len>1 split-KV dispatch per step with rollback-by-rewind —
#     greedy runs parity-gated against the generate oracle, the spec trace
#     summarized (verify steps / accept rate) by scripts/trace_report.py
#   * fault drills (--inject): NaN-poisoned slot recovered via the jnp_ref
#     retry, and an injected preemption under --restartable restored from
#     an engine checkpoint — both parity-gated against the generate oracle
#   * the serving simulator (synthetic-arrival sweep + chunked-vs-
#     monolithic and fused-EOS-gating twin runs -> BENCH_serving.json,
#     uploaded as a CI artifact)
#   * the telemetry smoke (--trace-out + --log-json + --quant-health-every):
#     the engine exports a Chrome trace that scripts/trace_report.py
#     validates (one terminal instant per request track) and summarizes;
#     the trace is uploaded as a CI artifact next to BENCH_serving.json
# The serve driver exits non-zero on non-finite logits (serve._check_finite),
# so a NaN anywhere in the quantized pipeline fails this script loudly.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# tier-1 suite; wall-clock `timing`-marked sweeps run after, non-gating
python -m pytest -x -q -m "not timing"
python -m pytest -q -m timing || echo "[ci_smoke] timing smoke failed (non-gating)"

python -m repro.launch.serve --smoke --gen 4
python -m repro.launch.serve --smoke --gen 4 --fused
python -m repro.launch.serve --smoke --gen 4 --paged
python -m repro.launch.serve --smoke --gen 4 --paged --fused --kv-splits 4
python -m repro.launch.serve --smoke --gen 4 --kv-splits 4
python -m repro.launch.serve --smoke --gen 4 --backend kernel
python -m repro.launch.serve --smoke --gen 4 --backend kernel --paged
python -m repro.launch.serve --smoke --gen 4 --backend kernel --fused
python -m repro.launch.serve --smoke --gen 4 --backend shard-map
python -m repro.launch.serve --smoke --gen 4 --fused \
    --temperature 0.8 --top-k 8 --top-p 0.9 --seed 3

# raw-kernel-speed knobs: AMLA combine-free rescaling (exponent-add grid,
# parity-pinned vs FMA in tests/test_parity.py), an explicit --block-n on
# the contiguous kernel (2D autotune override), --block-n on a paged pool
# (repages: block_n is structurally the page size), and the P-Cast sink
# guard (--sink-tokens: raw-f32 first rows substituted at decode)
python -m repro.launch.serve --smoke --gen 4 --backend kernel --rescale amla
python -m repro.launch.serve --smoke --gen 4 --backend kernel \
    --rescale amla --kv-splits 4
python -m repro.launch.serve --smoke --gen 4 --backend kernel --block-n 16
python -m repro.launch.serve --smoke --gen 4 --backend kernel --paged \
    --block-n 64
python -m repro.launch.serve --smoke --gen 4 --backend kernel --sink-tokens 4
python -m repro.launch.serve --smoke --gen 4 --sink-tokens 4 --fused

# serving engine: continuous batching with slot recycling + prefix sharing,
# greedy-parity-gated against the static-batch generate path
python -m repro.launch.serve --smoke --gen 6 --engine --max-batch 2 \
    --arrival-gap 2 --seed 1
python -m repro.launch.serve --smoke --gen 4 --engine --backend kernel \
    --seed 1

# chunked prefill: mixed long+short prompts admitted chunk-by-chunk under a
# per-step token budget, alongside in-flight decodes — parity-gated per
# prompt-length group, prefill compiles bounded by the bucket count
python -m repro.launch.serve --smoke --gen 6 --engine --max-batch 3 \
    --batch 6 --prompt-lens 48,16,24 --prefill-chunk 16 \
    --prefill-budget 32 --arrival-gap 1 --seed 1
python -m repro.launch.serve --smoke --gen 4 --engine --backend kernel \
    --prefill-chunk 16 --prompt-lens 40,16 --batch 4 --max-batch 2 \
    --seed 2

# radix prefix cache: every prompt opens with the same 2-page system
# prompt (--shared-prefix); retained refcount-0 pages serve later arrivals'
# prefixes so their prefill chunks are skipped — parity-gated against the
# generate oracle, so a cache hit that changes one token fails loudly. The
# second run squeezes the device budget so retained pages offload to the
# host tier and come back through the async restore path, on the kernel
# backend (real gather/write of fp8 page payloads).
python -m repro.launch.serve --smoke --gen 6 --engine --max-batch 2 \
    --batch 4 --prompt-len 48 --shared-prefix 32 --prefix-cache-pages 24 \
    --prefill-chunk 16 --prefill-budget 32 --arrival-gap 8 --seed 5
python -m repro.launch.serve --smoke --gen 4 --engine --backend kernel \
    --batch 3 --prompt-len 48 --shared-prefix 48 --prefix-cache-pages 2 \
    --host-tier-pages 12 --prefill-chunk 16 --arrival-gap 10 --seed 2

# self-speculative decoding: n-gram drafts verified in ONE q_len>1 split-KV
# dispatch per step, rejected tail rolled back by rewinding seq_lens (pages
# never move). Greedy runs are parity-gated against the static-batch
# generate oracle by the driver, so a draft surviving an incorrect verify
# fails loudly; the sampled run pins the fold_in(count) key-alignment
# contract (sampling through the verify path == sequential sampling). Both
# ref and kernel backends decode through the same rank-4 verify kernel.
python -m repro.launch.serve --smoke --gen 8 --engine --max-batch 2 \
    --batch 4 --spec-draft 3 --arrival-gap 2 --seed 1 \
    --trace-out TRACE_spec.json
python scripts/trace_report.py TRACE_spec.json --expect-requests 4
python -m repro.launch.serve --smoke --gen 6 --engine --backend kernel \
    --batch 3 --spec-draft 2 --seed 2
python -m repro.launch.serve --smoke --gen 6 --engine --max-batch 2 \
    --batch 4 --spec-draft 3 --temperature 0.8 --top-k 8 --seed 3

# fault drills: (1) a NaN injected into one slot's logits mid-decode —
# the poisoned request must recover via the one-shot jnp_ref retry while
# every other request stays token-identical to the static-batch oracle;
# (2) an injected preemption under --restartable — the engine snapshots,
# run_with_restarts restores from the checkpoint, and the drained run
# must still be token-identical.  The driver exits non-zero on parity
# divergence, leaked pool pages, or zero completed requests.
python -m repro.launch.serve --smoke --gen 6 --engine --max-batch 2 \
    --arrival-gap 2 --seed 1 --inject nan_logits:4:1
python -m repro.launch.serve --smoke --gen 8 --engine --max-batch 2 \
    --arrival-gap 2 --seed 1 --restartable --inject preempt:5 \
    --ckpt-every 3

# telemetry smoke: the chunked mixed workload again with every probe armed —
# span tracer (virtual clock -> byte-stable Chrome trace), JSON event log,
# quant-health sampling. Parity is still gated by the driver; trace_report
# exits non-zero if the trace is structurally invalid or the request-track
# count is off. TRACE_serving.json is uploaded as a CI artifact.
python -m repro.launch.serve --smoke --gen 6 --engine --max-batch 3 \
    --batch 6 --prompt-lens 48,16,24 --prefill-chunk 16 \
    --prefill-budget 32 --arrival-gap 1 --seed 1 \
    --trace-out TRACE_serving.json --log-json --quant-health-every 4
python scripts/trace_report.py TRACE_serving.json --expect-requests 6

# synthetic-arrival serving sweep (rate x prefix-share) -> BENCH_serving.json
python benchmarks/serving_sim.py --requests 8 --seed 0 \
    --out BENCH_serving.json

echo "[ci_smoke] OK"
