#!/usr/bin/env bash
# Fast end-to-end CI gate: tier-1 test suite + a real serving smoke run
# (prefill -> quantized decode -> greedy generation), both the per-step
# decode loop and the fused scan-based path.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

python -m repro.launch.serve --smoke --gen 4
python -m repro.launch.serve --smoke --gen 4 --fused

echo "[ci_smoke] OK"
