#!/usr/bin/env python
"""Perf-regression gate: fresh BENCH_serving.json / BENCH_splitkv.json vs
the committed baselines in benchmarks/baselines/*.json.

CI reruns the benchmarks on every PR and this script fails the build if a
DETERMINISTIC headline metric regressed past its per-metric relative
tolerance. Only metrics that are reproducible run-to-run on any machine are
gated: virtual work units (seeded engine steps), modeled roofline numbers,
page counts, and token-identity booleans. Wall-clock numbers (tok/s,
seconds) are never gated — a loaded CI runner would page the author for
noise.

Metric spec (paths into the BENCH payloads, direction, tolerance) lives
HERE; the baselines only record values. Directions:

    lower   regression = fresh > base * (1 + tol)
    higher  regression = fresh < base * (1 - tol)
    true    the fresh value must be truthy (token-identity gates;
            the baseline value is informational)

Refreshing baselines after an intentional perf change (one command, run
from the repo root with fresh BENCH files in place):

    python benchmarks/serving_sim.py && \
    python -c "from benchmarks.kernel_perf import write_bench_splitkv; \
               write_bench_splitkv()" && \
    python scripts/bench_gate.py --refresh

then commit benchmarks/baselines/*.json with a line in the PR about WHY the
numbers moved.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
BASELINE_DIR = ROOT / "benchmarks" / "baselines"

# (bench file stem, baseline file name, dotted path, direction, rel tol)
# Paths index dicts by key and lists by integer.
METRICS: list[tuple[str, str, str, str, float]] = [
    # -- serving: chunked-prefill headline (virtual work units, seeded) ----
    ("BENCH_serving.json", "serving.json",
     "chunked_prefill.tokens_equal", "true", 0.0),
    ("BENCH_serving.json", "serving.json",
     "chunked_prefill.chunked.stall.tokens_per_step_max", "lower", 0.0),
    ("BENCH_serving.json", "serving.json",
     "chunked_prefill.chunked.ttft_work.short.p99", "lower", 0.05),
    ("BENCH_serving.json", "serving.json",
     "chunked_prefill.delta.stall_tokens_per_step_max", "higher", 0.0),
    ("BENCH_serving.json", "serving.json",
     "chunked_prefill.delta.ttft_work_p99_short", "higher", 0.05),
    # -- serving: radix prefix cache + host tiering ------------------------
    ("BENCH_serving.json", "serving.json",
     "prefix_cache.tokens_equal", "true", 0.0),
    ("BENCH_serving.json", "serving.json",
     "prefix_cache.cached.ttft_work_rest_mean", "lower", 0.05),
    ("BENCH_serving.json", "serving.json",
     "prefix_cache.delta.hit_ttft_work_mean", "higher", 0.05),
    ("BENCH_serving.json", "serving.json",
     "prefix_cache.cached.prefill_skipped_tokens", "higher", 0.0),
    ("BENCH_serving.json", "serving.json",
     "prefix_cache.tiered.pages_restored_host", "higher", 0.0),
    ("BENCH_serving.json", "serving.json",
     "prefix_cache.tiered.hbm_peak_resident_pages", "lower", 0.0),
    # -- serving: bounded prefix fetch (deterministic page counters) -------
    # fetch work must track chunk_start (pages below the chunk boundary),
    # never the pool capacity: the 2x-capacity twin pins the same count.
    ("BENCH_serving.json", "serving.json",
     "chunked_prefill.fetch_bound.pages_fetched_bounded", "lower", 0.0),
    ("BENCH_serving.json", "serving.json",
     "chunked_prefill.fetch_bound.fetch_savings", "higher", 0.0),
    ("BENCH_serving.json", "serving.json",
     "chunked_prefill.fetch_bound.capacity_independent", "true", 0.0),
    # -- serving: fused EOS gating ----------------------------------------
    ("BENCH_serving.json", "serving.json",
     "fused_eos_gating.tokens_equal", "true", 0.0),
    ("BENCH_serving.json", "serving.json",
     "fused_eos_gating.appends_saved", "higher", 0.0),
    # -- splitkv: modeled roofline sweep (pure math, fully deterministic) --
    # 128k-context rows are the paper's regime: early exit must keep
    # skipping half the blocks and the 8-way split keeps the chain short.
    ("BENCH_splitkv.json", "splitkv.json",
     "sweep.12.blocks_visited", "lower", 0.0),
    ("BENCH_splitkv.json", "splitkv.json",
     "sweep.12.early_exit_savings", "higher", 0.0),
    ("BENCH_splitkv.json", "splitkv.json",
     "sweep.15.critical_path_blocks", "lower", 0.0),
    ("BENCH_splitkv.json", "splitkv.json",
     "sweep.15.t_us", "lower", 0.01),
    ("BENCH_splitkv.json", "splitkv.json",
     "paged_sweep.0.early_exit_savings", "higher", 0.0),
    # -- splitkv: AMLA rescale accuracy + combine-free kernel parity -------
    ("BENCH_splitkv.json", "splitkv.json",
     "amla_sweep.2.within_tol", "true", 0.0),
    ("BENCH_splitkv.json", "splitkv.json",
     "amla_sweep.2.parity_ok", "true", 0.0),
    # -- splitkv: bounded prefix fetch (deterministic DMA page counts) -----
    # row 1 = (4-page table, chunk_start 17): one live page out of four
    ("BENCH_splitkv.json", "splitkv.json",
     "fetch_bound.1.parity_ok", "true", 0.0),
    ("BENCH_splitkv.json", "splitkv.json",
     "fetch_bound.1.bounded_pages", "lower", 0.0),
    ("BENCH_splitkv.json", "splitkv.json",
     "fetch_bound.1.dma_savings", "higher", 0.0),
    # -- serving: self-speculative decoding twin (seeded, greedy) ----------
    # speculation must stay a pure throughput optimization: identical
    # tokens, committed tokens per slot-step above the sequential-decode
    # ceiling of 1.0 (the baseline value pins > 1.0), and the same
    # workload drained in no more engine steps than the baseline run.
    ("BENCH_serving.json", "serving.json",
     "speculative.tokens_equal", "true", 0.0),
    ("BENCH_serving.json", "serving.json",
     "speculative.spec.accepted_tokens_per_step", "higher", 0.0),
    ("BENCH_serving.json", "serving.json",
     "speculative.spec.accept_rate", "higher", 0.0),
    ("BENCH_serving.json", "serving.json",
     "speculative.spec.accepted_tokens", "higher", 0.0),
    ("BENCH_serving.json", "serving.json",
     "speculative.delta.steps_saved", "higher", 0.0),
    # -- serving: unified telemetry (registry work metrics, probes armed) --
    # all-probes-on tiered shared-prefix run: the trace and registry must
    # be byte-identical across same-seed twins, and the registry's page
    # counters must keep reporting real cache/tier/fetch work.
    ("BENCH_serving.json", "serving.json",
     "telemetry.trace.deterministic", "true", 0.0),
    ("BENCH_serving.json", "serving.json",
     "telemetry.registry_deterministic", "true", 0.0),
    ("BENCH_serving.json", "serving.json",
     "telemetry.metrics.snapmla_cache_reused_pages", "higher", 0.0),
    ("BENCH_serving.json", "serving.json",
     "telemetry.metrics.snapmla_tier_restore_pages", "higher", 0.0),
    ("BENCH_serving.json", "serving.json",
     "telemetry.metrics.snapmla_fetch_pages_bounded_total", "lower", 0.0),
    ("BENCH_serving.json", "serving.json",
     "telemetry.metrics.snapmla_engine_prefill_skipped_tokens_total",
     "higher", 0.0),
]


def _assert_work_only() -> None:
    """The gate's contract: only deterministic WORK metrics are pinned.
    The metrics registry segregates wall-clock series under a ``wall``
    subtree (``registry.snapshot()`` / ``engine.metrics()["wall"]``), so any
    gated dotted path with a ``wall`` segment is a spec bug — fail loudly
    before it pages someone for CI-runner noise."""
    bad = [path for _, _, path, _, _ in METRICS
           if "wall" in path.split(".")]
    if bad:
        raise SystemExit("[bench_gate] wall-clock metric(s) in the gate "
                         f"spec (never gate wall time): {bad}")


def dig(payload, path: str):
    cur = payload
    for part in path.split("."):
        if isinstance(cur, list):
            cur = cur[int(part)]
        else:
            cur = cur[part]
    return cur


def load_fresh(bench_dir: pathlib.Path) -> dict[str, dict]:
    out = {}
    for stem in {m[0] for m in METRICS}:
        p = bench_dir / stem
        if not p.exists():
            raise SystemExit(f"[bench_gate] missing fresh benchmark {p} — "
                             "run the benchmarks first (see scripts/"
                             "ci_smoke.sh / --refresh docs in this file)")
        out[stem] = json.loads(p.read_text())
    return out


def refresh(bench_dir: pathlib.Path) -> int:
    """Extract the gated metrics from fresh BENCH files into the committed
    baselines (values only; spec stays in this file)."""
    fresh = load_fresh(bench_dir)
    BASELINE_DIR.mkdir(parents=True, exist_ok=True)
    by_file: dict[str, dict] = {}
    for stem, base_name, path, direction, tol in METRICS:
        entry = by_file.setdefault(base_name, {"source": stem, "metrics": {}})
        entry["metrics"][path] = {
            "value": dig(fresh[stem], path),
            "direction": direction,
            "rel_tolerance": tol,
        }
    for base_name, entry in sorted(by_file.items()):
        p = BASELINE_DIR / base_name
        p.write_text(json.dumps(entry, indent=2, sort_keys=True) + "\n")
        print(f"[bench_gate] wrote {p.relative_to(ROOT)} "
              f"({len(entry['metrics'])} metrics)")
    return 0


def gate(bench_dir: pathlib.Path) -> int:
    fresh = load_fresh(bench_dir)
    failures, checked = [], 0
    for stem, base_name, path, direction, tol in METRICS:
        base_path = BASELINE_DIR / base_name
        if not base_path.exists():
            raise SystemExit(f"[bench_gate] no committed baseline "
                             f"{base_path.relative_to(ROOT)} — run "
                             "`python scripts/bench_gate.py --refresh` "
                             "and commit the result")
        baseline = json.loads(base_path.read_text())
        rec = baseline["metrics"].get(path)
        if rec is None:
            failures.append(f"{base_name}:{path}: not in baseline — "
                            "refresh baselines")
            continue
        try:
            val = dig(fresh[stem], path)
        except (KeyError, IndexError, TypeError):
            failures.append(f"{stem}:{path}: missing from fresh run "
                            "(schema drift?)")
            continue
        base, checked = rec["value"], checked + 1
        if direction == "true":
            ok, detail = bool(val), f"must be true, got {val!r}"
        elif direction == "lower":
            bound = base * (1 + tol) if base >= 0 else base * (1 - tol)
            ok = val <= bound + 1e-12
            detail = f"{val} > {base} (+{tol:.0%} tol)"
        else:                                   # "higher"
            bound = base * (1 - tol) if base >= 0 else base * (1 + tol)
            ok = val >= bound - 1e-12
            detail = f"{val} < {base} (-{tol:.0%} tol)"
        mark = "ok  " if ok else "FAIL"
        print(f"[bench_gate] {mark} {path:<55} "
              f"fresh={val} base={base} ({direction})")
        if not ok:
            failures.append(f"{stem}:{path}: {detail}")
    if failures:
        print(f"\n[bench_gate] {len(failures)}/{checked} metrics REGRESSED:",
              file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        print("[bench_gate] intentional change? refresh baselines (see "
              "module docstring) and explain the move in the PR.",
              file=sys.stderr)
        return 1
    print(f"[bench_gate] PASS: {checked} deterministic headline metrics "
          "within tolerance")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench-dir", default=str(ROOT), help="directory with "
                    "fresh BENCH_*.json (default: repo root)")
    ap.add_argument("--refresh", action="store_true",
                    help="rewrite benchmarks/baselines/*.json from the "
                    "fresh BENCH files instead of gating")
    args = ap.parse_args()
    _assert_work_only()
    bench_dir = pathlib.Path(args.bench_dir)
    return refresh(bench_dir) if args.refresh else gate(bench_dir)


if __name__ == "__main__":
    sys.exit(main())
