"""Kernel-vs-oracle parity gates, promoted from benchmarks/kernel_perf.py
into a fast pytest marker so CI catches combine-kernel regressions without
running the full benchmark sweep:

    pytest -m parity

These call the *same* gate functions the benchmarks sit behind (the bench
records numbers only for a kernel that passes them), at reduced size and
with no timing loops.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.kernel_perf import (parity_gate_paged_splitkv,
                                    parity_gate_splitkv)
from repro.kernels.mla_decode import ref as R
from repro.kernels.mla_decode.kernel import lse_combine_pallas

pytestmark = pytest.mark.parity


def test_parity_splitkv_contiguous():
    """Contiguous split-KV kernel == pure-jnp split+combine oracle."""
    err = parity_gate_splitkv(B=2, H=8, d_c=64, d_r=16, N=512, bn=64,
                              splits=(1, 2, 4))
    assert err < 1e-4, err


def test_parity_splitkv_paged():
    """Paged split-KV kernel == paged oracle over a shuffled page pool."""
    err = parity_gate_paged_splitkv(B=2, H=8, d_c=64, d_r=16, N=512, page=64,
                                    splits=(1, 2, 4))
    assert err < 1e-4, err


@pytest.mark.parametrize("paged", [False, True], ids=["contiguous", "paged"])
def test_parity_model_kernel_backend_logits(paged):
    """`serve --backend kernel` == `--backend ref` at the LOGITS level on the
    smoke config: teacher-forced decode through the jitted model step with
    the Pallas backends pinned to the einsum-twin refs.

    The two backends share every quantization decision (same prepare_q, same
    per-block sigma_p plan), differing only in summation schedule — measured
    max deviation is ~3e-7 on the smoke config; the gate pins it at 1e-5 and
    requires the argmax token stream to match exactly."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models import transformer as T

    cfg = get_smoke_config("mla-7b")
    key = jax.random.PRNGKey(0)
    params = T.init_model(key, cfg)
    B, S, steps = 2, 16, 3
    tokens = jax.random.randint(key, (B, S + steps), 0, cfg.vocab_size,
                                jnp.int32)

    def run(c):
        state = T.init_decode_state(c, B, 32)
        _, state = T.prefill(params, c, tokens[:, :S], state)
        out = []
        for t in range(S, S + steps):
            lg, state = T.decode_step(params, c, tokens[:, t], state,
                                      jnp.full((B,), t, jnp.int32))
            out.append(np.asarray(lg))
        return np.stack(out)

    ref = run(dataclasses.replace(cfg, kv_paged=paged, decode_backend="ref"))
    ker = run(dataclasses.replace(cfg, kv_paged=paged, use_kernels=True,
                                  decode_backend="kernel"))
    np.testing.assert_allclose(ker, ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(ker.argmax(-1), ref.argmax(-1))


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "none"])
def test_parity_qlen_verify_kernel_vs_ref(fmt):
    """Rank-4 (q_len > 1 verify) split-KV kernel == its jnp verify oracle —
    the same gate test_qlen_verify runs on the full grid, kept here under
    the parity marker so `pytest -m parity` covers the speculative-verify
    path too."""
    from repro.core.kvcache import CacheConfig, init_mla_cache, mla_prefill
    from repro.kernels.mla_decode.kernel import mla_decode_splitkv_pallas

    B, H, N, bn, Q = 2, 4, 256, 64, 3
    cfg = CacheConfig(fmt=fmt, page_size=bn)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    cache = mla_prefill(init_mla_cache(cfg, B, N, 32, 16), cfg,
                        jax.random.normal(ks[0], (B, N, 32)) * 2,
                        jax.random.normal(ks[1], (B, N, 16)) * 25)
    cache = cache._replace(seq_lens=jnp.asarray([200, 64], jnp.int32))
    q8, qr, sq = R.prepare_q(jax.random.normal(ks[2], (B, Q * H, 32)),
                             jax.random.normal(ks[3], (B, Q * H, 16)) * 5,
                             fmt)
    q4 = (q8.reshape(B, Q, H, 32), qr.reshape(B, Q, H, 16),
          sq.reshape(B, Q, H))
    cargs = (cache.content, cache.rope.astype(jnp.float32), cache.scale,
             cache.seq_lens)
    o_k, lse_k = mla_decode_splitkv_pallas(
        *q4, *cargs, softmax_scale=0.1, num_splits=2, block_n=bn, fmt=fmt)
    o_r, lse_r = R.snapmla_decode_splitkv_ref(
        *q4, *cargs, softmax_scale=0.1, num_splits=2, block_n=bn, fmt=fmt)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lse_k), np.asarray(lse_r),
                               rtol=1e-5, atol=1e-5)


def test_parity_verify_step_matches_sequential_decode():
    """Model-level speculative-verify gate: ONE verify_step dispatch over a
    [B, K] candidate block returns, at every row, logits matching K
    teacher-forced sequential decode_step calls — same positions, same
    quantized cache bytes. The argmax token stream must match exactly;
    this is the property the engine's longest-accepted-prefix rule (and its
    rollback-by-rewind) relies on."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models import transformer as T

    cfg = dataclasses.replace(get_smoke_config("mla-7b"), kv_paged=True)
    key = jax.random.PRNGKey(0)
    params = T.init_model(key, cfg)
    B, S, K = 2, 16, 3
    tokens = jax.random.randint(key, (B, S + K), 0, cfg.vocab_size,
                                jnp.int32)

    state = T.init_decode_state(cfg, B, 32)
    _, state = T.prefill(params, cfg, tokens[:, :S], state)
    seq = []
    for t in range(S, S + K):
        lg, state = T.decode_step(params, cfg, tokens[:, t], state,
                                  jnp.full((B,), t, jnp.int32))
        seq.append(np.asarray(lg))
    seq = np.stack(seq, axis=1)                       # [B, K, V]

    state2 = T.init_decode_state(cfg, B, 32)
    _, state2 = T.prefill(params, cfg, tokens[:, :S], state2)
    ver, _ = T.verify_step(params, cfg, tokens[:, S:S + K], state2,
                           jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(ver), seq, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ver).argmax(-1),
                                  seq.argmax(-1))


@pytest.mark.parametrize("num_splits", [1, 2, 4])
def test_parity_amla_kernel_vs_ref(num_splits):
    """Kernel-AMLA == ref-AMLA: the exponent-add rescale and the combine-free
    split emission are EXACT transforms, so the Pallas path must match its
    jnp twin to interpret-mode float tolerance at every split count."""
    from benchmarks.kernel_perf import _splitkv_inputs
    from repro.kernels.mla_decode.ops import snapmla_decode

    cache, (q_c8, q_r, sq), scale = _splitkv_inputs(2, 8, 64, 16, 512, 64)
    o_k, lse_k = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=scale,
                                block_n=64, num_splits=num_splits,
                                rescale="amla")
    o_r, lse_r = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=scale,
                                block_n=64, num_splits=num_splits,
                                use_kernel=False, rescale="amla")
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(lse_k), np.asarray(lse_r),
                               atol=1e-5)


@pytest.mark.parametrize("num_splits", [1, 2, 4])
def test_parity_amla_vs_fma_bit_tolerance(num_splits):
    """AMLA vs FMA on the same FP8 inputs: the power-of-two (m, sigma_p)
    grid changes only the P-quantization rounding points, so the modes agree
    to ~2% rel under FP8 (pinned at 5%) and the LSE — which AMLA reassembles
    from the integer grid exactly — agrees to float tolerance."""
    from benchmarks.kernel_perf import _splitkv_inputs
    from repro.kernels.mla_decode.ops import snapmla_decode

    cache, (q_c8, q_r, sq), scale = _splitkv_inputs(2, 8, 64, 16, 512, 64)
    o_f, lse_f = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=scale,
                                block_n=64, num_splits=num_splits,
                                rescale="fma")
    o_a, lse_a = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=scale,
                                block_n=64, num_splits=num_splits,
                                rescale="amla")
    rel = float(jnp.max(jnp.abs(o_a - o_f)) / (jnp.max(jnp.abs(o_f)) + 1e-12))
    assert rel < 0.05, rel
    np.testing.assert_allclose(np.asarray(lse_a), np.asarray(lse_f),
                               rtol=1e-5, atol=1e-5)


def test_parity_amla_unquantized_tight():
    """With fmt='none' there is no P-quantization, so AMLA's only deviation
    from FMA is the exact power-of-two regrouping — the modes must agree to
    float tolerance, pinning the exponent-add trick itself as exact."""
    from benchmarks.kernel_perf import _splitkv_inputs
    from repro.kernels.mla_decode.ops import snapmla_decode

    cache, (q_c8, q_r, sq), scale = _splitkv_inputs(2, 8, 64, 16, 512, 64)
    kw = dict(softmax_scale=scale, block_n=64, num_splits=2, fmt="none")
    o_f, _ = snapmla_decode(q_c8, q_r, sq, cache, rescale="fma", **kw)
    o_a, _ = snapmla_decode(q_c8, q_r, sq, cache, rescale="amla", **kw)
    np.testing.assert_allclose(np.asarray(o_a), np.asarray(o_f),
                               rtol=1e-5, atol=1e-5)


def test_parity_amla_paged():
    """Paged AMLA kernel == paged AMLA ref over a shuffled page pool."""
    from benchmarks.kernel_perf import _scatter_to_pool, _splitkv_inputs
    from repro.kernels.mla_decode.kernel import mla_decode_paged_splitkv_pallas
    from repro.kernels.mla_decode import ref as kref

    cache, (q_c8, q_r, sq), scale = _splitkv_inputs(2, 8, 64, 16, 512, 64,
                                                    seed=1)
    pool_c, pool_r, pool_s, pt = _scatter_to_pool(cache, 64)
    for s in (1, 2, 4):
        o_k, _ = mla_decode_paged_splitkv_pallas(
            q_c8, q_r, sq, pool_c, pool_r, pool_s, pt, cache.seq_lens,
            softmax_scale=scale, num_splits=s, rescale="amla")
        o_r, _ = kref.snapmla_decode_paged_splitkv_ref(
            q_c8, q_r, sq, pool_c, pool_r, pool_s, pt, cache.seq_lens,
            softmax_scale=scale, num_splits=s, rescale="amla")
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   atol=1e-4)


def test_parity_lse_combine():
    """The combine kernel itself == the max-shift combine reference — the
    narrowest gate on the shared merge path both split kernels feed."""
    key = jax.random.PRNGKey(0)
    B, S, H, d_c = 3, 4, 8, 32
    o_p = jax.random.normal(key, (B, S, H, d_c))
    lse_p = jax.random.normal(jax.random.PRNGKey(1), (B, S, H)) * 3
    # include a neutral (empty-split) partial in one row
    lse_p = lse_p.at[0, -1].set(R.NEG_INF)
    o_p = o_p.at[0, -1].set(0.0)
    o_k, lse_k = lse_combine_pallas(o_p, lse_p)
    o_r, lse_r = R.lse_combine_ref(o_p, lse_p)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(lse_k), np.asarray(lse_r),
                               rtol=1e-6, atol=1e-6)
