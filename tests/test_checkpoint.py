"""Checkpoint/restart: atomicity, latest(), elastic reshard, resume."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import (latest_checkpoint, load_checkpoint,
                                         save_checkpoint)
from repro.configs import get_smoke_config
from repro.launch.train import train_loop


def test_roundtrip_and_latest(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones(4, jnp.bfloat16), jnp.int32(7)]}
    save_checkpoint(str(tmp_path), 5, tree, {"note": "x"})
    save_checkpoint(str(tmp_path), 9, tree)
    latest = latest_checkpoint(str(tmp_path))
    assert latest.endswith("step_00000009")
    loaded, manifest = load_checkpoint(latest, tree)
    assert manifest["step"] == 9
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_no_tmp_dirs_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(2)})
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_elastic_reshard_on_load(tmp_path):
    """Checkpoints are logical: loading with explicit shardings re-places."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    save_checkpoint(str(tmp_path), 1, tree)
    sh = {"w": NamedSharding(mesh, P("data"))}
    loaded, _ = load_checkpoint(latest_checkpoint(str(tmp_path)), tree, sh)
    assert loaded["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(loaded["w"]), np.arange(8))


def test_train_resume_continues(tmp_path):
    cfg = get_smoke_config("qwen2.5-3b")
    out1 = train_loop(cfg, steps=6, batch=4, seq=16, ckpt_dir=str(tmp_path),
                      ckpt_every=3, log_every=100)
    assert latest_checkpoint(str(tmp_path)) is not None
    # "restart": loop resumes from latest checkpoint, runs only remaining steps
    out2 = train_loop(cfg, steps=10, batch=4, seq=16, ckpt_dir=str(tmp_path),
                      ckpt_every=100, log_every=100)
    assert out2["final_step"] == 10
    assert len(out2["losses"]) == 4          # 6..9 only
