"""Observability layer: metrics registry, span tracer, probes.

The acceptance criteria of the telemetry PR, as tests:
  * same-seed engine runs export BYTE-identical Chrome traces and
    identical registry work snapshots (ref AND kernel backends);
  * the trace's request spans reproduce the engine's reported TTFT /
    latency exactly (virtual clock: ``ts // TICKS_PER_STEP`` = step);
  * a checkpoint -> restore -> resume run continues the SAME trace —
    byte-identical to the uninterrupted run, with no duplicate span ids;
  * arming every probe (tracer + quant health) does not perturb a single
    greedy token.
"""
import dataclasses
import importlib.util
import json
import pathlib

import jax
import numpy as np
import pytest

from repro.checkpoint.checkpoint import latest_checkpoint
from repro.configs import get_smoke_config
from repro.core.kvcache import page_aligned_capacity
from repro.models import transformer as T
from repro.obs import (MetricsRegistry, SpanTracer, TICKS_PER_STEP,
                       validate_chrome_trace)
from repro.serving import EngineConfig, Request, ServingEngine

CHUNK = 16


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("mla-7b")
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _workload(cfg, n=3, S=24, gen=5):
    key = jax.random.PRNGKey(11)
    prompts = np.asarray(jax.random.randint(key, (n, S), 0, cfg.vocab_size,
                                            jax.numpy.int32))
    return [Request(rid=i, prompt=prompts[i], max_new=gen, arrival=float(i))
            for i in range(n)], S, gen


def _engine(cfg, params, S, gen, *, tracer=None, health=0, chunk=CHUNK):
    span = page_aligned_capacity(S + gen, cfg.page_size) // cfg.page_size
    ccfg = dataclasses.replace(cfg, prefill_chunk=chunk) if chunk else cfg
    return ServingEngine(ccfg, params, EngineConfig(
        max_batch=2, max_pages_per_seq=span, quant_health_every=health),
        tracer=tracer)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_specs_names_and_conflicts():
    r = MetricsRegistry()
    c = r.counter("snapmla_test_things_total", "things")
    assert r.counter("snapmla_test_things_total", "things") is c   # idempotent
    with pytest.raises(ValueError):                # same name, different spec
        r.gauge("snapmla_test_things_total", "things")
    with pytest.raises(ValueError):                # naming convention
        r.counter("bad-name", "x")
    with pytest.raises(ValueError):                # counters only go up
        c.inc(-1)


def test_registry_wall_segregation_and_labels():
    r = MetricsRegistry()
    r.counter("snapmla_test_work_total", "w").inc(3)
    r.counter("snapmla_test_wall_seconds_total", "t", wall=True).inc(0.5)
    lab = r.counter("snapmla_test_kinds_total", "k", labels=("kind",))
    lab.labels(kind="a").inc()
    lab.labels(kind="b").inc(2)
    snap = r.snapshot()
    assert "snapmla_test_wall_seconds_total" not in snap["work"]
    assert "wall" not in snap                      # only on request
    full = r.snapshot(include_wall=True)
    assert full["wall"]["snapmla_test_wall_seconds_total"]["values"][""] == 0.5
    assert snap["work"]["snapmla_test_kinds_total"]["values"] == \
        {"a": 1, "b": 2}


def test_registry_state_roundtrip():
    r = MetricsRegistry()
    r.counter("snapmla_test_a_total", "a").inc(7)
    r.gauge("snapmla_test_b_level", "b").set(-2.5)
    h = r.histogram("snapmla_test_c_width", "c")
    h.observe(3)
    h.observe(900)
    lab = r.counter("snapmla_test_d_total", "d", labels=("kind",))
    lab.labels(kind="x").inc(4)
    state = r.export_state()
    r2 = MetricsRegistry()
    r2.counter("snapmla_test_a_total", "a")
    r2.gauge("snapmla_test_b_level", "b")
    r2.histogram("snapmla_test_c_width", "c")
    r2.counter("snapmla_test_d_total", "d", labels=("kind",))
    r2.restore_state(state)
    assert r2.export_state() == state
    assert r2.snapshot() == r.snapshot()


# ---------------------------------------------------------------------------
# tracer (no engine)
# ---------------------------------------------------------------------------

def test_tracer_virtual_clock_spans_and_validation():
    tr = SpanTracer()
    tr.req_begin(0, "QUEUED", tr.ts(2, 50), args={"prompt_len": 8})
    with pytest.raises(RuntimeError):             # double-open is a bug
        tr.req_begin(0, "PREFILL", tr.ts(3))
    tr.req_transition(0, "PREFILL", tr.ts(3, 50))
    tr.req_chunk(0, 3)
    tr.req_transition(0, "DECODE", tr.ts(4, 445))
    with pytest.raises(RuntimeError):             # open span at export
        tr.chrome_payload()
    tr.req_end(0, tr.ts(6, 860))
    tr.req_instant(0, "DONE", tr.ts(6, 860), args={"tokens": 3})
    tr.step_phase(5, "decode", args={"rows": 1})
    tr.counter(5, "pages", {"in_use": 2, "free": 6})
    payload = tr.chrome_payload()
    stats = validate_chrome_trace(payload, expect_requests=1)
    assert stats["requests"] == 1 and stats["terminal"] == 1
    # every request-event timestamp integer-divides back to its step
    spans = {e["name"]: e for e in payload["traceEvents"]
             if e.get("ph") == "X" and e.get("pid") == 2}
    assert spans["QUEUED"]["ts"] // TICKS_PER_STEP == 2
    assert spans["DECODE"]["ts"] // TICKS_PER_STEP == 4
    assert (spans["DECODE"]["ts"] + spans["DECODE"]["dur"]) \
        // TICKS_PER_STEP == 6


def test_validate_rejects_leaked_and_malformed_tracks():
    tr = SpanTracer()
    tr.req_begin(0, "QUEUED", tr.ts(0))
    tr.req_end(0, tr.ts(1))                       # closed span, NO terminal
    with pytest.raises(ValueError, match="terminal"):
        validate_chrome_trace(tr.chrome_payload())
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"traceEvents": []})


# ---------------------------------------------------------------------------
# engine integration: determinism + exact TTFT/latency reproduction
# ---------------------------------------------------------------------------

def _traced_run(cfg, params, *, kernels=False):
    c = dataclasses.replace(cfg, use_kernels=True, decode_backend="kernel") \
        if kernels else cfg
    reqs, S, gen = _workload(c)
    tracer = SpanTracer()
    engine = _engine(c, params, S, gen, tracer=tracer, health=2)
    results = engine.run(reqs)
    return engine, tracer, results


@pytest.mark.parametrize("kernels", [False, True],
                         ids=["ref_backend", "kernel_backend"])
def test_trace_and_registry_byte_identical_across_seeded_runs(model,
                                                              kernels):
    cfg, params = model
    e1, t1, res1 = _traced_run(cfg, params, kernels=kernels)
    e2, t2, res2 = _traced_run(cfg, params, kernels=kernels)
    assert [r.tokens for r in res1] == [r.tokens for r in res2]
    dump1 = json.dumps(t1.chrome_payload(), sort_keys=True)
    assert dump1 == json.dumps(t2.chrome_payload(), sort_keys=True)
    assert e1.telemetry() == e2.telemetry()       # work subtree only

    # the trace REPRODUCES the engine's own timing numbers exactly
    payload = t1.chrome_payload()
    validate_chrome_trace(payload, expect_requests=len(res1))
    ev = [e for e in payload["traceEvents"] if e.get("pid") == 2]
    for r in res1:
        mine = [e for e in ev if e.get("tid") == r.rid]
        queued = min(e["ts"] for e in mine if e.get("name") == "QUEUED")
        first = next(e["ts"] for e in mine
                     if e.get("name") == "FIRST_TOKEN")
        done = next(e["ts"] for e in mine if e.get("name") == "DONE")
        assert first // TICKS_PER_STEP - queued // TICKS_PER_STEP \
            == r.ttft_steps
        assert done // TICKS_PER_STEP - queued // TICKS_PER_STEP \
            == r.latency_steps


def test_probes_do_not_perturb_greedy_tokens(model):
    """Arming the tracer + quant-health probe must not change a token
    (observability is read-only: probes never touch the decode state)."""
    cfg, params = model
    reqs, S, gen = _workload(cfg)
    plain = _engine(cfg, params, S, gen)          # no tracer, no probe
    base = [r.tokens for r in plain.run(reqs)]
    _, _, res = _traced_run(cfg, params)
    assert [r.tokens for r in res] == base


def test_quant_probe_sees_resident_fp8_pages(model):
    cfg, params = model
    reqs, S, gen = _workload(cfg)
    engine = _engine(cfg, params, S, gen, health=2)
    engine.run(reqs)
    probe = engine.quant_probe
    assert probe is not None and len(probe.samples) >= 2
    mid = [s for s in probe.samples if s["resident_pages"] > 0]
    assert mid, "no quant sample saw live pages"
    assert all(s["scale_max"] > 0 for s in mid)
    assert all(0.0 <= s["clip_rate_max"] <= 1.0 for s in mid)


# ---------------------------------------------------------------------------
# checkpoint -> restore -> resume: one contiguous trace
# ---------------------------------------------------------------------------

def test_restore_continues_same_trace(model, tmp_path):
    cfg, params = model
    reqs, S, gen = _workload(cfg)

    tracer_a = SpanTracer()
    engine_a = _engine(cfg, params, S, gen, tracer=tracer_a)
    res_a = engine_a.run(reqs, ckpt_dir=str(tmp_path), ckpt_every=3)
    full = json.dumps(tracer_a.chrome_payload(), sort_keys=True)

    # fresh engine adopts a MID-RUN snapshot (earliest retained — the
    # latest one may already be drained), resubmits the same workload
    # (seen rids skip) and drains: the resumed trace must be byte-identical
    # to the uninterrupted one — same span ids, no duplicates, contiguous
    assert latest_checkpoint(str(tmp_path)) is not None
    ckpt = sorted(p for p in tmp_path.iterdir()
                  if p.name.startswith("step_"))[0]
    tracer_b = SpanTracer()
    engine_b = _engine(cfg, params, S, gen, tracer=tracer_b)
    engine_b.restore(str(ckpt))
    assert engine_b.step_idx > 0
    assert len(engine_b.scheduler.finished) < len(reqs)   # truly mid-run
    reqs2, _, _ = _workload(cfg)          # fresh objects, same workload
    res_b = engine_b.run(reqs2)
    assert [r.tokens for r in res_b] == [r.tokens for r in res_a]
    assert json.dumps(tracer_b.chrome_payload(), sort_keys=True) == full
    sids = [e["sid"] for e in tracer_b._events]
    assert len(sids) == len(set(sids)), "duplicate span ids after restore"
    assert engine_b.faults["restores"] == 1


# ---------------------------------------------------------------------------
# trace_report consumes what the tracer exports
# ---------------------------------------------------------------------------

def _load_trace_report():
    path = pathlib.Path(__file__).resolve().parent.parent \
        / "scripts" / "trace_report.py"
    spec = importlib.util.spec_from_file_location("trace_report", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_trace_report_tables_match_engine(model):
    cfg, params = model
    _, tracer, results = _traced_run(cfg, params)
    report = _load_trace_report()
    payload = tracer.chrome_payload()
    summary = report.summarize(payload)
    by_rid = {r["rid"]: r for r in summary["requests"]}
    assert sorted(by_rid) == [r.rid for r in results]
    for r in results:
        row = by_rid[r.rid]
        assert row["ttft"] == r.ttft_steps
        assert row["latency"] == r.latency_steps
        assert row["outcome"] == "DONE"
        assert row["chunks"] >= 1                 # chunked admission traced
    assert summary["occupancy"]["in_use_peak"] > 0
    text = report.render(summary,
                         validate_chrome_trace(payload,
                                               expect_requests=len(results)))
    assert "ttft" in text and "pages: peak" in text
