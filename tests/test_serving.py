"""Serving engine subsystem: free-list page allocator (property-style
alloc/free interleavings, refcounted prefix sharing), FCFS scheduler, and
the continuous-batching engine — greedy token parity with the static-batch
``generate`` oracle (monolithic AND chunked prefill, contiguous AND paged
oracle variants, prompt lengths straddling chunk boundaries), clean drain
(free list == pool capacity), prefix sharing's page savings, the
O(log chunk) prefill recompile bound, evict-to-requeue under pool pressure,
and seeded-sampling reproducibility."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.kvcache import page_aligned_capacity
from repro.launch.serve import generate
from repro.launch.steps import bucket_for, chunk_buckets
from repro.models import transformer as T
from repro.serving import (EngineConfig, PageAllocator, Request,
                           ServingEngine, Status)

PAGE = 16


# ---------------------------------------------------------------------------
# allocator: free list + refcounts
# ---------------------------------------------------------------------------

def _prompt(rng, n):
    return rng.integers(0, 1000, size=n, dtype=np.int32)


def test_allocator_reserves_scratch_page():
    a = PageAllocator(8, PAGE)
    assert a.capacity == 7
    pages = a.alloc_prompt(_prompt(np.random.default_rng(0), 7 * PAGE))
    assert pages is not None and 0 not in pages
    assert a.num_free == 0
    a.free(pages)
    assert a.num_free == a.capacity


def test_allocator_admission_gate_and_partial_page():
    a = PageAllocator(4, PAGE)           # 3 allocatable
    rng = np.random.default_rng(1)
    assert a.alloc_prompt(_prompt(rng, 4 * PAGE)) is None   # needs 4 > 3
    pages = a.alloc_prompt(_prompt(rng, PAGE + 1))          # partial tail
    assert pages is not None and len(pages) == 2
    assert not a.can_admit(_prompt(rng, 2 * PAGE))          # only 1 free
    assert a.can_admit(_prompt(rng, PAGE))


def test_allocator_double_free_raises():
    a = PageAllocator(4, PAGE)
    pages = a.alloc_prompt(_prompt(np.random.default_rng(2), PAGE))
    a.free(pages)
    with pytest.raises(ValueError, match="double free"):
        a.free(pages)


def test_prefix_sharing_maps_same_physical_pages():
    a = PageAllocator(16, PAGE)
    rng = np.random.default_rng(3)
    prefix = _prompt(rng, 2 * PAGE)
    p1 = np.concatenate([prefix, _prompt(rng, PAGE // 2)])
    p2 = np.concatenate([prefix, _prompt(rng, PAGE // 2)])
    pages1 = a.alloc_prompt(p1)
    pages2 = a.alloc_prompt(p2)
    # the two full prefix pages are shared, refcount 2
    assert pages1[:2] == pages2[:2]
    assert a.stats().shared == 2
    assert a.pages_saved_by_sharing == 2
    # the partial boundary page is copy-on-write: private per request
    assert pages1[2] != pages2[2]
    # refcounted free: pages survive the first release, die on the second
    a.free(pages1)
    assert set(pages2) <= set(range(1, 16)) and a.stats().shared == 0
    assert a.num_in_use == 3                 # p2's three pages still live
    a.free(pages2)
    assert a.num_free == a.capacity
    a.check_invariants()


def test_prefix_registry_purged_at_refcount_zero():
    a = PageAllocator(16, PAGE)
    rng = np.random.default_rng(4)
    prefix = _prompt(rng, PAGE)
    pages1 = a.alloc_prompt(prefix.copy())
    a.free(pages1)
    # registry must not retain freed pages: a re-alloc gets a fresh mapping
    # (no stale sharing with a page whose contents are gone)
    pages2 = a.alloc_prompt(prefix.copy())
    assert a.pages_saved_by_sharing == 0
    a.free(pages2)
    assert a.num_free == a.capacity


def test_unshared_full_prompt_pages_registered_for_later_requests():
    a = PageAllocator(16, PAGE)
    rng = np.random.default_rng(5)
    long = _prompt(rng, 3 * PAGE)
    first = a.alloc_prompt(long)
    second = a.alloc_prompt(long.copy())     # identical page-aligned prompt
    assert second[:3] == first[:3]           # all three full pages shared
    a.free(first)
    a.free(second)
    assert a.num_free == a.capacity


def test_allocator_random_interleavings_keep_invariants():
    """Property-style: random alloc_prompt/grow/free interleavings (some
    prompts share prefixes) never double-assign a page, and a full drain
    returns every page to the free list."""
    rng = np.random.default_rng(6)
    a = PageAllocator(24, PAGE)
    prefixes = [_prompt(rng, 2 * PAGE) for _ in range(3)]
    live: list[list[int]] = []
    for _ in range(300):
        op = rng.random()
        if op < 0.5:
            if rng.random() < 0.5:
                body = _prompt(rng, int(rng.integers(1, 3 * PAGE)))
            else:
                body = np.concatenate([
                    prefixes[int(rng.integers(len(prefixes)))],
                    _prompt(rng, int(rng.integers(1, PAGE)))])
            pages = a.alloc_prompt(body)
            if pages is not None:
                live.append(pages)
        elif op < 0.75 and live:
            extra = a.grow(1)
            if extra is not None:
                live[int(rng.integers(len(live)))].extend(extra)
        elif live:
            a.free(live.pop(int(rng.integers(len(live)))))
        a.check_invariants()
        in_use = {p for run in live for p in run}
        assert len(in_use) == a.num_in_use      # no page assigned twice
    for run in live:
        a.free(run)
    a.check_invariants()
    assert a.num_free == a.capacity


# ---------------------------------------------------------------------------
# engine: parity, drain, sharing, eviction
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("mla-7b")      # pure-MLA, page_size 16
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _span_pages(cfg, S, gen):
    return page_aligned_capacity(S + gen, cfg.page_size) // cfg.page_size


def _mk_prompts(cfg, key, B, S):
    return np.asarray(jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                         jnp.int32))


def _drained_clean(engine):
    m = engine.metrics()
    return m["pages"]["free"] == m["pages"]["capacity"]


def test_engine_greedy_parity_with_generate(model):
    """Continuous-batching output is token-identical (greedy) to the
    static-batch generate path for the same prompts/gen lengths, with fewer
    slots than requests (slot recycling on the fly)."""
    cfg, params = model
    B, S, gen = 4, 24, 8
    prompts = _mk_prompts(cfg, jax.random.PRNGKey(1), B, S)
    ref = np.asarray(generate(cfg, params, jnp.asarray(prompts), gen)[0])

    engine = ServingEngine(cfg, params, EngineConfig(
        max_batch=2, max_pages_per_seq=_span_pages(cfg, S, gen)))
    results = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=0.0) for i in range(B)])
    assert [r.status for r in results] == ["done"] * B
    for r in results:
        assert r.tokens == list(ref[r.rid]), f"request {r.rid} diverged"
    assert _drained_clean(engine)


def test_engine_parity_with_staggered_arrivals_and_prefix_sharing(model):
    """Arrivals mid-flight join slots whose neighbours are at different
    positions; prefix sharing maps common prompt pages. Tokens must still
    match the static-batch oracle exactly, and the drain must be clean."""
    cfg, params = model
    S, gen = 40, 8                       # 2 full pages + a partial page
    key = jax.random.PRNGKey(2)
    common = np.asarray(jax.random.randint(key, (32,), 0, cfg.vocab_size,
                                           jnp.int32))
    prompts = np.stack([
        np.concatenate([common, _mk_prompts(cfg, jax.random.fold_in(key, i),
                                            1, S - 32)[0]])
        for i in range(4)])
    ref = np.asarray(generate(cfg, params, jnp.asarray(prompts), gen)[0])

    engine = ServingEngine(cfg, params, EngineConfig(
        max_batch=2, max_pages_per_seq=_span_pages(cfg, S, gen)))
    results = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=float([0, 0, 3, 5][i]))
                          for i in range(4)])
    for r in results:
        assert r.status == "done" and r.tokens == list(ref[r.rid])
    m = engine.metrics()
    assert m["pages"]["saved_by_sharing"] > 0
    assert _drained_clean(engine)


def test_engine_prefix_sharing_allocates_fewer_pages(model):
    """The same shared-prefix workload allocates strictly fewer pages with
    sharing on than off (the ISSUE's acceptance criterion)."""
    cfg, params = model
    S, gen = 40, 4
    key = jax.random.PRNGKey(3)
    common = np.asarray(jax.random.randint(key, (32,), 0, cfg.vocab_size,
                                           jnp.int32))
    prompts = np.stack([
        np.concatenate([common, _mk_prompts(cfg, jax.random.fold_in(key, i),
                                            1, S - 32)[0]])
        for i in range(4)])

    def run(share):
        engine = ServingEngine(cfg, params, EngineConfig(
            max_batch=4, max_pages_per_seq=_span_pages(cfg, S, gen),
            prefix_sharing=share))
        engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                            arrival=0.0) for i in range(4)])
        return engine.metrics()["pages"]

    shared, unshared = run(True), run(False)
    assert shared["saved_by_sharing"] == 6      # 2 pages x 3 later requests
    assert shared["total_allocs"] < unshared["total_allocs"]
    assert shared["peak_in_use"] < unshared["peak_in_use"]


def test_engine_evict_to_requeue_completes_everyone(model):
    """A pool too small for all admitted requests to grow forces eviction —
    but eviction is REQUEUE, not loss: the victim's pages are freed, its
    generated tokens are kept, it replay-prefills on readmission, and every
    request finishes with its full token count. No pages leak."""
    cfg, params = model
    S, gen = 20, 14                       # grows past 2 pages into a 3rd
    prompts = _mk_prompts(cfg, jax.random.PRNGKey(4), 3, S)
    engine = ServingEngine(cfg, params, EngineConfig(
        max_batch=2, max_pages_per_seq=3, n_pages=6,   # capacity 5 < 2x3
        prefix_sharing=False))
    results = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=0.0) for i in range(3)])
    assert engine.evictions > 0
    assert engine.metrics()["requeues"] == engine.evictions
    assert [r.status for r in results] == ["done"] * 3
    assert all(len(r.tokens) == gen for r in results)
    assert sum(r.requeues for r in results) == engine.evictions
    assert _drained_clean(engine)


def test_engine_requeued_request_resumes_from_pending_token(model):
    """The requeued victim's pre-eviction tokens survive verbatim: its final
    output must START with the tokens it had already emitted (replay-prefill
    reconstructs the cache, the pending sampled token is fed back in, and
    no token is ever re-sampled)."""
    cfg, params = model
    S, gen = 20, 14
    prompts = _mk_prompts(cfg, jax.random.PRNGKey(4), 3, S)

    emitted: dict[int, list[int]] = {}
    orig_requeue = ServingEngine._requeue

    def spy(self, req):
        emitted.setdefault(req.rid, list(req.out_tokens))
        orig_requeue(self, req)

    engine = ServingEngine(cfg, params, EngineConfig(
        max_batch=2, max_pages_per_seq=3, n_pages=6, prefix_sharing=False))
    engine._requeue = spy.__get__(engine)
    results = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=0.0) for i in range(3)])
    assert emitted, "workload must actually trigger a requeue"
    for rid, prefix in emitted.items():
        final = next(r.tokens for r in results if r.rid == rid)
        assert final[:len(prefix)] == prefix


def test_engine_eos_and_timing_fields(model):
    cfg, params = model
    B, S, gen = 2, 24, 8
    prompts = _mk_prompts(cfg, jax.random.PRNGKey(5), B, S)
    ref = np.asarray(generate(cfg, params, jnp.asarray(prompts), gen)[0])
    eos = int(ref[0][2])                  # force an early stop on request 0
    engine = ServingEngine(cfg, params, EngineConfig(
        max_batch=2, max_pages_per_seq=_span_pages(cfg, S, gen), eos_id=eos))
    results = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=0.0) for i in range(B)])
    r0 = results[0]
    assert r0.tokens[-1] == eos and len(r0.tokens) <= 3
    for r in results:
        assert r.ttft_steps >= 0 and r.latency_steps >= r.ttft_steps
        assert r.latency_s >= r.ttft_s >= 0.0
    assert _drained_clean(engine)


def test_engine_sampled_runs_reproducible_per_seed(model):
    """--seed threading: the same seeded workload + sampling config yields
    identical tokens run-to-run (per-request keys folded by token index)."""
    cfg, params = model
    S, gen = 24, 6
    prompts = _mk_prompts(cfg, jax.random.PRNGKey(6), 3, S)

    def run():
        engine = ServingEngine(cfg, params, EngineConfig(
            max_batch=2, max_pages_per_seq=_span_pages(cfg, S, gen),
            temperature=0.8, top_k=8, top_p=0.9, seed=7))
        res = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=float(i)) for i in range(3)])
        return [r.tokens for r in res]

    assert run() == run()


def test_engine_submit_validation(model):
    cfg, params = model
    engine = ServingEngine(cfg, params, EngineConfig(
        max_batch=1, max_pages_per_seq=2))
    big = np.zeros((3 * cfg.page_size,), np.int32)
    with pytest.raises(ValueError, match="page-table width"):
        engine.submit(Request(rid=0, prompt=big, max_new=4))
    with pytest.raises(ValueError, match="max_new"):
        engine.submit(Request(rid=1, prompt=big[:4], max_new=0))


def test_engine_rejects_non_mla_arch():
    cfg = get_smoke_config("llama3.2-3b")
    with pytest.raises(ValueError, match="pure-MLA"):
        ServingEngine(cfg, {}, EngineConfig())


# ---------------------------------------------------------------------------
# chunked prefill: parity, buckets, recompile bound, budget
# ---------------------------------------------------------------------------

CHUNK = 16


def _chunked(cfg, chunk=CHUNK):
    return dataclasses.replace(cfg, prefill_chunk=chunk)


def _oracle(cfg, params, prompts, gen, paged=False):
    """Static-batch greedy oracle, per prompt-length group (ragged-safe);
    ``paged=True`` runs the paged static decode path instead."""
    ocfg = dataclasses.replace(cfg, kv_paged=paged)
    by_len: dict[int, list[int]] = {}
    for i, p in enumerate(prompts):
        by_len.setdefault(len(p), []).append(i)
    ref: dict[int, list[int]] = {}
    for rids in by_len.values():
        batch = jnp.asarray(np.stack([prompts[i] for i in rids]))
        toks, _ = generate(ocfg, params, batch, gen)
        for row, rid in zip(np.asarray(toks), rids):
            ref[rid] = list(row)
    return ref


def test_chunk_buckets_rule():
    assert chunk_buckets(16) == [1, 2, 4, 8, 16]
    assert chunk_buckets(24) == [1, 2, 4, 8, 16, 24]
    assert chunk_buckets(1) == [1]
    assert bucket_for(5, 16) == 8
    assert bucket_for(16, 16) == 16
    assert bucket_for(17, 24) == 24
    with pytest.raises(ValueError):
        bucket_for(17, 16)


def test_chunked_engine_token_identical_to_generate(model):
    """The tentpole parity pin: chunked-prefill engine output is
    token-identical to the static-batch ``generate`` oracle — BOTH oracle
    cache layouts (contiguous and paged run the same greedy tokens) — for
    prompt lengths straddling the chunk boundary (chunk-1, chunk, chunk+1,
    2.5 chunks)."""
    cfg, params = model
    gen = 6
    lens = [CHUNK - 1, CHUNK, CHUNK + 1, 2 * CHUNK + CHUNK // 2]
    key = jax.random.PRNGKey(11)
    prompts = [_mk_prompts(cfg, jax.random.fold_in(key, i), 1, n)[0]
               for i, n in enumerate(lens)]
    ref = _oracle(cfg, params, prompts, gen)
    assert ref == _oracle(cfg, params, prompts, gen, paged=True)

    span = page_aligned_capacity(max(lens) + gen, cfg.page_size) \
        // cfg.page_size
    engine = ServingEngine(_chunked(cfg), params, EngineConfig(
        max_batch=2, max_pages_per_seq=span))
    results = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=0.0) for i in range(len(lens))])
    for r in results:
        assert r.status == "done" and r.tokens == ref[r.rid], \
            f"request {r.rid} (len {lens[r.rid]}) diverged"
    assert _drained_clean(engine)


def test_chunked_engine_parity_staggered_arrivals_and_sharing(model):
    """Chunks of late arrivals interleave with in-flight decodes (the whole
    point of chunked prefill) and shared prefix pages are REWRITTEN
    chunk-by-chunk bit-identically — tokens still match the oracle and the
    drain stays clean."""
    cfg, params = model
    gen = 6
    key = jax.random.PRNGKey(12)
    common = _mk_prompts(cfg, key, 1, 2 * CHUNK)[0]       # 2 shared chunks
    prompts = [np.concatenate([common, _mk_prompts(
        cfg, jax.random.fold_in(key, i), 1, CHUNK // 2 + i)[0]])
        for i in range(4)]
    ref = _oracle(cfg, params, prompts, gen)
    span = page_aligned_capacity(max(len(p) for p in prompts) + gen,
                                 cfg.page_size) // cfg.page_size
    engine = ServingEngine(_chunked(cfg), params, EngineConfig(
        max_batch=2, max_pages_per_seq=span))
    results = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=float([0, 0, 3, 7][i]))
                          for i in range(4)])
    for r in results:
        assert r.status == "done" and r.tokens == ref[r.rid]
    assert engine.metrics()["pages"]["saved_by_sharing"] > 0
    assert _drained_clean(engine)


def test_chunked_prefill_recompiles_bounded_by_buckets(model):
    """The recompile bound: across a workload mixing MANY distinct prompt
    lengths, the engine may trace at most one chunked-prefill variant per
    bucket (powers of two up to the chunk) — never one per prompt length.
    The monolithic engine on the same workload traces one variant per
    distinct length (the regression chunking fixes)."""
    cfg, params = model
    gen = 4
    lens = [7, 9, 15, 16, 17, 23, 33, 40]       # 8 distinct lengths
    key = jax.random.PRNGKey(13)
    prompts = [_mk_prompts(cfg, jax.random.fold_in(key, i), 1, n)[0]
               for i, n in enumerate(lens)]
    span = page_aligned_capacity(max(lens) + gen, cfg.page_size) \
        // cfg.page_size

    def run(chunk):
        engine = ServingEngine(
            dataclasses.replace(cfg, prefill_chunk=chunk), params,
            EngineConfig(max_batch=3, max_pages_per_seq=span))
        engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                            arrival=float(i)) for i in range(len(lens))])
        assert _drained_clean(engine)
        return engine.prefill_traces

    assert run(CHUNK) <= len(chunk_buckets(CHUNK))      # <= 5
    assert run(0) == len(set(lens))                     # monolithic: 8


def test_chunked_budget_bounds_per_step_prefill_work(model):
    """``prefill_budget`` caps the prefill tokens any engine step processes
    (the decode-stall bound), while the FCFS head's guaranteed chunk keeps
    prefill progressing."""
    cfg, params = model
    gen = 4
    key = jax.random.PRNGKey(14)
    prompts = [_mk_prompts(cfg, jax.random.fold_in(key, i), 1, 3 * CHUNK)[0]
               for i in range(3)]
    span = page_aligned_capacity(3 * CHUNK + gen, cfg.page_size) \
        // cfg.page_size
    engine = ServingEngine(_chunked(cfg), params, EngineConfig(
        max_batch=3, max_pages_per_seq=span, prefill_budget=CHUNK))
    results = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=0.0) for i in range(3)])
    assert [r.status for r in results] == ["done"] * 3
    series = engine.metrics()["prefill"]["tokens_series"]
    assert max(series) <= CHUNK
    assert sum(series) == 3 * 3 * CHUNK        # every prompt fully prefilled


def test_chunked_engine_sampled_reproducible_and_kernel_backend(model):
    """Chunked admission composes with sampling (seeded reproducibility —
    per-request keys are arrival-independent) and with the Pallas kernel
    backend (paged fetch-dequant feeds the chunk attention)."""
    cfg, params = model
    S, gen = CHUNK + CHUNK // 2, 5
    prompts = _mk_prompts(cfg, jax.random.PRNGKey(15), 3, S)
    span = page_aligned_capacity(S + gen, cfg.page_size) // cfg.page_size

    def run_sampled():
        engine = ServingEngine(_chunked(cfg), params, EngineConfig(
            max_batch=2, max_pages_per_seq=span,
            temperature=0.8, top_k=8, top_p=0.9, seed=7))
        res = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=float(i)) for i in range(3)])
        return [r.tokens for r in res]

    assert run_sampled() == run_sampled()

    # kernel backend (Pallas split-KV decode + paged fetch-dequant feeding
    # the chunk attention) must be token-identical to the SAME chunked
    # engine on the ref backend — engine-to-engine, so the comparison
    # isolates the kernel backend (the model-level parity gates pin
    # kernel-vs-ref logits to 1e-5 already)
    def run_engine(c):
        engine = ServingEngine(c, params, EngineConfig(
            max_batch=2, max_pages_per_seq=span))
        res = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=0.0) for i in range(3)])
        assert _drained_clean(engine)
        return [r.tokens for r in res]

    kcfg = dataclasses.replace(_chunked(cfg), use_kernels=True,
                               decode_backend="kernel")
    assert run_engine(kcfg) == run_engine(_chunked(cfg))


def test_scheduler_fcfs_no_head_of_line_skip():
    """A small follow-up request must NOT jump a large queue-head the
    allocator cannot yet cover (strict FCFS)."""
    from repro.serving.scheduler import Scheduler
    rng = np.random.default_rng(7)
    a = PageAllocator(4, PAGE)            # 3 allocatable pages
    held = a.alloc_prompt(_prompt(rng, 2 * PAGE))   # 1 page left
    sched = Scheduler(max_batch=2)
    sched.submit(Request(rid=0, prompt=_prompt(rng, 2 * PAGE), max_new=2))
    sched.submit(Request(rid=1, prompt=_prompt(rng, PAGE), max_new=2))
    assert sched.admit(a, step=0) == []   # head blocked -> nobody admitted
    a.free(held)
    admitted = sched.admit(a, step=1)
    assert [r.rid for r in admitted] == [0, 1]


# ---------------------------------------------------------------------------
# speculative decoding: token identity, acceptance, eviction storm
# ---------------------------------------------------------------------------

def _spec_prompts(cfg, key, n_random, S):
    """n_random random prompts + one highly repetitive prompt (the traffic
    n-gram drafting wins on)."""
    rand = _mk_prompts(cfg, key, n_random, S)
    pat = np.asarray(([5, 9, 2, 7] * S)[:S], np.int32)
    return np.concatenate([rand, pat[None]], 0)


def test_engine_spec_greedy_token_identity(model):
    """spec_draft_len > 0 must be a pure throughput optimization: greedy
    output is token-identical to the non-speculative engine on a mixed
    random + repetitive workload, drafts actually get accepted, and the
    drain is clean."""
    cfg, params = model
    S, gen = 24, 12
    prompts = _spec_prompts(cfg, jax.random.PRNGKey(11), 3, S)
    span = _span_pages(cfg, S, gen)

    def run(spec):
        engine = ServingEngine(cfg, params, EngineConfig(
            max_batch=2, max_pages_per_seq=span, spec_draft_len=spec))
        res = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=0.0) for i in range(len(prompts))])
        assert _drained_clean(engine)
        return {r.rid: (r.status, r.tokens) for r in res}, engine.metrics()

    base, m0 = run(0)
    spec, m = run(3)
    assert base == spec
    assert not m0["speculative"]["enabled"]
    sp = m["speculative"]
    assert sp["enabled"] and sp["verify_steps"] > 0
    assert sp["accepted_tokens"] > 0, "repetitive prompt must accept drafts"
    assert 0.0 < sp["accept_rate"] <= 1.0
    # per-slot-step: non-speculative decode is exactly 1.0 by construction,
    # so > 1.0 certifies real multi-token commits
    assert sp["accepted_tokens_per_step"] > 1.0


def test_engine_spec_sampled_token_identity(model):
    """Seeded sampling through the verify path: row t's sampling key is the
    same fold_in(count) key sequential decode would use, so sampled output
    is token-identical too (not just greedy)."""
    cfg, params = model
    S, gen = 24, 10
    prompts = _spec_prompts(cfg, jax.random.PRNGKey(12), 2, S)
    span = _span_pages(cfg, S, gen)

    def run(spec):
        engine = ServingEngine(cfg, params, EngineConfig(
            max_batch=2, max_pages_per_seq=span, spec_draft_len=spec,
            temperature=0.8, top_k=8, seed=7))
        res = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=0.0) for i in range(len(prompts))])
        assert _drained_clean(engine)
        return {r.rid: (r.status, r.tokens) for r in res}

    assert run(0) == run(3)


def test_engine_spec_eviction_storm_never_registers_draft_bytes(model):
    """Seeded eviction/requeue storm with speculation live: a pool too small
    for every request forces evictions MID-speculation. Pins

      * every (re)admission registers only prompt + COMMITTED tokens in the
        prefix tree — rejected draft bytes (written into tail pages by the
        verify block, then rolled back by rewind) never enter alloc_prompt,
      * requeue rewinds happen BEFORE pages are freed (the run would corrupt
        or crash otherwise), and proposer state is dropped with them,
      * everyone completes with full token counts, token-identical to the
        non-speculative engine under the same pressure, and the drain is
        clean."""
    cfg, params = model
    S, gen = 20, 14                        # grows past 2 pages into a 3rd
    prompts = _spec_prompts(cfg, jax.random.PRNGKey(13), 2, S)

    def run(spec):
        engine = ServingEngine(cfg, params, EngineConfig(
            max_batch=2, max_pages_per_seq=3, n_pages=6,   # capacity 5 < 2x3
            prefix_sharing=True, spec_draft_len=spec))
        seen: list[np.ndarray] = []
        orig = engine.allocator.alloc_prompt

        def spy(prompt):
            seen.append(np.asarray(prompt).copy())
            return orig(prompt)

        engine.allocator.alloc_prompt = spy
        res = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=0.0) for i in range(len(prompts))])
        assert engine.evictions > 0, "workload must actually evict"
        assert [r.status for r in res] == ["done"] * len(prompts)
        assert all(len(r.tokens) == gen for r in res)
        assert _drained_clean(engine)
        # every registered byte stream is a prefix of prompt + the FINAL
        # committed tokens: a rejected draft byte would diverge from the
        # committed stream at its position
        final = {r.rid: np.concatenate([prompts[r.rid],
                                        np.asarray(r.tokens, np.int32)])
                 for r in res}
        for reg in seen:
            assert any(len(reg) <= len(f)
                       and np.array_equal(reg, f[:len(reg)])
                       for f in final.values()), \
                "alloc_prompt saw bytes outside any committed stream"
        if engine.proposer is not None:
            # _drop_spec_state ran for every retire/requeue: nothing lingers
            assert engine.proposer.export_state() == {}
        return {r.rid: r.tokens for r in res}

    assert run(3) == run(0)


def test_engine_spec_checkpoint_roundtrip_carries_proposer_state(model):
    """Snapshot/restore mid-run: the proposer's per-slot adaptive state
    rides the checkpoint, and the restored engine finishes token-identical
    to an uninterrupted speculative run."""
    import tempfile

    cfg, params = model
    S, gen = 24, 12
    prompts = _spec_prompts(cfg, jax.random.PRNGKey(14), 1, S)
    span = _span_pages(cfg, S, gen)
    ecfg = EngineConfig(max_batch=2, max_pages_per_seq=span, spec_draft_len=3)
    reqs = lambda: [Request(rid=i, prompt=prompts[i], max_new=gen,
                            arrival=0.0) for i in range(len(prompts))]

    straight = ServingEngine(cfg, params, ecfg)
    want = {r.rid: r.tokens for r in straight.run(reqs())}

    with tempfile.TemporaryDirectory() as d:
        eng1 = ServingEngine(cfg, params, ecfg)
        for req in reqs():
            eng1.submit(req)
        for _ in range(6):
            eng1.step()
        path = eng1.snapshot(d)
        assert eng1.proposer.export_state(), "mid-run slots must exist"

        eng2 = ServingEngine(cfg, params, ecfg)
        eng2.restore(path)
        assert eng2.proposer.export_state() == eng1.proposer.export_state()
        results = eng2.run([])
        assert {r.rid: r.tokens for r in results} == want
        assert _drained_clean(eng2)
