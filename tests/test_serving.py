"""Serving engine subsystem: free-list page allocator (property-style
alloc/free interleavings, refcounted prefix sharing), FCFS scheduler, and
the continuous-batching engine — greedy token parity with the static-batch
``generate`` oracle, clean drain (free list == pool capacity), prefix
sharing's page savings, eviction under pool pressure, and seeded-sampling
reproducibility."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.kvcache import page_aligned_capacity
from repro.launch.serve import generate
from repro.models import transformer as T
from repro.serving import (EngineConfig, PageAllocator, Request,
                           ServingEngine, Status)

PAGE = 16


# ---------------------------------------------------------------------------
# allocator: free list + refcounts
# ---------------------------------------------------------------------------

def _prompt(rng, n):
    return rng.integers(0, 1000, size=n, dtype=np.int32)


def test_allocator_reserves_scratch_page():
    a = PageAllocator(8, PAGE)
    assert a.capacity == 7
    pages = a.alloc_prompt(_prompt(np.random.default_rng(0), 7 * PAGE))
    assert pages is not None and 0 not in pages
    assert a.num_free == 0
    a.free(pages)
    assert a.num_free == a.capacity


def test_allocator_admission_gate_and_partial_page():
    a = PageAllocator(4, PAGE)           # 3 allocatable
    rng = np.random.default_rng(1)
    assert a.alloc_prompt(_prompt(rng, 4 * PAGE)) is None   # needs 4 > 3
    pages = a.alloc_prompt(_prompt(rng, PAGE + 1))          # partial tail
    assert pages is not None and len(pages) == 2
    assert not a.can_admit(_prompt(rng, 2 * PAGE))          # only 1 free
    assert a.can_admit(_prompt(rng, PAGE))


def test_allocator_double_free_raises():
    a = PageAllocator(4, PAGE)
    pages = a.alloc_prompt(_prompt(np.random.default_rng(2), PAGE))
    a.free(pages)
    with pytest.raises(ValueError, match="double free"):
        a.free(pages)


def test_prefix_sharing_maps_same_physical_pages():
    a = PageAllocator(16, PAGE)
    rng = np.random.default_rng(3)
    prefix = _prompt(rng, 2 * PAGE)
    p1 = np.concatenate([prefix, _prompt(rng, PAGE // 2)])
    p2 = np.concatenate([prefix, _prompt(rng, PAGE // 2)])
    pages1 = a.alloc_prompt(p1)
    pages2 = a.alloc_prompt(p2)
    # the two full prefix pages are shared, refcount 2
    assert pages1[:2] == pages2[:2]
    assert a.stats().shared == 2
    assert a.pages_saved_by_sharing == 2
    # the partial boundary page is copy-on-write: private per request
    assert pages1[2] != pages2[2]
    # refcounted free: pages survive the first release, die on the second
    a.free(pages1)
    assert set(pages2) <= set(range(1, 16)) and a.stats().shared == 0
    assert a.num_in_use == 3                 # p2's three pages still live
    a.free(pages2)
    assert a.num_free == a.capacity
    a.check_invariants()


def test_prefix_registry_purged_at_refcount_zero():
    a = PageAllocator(16, PAGE)
    rng = np.random.default_rng(4)
    prefix = _prompt(rng, PAGE)
    pages1 = a.alloc_prompt(prefix.copy())
    a.free(pages1)
    # registry must not retain freed pages: a re-alloc gets a fresh mapping
    # (no stale sharing with a page whose contents are gone)
    pages2 = a.alloc_prompt(prefix.copy())
    assert a.pages_saved_by_sharing == 0
    a.free(pages2)
    assert a.num_free == a.capacity


def test_unshared_full_prompt_pages_registered_for_later_requests():
    a = PageAllocator(16, PAGE)
    rng = np.random.default_rng(5)
    long = _prompt(rng, 3 * PAGE)
    first = a.alloc_prompt(long)
    second = a.alloc_prompt(long.copy())     # identical page-aligned prompt
    assert second[:3] == first[:3]           # all three full pages shared
    a.free(first)
    a.free(second)
    assert a.num_free == a.capacity


def test_allocator_random_interleavings_keep_invariants():
    """Property-style: random alloc_prompt/grow/free interleavings (some
    prompts share prefixes) never double-assign a page, and a full drain
    returns every page to the free list."""
    rng = np.random.default_rng(6)
    a = PageAllocator(24, PAGE)
    prefixes = [_prompt(rng, 2 * PAGE) for _ in range(3)]
    live: list[list[int]] = []
    for _ in range(300):
        op = rng.random()
        if op < 0.5:
            if rng.random() < 0.5:
                body = _prompt(rng, int(rng.integers(1, 3 * PAGE)))
            else:
                body = np.concatenate([
                    prefixes[int(rng.integers(len(prefixes)))],
                    _prompt(rng, int(rng.integers(1, PAGE)))])
            pages = a.alloc_prompt(body)
            if pages is not None:
                live.append(pages)
        elif op < 0.75 and live:
            extra = a.grow(1)
            if extra is not None:
                live[int(rng.integers(len(live)))].extend(extra)
        elif live:
            a.free(live.pop(int(rng.integers(len(live)))))
        a.check_invariants()
        in_use = {p for run in live for p in run}
        assert len(in_use) == a.num_in_use      # no page assigned twice
    for run in live:
        a.free(run)
    a.check_invariants()
    assert a.num_free == a.capacity


# ---------------------------------------------------------------------------
# engine: parity, drain, sharing, eviction
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("mla-7b")      # pure-MLA, page_size 16
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _span_pages(cfg, S, gen):
    return page_aligned_capacity(S + gen, cfg.page_size) // cfg.page_size


def _mk_prompts(cfg, key, B, S):
    return np.asarray(jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                         jnp.int32))


def _drained_clean(engine):
    m = engine.metrics()
    return m["pages"]["free"] == m["pages"]["capacity"]


def test_engine_greedy_parity_with_generate(model):
    """Continuous-batching output is token-identical (greedy) to the
    static-batch generate path for the same prompts/gen lengths, with fewer
    slots than requests (slot recycling on the fly)."""
    cfg, params = model
    B, S, gen = 4, 24, 8
    prompts = _mk_prompts(cfg, jax.random.PRNGKey(1), B, S)
    ref = np.asarray(generate(cfg, params, jnp.asarray(prompts), gen)[0])

    engine = ServingEngine(cfg, params, EngineConfig(
        max_batch=2, max_pages_per_seq=_span_pages(cfg, S, gen)))
    results = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=0.0) for i in range(B)])
    assert [r.status for r in results] == ["done"] * B
    for r in results:
        assert r.tokens == list(ref[r.rid]), f"request {r.rid} diverged"
    assert _drained_clean(engine)


def test_engine_parity_with_staggered_arrivals_and_prefix_sharing(model):
    """Arrivals mid-flight join slots whose neighbours are at different
    positions; prefix sharing maps common prompt pages. Tokens must still
    match the static-batch oracle exactly, and the drain must be clean."""
    cfg, params = model
    S, gen = 40, 8                       # 2 full pages + a partial page
    key = jax.random.PRNGKey(2)
    common = np.asarray(jax.random.randint(key, (32,), 0, cfg.vocab_size,
                                           jnp.int32))
    prompts = np.stack([
        np.concatenate([common, _mk_prompts(cfg, jax.random.fold_in(key, i),
                                            1, S - 32)[0]])
        for i in range(4)])
    ref = np.asarray(generate(cfg, params, jnp.asarray(prompts), gen)[0])

    engine = ServingEngine(cfg, params, EngineConfig(
        max_batch=2, max_pages_per_seq=_span_pages(cfg, S, gen)))
    results = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=float([0, 0, 3, 5][i]))
                          for i in range(4)])
    for r in results:
        assert r.status == "done" and r.tokens == list(ref[r.rid])
    m = engine.metrics()
    assert m["pages"]["saved_by_sharing"] > 0
    assert _drained_clean(engine)


def test_engine_prefix_sharing_allocates_fewer_pages(model):
    """The same shared-prefix workload allocates strictly fewer pages with
    sharing on than off (the ISSUE's acceptance criterion)."""
    cfg, params = model
    S, gen = 40, 4
    key = jax.random.PRNGKey(3)
    common = np.asarray(jax.random.randint(key, (32,), 0, cfg.vocab_size,
                                           jnp.int32))
    prompts = np.stack([
        np.concatenate([common, _mk_prompts(cfg, jax.random.fold_in(key, i),
                                            1, S - 32)[0]])
        for i in range(4)])

    def run(share):
        engine = ServingEngine(cfg, params, EngineConfig(
            max_batch=4, max_pages_per_seq=_span_pages(cfg, S, gen),
            prefix_sharing=share))
        engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                            arrival=0.0) for i in range(4)])
        return engine.metrics()["pages"]

    shared, unshared = run(True), run(False)
    assert shared["saved_by_sharing"] == 6      # 2 pages x 3 later requests
    assert shared["total_allocs"] < unshared["total_allocs"]
    assert shared["peak_in_use"] < unshared["peak_in_use"]


def test_engine_evicts_under_pool_pressure_and_still_drains(model):
    """A pool too small for all admitted requests to grow forces eviction:
    the youngest active request is retired EVICTED, everyone else finishes,
    and no pages leak."""
    cfg, params = model
    S, gen = 20, 14                       # grows past 2 pages into a 3rd
    prompts = _mk_prompts(cfg, jax.random.PRNGKey(4), 3, S)
    engine = ServingEngine(cfg, params, EngineConfig(
        max_batch=2, max_pages_per_seq=3, n_pages=6,   # capacity 5 < 2x3
        prefix_sharing=False))
    results = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=0.0) for i in range(3)])
    statuses = sorted(r.status for r in results)
    assert engine.evictions > 0 and "evicted" in statuses
    assert "done" in statuses             # older requests survived FCFS
    assert _drained_clean(engine)


def test_engine_eos_and_timing_fields(model):
    cfg, params = model
    B, S, gen = 2, 24, 8
    prompts = _mk_prompts(cfg, jax.random.PRNGKey(5), B, S)
    ref = np.asarray(generate(cfg, params, jnp.asarray(prompts), gen)[0])
    eos = int(ref[0][2])                  # force an early stop on request 0
    engine = ServingEngine(cfg, params, EngineConfig(
        max_batch=2, max_pages_per_seq=_span_pages(cfg, S, gen), eos_id=eos))
    results = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=0.0) for i in range(B)])
    r0 = results[0]
    assert r0.tokens[-1] == eos and len(r0.tokens) <= 3
    for r in results:
        assert r.ttft_steps >= 0 and r.latency_steps >= r.ttft_steps
        assert r.latency_s >= r.ttft_s >= 0.0
    assert _drained_clean(engine)


def test_engine_sampled_runs_reproducible_per_seed(model):
    """--seed threading: the same seeded workload + sampling config yields
    identical tokens run-to-run (per-request keys folded by token index)."""
    cfg, params = model
    S, gen = 24, 6
    prompts = _mk_prompts(cfg, jax.random.PRNGKey(6), 3, S)

    def run():
        engine = ServingEngine(cfg, params, EngineConfig(
            max_batch=2, max_pages_per_seq=_span_pages(cfg, S, gen),
            temperature=0.8, top_k=8, top_p=0.9, seed=7))
        res = engine.run([Request(rid=i, prompt=prompts[i], max_new=gen,
                                  arrival=float(i)) for i in range(3)])
        return [r.tokens for r in res]

    assert run() == run()


def test_engine_submit_validation(model):
    cfg, params = model
    engine = ServingEngine(cfg, params, EngineConfig(
        max_batch=1, max_pages_per_seq=2))
    big = np.zeros((3 * cfg.page_size,), np.int32)
    with pytest.raises(ValueError, match="page-table width"):
        engine.submit(Request(rid=0, prompt=big, max_new=4))
    with pytest.raises(ValueError, match="max_new"):
        engine.submit(Request(rid=1, prompt=big[:4], max_new=0))


def test_engine_rejects_non_mla_arch():
    cfg = get_smoke_config("llama3.2-3b")
    with pytest.raises(ValueError, match="pure-MLA"):
        ServingEngine(cfg, {}, EngineConfig())


def test_scheduler_fcfs_no_head_of_line_skip():
    """A small follow-up request must NOT jump a large queue-head the
    allocator cannot yet cover (strict FCFS)."""
    from repro.serving.scheduler import Scheduler
    rng = np.random.default_rng(7)
    a = PageAllocator(4, PAGE)            # 3 allocatable pages
    held = a.alloc_prompt(_prompt(rng, 2 * PAGE))   # 1 page left
    sched = Scheduler(max_batch=2)
    sched.submit(Request(rid=0, prompt=_prompt(rng, 2 * PAGE), max_new=2))
    sched.submit(Request(rid=1, prompt=_prompt(rng, PAGE), max_new=2))
    assert sched.admit(a, step=0) == []   # head blocked -> nobody admitted
    a.free(held)
    admitted = sched.admit(a, step=1)
    assert [r.rid for r in admitted] == [0, 1]
