"""Chaos suite: deterministic fault injection against the serving engine.

Every test here drives REAL faults through the real engine (no mocks): NaN
quarantine with the jnp_ref graceful-degradation retry, backend-raise
fallback, forced allocator exhaustion, deadline cancellation, bounded-queue
load shedding, checkpoint/restore, and end-to-end preemption under
``run_with_restarts``. The recurring acceptance gate is ISOLATION: after any
injected fault, every surviving request's tokens are identical to its
fault-free twin and the drained engine holds zero leaked pages.

Also home to the allocator invariant storms (seeded adversarial alloc /
free / share interleavings; hypothesis-driven when hypothesis is
installed, seeded-rng otherwise): no double free, refcounts consistent
with the prefix registry, and free ∪ allocated == all pages after drain.

Marked ``chaos`` so CI can run it as its own job: ``pytest -m chaos``.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised on clean envs
    HAVE_HYPOTHESIS = False

from repro.checkpoint import checkpoint as CK
from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.runtime.fault_tolerance import (PreemptionHandler, RestartPolicy,
                                           run_with_restarts)
from repro.serving import (EngineConfig, FaultEvent, FaultPlan,
                           PageAllocator, Request, ServingEngine)

pytestmark = pytest.mark.chaos

PAGE = 16


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("mla-7b")      # pure-MLA, page_size 16
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, n=3, pages=2, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=pages * cfg.page_size,
                         dtype=np.int32) for _ in range(n)]


def _reqs(prompts, gen, **kw):
    return [Request(rid=i, prompt=p.copy(), max_new=gen, arrival=float(i),
                    **kw) for i, p in enumerate(prompts)]


def _ecfg(**kw):
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_pages_per_seq", 4)
    return EngineConfig(seed=0, **kw)


def _drained(engine):
    m = engine.metrics()
    return m["pages"]["free"] == m["pages"]["capacity"]


@pytest.fixture(scope="module")
def clean_run(model):
    """Fault-free twin every isolation gate compares against."""
    cfg, params = model
    engine = ServingEngine(cfg, params, _ecfg())
    results = engine.run(_reqs(_prompts(cfg), gen=8))
    assert all(r.status == "done" for r in results)
    return {r.rid: r.tokens for r in results}


# ---------------------------------------------------------------------------
# FaultPlan mechanics
# ---------------------------------------------------------------------------

def test_fault_plan_validation_and_parse():
    with pytest.raises(ValueError):
        FaultEvent("bad_kind", 1)
    with pytest.raises(ValueError):
        FaultEvent("nan_logits", -1)
    plan = FaultPlan.parse(["nan_logits:3:1:sticky", "alloc_fail:2:3",
                            "backend_raise:5", "preempt:7"])
    assert plan.retry_poisoned(3, 1) and not plan.retry_poisoned(3, 0)
    assert plan.alloc_fail(2) and plan.alloc_fail(4) \
        and not plan.alloc_fail(5)
    assert plan.backend_raise(5) and not plan.backend_raise(4)
    assert plan.preempt(7)
    assert ("alloc_fail" in {k for _, k, _ in plan.fired})


def test_fault_plan_random_is_seeded():
    a = FaultPlan.random(7, n_steps=20, n_faults=5, max_batch=4)
    b = FaultPlan.random(7, n_steps=20, n_faults=5, max_batch=4)
    assert a.events == b.events
    assert FaultPlan.random(8, n_steps=20, n_faults=5,
                            max_batch=4).events != a.events


# ---------------------------------------------------------------------------
# per-request isolation: NaN quarantine + jnp_ref retry
# ---------------------------------------------------------------------------

def test_nan_quarantine_recovers_via_ref_retry(model, clean_run):
    """A transient (kernel-side) NaN: the jnp_ref retry recomputes the row
    clean, the request CONTINUES, and — the chaos gate — every request
    still finishes token-identical to the fault-free run."""
    cfg, params = model
    plan = FaultPlan([FaultEvent("nan_logits", 4, slot=1)])
    engine = ServingEngine(cfg, params, _ecfg(), fault_plan=plan)
    results = engine.run(_reqs(_prompts(cfg), gen=8))
    m = engine.metrics()
    assert m["faults"]["nonfinite_rows"] == 1
    assert m["faults"]["recovered_ref"] == 1
    assert m["faults"]["failed_nonfinite"] == 0
    assert [r.status for r in results] == ["done"] * 3
    for r in results:
        assert r.tokens == clean_run[r.rid], f"request {r.rid} diverged"
    assert _drained(engine)
    assert (4, "nan_logits", 1) in plan.fired


def test_nan_quarantine_sticky_fails_one_isolates_rest(model, clean_run):
    """A sticky NaN (genuinely divergent input): exactly ONE request ends
    FAILED("nonfinite") with its pages freed; every other slot keeps
    decoding and finishes token-identical to the fault-free run."""
    cfg, params = model
    plan = FaultPlan([FaultEvent("nan_logits", 4, slot=1, sticky=True)])
    engine = ServingEngine(cfg, params, _ecfg(), fault_plan=plan)
    results = engine.run(_reqs(_prompts(cfg), gen=8))
    m = engine.metrics()
    failed = [r for r in results if r.status == "failed"]
    assert len(failed) == 1
    assert failed[0].fail_reason == "nonfinite"
    assert m["faults"]["recovered_ref"] == 0
    assert m["faults"]["failed_nonfinite"] == 1
    for r in results:
        if r.status == "done":
            assert r.tokens == clean_run[r.rid], f"survivor {r.rid} diverged"
    assert _drained(engine)          # the failed request's pages came back


def test_nan_quarantine_without_ref_retry_fails_fast(model):
    cfg, params = model
    plan = FaultPlan([FaultEvent("nan_logits", 4, slot=1)])
    engine = ServingEngine(cfg, params, _ecfg(ref_retry=False),
                           fault_plan=plan)
    results = engine.run(_reqs(_prompts(cfg), gen=8))
    m = engine.metrics()
    assert m["faults"]["recovered_ref"] == 0
    assert m["faults"]["failed_nonfinite"] == 1
    assert sum(r.status == "failed" for r in results) == 1
    assert _drained(engine)


def test_failed_result_keeps_partial_tokens(model):
    """The terminal FAILED result carries the tokens generated before the
    fault (partial progress is a result, not a loss)."""
    cfg, params = model
    plan = FaultPlan([FaultEvent("nan_logits", 5, slot=0, sticky=True)])
    engine = ServingEngine(cfg, params, _ecfg(max_batch=1),
                           fault_plan=plan)
    results = engine.run(_reqs(_prompts(cfg, n=1), gen=8))
    (r,) = results
    assert r.status == "failed" and r.fail_reason == "nonfinite"
    assert 0 < len(r.tokens) < 8
    assert _drained(engine)


# ---------------------------------------------------------------------------
# backend raise -> whole-step jnp_ref fallback
# ---------------------------------------------------------------------------

def test_backend_raise_degrades_step_to_ref(model, clean_run):
    cfg, params = model
    plan = FaultPlan([FaultEvent("backend_raise", 3)])
    engine = ServingEngine(cfg, params, _ecfg(), fault_plan=plan)
    results = engine.run(_reqs(_prompts(cfg), gen=8))
    m = engine.metrics()
    assert m["faults"]["backend_faults"] == 1
    assert m["faults"]["ref_fallback_steps"] == 1
    assert [r.status for r in results] == ["done"] * 3
    for r in results:
        assert r.tokens == clean_run[r.rid]
    assert _drained(engine)


# ---------------------------------------------------------------------------
# forced pool exhaustion -> eviction machinery
# ---------------------------------------------------------------------------

def test_forced_alloc_exhaustion_evicts_and_completes(model, clean_run):
    """Injected allocator exhaustion drives evict-to-requeue without a tiny
    pool; the requeued request replays and still finishes with the right
    tokens (replay-prefill is exact)."""
    cfg, params = model
    plan = FaultPlan([FaultEvent("alloc_fail", 2, count=3)])
    engine = ServingEngine(cfg, params, _ecfg(), fault_plan=plan)
    results = engine.run(_reqs(_prompts(cfg), gen=8))
    m = engine.metrics()
    assert m["evictions"] >= 1
    assert [r.status for r in results] == ["done"] * 3
    for r in results:
        assert r.tokens == clean_run[r.rid]
    assert _drained(engine)


# ---------------------------------------------------------------------------
# deadlines + backpressure
# ---------------------------------------------------------------------------

def test_ttft_deadline_cancels_waiting_requests(model):
    """One slot, three same-time arrivals, tight TTFT deadline: the head
    finishes, the queue-stuck tail is cancelled FAILED("deadline") with
    its queue position surrendered."""
    cfg, params = model
    engine = ServingEngine(cfg, params, _ecfg(max_batch=1))
    reqs = [Request(rid=i, prompt=p.copy(), max_new=cfg.page_size,
                    arrival=0.0, ttft_deadline=2)
            for i, p in enumerate(_prompts(cfg))]
    results = engine.run(reqs)
    st = {r.rid: r for r in results}
    assert st[0].status == "done"
    cancelled = [r for r in results if r.status == "failed"]
    assert cancelled and all(r.fail_reason == "deadline" for r in cancelled)
    m = engine.metrics()
    assert m["faults"]["deadline_cancelled"] == len(cancelled)
    assert _drained(engine)


def test_blown_deadline_is_preferred_eviction_victim(model):
    """Under pool pressure the engine cancels the blown-deadline request
    (freeing pages mid-decode) instead of requeueing the youngest."""
    cfg, params = model
    # growth happens when seq_len crosses a page boundary: prompts are 2
    # full pages, so the second growth lands at step 17 (seq_len 48) —
    # force exhaustion exactly there, long after rid 2's deadline blew
    plan = FaultPlan([FaultEvent("alloc_fail", 16, count=4)])
    prompts = _prompts(cfg)
    reqs = [Request(rid=i, prompt=p.copy(), max_new=cfg.page_size + 4,
                    arrival=0.0, deadline=3 if i == 2 else None)
            for i, p in enumerate(prompts)]
    engine = ServingEngine(cfg, params, _ecfg(), fault_plan=plan)
    results = engine.run(reqs)
    st = {r.rid: r for r in results}
    assert st[2].status == "failed" and st[2].fail_reason == "deadline"
    assert st[0].status == "done" and st[1].status == "done"
    m = engine.metrics()
    assert m["requeues"] == 0        # cancel, not requeue, freed the pages
    assert _drained(engine)


def test_bounded_queue_load_shedding(model):
    cfg, params = model
    engine = ServingEngine(cfg, params, _ecfg(max_batch=1, max_queue=1))
    prompts = _prompts(cfg, n=4)
    results = engine.run([Request(rid=i, prompt=p.copy(), max_new=4,
                                  arrival=0.0)
                          for i, p in enumerate(prompts)])
    st = [r.status for r in sorted(results, key=lambda r: r.rid)]
    assert st.count("rejected") >= 1 and st.count("done") >= 1
    rej = [r for r in results if r.status == "rejected"]
    assert all(r.fail_reason == "queue_full" and r.tokens == []
               for r in rej)
    m = engine.metrics()
    assert m["faults"]["rejected"] == len(rej)
    assert _drained(engine)


# ---------------------------------------------------------------------------
# checkpoint/restore + preemption
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_midflight(model, clean_run, tmp_path):
    """Mid-run snapshot -> FRESH engine restore -> drain: the combined
    output is token-identical to the uninterrupted run (pool pages, page
    tables, pending tokens and sampling positions all round-trip)."""
    cfg, params = model
    reqs = _reqs(_prompts(cfg), gen=8)
    e1 = ServingEngine(cfg, params, _ecfg())
    for r in sorted(reqs, key=lambda r: r.arrival):
        while e1.step_idx < r.arrival:
            e1.step()
        e1.submit(r)
    for _ in range(3):                   # mid-flight: decodes in progress
        e1.step()
    path = e1.snapshot(str(tmp_path))
    assert CK.latest_checkpoint(str(tmp_path)) == path

    e2 = ServingEngine(cfg, params, _ecfg())
    e2.restore(path)
    assert e2.step_idx == e1.step_idx
    assert e2.metrics()["faults"]["restores"] == 1
    while not e2.scheduler.drained:
        e2.step()
    results = sorted(e2.scheduler.finished, key=lambda r: r.rid)
    assert [r.status.value for r in results] == ["done"] * 3
    for r in results:
        assert [int(t) for t in r.out_tokens] == clean_run[r.rid], \
            f"request {r.rid} diverged after restore"
    assert _drained(e2)


def test_preemption_under_run_with_restarts(model, clean_run, tmp_path):
    """The full --restartable drill in-process: an injected preemption
    snapshots and raises EnginePreempted; run_with_restarts restarts the
    attempt, which restores from the latest checkpoint and finishes with
    token-identical output."""
    cfg, params = model
    plan = FaultPlan([FaultEvent("preempt", 5)])
    handler = PreemptionHandler(install=False)
    out: dict = {}
    restarts: list[int] = []

    def attempt() -> str:
        handler.reset()
        engine = ServingEngine(cfg, params, _ecfg(), fault_plan=plan,
                               preemption=handler)
        latest = CK.latest_checkpoint(str(tmp_path))
        if latest:
            engine.restore(latest)
        out["engine"] = engine
        out["results"] = engine.run(_reqs(_prompts(cfg), gen=8),
                                    ckpt_dir=str(tmp_path), ckpt_every=3)
        return "done"

    assert run_with_restarts(attempt, RestartPolicy(max_restarts=2),
                             on_restart=restarts.append) == "done"
    assert restarts == [1]               # exactly one preemption round trip
    results, m = out["results"], out["engine"].metrics()
    assert m["faults"]["preemptions"] >= 1 or m["faults"]["restores"] == 1
    assert m["faults"]["restores"] == 1
    assert [r.status for r in results] == ["done"] * 3
    for r in results:
        assert r.tokens == clean_run[r.rid], "restore diverged"
    assert _drained(out["engine"])


def test_checkpoint_keep_prunes_old_snapshots(model, tmp_path):
    cfg, params = model
    engine = ServingEngine(cfg, params, _ecfg())
    for r in _reqs(_prompts(cfg), gen=8):
        engine.submit(r)
    import os
    for _ in range(4):
        engine.step()
        engine.snapshot(str(tmp_path), keep=2)
    kept = sorted(d for d in os.listdir(tmp_path)
                  if d.startswith("step_"))
    assert len(kept) == 2
    assert CK.latest_checkpoint(str(tmp_path)).endswith(kept[-1])


# ---------------------------------------------------------------------------
# random storm: everything at once, still isolated + drained
# ---------------------------------------------------------------------------

def test_random_fault_storm_survivors_identical(model, clean_run):
    cfg, params = model
    for seed in (1, 2):
        plan = FaultPlan.random(seed, n_steps=12, n_faults=4, max_batch=3,
                                kinds=("nan_logits", "alloc_fail",
                                       "backend_raise"),
                                sticky_ratio=0.5)
        engine = ServingEngine(cfg, params, _ecfg(), fault_plan=plan)
        results = engine.run(_reqs(_prompts(cfg), gen=8))
        assert _drained(engine), f"storm seed {seed} leaked pages"
        for r in results:
            if r.status == "done" and r.requeues == 0:
                assert r.tokens == clean_run[r.rid], \
                    f"storm seed {seed}: survivor {r.rid} diverged"


# ---------------------------------------------------------------------------
# allocator invariant storms (adversarial interleavings)
# ---------------------------------------------------------------------------

def _allocator_storm(seed: int, n_pages: int, n_ops: int = 200) -> None:
    """Adversarial interleaving of alloc_prompt/grow/free with prefix
    sharing: after every op the partition invariant holds (checked inside
    check_invariants: free ∪ allocated == all pages, no double entries,
    refcounts >= 1 consistent with the registry); at drain the free list
    is exactly the capacity."""
    rng = np.random.default_rng(seed)
    a = PageAllocator(n_pages, PAGE)
    prefix = rng.integers(0, 1000, size=2 * PAGE, dtype=np.int32)
    live: list[list[int]] = []
    for _ in range(n_ops):
        op = rng.random()
        if op < 0.45:
            n_tok = int(rng.integers(1, 4 * PAGE))
            body = rng.integers(0, 1000, size=n_tok, dtype=np.int32)
            if rng.random() < 0.5:           # shareable-prefix prompt
                n = min(n_tok, len(prefix))
                body[:n] = prefix[:n]
            pages = a.alloc_prompt(body)
            if pages is not None:
                live.append(list(pages))
        elif op < 0.65 and live:
            grown = a.grow(1)                # decode growth on a live run
            if grown is not None:
                live[int(rng.integers(len(live)))].extend(grown)
        elif live:                           # retire a random request
            a.free(live.pop(int(rng.integers(len(live)))))
        a.check_invariants()
    for pages in live:
        a.free(pages)
        a.check_invariants()
    assert a.num_free == a.capacity
    assert a.num_in_use == 0


@pytest.mark.parametrize("seed", range(8))
def test_allocator_storm_seeded(seed):
    _allocator_storm(seed, n_pages=12 + seed)


def test_allocator_double_free_detected_in_storm():
    a = PageAllocator(8, PAGE)
    pages = a.alloc_prompt(np.arange(PAGE, dtype=np.int32))
    a.free(pages)
    with pytest.raises(ValueError, match="double free"):
        a.free(pages)
    a.check_invariants()


def test_allocator_snapshot_roundtrip_preserves_invariants():
    rng = np.random.default_rng(3)
    a = PageAllocator(16, PAGE)
    prefix = rng.integers(0, 1000, size=PAGE, dtype=np.int32)
    runs = [a.alloc_prompt(np.concatenate([
        prefix, rng.integers(0, 1000, size=PAGE // 2, dtype=np.int32)]))
        for _ in range(3)]
    state = a.export_state()
    b = PageAllocator(16, PAGE)
    b.restore_state(state)
    assert b.num_free == a.num_free and b.num_in_use == a.num_in_use
    assert b._free == a._free            # LIFO order preserved exactly
    for pages in runs:
        b.free(pages)
        b.check_invariants()
    assert b.num_free == b.capacity
    with pytest.raises(ValueError, match="geometry"):
        PageAllocator(8, PAGE).restore_state(state)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), n_pages=st.integers(4, 40))
    def test_allocator_storm_hypothesis(seed, n_pages):
        _allocator_storm(seed, n_pages, n_ops=60)
