"""Recurrent blocks: parallel (train) forms == step-by-step (decode) forms."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import rglru as R
from repro.models import xlstm as X


def test_rglru_block_equals_steps():
    d, B, S = 16, 2, 12
    params = R.init_rglru_params(jax.random.PRNGKey(0), d, d)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    y_par, st_par = R.rglru_block(params, x)
    st = R.init_rglru_state(B, d)
    ys = []
    for t in range(S):
        y, st = R.rglru_step(params, x[:, t], st)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_par.h), np.asarray(st.h),
                               rtol=1e-4, atol=1e-5)


def test_rglru_state_carries_across_chunks():
    d, B = 8, 1
    params = R.init_rglru_params(jax.random.PRNGKey(2), d, d)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, 10, d))
    y_full, _ = R.rglru_block(params, x)
    y1, st = R.rglru_block(params, x[:, :6])
    y2, _ = R.rglru_block(params, x[:, 6:], st)
    np.testing.assert_allclose(np.asarray(y_full[:, 6:]), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)


def test_mlstm_parallel_equals_recurrent():
    d, B, S, H, dh = 16, 2, 10, 2, 8
    params = X.init_mlstm_params(jax.random.PRNGKey(4), d, H, dh)
    x = jax.random.normal(jax.random.PRNGKey(5), (B, S, d))
    y_par, st_par = X.mlstm_block(params, x)
    st = X.init_mlstm_state(B, H, dh)
    ys = []
    for t in range(S):
        y, st = X.mlstm_step(params, x[:, t], st)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_par.c), np.asarray(st.c),
                               rtol=2e-3, atol=2e-4)


def test_slstm_block_equals_steps():
    d, B, S, H, dh = 12, 2, 7, 2, 6
    params = X.init_slstm_params(jax.random.PRNGKey(6), d, H, dh)
    x = jax.random.normal(jax.random.PRNGKey(7), (B, S, d))
    y_par, st_par = X.slstm_block(params, x)
    st = X.init_slstm_state(B, H, dh)
    ys = []
    for t in range(S):
        y, st = X.slstm_step(params, x[:, t], st)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(jnp.stack(ys, 1)),
                               rtol=1e-5, atol=1e-6)
