"""MLA math tests: absorbed decode == naive attention; decoupled RoPE."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mla as M


def _setup(q_lora=0):
    cfg = M.MLAConfig(d_model=96, n_heads=4, d_head=24, d_rope=12, d_c=48,
                      q_lora_rank=q_lora)
    params = M.init_mla_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_absorbed_decode_matches_full_attention():
    """Eq. 5: the absorbed decode form must equal naive attention for the
    last token of a sequence (BF16/unquantized path)."""
    for q_lora in (0, 32):
        cfg, params = _setup(q_lora)
        B, S = 2, 17
        h = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
        full = M.mla_attention(params, cfg, h, jnp.arange(S), causal=True)

        c_kv, k_r = M.project_kv(params, cfg, h, jnp.arange(S))
        out = M.mla_decode_absorbed(
            params, cfg, h[:, -1], c_kv, k_r,
            seq_lens=jnp.full((B,), S, jnp.int32),
            positions=jnp.full((B,), S - 1, jnp.int32))
        assert np.allclose(np.asarray(out), np.asarray(full[:, -1]),
                           rtol=2e-4, atol=2e-4), \
            np.abs(np.asarray(out) - np.asarray(full[:, -1])).max()


def test_rope_is_position_sensitive_content_is_not():
    cfg, params = _setup()
    h = jax.random.normal(jax.random.PRNGKey(2), (1, 4, cfg.d_model))
    c1, r1 = M.project_kv(params, cfg, h, jnp.arange(4))
    c2, r2 = M.project_kv(params, cfg, h, jnp.arange(4) + 7)
    assert np.allclose(np.asarray(c1), np.asarray(c2))          # content: no pos
    assert not np.allclose(np.asarray(r1), np.asarray(r2))      # rope: pos


def test_kv_cache_is_compressed():
    """The MLA selling point: cached dims << full K/V dims."""
    cfg, _ = _setup()
    cached = cfg.d_c + cfg.d_rope
    full = 2 * cfg.n_heads * cfg.d_head
    assert cached < full / 3
