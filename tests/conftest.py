import pathlib
import sys

# allow `pytest tests/` without PYTHONPATH=src
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))
