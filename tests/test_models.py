"""Per-architecture smoke tests (deliverable f) + prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import transformer as T


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_decode(arch):
    """Reduced same-family config: one forward + train loss + prefill +
    decode step on CPU; asserts shapes and no NaNs."""
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    B, S = 2, 24
    params = T.init_model(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    aux = (jax.random.normal(key, (B, cfg.n_aux_tokens, cfg.d_model))
           if cfg.n_aux_tokens else None)

    logits, _ = T.forward(params, cfg, tokens, aux)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()

    loss, metrics = T.loss_fn(params, cfg, tokens, tokens, aux)
    assert np.isfinite(float(loss))

    state = T.init_decode_state(cfg, B, 64)
    lg_p, state = T.prefill(params, cfg, tokens, state, aux)
    assert lg_p.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(lg_p, -1).astype(jnp.int32)
    lg_d, state = T.decode_step(params, cfg, tok, state,
                                jnp.full((B,), S, jnp.int32))
    assert lg_d.shape == (B, cfg.vocab_size)
    assert not np.isnan(np.asarray(lg_d)).any()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_instantiates(arch):
    """The full-size config is structurally valid (abstract init only)."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: T.init_model(jax.random.PRNGKey(0), cfg,
                                                 dtype=jnp.bfloat16))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    # within 2x of the config's analytic param count (layout overheads aside)
    assert 0.5 < n / cfg.param_count() < 2.0, (n, cfg.param_count())


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mla-7b", "recurrentgemma-9b",
                                  "xlstm-1.3b", "whisper-base"])
def test_prefill_decode_consistency_unquantized(arch):
    """Teacher-forced decode after prefill must reproduce forward() logits
    when the cache is BF16 (no quantization error)."""
    cfg = dataclasses.replace(get_smoke_config(arch), kv_fmt="none")
    key = jax.random.PRNGKey(1)
    B, S = 1, 12
    params = T.init_model(key, cfg)
    tokens = jax.random.randint(key, (B, S + 4), 0, cfg.vocab_size)
    aux = (jax.random.normal(key, (B, cfg.n_aux_tokens, cfg.d_model))
           if cfg.n_aux_tokens else None)

    full_logits, _ = T.forward(params, cfg, tokens, aux)
    state = T.init_decode_state(cfg, B, 64)
    _, state = T.prefill(params, cfg, tokens[:, :S], state, aux)
    for t in range(S, S + 3):
        lg, state = T.decode_step(params, cfg, tokens[:, t], state,
                                  jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lg[0]), np.asarray(full_logits[0, t]),
            rtol=5e-2, atol=5e-2)


def test_quantized_decode_close_to_bf16():
    """FP8 pipeline decode logits track the BF16 pipeline (paper Table 1 spirit)."""
    cfg = get_smoke_config("mla-7b")
    key = jax.random.PRNGKey(2)
    B, S = 2, 16
    params = T.init_model(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    outs = {}
    for fmt in ("fp8_e4m3", "none"):
        c = dataclasses.replace(cfg, kv_fmt=fmt)
        state = T.init_decode_state(c, B, 64)
        lg, state = T.prefill(params, c, tokens, state)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        lg2, _ = T.decode_step(params, c, tok, state, jnp.full((B,), S, jnp.int32))
        outs[fmt] = np.asarray(lg2)
    denom = np.abs(outs["none"]).max()
    assert np.abs(outs["fp8_e4m3"] - outs["none"]).max() / denom < 0.05
