"""shard_map-explicit decode attention == the pjit oracle (EXPERIMENTS §Perf)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed_decode import (mla_decode_shard_map,
                                           shard_map_applicable)
from repro.core.kvcache import CacheConfig, init_mla_cache, mla_prefill
from repro.kernels.mla_decode import ref as R


def test_applicability_rules():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert shard_map_applicable(mesh, "data", 4, 8)
    assert shard_map_applicable(mesh, None, 1, 8)


def test_shard_map_matches_oracle_single_device():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    B, H, d_c, d_r, N, S = 2, 4, 32, 16, 64, 50
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=32)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    cache = mla_prefill(init_mla_cache(cfg, B, N, d_c, d_r), cfg,
                        jax.random.normal(ks[0], (B, S, d_c)) * 2,
                        jax.random.normal(ks[1], (B, S, d_r)) * 20)
    q_c8, q_r, sq = R.prepare_q(jax.random.normal(ks[2], (B, H, d_c)),
                                jax.random.normal(ks[3], (B, H, d_r)) * 3)
    with mesh:
        o_sm = jax.jit(lambda qc, qr, s: mla_decode_shard_map(
            mesh, "data", qc, qr, s, cache, softmax_scale=0.1, block_n=32,
            fmt="fp8_e4m3"))(q_c8, q_r, sq)
    o_ref, _ = R.snapmla_decode_parallel_ref(
        q_c8, q_r.astype(jnp.float32), sq, cache.content,
        cache.rope.astype(jnp.float32), cache.scale, cache.seq_lens,
        softmax_scale=0.1, block_n=32)
    np.testing.assert_allclose(np.asarray(o_sm), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_shard_map_append_matches_pjit_and_honors_active():
    """The collective-free append == the pjit ``mla_append`` twin, with AND
    without the per-row ``active`` gate: gated-off rows rewrite their slot
    with its old value and freeze their seq_lens (the ROADMAP leftover —
    finished-row gating is no longer a no-op on the shard_map backend)."""
    from repro.core.distributed_decode import mla_append_shard_map
    from repro.core.kvcache import mla_append

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    B, d_c, d_r, N, S = 4, 32, 16, 64, 20
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=32)
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    cache = mla_prefill(init_mla_cache(cfg, B, N, d_c, d_r), cfg,
                        jax.random.normal(ks[0], (B, S, d_c)) * 2,
                        jax.random.normal(ks[1], (B, S, d_r)) * 20)
    c_kv = jax.random.normal(ks[2], (B, d_c))
    k_r = jax.random.normal(ks[3], (B, d_r)) * 3
    active = jnp.asarray([True, False, True, False])

    for act in (None, active):
        ref = mla_append(cache, cfg, c_kv, k_r, active=act)
        with mesh:
            sm = jax.jit(lambda c, k, a=act: mla_append_shard_map(
                mesh, "data", cache, cfg, c, k, active=a))(c_kv, k_r)
        for name in ("content", "rope", "seq_lens"):
            np.testing.assert_array_equal(
                np.asarray(getattr(sm, name)),
                np.asarray(getattr(ref, name)),
                err_msg=f"{name} diverged (active={act is not None})")
        # scale is recomputed inside vs outside jit; allow rounding slack
        np.testing.assert_allclose(
            np.asarray(sm.scale), np.asarray(ref.scale), rtol=1e-6, atol=1e-8,
            err_msg=f"scale diverged (active={act is not None})")

    with mesh:
        gated = jax.jit(lambda c, k: mla_append_shard_map(
            mesh, "data", cache, cfg, c, k, active=active))(c_kv, k_r)
    lens = np.asarray(gated.seq_lens)
    assert list(lens) == [S + 1, S, S + 1, S]       # frozen where inactive
    # inactive rows kept their old (zero-initialized) next slot verbatim
    np.testing.assert_array_equal(np.asarray(gated.content)[1, S],
                                  np.asarray(cache.content)[1, S])
