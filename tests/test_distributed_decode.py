"""shard_map-explicit decode attention == the pjit oracle (EXPERIMENTS §Perf)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.distributed_decode import (mla_decode_shard_map,
                                           shard_map_applicable)
from repro.core.kvcache import CacheConfig, init_mla_cache, mla_prefill
from repro.kernels.mla_decode import ref as R


def test_applicability_rules():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert shard_map_applicable(mesh, "data", 4, 8)
    assert shard_map_applicable(mesh, None, 1, 8)


def test_shard_map_matches_oracle_single_device():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    B, H, d_c, d_r, N, S = 2, 4, 32, 16, 64, 50
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=32)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    cache = mla_prefill(init_mla_cache(cfg, B, N, d_c, d_r), cfg,
                        jax.random.normal(ks[0], (B, S, d_c)) * 2,
                        jax.random.normal(ks[1], (B, S, d_r)) * 20)
    q_c8, q_r, sq = R.prepare_q(jax.random.normal(ks[2], (B, H, d_c)),
                                jax.random.normal(ks[3], (B, H, d_r)) * 3)
    with mesh:
        o_sm = jax.jit(lambda qc, qr, s: mla_decode_shard_map(
            mesh, "data", qc, qr, s, cache, softmax_scale=0.1, block_n=32,
            fmt="fp8_e4m3"))(q_c8, q_r, sq)
    o_ref, _ = R.snapmla_decode_parallel_ref(
        q_c8, q_r.astype(jnp.float32), sq, cache.content,
        cache.rope.astype(jnp.float32), cache.scale, cache.seq_lens,
        softmax_scale=0.1, block_n=32)
    np.testing.assert_allclose(np.asarray(o_sm), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)
