"""MoE: sort-based dispatch vs dense oracle; capacity semantics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import MoEConfig, init_moe_params, moe_layer, moe_ref_dense


def test_moe_matches_dense_oracle_at_high_capacity():
    cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, capacity_factor=8.0)
    params = init_moe_params(jax.random.PRNGKey(0), 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 10, 32))
    out, dropped = moe_layer(params, cfg, x)
    ref = moe_ref_dense(params, cfg, x)
    assert float(dropped) == 0.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_moe_shared_experts():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, capacity_factor=8.0,
                    n_shared_experts=1)
    params = init_moe_params(jax.random.PRNGKey(2), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 6, 16))
    out, _ = moe_layer(params, cfg, x)
    ref = moe_ref_dense(params, cfg, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_tokens():
    cfg_low = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, capacity_factor=0.3)
    cfg_high = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, capacity_factor=4.0)
    params = init_moe_params(jax.random.PRNGKey(4), 16, cfg_low)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 32, 16))
    _, d_low = moe_layer(params, cfg_low, x)
    _, d_high = moe_layer(params, cfg_high, x)
    assert float(d_low) > 0.0
    assert float(d_high) <= float(d_low)


def test_router_weights_renormalized():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, capacity_factor=8.0)
    params = init_moe_params(jax.random.PRNGKey(6), 16, cfg)
    # identical experts -> output independent of routing if weights sum to 1
    w = jnp.broadcast_to(params.w_gate[:1], params.w_gate.shape)
    params = params._replace(
        w_gate=w, w_up=jnp.broadcast_to(params.w_up[:1], params.w_up.shape),
        w_down=jnp.broadcast_to(params.w_down[:1], params.w_down.shape))
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 5, 16))
    out, _ = moe_layer(params, cfg, x)
    # single-expert MLP result
    h = jax.nn.silu(x @ params.w_gate[0]) * (x @ params.w_up[0])
    ref = h @ params.w_down[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)
