"""q_len > 1 (speculative-verify) split-KV decode: the rank-4 query path.

The verify contract: a [B, q_len, H, ...] query block holds the LAST q_len
positions of each sequence — row t attends a causal prefix of
``seq_lens - (q_len - 1) + t`` entries. The grid here pins

  * kernel == jnp oracle over fmt x num_splits on ragged seq_lens (rows
    shorter than q_len included — their dead rows agree too),
  * q_len = 1 through the rank-4 path is BIT-identical to the rank-3 path
    (the PR-8 contract: generalizing the kernel changed nothing at Q=1),
  * row t of one rank-4 call is bit-identical to a sequential q_len = 1
    call at the row's own seq_lens — the property the engine's rollback-by-
    rewind correctness argument rests on,
  * the paged rank-4 kernel agrees with the contiguous one on the same data,
  * the AMLA rescale stays within quantization-rounding distance of FMA.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kvcache import (CacheConfig, init_mla_cache, mla_prefill)
from repro.kernels.mla_decode import ref as R
from repro.kernels.mla_decode.kernel import (mla_decode_paged_splitkv_pallas,
                                             mla_decode_splitkv_pallas)

SCALE = 0.1
Q = 4
# ragged batch: shorter than q_len (dead rows), == q_len, mid-block, full
RAGGED_LENS = [2, Q, 77, 130, 256]


def _setup(key, B, N, d_c, d_r, fmt, page, seq_lens, H=4, q_len=Q):
    cfg = CacheConfig(fmt=fmt, page_size=page)
    ks = jax.random.split(key, 4)
    cache = mla_prefill(init_mla_cache(cfg, B, N, d_c, d_r), cfg,
                        jax.random.normal(ks[0], (B, N, d_c)) * 2,
                        jax.random.normal(ks[1], (B, N, d_r)) * 25)
    cache = cache._replace(seq_lens=jnp.asarray(seq_lens, jnp.int32))
    q = jax.random.normal(ks[2], (B, q_len, H, d_c))
    qr = jax.random.normal(ks[3], (B, q_len, H, d_r)) * 5
    q8, qrf, sq = R.prepare_q(q.reshape(B, q_len * H, d_c),
                              qr.reshape(B, q_len * H, d_r), fmt)
    q4 = (q8.reshape(B, q_len, H, d_c), qrf.reshape(B, q_len, H, d_r),
          sq.reshape(B, q_len, H))
    cargs = (cache.content, cache.rope.astype(jnp.float32), cache.scale,
             cache.seq_lens)
    return cache, q4, cargs


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "int8", "none"])
@pytest.mark.parametrize("num_splits", [1, 2, 4])
def test_qlen_kernel_matches_ref_ragged(fmt, num_splits):
    """Rank-4 kernel == jnp verify oracle over the fmt x splits grid on
    ragged seq_lens, including rows shorter than q_len."""
    B, N, bn = len(RAGGED_LENS), 256, 32
    _, q4, cargs = _setup(jax.random.PRNGKey(0), B, N, 32, 16, fmt, bn,
                          RAGGED_LENS)
    o_k, lse_k = mla_decode_splitkv_pallas(
        *q4, *cargs, softmax_scale=SCALE, num_splits=num_splits, block_n=bn,
        fmt=fmt)
    o_r, lse_r = R.snapmla_decode_splitkv_ref(
        *q4, *cargs, softmax_scale=SCALE, num_splits=num_splits, block_n=bn,
        fmt=fmt)
    assert o_k.shape == (B, Q, 4, 32) and lse_k.shape == (B, Q, 4)
    np.testing.assert_array_equal(np.isnan(np.asarray(o_k)),
                                  np.isnan(np.asarray(o_r)))
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lse_k), np.asarray(lse_r),
                               rtol=1e-5, atol=1e-5)


def test_qlen1_rank4_bit_identical_to_rank3():
    """The rank contract: a [B, 1, H, ...] query through the generalized
    kernel returns exactly the rank-3 decode's bits (plus the q_len axis)."""
    B, N, bn = 3, 256, 64
    _, q4, cargs = _setup(jax.random.PRNGKey(1), B, N, 32, 16, "fp8_e4m3",
                          bn, [200, 64, 77])
    q3 = tuple(a[:, 0] for a in q4)
    q41 = tuple(a[:, :1] for a in q4)
    for splits in (1, 2, 4):
        o3, l3 = mla_decode_splitkv_pallas(
            *q3, *cargs, softmax_scale=SCALE, num_splits=splits, block_n=bn,
            fmt="fp8_e4m3")
        o4, l4 = mla_decode_splitkv_pallas(
            *q41, *cargs, softmax_scale=SCALE, num_splits=splits, block_n=bn,
            fmt="fp8_e4m3")
        assert o4.shape == (B, 1) + o3.shape[1:]
        assert jnp.array_equal(o3, o4[:, 0]) and jnp.array_equal(l3, l4[:, 0])


def test_qlen_rows_bit_identical_to_sequential_qlen1():
    """Causal masking semantics: row t of one rank-4 call == a rank-3 call
    at ``seq_lens - (q_len-1) + t``, bit for bit. This is the property the
    engine's verify step (and its rollback-by-rewind argument) rests on —
    every candidate position sees exactly the cache a sequential decode
    would have seen."""
    B, N, bn = 3, 256, 64
    cache, q4, cargs = _setup(jax.random.PRNGKey(2), B, N, 32, 16,
                              "fp8_e4m3", bn, [200, Q, 77])
    o_k, lse_k = mla_decode_splitkv_pallas(
        *q4, *cargs, softmax_scale=SCALE, num_splits=2, block_n=bn,
        fmt="fp8_e4m3")
    for t in range(Q):
        sl_t = cache.seq_lens - (Q - 1 - t)
        o_t, lse_t = mla_decode_splitkv_pallas(
            *(a[:, t] for a in q4), *cargs[:3], sl_t,
            softmax_scale=SCALE, num_splits=2, block_n=bn, fmt="fp8_e4m3")
        assert jnp.array_equal(o_t, o_k[:, t]), t
        assert jnp.array_equal(lse_t, lse_k[:, t]), t


def test_qlen_paged_matches_contiguous():
    """The paged rank-4 kernel on a shuffled page pool agrees with the
    contiguous rank-4 kernel on the same entries."""
    B, N, page = 3, 256, 32
    cache, q4, cargs = _setup(jax.random.PRNGKey(3), B, N, 32, 16,
                              "fp8_e4m3", page, [200, 64, 130])
    P = N // page
    rng = np.random.RandomState(0)
    n_pool = B * P + 3
    perm = rng.permutation(n_pool)[: B * P].reshape(B, P)
    pool_c = np.zeros((n_pool, page, 32), np.asarray(cache.content).dtype)
    pool_r = np.zeros((n_pool, page, 16), np.float32)
    pool_s = np.ones((n_pool, page), np.float32)
    for b in range(B):
        for j in range(P):
            sl = slice(j * page, (j + 1) * page)
            pool_c[perm[b, j]] = np.asarray(cache.content[b, sl])
            pool_r[perm[b, j]] = np.asarray(cache.rope[b, sl], np.float32)
            pool_s[perm[b, j]] = np.asarray(cache.scale[b, sl])
    o_p, lse_p = mla_decode_paged_splitkv_pallas(
        *q4, jnp.asarray(pool_c), jnp.asarray(pool_r), jnp.asarray(pool_s),
        jnp.asarray(perm, jnp.int32), cache.seq_lens, softmax_scale=SCALE,
        num_splits=2, fmt="fp8_e4m3")
    o_c, lse_c = mla_decode_splitkv_pallas(
        *q4, *cargs, softmax_scale=SCALE, num_splits=2, block_n=page,
        fmt="fp8_e4m3")
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_c),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lse_p), np.asarray(lse_c),
                               rtol=1e-5, atol=1e-5)


def test_qlen_amla_within_tolerance_of_fma():
    """The AMLA exponent-add rescale on rank-4 queries differs from exact
    FMA only at quantization-rounding level (its sigma_p grid is powers of
    two) — and each rescale matches its own oracle."""
    B, N, bn = 3, 256, 64
    _, q4, cargs = _setup(jax.random.PRNGKey(4), B, N, 32, 16, "fp8_e4m3",
                          bn, [200, 64, 130])
    outs = {}
    for rescale in ("fma", "amla"):
        o_k, _ = mla_decode_splitkv_pallas(
            *q4, *cargs, softmax_scale=SCALE, num_splits=2, block_n=bn,
            fmt="fp8_e4m3", rescale=rescale)
        o_r, _ = R.snapmla_decode_splitkv_ref(
            *q4, *cargs, softmax_scale=SCALE, num_splits=2, block_n=bn,
            fmt="fp8_e4m3", rescale=rescale)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   rtol=1e-5, atol=1e-5)
        outs[rescale] = np.asarray(o_k)
    # same global-relative metric test_parity pins for the rank-3 kernels
    rel = float(np.max(np.abs(outs["amla"] - outs["fma"]))
                / (np.max(np.abs(outs["fma"])) + 1e-12))
    assert rel < 0.05, rel
