"""INT8 error-feedback gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.grad_compression import (EFState, compress, decompress,
                                          compress_tree, decompress_tree,
                                          init_ef_state)


def test_single_step_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,))
    q, s, resid = compress(g, jnp.zeros_like(g))
    rt = decompress(q, s)
    assert float(jnp.abs(rt - g).max()) <= float(s) * 0.5 + 1e-6
    np.testing.assert_allclose(np.asarray(rt + resid), np.asarray(g), rtol=1e-5,
                               atol=1e-6)


def test_error_feedback_sum_converges():
    """Sum of decompressed grads over T steps tracks the true sum (EF property)."""
    key = jax.random.PRNGKey(1)
    resid = jnp.zeros(64)
    true_sum = jnp.zeros(64)
    comp_sum = jnp.zeros(64)
    for t in range(50):
        g = jax.random.normal(jax.random.fold_in(key, t), (64,)) * 0.1
        true_sum = true_sum + g
        q, s, resid = compress(g, resid)
        comp_sum = comp_sum + decompress(q, s)
    # residual is the exact gap
    np.testing.assert_allclose(np.asarray(comp_sum + resid),
                               np.asarray(true_sum), rtol=1e-4, atol=1e-5)


def test_tree_roundtrip():
    grads = {"a": jnp.ones((4, 4)), "b": [jnp.full(3, -2.0)]}
    state = init_ef_state(grads)
    payload, state2 = compress_tree(grads, state)
    out = decompress_tree(payload)
    for a, b in zip(jax.tree.leaves(grads), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0.02,
                                   atol=0.02)
