"""End-to-end system tests: the SnapMLA serving pipeline as a user sees it."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import mla as M
from repro.core.kvcache import CacheConfig
from repro.core.snapmla import SnapMLAConfig, decode_step, init_cache, prefill
from repro.launch.serve import generate
from repro.models import transformer as T


def test_snapmla_layer_end_to_end():
    """Prefill + multi-step decode through the public SnapMLA layer API,
    FP8 vs BF16 pipelines stay close (the paper's core quality claim)."""
    cfg_mla = M.MLAConfig(d_model=96, n_heads=4, d_head=24, d_rope=12, d_c=48)
    params = M.init_mla_params(jax.random.PRNGKey(0), cfg_mla)
    B, S = 2, 30
    h = jax.random.normal(jax.random.PRNGKey(1), (B, S, 96))
    steps = jax.random.normal(jax.random.PRNGKey(2), (5, B, 96))

    outs = {}
    for fmt in ("fp8_e4m3", "none"):
        cfg = SnapMLAConfig(mla=cfg_mla, cache=CacheConfig(fmt=fmt, page_size=32))
        cache = init_cache(cfg, B, 128)
        _, cache = prefill(params, cfg, h, cache)
        acc = []
        for t in range(5):
            o, cache = decode_step(params, cfg, steps[t], cache)
            acc.append(o)
        outs[fmt] = np.asarray(jnp.stack(acc))
    rel = np.abs(outs["fp8_e4m3"] - outs["none"]).max() / np.abs(outs["none"]).max()
    assert rel < 0.08, rel


def test_generate_end_to_end_fp8_vs_bf16_agreement():
    """Teacher-forced decode: per-step FP8 logits track BF16 logits closely.

    (Free-running greedy agreement is chaotic under random weights — logits
    are near-uniform so any epsilon flips argmax and errors compound; trained
    models are far more stable, cf. paper Table 1. The per-step logit bound
    is the well-posed CPU-scale property.)"""
    cfg = get_smoke_config("mla-7b")
    key = jax.random.PRNGKey(3)
    params = T.init_model(key, cfg)
    B, S, steps = 2, 16, 5
    tokens = jax.random.randint(key, (B, S + steps), 0, cfg.vocab_size, jnp.int32)
    logits = {}
    for fmt in ("fp8_e4m3", "none"):
        c = dataclasses.replace(cfg, kv_fmt=fmt)
        state = T.init_decode_state(c, B, 64)
        _, state = T.prefill(params, c, tokens[:, :S], state)
        per_step = []
        for t in range(S, S + steps):
            lg, state = T.decode_step(params, c, tokens[:, t], state,
                                      jnp.full((B,), t, jnp.int32))
            per_step.append(np.asarray(lg))
        logits[fmt] = np.stack(per_step)
    denom = np.abs(logits["none"]).max()
    rel = np.abs(logits["fp8_e4m3"] - logits["none"]).max() / denom
    # 0.08: observed 0.074 on CPU jax 0.4.37 with random smoke weights — the
    # per-step fp8-vs-bf16 logit gap is seed/toolchain sensitive at this scale
    assert rel < 0.08, rel
    # and the BF16 decode choice stays a top-5 FP8 candidate at the first step
    # (exact-argmax is ill-posed here: random-weight logits have near-ties —
    # observed top1-top2 gap ~1e-3 of the logit scale — that any epsilon flips)
    fp8_0, bf16_0 = logits["fp8_e4m3"][0], logits["none"][0]
    for row_fp8, row_bf16 in zip(fp8_0, bf16_0):
        rank = int((row_fp8 > row_fp8[row_bf16.argmax()]).sum())
        assert rank < 5, rank


def test_generate_int8_path():
    cfg = dataclasses.replace(get_smoke_config("qwen2.5-3b"), kv_fmt="int8")
    key = jax.random.PRNGKey(4)
    params = T.init_model(key, cfg)
    prompts = jax.random.randint(key, (2, 12), 0, cfg.vocab_size, jnp.int32)
    toks, tps = generate(cfg, params, prompts, 6)
    assert toks.shape[1] == 6
    assert tps > 0
