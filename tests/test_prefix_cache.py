"""Radix prefix cache + host-memory KV tiering.

Allocator level: refcount-0 retention and promote-on-rematch, the COW
boundary page staying private, LRU budget eviction (oldest-first,
leaf-first), host-tier offload/restore slot accounting with dummy payloads,
export/restore round trips of tree + tier state, and seeded storms that
interleave every operation with ``check_invariants`` after each one.

Engine level: cache-hit runs must be TOKEN-IDENTICAL to their cache-cold
twins (ref and kernel decode backends), the drain accounting treats retained
pages as not-leaked, and an engine checkpoint round-trips a POPULATED host
tier (payloads ride in the manifest) so a restored engine serves host
restores without the original device pages.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.kvcache import page_aligned_capacity
from repro.models import transformer as T
from repro.serving import (EngineConfig, HostTier, PageAllocator, Request,
                           ServingEngine)

PAGE = 16


def _prompt(rng, n):
    return rng.integers(0, 1000, size=n, dtype=np.int32)


def _payload(pid: int) -> list[tuple]:
    """Dummy per-page payload shaped like the engine's (list of per-leaf
    array tuples) so tier export/restore round-trips it."""
    return [(np.full((2,), pid, np.int32),)]


def _drain(a: PageAllocator, tier: HostTier | None) -> None:
    """Stand-in for the engine's ``_drain_tier_ops``: move dummy payloads
    for every pending op, in decision order."""
    for kind, pid, slot in a.take_pending_tier_ops():
        if kind == "offload":
            tier.store(slot, _payload(pid))
        else:
            tier.take(slot)


def _alloc(a: PageAllocator, prompt: np.ndarray):
    """alloc + the engine's prefill-landed confirmation."""
    pages = a.alloc_prompt(prompt)
    if pages is not None:
        a.mark_ready(pages, len(prompt))
    return pages


# ---------------------------------------------------------------------------
# retention + promote
# ---------------------------------------------------------------------------

def test_retained_pages_promoted_on_rematch():
    a = PageAllocator(16, PAGE, prefix_cache_pages=8)
    rng = np.random.default_rng(0)
    prompt = _prompt(rng, 2 * PAGE + PAGE // 2)
    first = _alloc(a, prompt)
    a.free(first)
    a.check_invariants()
    assert a.num_cached == 2                  # full pages retained, COW tail not
    second = _alloc(a, prompt.copy())
    assert list(second[:2]) == list(first[:2])    # same physical pages
    assert second.cached_tokens == 2 * PAGE
    assert second.reused_pages == 2
    assert second.restored_pages == 0
    # the boundary page is a FRESH copy-on-write page, never shared/reused
    assert second[2] != first[2] or a.num_cached == 0
    a.free(second)
    a.check_invariants()


def test_cache_hit_extends_deeper_prefix():
    """A longer prompt reuses the retained prefix chain of a shorter one and
    registers its own deeper nodes."""
    a = PageAllocator(16, PAGE, prefix_cache_pages=8)
    rng = np.random.default_rng(1)
    base = _prompt(rng, 2 * PAGE)
    a.free(_alloc(a, base))
    longer = np.concatenate([base, _prompt(rng, PAGE)])
    pages = _alloc(a, longer)
    assert pages.cached_tokens == 2 * PAGE
    a.free(pages)
    a.check_invariants()
    assert a.num_cached == 3                  # now the 3-page chain is cached


def test_budget_zero_is_purge_at_refcount_zero():
    """prefix_cache_pages=0 (default) is exactly the pre-cache behavior:
    nothing survives refcount-0, re-alloc recomputes."""
    a = PageAllocator(16, PAGE)
    rng = np.random.default_rng(2)
    prompt = _prompt(rng, 2 * PAGE)
    a.free(_alloc(a, prompt))
    assert a.num_cached == 0 and a.num_free == a.capacity
    again = _alloc(a, prompt.copy())
    assert again.cached_tokens == 0 and a.pages_saved_by_sharing == 0
    a.free(again)


def test_lru_eviction_is_oldest_first_leaf_first():
    """Budget pressure drops the LRU chain; within one release the deepest
    page goes first so a parent is never dropped under a retained child."""
    a = PageAllocator(32, PAGE, prefix_cache_pages=4)
    rng = np.random.default_rng(3)
    old = _prompt(rng, 2 * PAGE)
    hot = _prompt(rng, 2 * PAGE)
    a.free(_alloc(a, old))               # cached @ tick 1
    a.free(_alloc(a, hot))               # cached @ tick 2
    assert a.num_cached == 4
    # a third release overflows the budget by 2: the OLD chain is the victim
    a.free(_alloc(a, _prompt(rng, 2 * PAGE)))
    a.check_invariants()
    assert a.num_cached == 4 and a.cache_drops == 2
    hit = _alloc(a, hot.copy())
    assert hit.cached_tokens == 2 * PAGE      # hot chain survived
    miss_pages = _alloc(a, old.copy())
    assert miss_pages.cached_tokens == 0      # old chain was dropped
    a.free(hit)
    a.free(miss_pages)


def test_unwritten_pages_never_cached_or_hit():
    """Registration happens at alloc time but data lands chunk-by-chunk: a
    page whose prefill never completed (mid-prefill eviction) must not be
    retained, and a concurrent arrival is only a cache HIT for the landed
    prefix — the rest live-shares and rewrites, exactly pre-cache."""
    a = PageAllocator(16, PAGE, prefix_cache_pages=8)
    rng = np.random.default_rng(9)
    prompt = _prompt(rng, 2 * PAGE)
    first = a.alloc_prompt(prompt)
    a.mark_ready(first, PAGE)              # only page 0 landed so far
    second = a.alloc_prompt(prompt.copy())
    assert list(second) == list(first)     # both pages live-shared
    assert second.cached_tokens == PAGE    # but only one is a hit
    a.free(second)
    a.free(first)                          # retire mid-prefill
    a.check_invariants()
    assert a.num_cached == 1               # the unwritten page was purged
    third = _alloc(a, prompt.copy())
    assert third.cached_tokens == PAGE
    a.free(third)
    a.check_invariants()


# ---------------------------------------------------------------------------
# host tier
# ---------------------------------------------------------------------------

def test_offload_then_restore_roundtrip():
    tier = HostTier(4)
    a = PageAllocator(16, PAGE, prefix_cache_pages=1, host_tier=tier)
    rng = np.random.default_rng(4)
    prompt = _prompt(rng, 2 * PAGE)
    a.free(_alloc(a, prompt))
    # budget 1: one page stays on device, the evicted one offloads to host
    a.check_invariants()
    _drain(a, tier)
    a.check_invariants()
    assert a.num_cached == 1 and tier.num_used == 1 and tier.offloads == 1
    hit = _alloc(a, prompt.copy())
    assert hit.cached_tokens == 2 * PAGE
    assert hit.reused_pages == 1 and hit.restored_pages == 1
    assert a.has_pending_tier_ops              # restore waits for the drain
    a.check_invariants()
    _drain(a, tier)
    a.check_invariants()
    assert tier.restores == 1 and tier.num_used == 0
    a.free(hit)


def test_host_tier_full_drops_lru_host_page():
    """Tier exhaustion LRU-evicts a host-resident node to make room (or
    drops the page when nothing is evictable) — never errors."""
    tier = HostTier(1)
    a = PageAllocator(32, PAGE, prefix_cache_pages=1, host_tier=tier)
    rng = np.random.default_rng(5)
    for _ in range(3):                        # each release offloads 1 page
        a.free(_alloc(a, _prompt(rng, 2 * PAGE)))
        a.check_invariants()
        _drain(a, tier)
        a.check_invariants()
    assert tier.num_used == 1                 # only the newest host page kept
    assert a.num_free + a.num_cached == a.capacity


def test_export_raises_with_pending_ops_and_roundtrips_after_drain():
    tier = HostTier(4)
    a = PageAllocator(16, PAGE, prefix_cache_pages=1, host_tier=tier)
    rng = np.random.default_rng(6)
    a.free(_alloc(a, _prompt(rng, 2 * PAGE)))
    assert a.has_pending_tier_ops
    with pytest.raises(RuntimeError, match="pending"):
        a.export_state()
    _drain(a, tier)
    state = a.export_state()
    tier2 = HostTier(4)
    tier2.restore_state(tier.export_state())
    b = PageAllocator(16, PAGE, prefix_cache_pages=1, host_tier=tier2)
    b.restore_state(state)
    assert b.export_state() == state
    assert tier2.export_state() == tier.export_state()
    with pytest.raises(ValueError, match="geometry"):
        HostTier(5).restore_state(tier.export_state())


# ---------------------------------------------------------------------------
# storms: every operation interleaved, invariants after each
# ---------------------------------------------------------------------------

def _storm(seed: int, ops: int, n_pages: int = 24, budget: int = 6,
           tier_slots: int = 8) -> None:
    rng = np.random.default_rng(seed)
    tier = HostTier(tier_slots)
    a = PageAllocator(n_pages, PAGE, prefix_cache_pages=budget,
                      host_tier=tier)
    prefixes = [_prompt(rng, int(k) * PAGE) for k in rng.integers(1, 4, 3)]
    live: list[list[int]] = []
    for _ in range(ops):
        op = rng.random()
        if op < 0.45:                          # alloc (often prefix-sharing)
            if rng.random() < 0.7:
                body = np.concatenate([
                    prefixes[int(rng.integers(len(prefixes)))],
                    _prompt(rng, int(rng.integers(1, PAGE)))])
            else:
                body = _prompt(rng, int(rng.integers(1, 3 * PAGE)))
            pages = a.alloc_prompt(body)
            if pages is not None:
                land = rng.random()
                if land < 0.75:        # prefill fully landed
                    a.mark_ready(pages, len(body))
                elif land < 0.9:       # request will retire mid-prefill
                    a.mark_ready(pages, int(rng.integers(0, len(body) + 1)))
                live.append(pages)
        elif op < 0.6 and live:                # decode growth under pressure
            extra = a.grow(1)
            if extra is not None:
                live[int(rng.integers(len(live)))].extend(extra)
        elif op < 0.85 and live:               # release -> retain/evict
            a.free(live.pop(int(rng.integers(len(live)))))
        else:                                  # engine drain point
            if a.has_pending_tier_ops and rng.random() < 0.3:
                # partial-drain ordering is not a thing: ops drain in
                # decision order or not at all this turn
                pass
            else:
                _drain(a, tier)
        a.check_invariants()
        in_use = {p for run in live for p in run}
        assert len(in_use) == a.num_in_use
        if rng.random() < 0.05 and not a.has_pending_tier_ops:
            state = a.export_state()
            t2 = HostTier(tier_slots)
            t2.restore_state(tier.export_state())
            b = PageAllocator(n_pages, PAGE, prefix_cache_pages=budget,
                              host_tier=t2)
            b.restore_state(state)
            assert b.export_state() == state
    for run in live:
        a.free(run)
    _drain(a, tier)
    a.check_invariants()
    assert a.num_free + a.num_cached == a.capacity


def test_prefix_cache_storm_keeps_invariants():
    _storm(seed=7, ops=250)


@pytest.mark.chaos
def test_prefix_cache_storm_tiny_budgets():
    """Degenerate geometries: budget 1, single host slot, tight pool."""
    _storm(seed=8, ops=200, n_pages=10, budget=1, tier_slots=1)


@pytest.mark.chaos
@pytest.mark.nightly
@pytest.mark.parametrize("seed", [11, 12, 13, 14])
def test_prefix_cache_long_storm_nightly(seed):
    """Nightly-scale storms across seeds and geometries."""
    _storm(seed=seed, ops=1500, n_pages=20 + 4 * seed, budget=seed % 7 + 1,
           tier_slots=seed % 5 + 1)


# ---------------------------------------------------------------------------
# engine: cache hits are token-identical; checkpoint carries the tier
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("mla-7b")          # pure-MLA, page_size 16
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _shared_reqs(cfg, seed: int, n: int, gap: int, gen: int):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, size=2 * PAGE, dtype=np.int32)
    return [Request(rid=i,
                    prompt=np.concatenate([
                        shared, rng.integers(0, cfg.vocab_size,
                                             size=PAGE // 2, dtype=np.int32)]),
                    max_new=gen, arrival=float(i * gap))
            for i in range(n)]


def _run(cfg, params, reqs, gen, *, cache=0, tier=0, backend=None):
    S = max(len(r.prompt) for r in reqs)
    span = page_aligned_capacity(S + gen, cfg.page_size) // cfg.page_size
    rcfg = dataclasses.replace(cfg, prefill_chunk=PAGE)
    if backend is not None:
        rcfg = dataclasses.replace(rcfg, decode_backend=backend,
                                   use_kernels=backend == "kernel")
    engine = ServingEngine(rcfg, params, EngineConfig(
        max_batch=2, max_pages_per_seq=span, n_pages=2 * span + 1,
        prefix_cache_pages=cache, host_tier_pages=tier, seed=0))
    results = engine.run(reqs)
    return engine, {r.rid: r.tokens for r in results}


def test_engine_cache_hit_token_identical_to_cold(model):
    """The acceptance pin: retained-cache and host-tiered runs of the same
    shared-prefix workload produce EXACTLY the cold run's tokens, while
    actually skipping prefill work and restoring pages from host."""
    cfg, params = model
    gen = 6
    # arrivals spaced past each request's lifetime: reuse must come from
    # RETAINED pages, not live refcount sharing
    mk = lambda: _shared_reqs(cfg, seed=21, n=3, gap=24, gen=gen)
    e_cold, cold = _run(cfg, params, mk(), gen)
    e_cache, cached = _run(cfg, params, mk(), gen, cache=12)
    e_tier, tiered = _run(cfg, params, mk(), gen, cache=1, tier=8)
    assert cached == cold
    assert tiered == cold
    mc, mt = e_cache.metrics(), e_tier.metrics()
    assert mc["prefix_cache"]["prefill_skipped_tokens"] > 0
    assert mt["prefix_cache"]["restored_host"] > 0
    assert mt["prefix_cache"]["peak_resident"] \
        <= mc["prefix_cache"]["peak_resident"]
    for m in (mc, mt):
        # retained pages are NOT leaks: free + cached == capacity
        assert m["pages"]["free"] + m["pages"]["cached"] \
            == m["pages"]["capacity"]
    # cold engine (cache off) drains to a fully free pool, as before
    m0 = e_cold.metrics()
    assert m0["pages"]["free"] == m0["pages"]["capacity"]


def test_engine_cache_hit_token_identical_kernel_backend(model):
    """Same pin on the Pallas kernel decode backend (interpret mode): the
    tiered gather/write round-trips real fp8 page payloads."""
    cfg, params = model
    gen = 4
    mk = lambda: _shared_reqs(cfg, seed=22, n=2, gap=24, gen=gen)
    _, cold = _run(cfg, params, mk(), gen, backend="kernel")
    e, tiered = _run(cfg, params, mk(), gen, cache=1, tier=8,
                     backend="kernel")
    assert tiered == cold
    assert e.metrics()["prefix_cache"]["restored_host"] > 0


def test_engine_checkpoint_roundtrips_populated_host_tier(model, tmp_path):
    """Snapshot with pages parked in the host tier -> FRESH engine restore:
    tree + tier state must round-trip exactly, and the restored engine must
    serve a host RESTORE for the next shared-prefix request (no recompute,
    tokens identical to a cold twin)."""
    cfg, params = model
    gen = 4
    warm = _shared_reqs(cfg, seed=23, n=1, gap=1, gen=gen)
    # fresh Request object per run: Request carries mutable runtime state
    nxt = lambda: dataclasses.replace(
        _shared_reqs(cfg, seed=23, n=2, gap=24, gen=gen)[1], arrival=0.0)
    S = max(len(r.prompt) for r in warm)
    span = page_aligned_capacity(S + gen, cfg.page_size) // cfg.page_size
    rcfg = dataclasses.replace(cfg, prefill_chunk=PAGE)
    ecfg = EngineConfig(max_batch=2, max_pages_per_seq=span,
                        n_pages=2 * span + 1, prefix_cache_pages=1,
                        host_tier_pages=8, seed=0)
    e1 = ServingEngine(rcfg, params, ecfg)
    e1.run(warm)                              # populates cache + host tier
    assert e1.tier.num_used > 0
    path = e1.snapshot(str(tmp_path))
    e2 = ServingEngine(rcfg, params, ecfg)
    e2.restore(path)
    assert e2.allocator.export_state() == e1.allocator.export_state()
    assert e2.tier.export_state() == e1.tier.export_state()
    # restored engine serves the host page for the follow-up request
    # (run() also returns the pre-checkpoint completed record, rid 0)
    results = {r.rid: r.tokens for r in e2.run([nxt()])}
    assert e2.metrics()["prefix_cache"]["restored_host"] > 0
    # cold twin for token identity
    e3 = ServingEngine(rcfg, params, EngineConfig(
        max_batch=2, max_pages_per_seq=span, n_pages=2 * span + 1, seed=0))
    cold = {r.rid: r.tokens for r in e3.run([nxt()])}
    assert results[1] == cold[1]


def test_engine_restore_rejects_tier_checkpoint_without_tier(model,
                                                            tmp_path):
    """A checkpoint carrying host-tier state must not silently load into an
    engine configured without one."""
    cfg, params = model
    rcfg = dataclasses.replace(cfg, prefill_chunk=PAGE)
    span = 4
    ecfg = EngineConfig(max_batch=2, max_pages_per_seq=span, n_pages=9,
                        prefix_cache_pages=1, host_tier_pages=4, seed=0)
    e1 = ServingEngine(rcfg, params, ecfg)
    e1.run(_shared_reqs(cfg, seed=24, n=1, gap=1, gen=4))
    assert e1.tier.num_used > 0
    path = e1.snapshot(str(tmp_path))
    e2 = ServingEngine(rcfg, params, dataclasses.replace(
        ecfg, prefix_cache_pages=0, host_tier_pages=0))
    with pytest.raises(ValueError, match="host"):
        e2.restore(path)
