"""FP8 quantized GQA decode kernel: sweeps over kv-head counts, windows, formats."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import gqa_decode_dequant_ref
from repro.core.kvcache import CacheConfig, init_gqa_cache, gqa_prefill, gqa_append
from repro.kernels.gqa_decode import ref as R
from repro.kernels.gqa_decode.ops import gqa_decode


def _cache(key, B, S, N, Hkv, dh, fmt, window, page):
    cfg = CacheConfig(fmt=fmt, page_size=page, window=window)
    ks = jax.random.split(key, 2)
    cache = init_gqa_cache(cfg, B, N, Hkv, dh)
    return cfg, gqa_prefill(cache, cfg, jax.random.normal(ks[0], (B, S, Hkv, dh)),
                            jax.random.normal(ks[1], (B, S, Hkv, dh)))


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "int8", "none"])
@pytest.mark.parametrize("Hkv,g,dh,window", [
    (1, 8, 32, 0),        # MQA (recurrentgemma-like)
    (2, 8, 64, 0),        # qwen2.5-like
    (4, 2, 32, 96),       # windowed (mixtral/gemma3-like)
    (8, 1, 16, 0),        # MHA
])
def test_kernel_matches_pipeline_ref(fmt, Hkv, g, dh, window):
    B, S, N, bn = 2, 150, 192, 64
    H = Hkv * g
    key = jax.random.PRNGKey(Hkv * 31 + g)
    cfg, cache = _cache(key, B, S, N, Hkv, dh, fmt, window, bn)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, H, dh))
    pos = jnp.full((B,), S - 1, jnp.int32)
    o_k = gqa_decode(q, cache, pos, window=window, block_n=bn, fmt=fmt)
    o_r = gqa_decode(q, cache, pos, window=window, block_n=bn, fmt=fmt,
                     use_kernel=False)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=1e-5, atol=1e-5)


def test_vs_dequant_oracle_and_window_semantics():
    B, S, N, Hkv, g, dh, window = 2, 150, 192, 2, 4, 32, 64
    H = Hkv * g
    cfg, cache = _cache(jax.random.PRNGKey(2), B, S, N, Hkv, dh, "fp8_e4m3",
                        window, 64)
    q = jax.random.normal(jax.random.PRNGKey(3), (B, H, dh))
    pos = jnp.full((B,), S - 1, jnp.int32)
    o_k = gqa_decode(q, cache, pos, window=window, block_n=64)
    o_e = gqa_decode_dequant_ref(q, cache, pos, window=window)
    rel = np.abs(np.asarray(o_k - o_e)).max() / np.abs(np.asarray(o_e)).max()
    assert rel < 0.08, rel


def test_ring_buffer_append_matches_prefill():
    """Appending tokens one-by-one through the ring == bulk prefill."""
    B, Hkv, dh, window = 1, 2, 16, 32
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=16, window=window)
    S = 50
    key = jax.random.PRNGKey(4)
    k = jax.random.normal(key, (B, S, Hkv, dh))
    v = jax.random.normal(jax.random.PRNGKey(5), (B, S, Hkv, dh))
    c1 = gqa_prefill(init_gqa_cache(cfg, B, 64, Hkv, dh), cfg, k, v)
    c2 = init_gqa_cache(cfg, B, 64, Hkv, dh)
    for t in range(S):
        c2 = gqa_append(c2, cfg, k[:, t], v[:, t])
    np.testing.assert_allclose(np.asarray(c1.k, np.float32),
                               np.asarray(c2.k, np.float32), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(c1.slot_pos), np.asarray(c2.slot_pos))


def test_parallel_ref_equals_sequential():
    B, S, N, Hkv, g, dh = 2, 150, 192, 2, 4, 32
    for window in (0, 64):
        cfg, cache = _cache(jax.random.PRNGKey(6), B, S, N, Hkv, dh,
                            "fp8_e4m3", window, 64)
        q = jax.random.normal(jax.random.PRNGKey(7), (B, Hkv * g, dh)).astype(jnp.float32)
        pos = jnp.full((B,), S - 1, jnp.int32)
        a = R.gqa_decode_pipeline_ref(q, cache.k, cache.v, cache.k_scale,
                                      cache.v_scale, cache.slot_pos, pos,
                                      window=window, block_n=64)
        b = R.gqa_decode_parallel_ref(q, cache.k, cache.v, cache.k_scale,
                                      cache.v_scale, cache.slot_pos, pos,
                                      window=window, block_n=64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
