"""Fused-Fetch-Dequant kernel (paper §3.3.1) + chunked prefill."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mla as M
from repro.core.kvcache import CacheConfig, init_mla_cache, mla_prefill
from repro.kernels.quantize.fetch_dequant import (chunked_prefill_attention,
                                                  fetch_dequant_pallas,
                                                  fetch_dequant_ref)


def _cache(B=2, S=96, N=128, d_c=32, d_r=16, page=32):
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=page)
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    cache = init_mla_cache(cfg, B, N, d_c, d_r)
    return mla_prefill(cache, cfg, jax.random.normal(ks[0], (B, S, d_c)) * 2,
                       jax.random.normal(ks[1], (B, S, d_r)) * 15)


def test_kernel_matches_ref():
    cache = _cache()
    out_k = fetch_dequant_pallas(cache, page=32)
    out_r = fetch_dequant_ref(cache)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=1e-6)


def test_fetch_traffic_is_quantized_width():
    """The read side stays FP8-sized: at production dims (d_c=512 >> d_r=64)
    input bytes are ~0.56x the dequantized output bytes."""
    cache = _cache(B=1, S=96, N=128, d_c=512, d_r=64, page=64)
    in_bytes = (cache.content.size * cache.content.dtype.itemsize
                + cache.rope.size * 2 + cache.scale.size * 4)
    out = fetch_dequant_ref(cache)
    assert in_bytes < out.size * out.dtype.itemsize / 1.5


def test_chunked_prefill_matches_full_attention():
    """Chunk-by-chunk prefill over the quantized cache == full causal MLA
    attention, within fp8 round-trip tolerance."""
    cfg = M.MLAConfig(d_model=64, n_heads=4, d_head=16, d_rope=16, d_c=32)
    params = M.init_mla_params(jax.random.PRNGKey(1), cfg)
    B, S, chunk = 2, 64, 32
    h = jax.random.normal(jax.random.PRNGKey(2), (B, S, 64))
    positions = jnp.arange(S)

    # reference: full unquantized attention, but compare in latent space
    q_c, q_r = M.project_q(params, cfg, h, positions)
    q_lat = M.absorb_q(params, q_c)                        # [B,S,H,d_c]
    c_kv, k_r = M.project_kv(params, cfg, h, positions)
    logits = (jnp.einsum("bshc,bnc->bshn", q_lat, c_kv)
              + jnp.einsum("bshr,bnr->bshn", q_r, k_r)) * cfg.softmax_scale
    mask = jnp.tril(jnp.ones((S, S), bool))
    logits = jnp.where(mask[None, :, None, :], logits, -jnp.inf)
    o_ref = jnp.einsum("bshn,bnc->bshc", jax.nn.softmax(logits, -1), c_kv)

    # chunked: quantize the whole prompt into the cache, then attend chunks
    ccfg = CacheConfig(fmt="fp8_e4m3", page_size=32)
    cache = mla_prefill(init_mla_cache(ccfg, B, S, cfg.d_c, cfg.d_rope),
                        ccfg, c_kv, k_r)
    outs = []
    for start in range(0, S, chunk):
        sl = slice(start, start + chunk)
        o = chunked_prefill_attention(
            q_lat[:, sl], q_r[:, sl], cache, start,
            softmax_scale=cfg.softmax_scale, page=32)
        outs.append(o)
    o_chunked = jnp.concatenate(outs, axis=1)
    rel = (np.abs(np.asarray(o_chunked - o_ref)).max()
           / np.abs(np.asarray(o_ref)).max())
    assert rel < 0.06, rel
