"""Fused-Fetch-Dequant kernel (paper §3.3.1) + chunked prefill, contiguous
and paged (page-table-prefetched) variants."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mla as M
from repro.core.kvcache import (CacheConfig, init_mla_cache,
                                init_paged_mla_pool, mla_prefill,
                                paged_mla_prefill, paged_mla_prefill_at)
from repro.kernels.quantize.fetch_dequant import (
    chunked_prefill_attention, fetch_dequant_pallas, fetch_dequant_ref,
    paged_chunked_prefill_attention, paged_fetch_dequant_pallas,
    paged_fetch_dequant_ref)


def _cache(B=2, S=96, N=128, d_c=32, d_r=16, page=32):
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=page)
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    cache = init_mla_cache(cfg, B, N, d_c, d_r)
    return mla_prefill(cache, cfg, jax.random.normal(ks[0], (B, S, d_c)) * 2,
                       jax.random.normal(ks[1], (B, S, d_r)) * 15)


def test_kernel_matches_ref():
    cache = _cache()
    out_k = fetch_dequant_pallas(cache, page=32)
    out_r = fetch_dequant_ref(cache)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=1e-6)


def test_fetch_traffic_is_quantized_width():
    """The read side stays FP8-sized: at production dims (d_c=512 >> d_r=64)
    input bytes are ~0.56x the dequantized output bytes."""
    cache = _cache(B=1, S=96, N=128, d_c=512, d_r=64, page=64)
    in_bytes = (cache.content.size * cache.content.dtype.itemsize
                + cache.rope.size * 2 + cache.scale.size * 4)
    out = fetch_dequant_ref(cache)
    assert in_bytes < out.size * out.dtype.itemsize / 1.5


def _paged_pool(table, S, d_c=32, d_r=16, page=32, n_pages=12):
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=page)
    B = table.shape[0]
    pool = init_paged_mla_pool(cfg, n_pages, table.shape[1], B, d_c, d_r)
    pool = pool._replace(page_table=jnp.asarray(table, jnp.int32))
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    return paged_mla_prefill(pool, cfg,
                             jax.random.normal(ks[0], (B, S, d_c)) * 2,
                             jax.random.normal(ks[1], (B, S, d_r)) * 15), cfg


def test_paged_fetch_kernel_matches_ref():
    """The page-table-prefetched fetch kernel == the gather oracle, with
    SCRAMBLED (non-contiguous, per-row arbitrary) page tables."""
    pool, _ = _paged_pool(np.array([[5, 2, 9], [1, 7, 3]]), S=96)
    out_k = paged_fetch_dequant_pallas(pool)
    out_r = paged_fetch_dequant_ref(pool)
    assert out_k.shape == (2, 96, 48)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=1e-6)


def test_bounded_fetch_matches_gather_oracle_scrambled_table():
    """Bounded prefix fetch (chunk_start-prefetched index maps + pl.when
    block skips) == the gather oracle with a SCRAMBLED page table, at every
    chunk-boundary class: first chunk (chunk_start 0 — nothing live),
    page-straddling chunk_start (the straddling page is fetched in full and
    masked downstream), page-aligned chunk_start, and a full-capacity table
    (every page live — identical to the unbounded fetch)."""
    pool, _ = _paged_pool(np.array([[5, 2, 9], [1, 7, 3]]), S=96)  # page=32
    full_k = paged_fetch_dequant_pallas(pool)
    for cs_rows in ([0, 0], [1, 17], [32, 64], [96, 96], [0, 96]):
        cs = jnp.asarray(cs_rows, jnp.int32)
        out_k = paged_fetch_dequant_pallas(pool, chunk_start=cs)
        out_r = paged_fetch_dequant_ref(pool, chunk_start=cs)
        np.testing.assert_allclose(np.asarray(out_k, np.float32),
                                   np.asarray(out_r, np.float32), atol=1e-6,
                                   err_msg=str(cs_rows))
        # live prefix identical to the unbounded fetch; dead pages zeroed
        for b, c in enumerate(cs_rows):
            live = -(-c // 32) * 32            # straddling page kept whole
            np.testing.assert_array_equal(
                np.asarray(out_k[b, :live], np.float32),
                np.asarray(full_k[b, :live], np.float32))
            assert not np.asarray(out_k[b, live:], np.float32).any(), cs_rows


def test_bounded_fetch_full_capacity_equals_unbounded():
    """chunk_start == capacity on every row: the bounded kernel reads every
    page and must be BIT-identical to the unbounded (seed) fetch path."""
    pool, _ = _paged_pool(np.array([[5, 2, 9], [1, 7, 3]]), S=96)
    cs = jnp.full((2,), 96, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(paged_fetch_dequant_pallas(pool, chunk_start=cs),
                   np.float32),
        np.asarray(paged_fetch_dequant_pallas(pool), np.float32))


def test_paged_fetch_matches_contiguous_fetch():
    """A paged pool whose table is the identity run lays out exactly like a
    contiguous cache: both fetch paths dequantize to the same bytes."""
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=32)
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    c_kv = jax.random.normal(ks[0], (1, 96, 32)) * 2
    k_r = jax.random.normal(ks[1], (1, 96, 16)) * 15
    cache = mla_prefill(init_mla_cache(cfg, 1, 96, 32, 16), cfg, c_kv, k_r)
    pool = init_paged_mla_pool(cfg, 3, 3, 1, 32, 16)
    pool = pool._replace(page_table=jnp.arange(3, dtype=jnp.int32)[None])
    pool = paged_mla_prefill(pool, cfg, c_kv, k_r)
    np.testing.assert_array_equal(
        np.asarray(paged_fetch_dequant_ref(pool), np.float32),
        np.asarray(fetch_dequant_ref(cache), np.float32))


def test_paged_prefill_at_writes_offset_and_routes_padding_to_scratch():
    """Partial-length paged prefill: a chunk written at offset lands in the
    right (page, slot) cells; padded-tail positions land on physical page 0
    and never clobber live pages."""
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=32)
    pool = init_paged_mla_pool(cfg, 12, 3, 1, 32, 16)
    pool = pool._replace(page_table=jnp.asarray([[4, 6, 2]], jnp.int32))
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    full_c = jax.random.normal(ks[0], (1, 80, 32)) * 2
    full_r = jax.random.normal(ks[1], (1, 80, 16)) * 15
    want = paged_mla_prefill(pool, cfg, full_c, full_r)
    # chunked writes: [0,32) then [32,64) then [64,80) padded to 32
    got = pool
    for start in (0, 32, 64):
        width = min(32, 80 - start)
        pad = 32 - width
        c = jnp.pad(full_c[:, start:start + 32], ((0, 0), (0, pad), (0, 0)))
        r = jnp.pad(full_r[:, start:start + 32], ((0, 0), (0, pad), (0, 0)))
        valid = (jnp.arange(32) < width)[None]
        got = paged_mla_prefill_at(got, cfg, c, r,
                                   jnp.asarray([start], jnp.int32), valid)
    assert int(got.seq_lens[0]) == 80
    for pid in (4, 6, 2):
        np.testing.assert_array_equal(np.asarray(want.content[pid]),
                                      np.asarray(got.content[pid]))
        np.testing.assert_array_equal(np.asarray(want.scale[pid]),
                                      np.asarray(got.scale[pid]))
    # padding landed on the scratch page, not on any live page: only page 0
    # may differ from the bulk-write reference
    diff_pages = [p for p in range(12)
                  if not np.array_equal(np.asarray(want.content[p]),
                                        np.asarray(got.content[p]))]
    assert diff_pages in ([], [0])


def test_paged_chunked_attention_matches_full_attention():
    """Chunk-by-chunk paged prefill attention (prefix via the FP8 pool,
    in-chunk keys at full precision) == full causal MLA attention in latent
    space, within fp8 round-trip tolerance — and the Pallas fetch kernel
    path agrees with the jnp fetch path to float tolerance."""
    cfg = M.MLAConfig(d_model=64, n_heads=4, d_head=16, d_rope=16, d_c=32)
    params = M.init_mla_params(jax.random.PRNGKey(1), cfg)
    B, S, chunk, page = 2, 64, 32, 32
    h = jax.random.normal(jax.random.PRNGKey(2), (B, S, 64))
    positions = jnp.arange(S)

    q_c, q_r = M.project_q(params, cfg, h, positions)
    q_lat = M.absorb_q(params, q_c)
    c_kv, k_r = M.project_kv(params, cfg, h, positions)
    logits = (jnp.einsum("bshc,bnc->bshn", q_lat, c_kv)
              + jnp.einsum("bshr,bnr->bshn", q_r, k_r)) * cfg.softmax_scale
    mask = jnp.tril(jnp.ones((S, S), bool))
    logits = jnp.where(mask[None, :, None, :], logits, -jnp.inf)
    o_ref = jnp.einsum("bshn,bnc->bshc", jax.nn.softmax(logits, -1), c_kv)

    ccfg = CacheConfig(fmt="fp8_e4m3", page_size=page)
    pool = init_paged_mla_pool(ccfg, 2 * (S // page) + 1, S // page, B,
                               cfg.d_c, cfg.d_rope)
    table = 1 + jnp.arange(B * (S // page), dtype=jnp.int32).reshape(B, -1)
    pool = pool._replace(page_table=table)
    outs = {True: [], False: []}
    for start in range(0, S, chunk):
        sl = slice(start, start + chunk)
        starts = jnp.full((B,), start, jnp.int32)
        valid = jnp.ones((B, chunk), bool)
        pool = paged_mla_prefill_at(pool, ccfg, c_kv[:, sl], k_r[:, sl],
                                    starts, valid)
        for use_kernel in (False, True):
            outs[use_kernel].append(paged_chunked_prefill_attention(
                q_lat[:, sl], q_r[:, sl], pool, c_kv[:, sl], k_r[:, sl],
                starts, valid, softmax_scale=cfg.softmax_scale,
                use_kernel=use_kernel))
    for use_kernel in (False, True):
        o_chunked = jnp.concatenate(outs[use_kernel], axis=1)
        rel = (np.abs(np.asarray(o_chunked - o_ref)).max()
               / np.abs(np.asarray(o_ref)).max())
        assert rel < 0.06, (use_kernel, rel)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs[True], 1)),
        np.asarray(jnp.concatenate(outs[False], 1)), atol=2e-5)


def test_paged_chunked_attention_first_chunk_is_full_precision():
    """A FIRST chunk (no prefix) never touches the quantized pool on its
    read side: the result matches the full-precision causal attention to
    float tolerance, not just fp8 tolerance."""
    cfg = M.MLAConfig(d_model=64, n_heads=4, d_head=16, d_rope=16, d_c=32)
    params = M.init_mla_params(jax.random.PRNGKey(3), cfg)
    B, C, page = 2, 32, 32
    h = jax.random.normal(jax.random.PRNGKey(4), (B, C, 64))
    positions = jnp.arange(C)
    q_c, q_r = M.project_q(params, cfg, h, positions)
    q_lat = M.absorb_q(params, q_c)
    c_kv, k_r = M.project_kv(params, cfg, h, positions)
    logits = (jnp.einsum("bshc,bnc->bshn", q_lat, c_kv)
              + jnp.einsum("bshr,bnr->bshn", q_r, k_r)) * cfg.softmax_scale
    mask = jnp.tril(jnp.ones((C, C), bool))
    logits = jnp.where(mask[None, :, None, :], logits, -jnp.inf)
    o_ref = jnp.einsum("bshn,bnc->bshc", jax.nn.softmax(logits, -1), c_kv)

    ccfg = CacheConfig(fmt="fp8_e4m3", page_size=page)
    pool = init_paged_mla_pool(ccfg, 4, 1, B, cfg.d_c, cfg.d_rope)
    pool = pool._replace(page_table=jnp.asarray([[1], [2]], jnp.int32))
    starts = jnp.zeros((B,), jnp.int32)
    valid = jnp.ones((B, C), bool)
    pool = paged_mla_prefill_at(pool, ccfg, c_kv, k_r, starts, valid)
    o = paged_chunked_prefill_attention(
        q_lat, q_r, pool, c_kv, k_r, starts, valid,
        softmax_scale=cfg.softmax_scale)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref, np.float32),
                               atol=1e-5)


def test_chunked_prefill_matches_full_attention():
    """Chunk-by-chunk prefill over the quantized cache == full causal MLA
    attention, within fp8 round-trip tolerance."""
    cfg = M.MLAConfig(d_model=64, n_heads=4, d_head=16, d_rope=16, d_c=32)
    params = M.init_mla_params(jax.random.PRNGKey(1), cfg)
    B, S, chunk = 2, 64, 32
    h = jax.random.normal(jax.random.PRNGKey(2), (B, S, 64))
    positions = jnp.arange(S)

    # reference: full unquantized attention, but compare in latent space
    q_c, q_r = M.project_q(params, cfg, h, positions)
    q_lat = M.absorb_q(params, q_c)                        # [B,S,H,d_c]
    c_kv, k_r = M.project_kv(params, cfg, h, positions)
    logits = (jnp.einsum("bshc,bnc->bshn", q_lat, c_kv)
              + jnp.einsum("bshr,bnr->bshn", q_r, k_r)) * cfg.softmax_scale
    mask = jnp.tril(jnp.ones((S, S), bool))
    logits = jnp.where(mask[None, :, None, :], logits, -jnp.inf)
    o_ref = jnp.einsum("bshn,bnc->bshc", jax.nn.softmax(logits, -1), c_kv)

    # chunked: quantize the whole prompt into the cache, then attend chunks
    ccfg = CacheConfig(fmt="fp8_e4m3", page_size=32)
    cache = mla_prefill(init_mla_cache(ccfg, B, S, cfg.d_c, cfg.d_rope),
                        ccfg, c_kv, k_r)
    outs = []
    for start in range(0, S, chunk):
        sl = slice(start, start + chunk)
        o = chunked_prefill_attention(
            q_lat[:, sl], q_r[:, sl], cache, start,
            softmax_scale=cfg.softmax_scale, page=32)
        outs.append(o)
    o_chunked = jnp.concatenate(outs, axis=1)
    rel = (np.abs(np.asarray(o_chunked - o_ref)).max()
           / np.abs(np.asarray(o_ref)).max())
    assert rel < 0.06, rel
