"""Flagship kernel: SnapMLA FP8 MLA decode. Shape/dtype sweeps vs the pure-jnp
pipeline oracle (exact-match) and the dequant-first oracle (quant-error bound)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import mla_decode_dequant_ref
from repro.core.kvcache import (CacheConfig, init_mla_cache, init_paged_mla_pool,
                                mla_prefill, PagedMLAPool)
from repro.kernels.mla_decode import ref as R
from repro.kernels.mla_decode.kernel import mla_decode_paged_pallas
from repro.kernels.mla_decode.ops import snapmla_decode

SCALE = 0.1


def _cache(key, B, S, N, d_c, d_r, fmt, page):
    cfg = CacheConfig(fmt=fmt, page_size=page)
    ks = jax.random.split(key, 2)
    cache = init_mla_cache(cfg, B, N, d_c, d_r)
    return mla_prefill(cache, cfg, jax.random.normal(ks[0], (B, S, d_c)) * 2,
                       jax.random.normal(ks[1], (B, S, d_r)) * 25)


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "int8", "none"])
@pytest.mark.parametrize("B,H,d_c,d_r,S,N,bn", [
    (1, 4, 32, 16, 50, 64, 32),
    (2, 8, 64, 16, 200, 256, 64),
    (3, 16, 128, 32, 130, 256, 128),
])
def test_kernel_matches_pipeline_ref(fmt, B, H, d_c, d_r, S, N, bn):
    key = jax.random.PRNGKey(B * 7 + H)
    cache = _cache(key, B, S, N, d_c, d_r, fmt, bn)
    ks = jax.random.split(key, 2)
    q_c = jax.random.normal(ks[0], (B, H, d_c))
    q_r = jax.random.normal(ks[1], (B, H, d_r)) * 5
    q_c8, q_r_s, sq = R.prepare_q(q_c, q_r, fmt)

    o_k, lse_k = snapmla_decode(q_c8, q_r_s, sq, cache, softmax_scale=SCALE,
                                block_n=bn, fmt=fmt)
    o_r, lse_r = R.snapmla_decode_pipeline_ref(
        q_c8, q_r_s, sq, cache.content, cache.rope.astype(jnp.float32),
        cache.scale, cache.seq_lens, softmax_scale=SCALE, block_n=bn, fmt=fmt)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lse_k), np.asarray(lse_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fmt,tol", [("fp8_e4m3", 0.06), ("int8", 0.03)])
def test_kernel_vs_dequant_oracle(fmt, tol):
    """Only P-quantization separates kernel from the exact-dequant oracle."""
    B, H, d_c, d_r, S, N = 2, 8, 64, 16, 200, 256
    key = jax.random.PRNGKey(3)
    cache = _cache(key, B, S, N, d_c, d_r, fmt, 64)
    ks = jax.random.split(key, 2)
    q_c8, q_r_s, sq = R.prepare_q(jax.random.normal(ks[0], (B, H, d_c)),
                                  jax.random.normal(ks[1], (B, H, d_r)) * 5, fmt)
    o_k, _ = snapmla_decode(q_c8, q_r_s, sq, cache, softmax_scale=SCALE,
                            block_n=64, fmt=fmt)
    q_lat = q_c8.astype(jnp.float32) * sq[..., None]
    q_rd = q_r_s * sq[..., None]
    o_e = mla_decode_dequant_ref(q_lat, q_rd, cache, SCALE)
    rel = np.abs(np.asarray(o_k - o_e)).max() / np.abs(np.asarray(o_e)).max()
    assert rel < tol, rel


def test_parallel_ref_equals_sequential_ref():
    B, H, d_c, d_r, S, N = 2, 8, 64, 16, 200, 256
    cache = _cache(jax.random.PRNGKey(5), B, S, N, d_c, d_r, "fp8_e4m3", 64)
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    q_c8, q_r_s, sq = R.prepare_q(jax.random.normal(ks[0], (B, H, d_c)),
                                  jax.random.normal(ks[1], (B, H, d_r)) * 5)
    args = (q_c8, q_r_s, sq, cache.content, cache.rope.astype(jnp.float32),
            cache.scale, cache.seq_lens)
    o1, l1 = R.snapmla_decode_pipeline_ref(*args, softmax_scale=SCALE, block_n=64)
    o2, l2 = R.snapmla_decode_parallel_ref(*args, softmax_scale=SCALE, block_n=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-5)


def test_paged_kernel_matches_contiguous():
    """Scalar-prefetched page-table kernel == contiguous kernel on the same data."""
    B, H, d_c, d_r, page, P = 2, 4, 32, 16, 32, 4
    N = page * P
    S = 100
    key = jax.random.PRNGKey(7)
    cache = _cache(key, B, S, N, d_c, d_r, "fp8_e4m3", page)
    ks = jax.random.split(key, 2)
    q_c8, q_r_s, sq = R.prepare_q(jax.random.normal(ks[0], (B, H, d_c)),
                                  jax.random.normal(ks[1], (B, H, d_r)) * 5)
    o_c, lse_c = snapmla_decode(q_c8, q_r_s, sq, cache, softmax_scale=SCALE,
                                block_n=page)
    # build a paged pool with a shuffled page mapping
    rng = np.random.RandomState(0)
    n_pool = B * P + 3
    perm = rng.permutation(n_pool)[: B * P].reshape(B, P)
    content_pool = np.zeros((n_pool, page, d_c), np.asarray(cache.content).dtype)
    rope_pool = np.zeros((n_pool, page, d_r), np.float32)
    scale_pool = np.ones((n_pool, page), np.float32)
    for b in range(B):
        for j in range(P):
            pid = perm[b, j]
            content_pool[pid] = np.asarray(cache.content[b, j * page:(j + 1) * page])
            rope_pool[pid] = np.asarray(cache.rope[b, j * page:(j + 1) * page],
                                        np.float32)
            scale_pool[pid] = np.asarray(cache.scale[b, j * page:(j + 1) * page])
    o_p, lse_p = mla_decode_paged_pallas(
        q_c8, q_r_s, sq, jnp.asarray(content_pool), jnp.asarray(rope_pool),
        jnp.asarray(scale_pool), jnp.asarray(perm, dtype=jnp.int32),
        cache.seq_lens, softmax_scale=SCALE)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_c), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lse_p), np.asarray(lse_c), rtol=1e-5, atol=1e-5)


def test_variable_seq_lens_mask():
    """Tokens beyond seq_len must not contribute."""
    B, H, d_c, d_r, N = 2, 4, 32, 16, 128
    key = jax.random.PRNGKey(9)
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=32)
    cache = init_mla_cache(cfg, B, N, d_c, d_r)
    ks = jax.random.split(key, 4)
    cache = mla_prefill(cache, cfg, jax.random.normal(ks[0], (B, N, d_c)),
                        jax.random.normal(ks[1], (B, N, d_r)))
    short = cache._replace(seq_lens=jnp.array([40, 100], jnp.int32))
    q_c8, q_r_s, sq = R.prepare_q(jax.random.normal(ks[2], (B, H, d_c)),
                                  jax.random.normal(ks[3], (B, H, d_r)))
    o1, _ = snapmla_decode(q_c8, q_r_s, sq, short, softmax_scale=SCALE, block_n=32)
    # zero out the cache beyond lengths: result must be identical
    mask = (jnp.arange(N)[None, :] < short.seq_lens[:, None])
    cleaned = short._replace(
        content=jnp.where(mask[..., None], short.content.astype(jnp.float32), 0
                          ).astype(short.content.dtype),
        rope=jnp.where(mask[..., None], short.rope.astype(jnp.float32), 0
                       ).astype(short.rope.dtype))
    o2, _ = snapmla_decode(q_c8, q_r_s, sq, cleaned, softmax_scale=SCALE, block_n=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-6, atol=1e-6)
