"""Preemption -> checkpoint -> clean exit; restart supervisor; stragglers."""
import numpy as np

from repro.checkpoint.checkpoint import latest_checkpoint
from repro.configs import get_smoke_config
from repro.launch.train import train_loop
from repro.runtime.fault_tolerance import (PreemptionHandler, RestartPolicy,
                                           run_with_restarts)
from repro.runtime.straggler import StragglerConfig, StragglerDetector


def test_preemption_checkpoints_and_exits(tmp_path):
    cfg = get_smoke_config("granite-3-2b")
    handler = PreemptionHandler(install=False)

    # trigger preemption after ~2 steps via a wrapped handler flag
    class TripWire:
        def __init__(self):
            self.count = 0
        @property
        def requested(self):
            self.count += 1
            return self.count > 2

    out = train_loop(cfg, steps=50, batch=4, seq=16, ckpt_dir=str(tmp_path),
                     ckpt_every=1000, preemption=TripWire(), log_every=100)
    assert out["status"] == "preempted"
    assert out["final_step"] < 50
    assert latest_checkpoint(str(tmp_path)) is not None


def test_run_with_restarts_retries_then_succeeds():
    attempts = {"n": 0}

    def flaky():
        attempts["n"] += 1
        if attempts["n"] < 3:
            raise RuntimeError("simulated node failure")
        return "done"

    restarts = []
    out = run_with_restarts(flaky, RestartPolicy(max_restarts=5),
                            on_restart=lambda i: restarts.append(i))
    assert out == "done"
    assert len(restarts) == 2


def test_run_with_restarts_exhausts_budget():
    def always_fails():
        raise RuntimeError("hard failure")

    try:
        run_with_restarts(always_fails, RestartPolicy(max_restarts=2))
        raised = False
    except RuntimeError:
        raised = True
    assert raised


def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(StragglerConfig(warmup_steps=2, threshold=1.5), 8)
    times = np.ones(8)
    for step in range(10):
        t = times.copy()
        if step >= 5:
            t[3] = 4.0                      # host 3 goes slow
        flagged = det.update(t)
    assert 3 in flagged
    assert all(h == 3 for _, h in det.flagged)
