"""Preemption -> checkpoint -> clean exit; restart supervisor; stragglers."""
import numpy as np

from repro.checkpoint.checkpoint import latest_checkpoint
from repro.configs import get_smoke_config
from repro.launch.train import train_loop
from repro.runtime.fault_tolerance import (PreemptionHandler, RestartPolicy,
                                           run_with_restarts)
from repro.runtime.straggler import StragglerConfig, StragglerDetector


def test_preemption_checkpoints_and_exits(tmp_path):
    cfg = get_smoke_config("granite-3-2b")
    handler = PreemptionHandler(install=False)

    # trigger preemption after ~2 steps via a wrapped handler flag
    class TripWire:
        def __init__(self):
            self.count = 0
        @property
        def requested(self):
            self.count += 1
            return self.count > 2

    out = train_loop(cfg, steps=50, batch=4, seq=16, ckpt_dir=str(tmp_path),
                     ckpt_every=1000, preemption=TripWire(), log_every=100)
    assert out["status"] == "preempted"
    assert out["final_step"] < 50
    assert latest_checkpoint(str(tmp_path)) is not None


def test_run_with_restarts_retries_then_succeeds():
    attempts = {"n": 0}

    def flaky():
        attempts["n"] += 1
        if attempts["n"] < 3:
            raise RuntimeError("simulated node failure")
        return "done"

    restarts = []
    out = run_with_restarts(flaky, RestartPolicy(max_restarts=5),
                            on_restart=lambda i: restarts.append(i))
    assert out == "done"
    assert len(restarts) == 2


def test_run_with_restarts_exhausts_budget():
    def always_fails():
        raise RuntimeError("hard failure")

    try:
        run_with_restarts(always_fails, RestartPolicy(max_restarts=2))
        raised = False
    except RuntimeError:
        raised = True
    assert raised


def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(StragglerConfig(warmup_steps=2, threshold=1.5), 8)
    times = np.ones(8)
    for step in range(10):
        t = times.copy()
        if step >= 5:
            t[3] = 4.0                      # host 3 goes slow
        flagged = det.update(t)
    assert 3 in flagged
    assert all(h == 3 for _, h in det.flagged)


def test_preemption_handler_reset_and_restore():
    """reset() clears the flag between restart attempts (the handler stays
    installed); restore() reinstalls the previous signal dispositions —
    including a None capture (handler set outside Python), which falls back
    to SIG_DFL instead of raising mid-teardown."""
    import signal

    h = PreemptionHandler(install=True)
    h.trigger()
    assert h.requested
    h.reset()
    assert not h.requested
    # simulate a pre-existing disposition captured as None
    h._prev[signal.SIGTERM] = None
    h.restore()
    assert h._prev == {}
    assert signal.getsignal(signal.SIGTERM) == signal.SIG_DFL
    # restore the test runner's default disposition
    signal.signal(signal.SIGTERM, signal.SIG_DFL)
    signal.signal(signal.SIGINT, signal.default_int_handler)


def test_restart_policy_backoff_and_jitter():
    p = RestartPolicy(max_restarts=5, backoff_s=1.0, backoff_factor=2.0,
                      max_backoff_s=5.0)
    assert p.delay(1) == 1.0
    assert p.delay(2) == 2.0
    assert p.delay(3) == 4.0
    assert p.delay(4) == 5.0             # capped
    assert RestartPolicy().delay(3) == 0.0   # backoff disabled by default

    a = RestartPolicy(backoff_s=1.0, jitter=0.5, seed=0)
    b = RestartPolicy(backoff_s=1.0, jitter=0.5, seed=0)
    da = [a.delay(1) for _ in range(4)]
    db = [b.delay(1) for _ in range(4)]
    assert da == db                      # seeded jitter is deterministic
    assert all(0.5 <= d <= 1.0 for d in da)
    assert len(set(da)) > 1              # ...but actually jitters
