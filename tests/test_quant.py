"""Quantization primitive tests (paper §3.1 / Appendix C) + hypothesis properties.

The property tests run under hypothesis when it is installed; on a clean
environment they fall back to fixed-seed sampled cases so the suite still
collects and exercises the same invariants (just without shrinking).
"""
import numpy as _np

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised on clean envs
    HAVE_HYPOTHESIS = False

from repro.core import quant


def _fixed_cases(n_cases, sampler):
    """Deterministic substitute for @given: sample n_cases arg tuples."""
    rng = _np.random.RandomState(0)
    return [sampler(rng) for _ in range(n_cases)]


@pytest.mark.parametrize("fmt,tol", [("fp8_e4m3", 0.07), ("int8", 0.03)])
@pytest.mark.parametrize("gran", ["per_token", "per_channel", "per_tensor", "per_block"])
def test_roundtrip_error_bound(fmt, tol, gran):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 64)) * 3.0
    fn = {
        "per_token": quant.quantize_per_token,
        "per_channel": quant.quantize_per_channel,
        "per_tensor": quant.quantize_per_tensor,
        "per_block": lambda t, fmt: quant.quantize_per_block(t, (32, 32), fmt),
    }[gran]
    q = fn(x, fmt)
    rt = q.dequant()
    rel = np.abs(np.asarray(rt - x)) / (np.abs(np.asarray(x)) + 1e-3)
    # elementwise relative error bounded by format mantissa resolution
    assert np.median(rel) < tol, (gran, fmt, np.median(rel))


def test_per_token_scale_shape_and_positivity():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 7, 16))
    q = quant.quantize_per_token(x)
    assert q.scale.shape == (4, 7, 1)
    assert np.all(np.asarray(q.scale) > 0)


def test_rope_aware_domain_alignment():
    """Key Step 1 (Eq. 6): concat(content_q, rope_scaled) * scale must
    reconstruct [content; rope] (rope exactly up to bf16, content to fp8)."""
    key = jax.random.PRNGKey(2)
    c = jax.random.normal(key, (8, 64)) * 2
    r = jax.random.normal(jax.random.PRNGKey(3), (8, 16)) * 300  # wide range
    raq = quant.quantize_rope_aware(c, r, rope_dtype=jnp.float32)
    rope_rt = np.asarray(raq.dequant_rope())
    assert np.allclose(rope_rt, np.asarray(r), rtol=2e-3, atol=1e-3)
    content_rt = np.asarray(raq.dequant_content())
    rel = np.abs(content_rt - np.asarray(c)).max() / np.abs(np.asarray(c)).max()
    assert rel < 0.1


def test_rope_aware_beats_unaware_on_heavy_tailed_rope():
    """The paper's central numerical claim (Fig. 3b)."""
    key = jax.random.PRNGKey(4)
    c = jax.random.normal(key, (256, 64)) * 2
    r_base = jax.random.normal(jax.random.PRNGKey(5), (256, 16)) * 20
    out = jax.random.normal(jax.random.PRNGKey(6), (256, 16)) * 500
    mask = jax.random.bernoulli(jax.random.PRNGKey(7), 0.05, (256, 16))
    r = jnp.where(mask, out, r_base)

    aware = quant.quantize_rope_aware(c, r, rope_dtype=jnp.float32)
    unaware = quant.quantize_rope_unaware(c, r)
    err_aware = float(jnp.mean((aware.dequant_rope() - r) ** 2))
    err_unaware = float(jnp.mean(
        (unaware.rope_scaled * unaware.scale - r) ** 2))
    assert err_aware < err_unaware / 10, (err_aware, err_unaware)
    # content error also suffers under joint scale
    errc_aware = float(jnp.mean((aware.dequant_content() - c) ** 2))
    errc_unaware = float(jnp.mean((unaware.dequant_content() - c) ** 2))
    assert errc_aware < errc_unaware


def test_scale_fusion_algebra():
    """Key Step 2: P (S_V . V_q) == (P . S_V) V_q (associativity, Eq. in §3.2.2)."""
    key = jax.random.PRNGKey(8)
    p = jax.nn.softmax(jax.random.normal(key, (4, 32)))
    vq = jax.random.normal(jax.random.PRNGKey(9), (32, 16))
    sv = jax.random.uniform(jax.random.PRNGKey(10), (32,), minval=0.1, maxval=2.0)
    lhs = p @ (sv[:, None] * vq)
    rhs = (p * sv[None, :]) @ vq
    assert np.allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-5, atol=1e-5)


def test_fuse_and_quantize_p_bounds():
    p = jnp.abs(jax.random.normal(jax.random.PRNGKey(11), (8, 64)))
    sv = jnp.ones((64,))
    p8, sp = quant.fuse_and_quantize_p(p, sv)
    assert p8.dtype == jnp.float8_e4m3fn
    assert np.all(np.abs(np.asarray(p8, np.float32)) <= 448.0)
    rt = np.asarray(p8, np.float32) * np.asarray(sp)
    assert np.allclose(rt, np.asarray(p), rtol=0.1, atol=1e-4)


def _check_scale_invariance(m, n, alpha, fmt):
    """Per-token quantization commutes with positive per-tensor scaling:
    q(alpha * x).q == q(x).q (same codes) and scale scales by alpha."""
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(m * 131 + n), (m, n)))
    x = x + np.sign(x) * 1e-3        # avoid denormal edge dominance
    q1 = quant.quantize_per_token(jnp.asarray(x), fmt)
    q2 = quant.quantize_per_token(jnp.asarray(alpha * x), fmt)
    assert np.allclose(np.asarray(q1.q, np.float32),
                       np.asarray(q2.q, np.float32), atol=1)
    assert np.allclose(np.asarray(q2.scale), alpha * np.asarray(q1.scale),
                       rtol=1e-4)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=25)
    @given(st.integers(2, 32), st.integers(2, 64),
           st.floats(1e-3, 1e3), st.sampled_from(["fp8_e4m3", "int8"]))
    def test_property_scale_invariance(m, n, alpha, fmt):
        _check_scale_invariance(m, n, alpha, fmt)
else:
    @pytest.mark.parametrize("m,n,alpha,fmt", _fixed_cases(
        25, lambda rng: (int(rng.randint(2, 33)), int(rng.randint(2, 65)),
                         float(10.0 ** rng.uniform(-3, 3)),
                         rng.choice(["fp8_e4m3", "int8"]))))
    def test_property_scale_invariance(m, n, alpha, fmt):
        _check_scale_invariance(m, n, alpha, fmt)


def _check_roundtrip_monotone_granularity(b, n):
    """Finer granularity never increases MSE for a FIXED-POINT format
    (int8): per_token <= per_tensor. This is *not* strictly true for FP8 —
    floating-point rounding is scale-free, so rescaling only helps against
    range clipping (the paper's outlier argument) — hence the loose fp8
    bound below instead of strict monotonicity."""
    x = np.array(jax.random.normal(jax.random.PRNGKey(b * 977 + n), (b, n)))
    x[0, 0] = 50.0                    # inject an outlier row
    mse_tok = float(quant.quant_mse(jnp.asarray(x), "int8", "per_token"))
    mse_ten = float(quant.quant_mse(jnp.asarray(x), "int8", "per_tensor"))
    assert mse_tok <= mse_ten * 1.01 + 1e-9
    # (no fp8 assertion: fp8 per-token can be locally worse than per-tensor on
    # tiny rows — its advantage is range/outlier handling, tested separately
    # in test_rope_aware_beats_unaware_on_heavy_tailed_rope.)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=25)
    @given(st.integers(1, 16), st.integers(2, 48))
    def test_property_roundtrip_monotone_granularity(b, n):
        _check_roundtrip_monotone_granularity(b, n)
else:
    @pytest.mark.parametrize("b,n", _fixed_cases(
        25, lambda rng: (int(rng.randint(1, 17)), int(rng.randint(2, 49)))))
    def test_property_roundtrip_monotone_granularity(b, n):
        _check_roundtrip_monotone_granularity(b, n)
