"""resolve_num_splits + split-profile autotuner: edge cases (capacity smaller
than one block, requested > blocks, single-token sequences), the heuristic
fallback when no profile cache exists, profile persistence round-trips, and
the measured sweep."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mla_decode import autotune
from repro.kernels.mla_decode.ops import (default_num_splits,
                                          resolve_num_splits, snapmla_decode)


@pytest.fixture(autouse=True)
def _isolated_profile(tmp_path, monkeypatch):
    """Every test starts with no profile singleton and a throwaway profile
    path, so the repo-root artifact (if present) can't leak in."""
    monkeypatch.setenv(autotune.PROFILE_ENV,
                       str(tmp_path / "splits_profile.json"))
    autotune.reset()
    yield
    autotune.reset()


# ---------------------------------------------------------------------------
# resolve_num_splits edge cases
# ---------------------------------------------------------------------------

def test_resolve_capacity_smaller_than_one_block():
    """capacity < block_n: there is at most one block — always single-pass,
    whatever was requested or profiled."""
    assert resolve_num_splits(None, 64, 128) == 1
    assert resolve_num_splits(8, 64, 128) == 1
    assert resolve_num_splits(1, 1, 128) == 1


def test_resolve_requested_exceeds_blocks_is_clamped():
    assert resolve_num_splits(8, 256, 128) == 2       # only 2 blocks
    assert resolve_num_splits(1000, 1024, 128) == 8
    assert resolve_num_splits(3, 1024, 128) == 3      # non-power-of-2 kept


def test_resolve_heuristic_fallback_without_profile():
    """No profile cache anywhere: auto (None/0) must equal the heuristic."""
    for cap in (256, 4096, 8192, 32768, 131072):
        expect = default_num_splits(cap, 128)
        assert resolve_num_splits(None, cap, 128, batch=4) == expect
        assert resolve_num_splits(0, cap, 128, batch=4) == expect
    # batch unknown (shard_map ref paths) also falls back cleanly
    assert resolve_num_splits(None, 32768, 128) == default_num_splits(32768, 128)


def test_resolve_profile_hit_beats_heuristic():
    profile = autotune.SplitProfile()
    profile.record(32768, 128, 4, {1: 900.0, 2: 500.0, 4: 400.0, 8: 450.0})
    autotune.reset(profile)
    assert resolve_num_splits(None, 32768, 128, batch=4) == 4
    # different batch -> nearest-neighbor interpolation from the batch=4 entry
    assert resolve_num_splits(None, 32768, 128, batch=2) == 4
    # different capacity -> no comparable entry -> heuristic
    assert resolve_num_splits(None, 16384, 128, batch=4) == \
        default_num_splits(16384, 128)
    # explicit request still wins over the profile
    assert resolve_num_splits(2, 32768, 128, batch=4) == 2


def test_lookup_nearest_batch_interpolation():
    """Exact miss interpolates from the nearest measured batch (log-space
    distance, ties to the smaller batch); capacity/block_n/layout never
    cross-pollinate."""
    profile = autotune.SplitProfile()
    profile.record(32768, 128, 2, {1: 900.0, 2: 500.0})
    profile.record(32768, 128, 64, {1: 900.0, 8: 400.0})
    autotune.reset(profile)
    # batch 4 is nearer (in log space) to 2 than to 64
    assert profile.lookup_nearest(32768, 128, 4) == 2
    # batch 32 is nearer to 64
    assert profile.lookup_nearest(32768, 128, 32) == 8
    # ratios decide: 16 is 8x away from 2 but only 4x from 64 -> nearer 64
    assert profile.lookup_nearest(32768, 128, 16) == 8
    # a true log-space tie goes to the smaller batch
    tie = autotune.SplitProfile()
    tie.record(4096, 128, 2, {1: 100.0, 2: 50.0})
    tie.record(4096, 128, 8, {1: 100.0, 4: 50.0})
    assert tie.lookup_nearest(4096, 128, 4) == 2
    # exact hit still wins
    assert profile.lookup_nearest(32768, 128, 64) == 8
    # exact-match lookup is untouched by interpolation
    assert profile.lookup(32768, 128, 4) is None
    # resolve_num_splits consumes the interpolated best
    assert resolve_num_splits(None, 32768, 128, batch=4) == 2
    # batch None (shard_map ref paths) never interpolates
    assert profile.lookup_nearest(32768, 128, None) is None
    # other block_n / capacity / layout -> no neighbors -> None
    assert profile.lookup_nearest(32768, 64, 4) is None
    assert profile.lookup_nearest(16384, 128, 4) is None
    assert profile.lookup_nearest(32768, 128, 4, layout="paged") is None


def test_lookup_nearest_skips_malformed_neighbors():
    """Malformed entries (garbage best, unparseable keys) are skipped, not
    fatal, and a well-formed neighbor still wins."""
    profile = autotune.SplitProfile({
        "32768/128/8": {"best": "garbage"},
        "not-a-key": {"best": 4},
        "32768/128/oops": {"best": 4},
        "32768/128/2": {"best": 2, "measured_us": {}},
    })
    autotune.reset(profile)
    assert profile.lookup_nearest(32768, 128, 4) == 2


def test_profile_layouts_are_separate():
    """A best measured on the contiguous kernel never drives the paged path
    (and vice versa) — their DMA patterns differ."""
    profile = autotune.SplitProfile()
    profile.record(32768, 128, 4, {1: 900.0, 4: 400.0})
    profile.record(32768, 128, 4, {1: 900.0, 2: 300.0, 4: 400.0},
                   layout="paged")
    autotune.reset(profile)
    assert resolve_num_splits(None, 32768, 128, batch=4) == 4
    assert resolve_num_splits(None, 32768, 128, batch=4, layout="paged") == 2
    # paged-only entry -> contiguous still falls back to the heuristic
    profile2 = autotune.SplitProfile()
    profile2.record(32768, 128, 2, {4: 100.0}, layout="paged")
    autotune.reset(profile2)
    assert resolve_num_splits(None, 32768, 128, batch=2) == \
        default_num_splits(32768, 128)


def test_profile_rescale_axis_is_separate():
    """AMLA-timed sweeps live under their own "/amla" keys: an FMA best never
    drives an AMLA plan (or vice versa), nearest-batch interpolation never
    crosses the rescale axis, and an un-swept rescale falls back to the
    heuristic."""
    profile = autotune.SplitProfile()
    profile.record(32768, 128, 4, {1: 900.0, 4: 400.0})
    profile.record(32768, 128, 4, {1: 900.0, 2: 300.0, 4: 400.0},
                   rescale="amla")
    autotune.reset(profile)
    assert resolve_num_splits(None, 32768, 128, batch=4) == 4
    assert resolve_num_splits(None, 32768, 128, batch=4, rescale="amla") == 2
    # nearest-batch interpolation stays within the rescale
    assert profile.lookup_nearest(32768, 128, 8, rescale="amla") == 2
    assert profile.lookup_nearest(32768, 128, 8) == 4
    # the joint 2D plan also keys on rescale
    assert profile.lookup_config(32768, 4) == autotune.SplitConfig(4, 128)
    assert profile.lookup_config(32768, 4, rescale="amla") == \
        autotune.SplitConfig(2, 128)
    # AMLA-only entry -> FMA still falls back to the heuristic
    profile2 = autotune.SplitProfile()
    profile2.record(32768, 128, 2, {4: 100.0}, rescale="amla")
    autotune.reset(profile2)
    assert resolve_num_splits(None, 32768, 128, batch=2) == \
        default_num_splits(32768, 128)
    # paged + amla compose: the suffixes stack (".../paged/amla")
    profile2.record(32768, 128, 2, {2: 100.0}, layout="paged", rescale="amla")
    assert "32768/128/2/paged/amla" in profile2.entries
    assert profile2.lookup(32768, 128, 2, layout="paged", rescale="amla") == 2
    assert profile2.lookup(32768, 128, 2, layout="paged") is None


def test_rescale_keys_round_trip_through_save_load(tmp_path):
    """The FMA key shape is unchanged (existing artifacts stay exact hits)
    and AMLA entries survive persistence."""
    p = tmp_path / "prof.json"
    profile = autotune.SplitProfile()
    profile.record(4096, 128, 2, {1: 900.0, 2: 500.0})
    profile.record(4096, 128, 2, {1: 900.0, 4: 300.0}, rescale="amla")
    profile.save(p)
    payload = json.loads(p.read_text())
    assert set(payload["entries"]) == {"4096/128/2", "4096/128/2/amla"}
    loaded = autotune.SplitProfile.load(p)
    assert loaded.lookup(4096, 128, 2) == 2
    assert loaded.lookup(4096, 128, 2, rescale="amla") == 4


def test_measure_split_sweep_rescale_records_amla_key():
    """A sweep run under rescale="amla" records only the AMLA key — the
    timings come from the AMLA kernel path, so they must never drive the
    default FMA plan."""
    profile = autotune.SplitProfile()
    measured = autotune.measure_split_sweep(
        128, 32, 1, d_c=16, d_r=8, heads=2, profile=profile, rescale="amla",
        timer=autotune.synthetic_timer({1: 300.0, 2: 200.0, 4: 100.0}))
    assert set(measured) == {1, 2, 4}
    assert profile.lookup(128, 32, 1, rescale="amla") == 4
    assert profile.lookup(128, 32, 1) is None          # FMA untouched


def test_record_prefers_fewer_splits_within_noise_margin():
    """Ties within WIN_MARGIN go to the smaller split count, so measurement
    jitter can't flip a plan away from the bit-exact single-pass path."""
    profile = autotune.SplitProfile()
    assert profile.record(4096, 128, 2, {1: 100.0, 2: 97.0, 4: 99.0}) == 1
    assert profile.record(4096, 128, 4, {1: 100.0, 2: 80.0, 4: 79.0}) == 2
    assert profile.record(4096, 128, 8, {1: 100.0, 4: 50.0}) == 4


def test_lookup_malformed_entry_falls_back_to_heuristic():
    """A hand-edited entry missing 'best' (or with garbage) must not crash
    decode — lookup returns None and resolve uses the heuristic."""
    profile = autotune.SplitProfile({
        "512/64/2": {"measured_us": {"1": 100.0}},    # no "best"
        "1024/64/2": "garbage",
        "2048/64/2": {"best": "not-an-int-able"},
    })
    autotune.reset(profile)
    assert profile.lookup(512, 64, 2) is None
    assert profile.lookup(1024, 64, 2) is None
    assert profile.lookup(2048, 64, 2) is None
    assert resolve_num_splits(None, 512, 64, batch=2) == \
        default_num_splits(512, 64)


def test_resolve_profiled_best_clamped_to_block_count():
    """A profile measured on long contexts must not break a short cache."""
    profile = autotune.SplitProfile()
    profile.record(256, 128, 2, {8: 100.0})           # absurd entry: 8 > blocks
    autotune.reset(profile)
    assert resolve_num_splits(None, 256, 128, batch=2) == 2


def test_single_token_sequences_decode_under_auto_splits():
    """seq_lens == 1 with a profiled multi-split plan: the kernel's early
    exit handles the all-dead-blocks splits; output matches single-pass."""
    from repro.core.kvcache import CacheConfig, init_mla_cache, mla_prefill
    from repro.kernels.mla_decode import ref as R

    B, N, bn = 2, 256, 32
    profile = autotune.SplitProfile()
    profile.record(N, bn, B, {1: 500.0, 4: 100.0})    # force 4 splits
    autotune.reset(profile)
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=bn)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    cache = mla_prefill(init_mla_cache(cfg, B, N, 32, 16), cfg,
                        jax.random.normal(ks[0], (B, N, 32)),
                        jax.random.normal(ks[1], (B, N, 16)))
    cache = cache._replace(seq_lens=jnp.ones((B,), jnp.int32))
    q_c8, q_r, sq = R.prepare_q(jax.random.normal(ks[2], (B, 4, 32)),
                                jax.random.normal(ks[3], (B, 4, 16)))
    o_auto, _ = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=0.1,
                               block_n=bn)            # auto -> profiled 4
    o_one, _ = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=0.1,
                              block_n=bn, num_splits=1)
    assert not np.isnan(np.asarray(o_auto)).any()
    np.testing.assert_allclose(np.asarray(o_auto), np.asarray(o_one),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# profile persistence + measured sweep
# ---------------------------------------------------------------------------

def test_profile_save_load_round_trip(tmp_path):
    p = tmp_path / "prof.json"
    profile = autotune.SplitProfile()
    best = profile.record(4096, 128, 2, {1: 300.0, 2: 200.5, 4: 250.0})
    assert best == 2
    profile.save(p)
    loaded = autotune.SplitProfile.load(p)
    assert loaded.lookup(4096, 128, 2) == 2
    assert loaded.lookup(4096, 128, 3) is None
    assert loaded.lookup(4096, 128, None) is None
    payload = json.loads(p.read_text())
    assert payload["version"] == autotune.PROFILE_VERSION
    assert payload["entries"]["4096/128/2"]["measured_us"]["2"] == 200.5


def test_profile_load_missing_or_corrupt_is_empty(tmp_path):
    assert autotune.SplitProfile.load(tmp_path / "nope.json").entries == {}
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert autotune.SplitProfile.load(bad).entries == {}
    wrong = tmp_path / "wrong.json"
    wrong.write_text(json.dumps({"version": 999, "entries": {"a": 1}}))
    assert autotune.SplitProfile.load(wrong).entries == {}


def test_candidate_splits_respect_block_count():
    assert autotune.candidate_splits(64, 128) == [1]
    assert autotune.candidate_splits(256, 128) == [1, 2]
    assert autotune.candidate_splits(131072, 128) == [1, 2, 4, 8]


def test_measure_split_sweep_records_profile_entry():
    """Sweep plumbing with injected synthetic timings — fully deterministic,
    no wall clock anywhere: the sweep walks the candidate splits, feeds the
    fixed numbers through ``record``, and the WIN_MARGIN tie rule decides
    the plan (2 wins by >5% over 1; 4's further 1.25% win is within noise
    margin, so the smaller count keeps the slot)."""
    profile = autotune.SplitProfile()
    fixed = {1: 100.0, 2: 80.0, 4: 79.0}
    measured = autotune.measure_split_sweep(
        128, 32, 1, d_c=16, d_r=8, heads=2, profile=profile,
        timer=autotune.synthetic_timer(fixed))
    assert measured == fixed                          # 4 blocks -> 1,2,4
    assert profile.lookup(128, 32, 1) == 2
    assert profile.lookup(128, 32, 1) == autotune._pick_best(measured)


def test_measure_split_sweep_win_margin_tie_goes_to_fewer_splits():
    """Near-ties (within WIN_MARGIN) must keep the bit-exact single-pass
    plan — the exact jitter scenario that used to flake when this sweep was
    measured: 2 and 4 are 3% and 1% faster than 1, neither a real win."""
    profile = autotune.SplitProfile()
    autotune.measure_split_sweep(
        128, 32, 1, d_c=16, d_r=8, heads=2, profile=profile,
        timer=autotune.synthetic_timer({1: 100.0, 2: 97.0, 4: 99.0}))
    assert profile.lookup(128, 32, 1) == 1


def test_measure_split_sweep_paged_layout():
    """The paged sweep records under the paged key only."""
    profile = autotune.SplitProfile()
    measured = autotune.measure_split_sweep(
        128, 32, 1, d_c=16, d_r=8, heads=2, profile=profile, layout="paged",
        timer=autotune.synthetic_timer({1: 300.0, 2: 200.0, 4: 100.0}))
    assert set(measured) == {1, 2, 4}
    assert profile.lookup(128, 32, 1, layout="paged") == 4
    assert profile.lookup(128, 32, 1) is None          # contiguous untouched


@pytest.mark.timing
def test_measure_split_sweep_measured_smoke():
    """The real wall-clock timer path, end to end (compile + timed runs of
    the interpret-mode kernel). Informational ONLY — asserts the sweep ran
    and recorded a sane plan, never anything about relative speed; CI runs
    it non-gating (see pytest.ini `timing`)."""
    profile = autotune.SplitProfile()
    measured = autotune.measure_split_sweep(128, 32, 1, d_c=16, d_r=8,
                                            heads=2, iters=1, profile=profile)
    assert set(measured) == {1, 2, 4}
    assert all(us > 0 for us in measured.values())
    best = profile.lookup(128, 32, 1)
    assert best in measured
    assert best == autotune._pick_best(measured)


# ---------------------------------------------------------------------------
# v2 joint (num_splits, block_n) plans + v1 migration
# ---------------------------------------------------------------------------

def test_v1_profile_migration_round_trip(tmp_path):
    """A committed v1 artifact (no per-entry best_us) keeps driving plans:
    load -> 1D lookups AND the joint 2D lookup work (best_us derived from the
    entry's own sweep), and a re-save upgrades the file to version 2 without
    losing anything."""
    p = tmp_path / "v1.json"
    p.write_text(json.dumps({
        "version": 1,
        "entries": {
            "4096/64/2": {"best": 2, "measured_us": {"1": 900.0, "2": 500.0}},
            "4096/128/2": {"best": 4,
                           "measured_us": {"1": 800.0, "4": 420.0}},
        },
    }))
    loaded = autotune.SplitProfile.load(p)
    assert loaded.lookup(4096, 64, 2) == 2
    assert loaded.lookup(4096, 128, 2) == 4
    # joint plan: the 128-block best (420us) beats the 64-block best (500us)
    assert loaded.lookup_config(4096, 2) == autotune.SplitConfig(4, 128)
    # round-trip: save writes version 2; entries survive verbatim
    p2 = tmp_path / "v2.json"
    loaded.save(p2)
    payload = json.loads(p2.read_text())
    assert payload["version"] == 2
    again = autotune.SplitProfile.load(p2)
    assert again.lookup_config(4096, 2) == autotune.SplitConfig(4, 128)
    assert again.entries == loaded.entries


def test_lookup_config_cross_block_n_and_ties():
    """The joint plan compares best_us ACROSS block_n; ties in measured time
    go to the smaller block_n; malformed entries are skipped."""
    profile = autotune.SplitProfile()
    profile.record(8192, 64, 4, {1: 700.0, 2: 300.0})
    profile.record(8192, 128, 4, {1: 600.0, 4: 250.0})
    profile.record(8192, 256, 4, {1: 900.0})
    assert profile.lookup_config(8192, 4) == autotune.SplitConfig(4, 128)
    # a time tie at another block_n -> smaller block_n wins
    profile.record(8192, 32, 4, {2: 250.0})
    assert profile.lookup_config(8192, 4) == autotune.SplitConfig(2, 32)
    # malformed entry at the "fastest" slot must not crash or win
    profile.entries["8192/16/4"] = {"best": "garbage", "best_us": 1.0}
    profile.entries["8192/8/4"] = {"best_us": 1.0}
    assert profile.lookup_config(8192, 4) == autotune.SplitConfig(2, 32)
    # batch None (shard_map ref paths) never produces a joint plan
    assert profile.lookup_config(8192, None) is None


def test_lookup_config_nearest_batch_and_layout_isolation():
    """Batch miss: only the nearest log-batch's entries compete (no mixing
    plans measured at wildly different batches); layouts never cross."""
    profile = autotune.SplitProfile()
    profile.record(8192, 64, 2, {1: 500.0, 2: 400.0})
    profile.record(8192, 128, 64, {1: 300.0, 8: 100.0})
    # batch 4 is nearer (log-space) to 2: the batch-64 plan (100us) must NOT
    # leak in even though it is faster
    assert profile.lookup_config(8192, 4) == autotune.SplitConfig(2, 64)
    assert profile.lookup_config(8192, 32) == autotune.SplitConfig(8, 128)
    # paged entries live in their own key space
    profile.record(8192, 128, 4, {4: 50.0}, layout="paged")
    assert profile.lookup_config(8192, 4) == autotune.SplitConfig(2, 64)
    assert profile.lookup_config(8192, 4, layout="paged") == \
        autotune.SplitConfig(4, 128)
    # capacity never cross-pollinates
    assert profile.lookup_config(4096, 4) is None


def test_resolve_split_config_auto_block_n():
    """ops.resolve_split_config: block_n auto -> the measured joint plan;
    explicit block_n pins the block axis; profile block_n that does not
    divide the capacity is ignored (heuristic fallback)."""
    from repro.kernels.mla_decode.ops import (DEFAULT_BLOCK_N,
                                              resolve_split_config)

    profile = autotune.SplitProfile()
    profile.record(4096, 64, 2, {1: 900.0, 2: 500.0})
    profile.record(4096, 128, 2, {1: 800.0, 4: 420.0})
    autotune.reset(profile)
    assert resolve_split_config(None, None, 4096, batch=2) == \
        autotune.SplitConfig(4, 128)
    # explicit block_n: splits resolve at that block size (profile hit)
    assert resolve_split_config(None, 64, 4096, batch=2) == \
        autotune.SplitConfig(2, 64)
    # explicit num_splits overrides the tuned count, keeps the tuned block_n
    assert resolve_split_config(2, None, 4096, batch=2) == \
        autotune.SplitConfig(2, 128)
    # no profile entry for this capacity -> heuristic block_n (the largest
    # standard candidate that divides it; 4160 % 128 != 0 -> 64)
    assert DEFAULT_BLOCK_N == 128
    assert resolve_split_config(None, None, 4096 + 64, batch=2).block_n == 64


def test_resolve_split_config_paged_structural_pin():
    """Paged layouts: block_n IS the page size — auto resolves to it, a
    mismatched explicit block_n is an error, and the profile only tunes
    num_splits."""
    from repro.kernels.mla_decode.ops import resolve_split_config

    profile = autotune.SplitProfile()
    profile.record(4096, 64, 2, {1: 900.0, 4: 300.0}, layout="paged")
    # a faster contiguous entry at another block_n must not repage anything
    profile.record(4096, 128, 2, {8: 10.0})
    autotune.reset(profile)
    cfg = resolve_split_config(None, None, 4096, batch=2, layout="paged",
                               page_size=64)
    assert cfg == autotune.SplitConfig(4, 64)
    with pytest.raises(ValueError):
        resolve_split_config(None, 128, 4096, batch=2, layout="paged",
                             page_size=64)
    with pytest.raises(ValueError):
        resolve_split_config(None, None, 4096, batch=2, layout="paged")


def test_candidate_block_ns_divisibility():
    assert autotune.candidate_block_ns(4096) == [32, 64, 128, 256]
    assert autotune.candidate_block_ns(96) == [32]
    assert autotune.candidate_block_ns(20) == [20]     # nothing divides -> cap
    assert autotune.block_ns_for_paged(4096) == 128
    assert autotune.block_ns_for_paged(64) == 64


def test_measure_config_sweep_synthetic_2d():
    """2D sweep plumbing with an injected synthetic grid — one profile entry
    per block_n, and lookup_config picks the joint winner deterministically
    (no wall clock anywhere)."""
    profile = autotune.SplitProfile()
    grid = {(32, 1): 200.0, (32, 2): 120.0, (32, 4): 110.0,
            (64, 1): 180.0, (64, 2): 90.0}
    measured = autotune.measure_config_sweep(
        128, 1, block_ns=[32, 64], d_c=16, d_r=8, heads=2, profile=profile,
        timer=autotune.synthetic_timer_2d(grid))
    assert measured == grid                 # 128/32 -> splits 1,2,4; /64 -> 1,2
    assert profile.lookup(128, 32, 1) == 4  # 110 beats 120 by > WIN_MARGIN
    assert profile.lookup(128, 64, 1) == 2
    assert profile.lookup_config(128, 1) == autotune.SplitConfig(2, 64)


def test_measure_config_sweep_paged_pins_block_n():
    """Paged 2D sweep: block_ns defaults to the single structural page-size
    candidate — no block_n freedom to sweep."""
    profile = autotune.SplitProfile()
    grid = {(128, 1): 100.0}
    measured = autotune.measure_config_sweep(
        128, 1, d_c=16, d_r=8, heads=2, profile=profile, layout="paged",
        timer=autotune.synthetic_timer_2d(grid))
    assert set(measured) == {(128, 1)}
    assert profile.lookup(128, 128, 1, layout="paged") == 1
    assert profile.lookup_config(128, 1, layout="paged") == \
        autotune.SplitConfig(1, 128)
    assert profile.lookup_config(128, 1) is None


@pytest.mark.timing
def test_measure_config_sweep_measured_smoke():
    """Real wall-clock 2D sweep, end to end (compile + timed interpret-mode
    runs at every (block_n, num_splits) cell). Informational ONLY — asserts
    the sweep covered the grid and recorded comparable entries, never
    anything about relative speed; CI runs it non-gating (pytest.ini
    `timing`)."""
    profile = autotune.SplitProfile()
    measured = autotune.measure_config_sweep(
        128, 1, block_ns=[32, 64], d_c=16, d_r=8, heads=2, iters=1,
        profile=profile, interpret=True)
    assert set(measured) == {(32, 1), (32, 2), (32, 4), (64, 1), (64, 2)}
    assert all(us > 0 for us in measured.values())
    cfg = profile.lookup_config(128, 1)
    assert cfg is not None and (cfg.block_n, cfg.num_splits) in measured


def test_emit_split_profile_artifact(tmp_path):
    """The benchmark entry point writes the JSON artifact resolve reads,
    covering both layouts and the AMLA-rescale key space."""
    from benchmarks.kernel_perf import emit_split_profile

    path = tmp_path / "prof.json"
    out = emit_split_profile(path=str(path), shapes=((128, 32, 1),),
                             paged_shapes=((128, 32, 1),),
                             config_shapes=((128, 1),),
                             amla_config_shapes=((128, 1),), iters=1)
    assert out == path
    loaded = autotune.SplitProfile.load(path)
    assert loaded.lookup(128, 32, 1) is not None
    assert loaded.lookup(128, 32, 1, layout="paged") is not None
    # the AMLA config sweep recorded its own "/amla" entries
    assert loaded.lookup_config(128, 1, rescale="amla") is not None
    # emit installs the fresh profile as the in-process singleton
    assert autotune.get_profile().lookup(128, 32, 1) is not None
