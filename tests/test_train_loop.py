"""Integration: the training loop learns on synthetic data; optimizer sanity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.train import train_loop
from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw
from repro.optim.schedule import warmup_cosine


def test_loss_decreases():
    cfg = get_smoke_config("llama3.2-3b")
    out = train_loop(cfg, steps=25, batch=8, seq=32, ckpt_dir=None, lr=3e-3,
                     log_every=100)
    first = np.mean(out["losses"][:3])
    last = np.mean(out["losses"][-3:])
    assert out["status"] == "done"
    assert last < first - 0.1, (first, last)


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_adamw(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw_update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1
    assert float(m["grad_norm"]) >= 0


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = init_adamw(params)
    grads = {"w": jnp.full(4, 1e6)}
    new, state, m = adamw_update(cfg, grads, state, params)
    assert float(m["grad_norm"]) > 1e5
    assert np.isfinite(np.asarray(new["w"])).all()


def test_schedule_shape():
    assert float(warmup_cosine(0, warmup_steps=10, total_steps=100)) == 0.0
    assert abs(float(warmup_cosine(10, warmup_steps=10, total_steps=100)) - 1.0) < 1e-5
    end = float(warmup_cosine(100, warmup_steps=10, total_steps=100))
    assert 0.05 < end < 0.15
