"""Paged split-KV decode: kernel vs paged pure-jnp oracle across a
num_splits × context grid (ragged seq_lens included), agreement with the
contiguous kernel, ops-level dispatch, early-exit accounting, and the
paged model/serve path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kvcache import (CacheConfig, init_mla_cache,
                                init_paged_mla_cache, mla_append, mla_prefill,
                                paged_gather, paged_mla_append,
                                paged_mla_prefill)
from repro.kernels.mla_decode import ref as R
from repro.kernels.mla_decode.kernel import (mla_decode_paged_pallas,
                                             mla_decode_paged_splitkv_pallas,
                                             mla_decode_splitkv_pallas)
from repro.kernels.mla_decode.ops import snapmla_decode_paged

SCALE = 0.1
# ragged batch: empty, one-page (<= page), mid-page, page-aligned, full
RAGGED_LENS = [0, 20, 130, 192, 256]


def _pool_setup(key, B, S, N, d_c, d_r, fmt, page, seq_lens=None, H=4,
                shuffle_seed=0, n_extra=3):
    """Contiguous cache + the same data scattered into a shuffled page pool."""
    cfg = CacheConfig(fmt=fmt, page_size=page)
    ks = jax.random.split(key, 4)
    cache = mla_prefill(init_mla_cache(cfg, B, N, d_c, d_r), cfg,
                        jax.random.normal(ks[0], (B, S, d_c)) * 2,
                        jax.random.normal(ks[1], (B, S, d_r)) * 25)
    if seq_lens is not None:
        cache = cache._replace(seq_lens=jnp.asarray(seq_lens, jnp.int32))
    q_c8, q_r, sq = R.prepare_q(jax.random.normal(ks[2], (B, H, d_c)),
                                jax.random.normal(ks[3], (B, H, d_r)) * 5, fmt)

    P = N // page
    rng = np.random.RandomState(shuffle_seed)
    n_pool = B * P + n_extra
    perm = rng.permutation(n_pool)[: B * P].reshape(B, P)
    pool_c = np.zeros((n_pool, page, d_c), np.asarray(cache.content).dtype)
    pool_r = np.zeros((n_pool, page, d_r), np.float32)
    pool_s = np.ones((n_pool, page), np.float32)
    for b in range(B):
        for j in range(P):
            sl = slice(j * page, (j + 1) * page)
            pool_c[perm[b, j]] = np.asarray(cache.content[b, sl])
            pool_r[perm[b, j]] = np.asarray(cache.rope[b, sl], np.float32)
            pool_s[perm[b, j]] = np.asarray(cache.scale[b, sl])
    pool = (jnp.asarray(pool_c), jnp.asarray(pool_r), jnp.asarray(pool_s),
            jnp.asarray(perm, jnp.int32))
    return cache, (q_c8, q_r, sq), pool


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "int8", "none"])
@pytest.mark.parametrize("num_splits", [1, 2, 4])
def test_paged_splitkv_kernel_matches_paged_oracle_ragged(fmt, num_splits):
    """Acceptance grid: kernel == paged pure-jnp oracle on ragged lens
    (incl. the empty and one-page rows), partials included."""
    B, N, page = len(RAGGED_LENS), 256, 32
    cache, q, (pc, pr, ps, pt) = _pool_setup(
        jax.random.PRNGKey(0), B, N, N, 32, 16, fmt, page,
        seq_lens=RAGGED_LENS)
    o_k, lse_k, (op_k, lp_k, sp_k) = mla_decode_paged_splitkv_pallas(
        *q, pc, pr, ps, pt, cache.seq_lens, softmax_scale=SCALE,
        num_splits=num_splits, fmt=fmt, return_partials=True)
    o_r, lse_r, (op_r, lp_r, sp_r) = R.snapmla_decode_paged_splitkv_ref(
        *q, pc, pr, ps, pt, cache.seq_lens, softmax_scale=SCALE,
        num_splits=num_splits, fmt=fmt, return_partials=True)
    assert not np.isnan(np.asarray(o_k)).any()
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lse_k), np.asarray(lse_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sp_k), np.asarray(sp_r),
                               rtol=1e-6, atol=0)
    np.testing.assert_allclose(np.asarray(op_k), np.asarray(op_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lp_k), np.asarray(lp_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("N,page", [(128, 32), (256, 64), (512, 64)])
@pytest.mark.parametrize("num_splits", [2, 4])
def test_paged_splitkv_matches_contiguous_across_contexts(N, page, num_splits):
    """num_splits × context grid: the paged kernel on a shuffled pool equals
    the contiguous split-KV kernel on the same data — the page table is pure
    addressing, never arithmetic."""
    B = 3
    lens = [N // 3, N // 2, N]
    cache, q, (pc, pr, ps, pt) = _pool_setup(
        jax.random.PRNGKey(N + num_splits), B, N, N, 32, 16, "fp8_e4m3",
        page, seq_lens=lens)
    o_p, lse_p = mla_decode_paged_splitkv_pallas(
        *q, pc, pr, ps, pt, cache.seq_lens, softmax_scale=SCALE,
        num_splits=num_splits)
    o_c, lse_c = mla_decode_splitkv_pallas(
        *q, cache.content, cache.rope.astype(jnp.float32), cache.scale,
        cache.seq_lens, softmax_scale=SCALE, num_splits=num_splits,
        block_n=page)
    np.testing.assert_allclose(np.asarray(o_p), np.asarray(o_c),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lse_p), np.asarray(lse_c),
                               rtol=1e-5, atol=1e-5)


def test_paged_splitkv_single_token_sequences():
    """seq_lens == 1 everywhere: one live token in one live page, every other
    page dead — the extreme early-exit case must stay NaN-free and match."""
    B, N, page = 2, 256, 32
    cache, q, (pc, pr, ps, pt) = _pool_setup(
        jax.random.PRNGKey(11), B, N, N, 32, 16, "fp8_e4m3", page,
        seq_lens=[1, 1])
    for s in (1, 2, 4):
        o_k, _ = mla_decode_paged_splitkv_pallas(
            *q, pc, pr, ps, pt, cache.seq_lens, softmax_scale=SCALE,
            num_splits=s)
        o_r, _ = R.snapmla_decode_paged_splitkv_ref(
            *q, pc, pr, ps, pt, cache.seq_lens, softmax_scale=SCALE,
            num_splits=s)
        assert not np.isnan(np.asarray(o_k)).any()
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   rtol=1e-5, atol=1e-5)


def test_paged_splitkv_one_split_bit_identical_to_seed_paged_kernel():
    """num_splits=1 with every page live runs the identical op sequence as
    the seed serial-page kernel (shared block pipeline) -> bitwise equal."""
    B, N, page = 2, 256, 32
    cache, q, (pc, pr, ps, pt) = _pool_setup(
        jax.random.PRNGKey(1), B, N, N, 32, 16, "fp8_e4m3", page,
        seq_lens=[N, N])
    o_s, lse_s = mla_decode_paged_pallas(
        *q, pc, pr, ps, pt, cache.seq_lens, softmax_scale=SCALE)
    o_1, lse_1 = mla_decode_paged_splitkv_pallas(
        *q, pc, pr, ps, pt, cache.seq_lens, softmax_scale=SCALE, num_splits=1)
    assert np.array_equal(np.asarray(o_s), np.asarray(o_1))
    assert np.array_equal(np.asarray(lse_s), np.asarray(lse_1))


def test_ops_paged_dispatch_and_ref_path():
    """ops.snapmla_decode_paged: fixed splits, auto, and the use_kernel=False
    oracle path all agree; oversized fixed splits are clamped."""
    from repro.core.kvcache import PagedMLAPool

    B, N, page = 2, 256, 32
    cache, q, (pc, pr, ps, pt) = _pool_setup(
        jax.random.PRNGKey(2), B, N, N, 32, 16, "fp8_e4m3", page,
        seq_lens=[70, 256])
    pool = PagedMLAPool(content=pc, rope=pr.astype(jnp.bfloat16), scale=ps,
                        page_table=pt, seq_lens=cache.seq_lens)
    o_auto, _ = snapmla_decode_paged(*q, pool, softmax_scale=SCALE)
    o_4, _ = snapmla_decode_paged(*q, pool, softmax_scale=SCALE, num_splits=4)
    o_ref, _ = snapmla_decode_paged(*q, pool, softmax_scale=SCALE,
                                    num_splits=4, use_kernel=False)
    o_big, _ = snapmla_decode_paged(*q, pool, softmax_scale=SCALE,
                                    num_splits=64)   # > P pages -> clamped
    for o in (o_auto, o_4, o_ref, o_big):
        assert not np.isnan(np.asarray(o)).any()
    np.testing.assert_allclose(np.asarray(o_4), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(o_4), np.asarray(o_auto),
                               rtol=0.05, atol=1e-4)   # quant rounding only


def test_paged_early_exit_insensitive_to_pool_capacity():
    """Growing the pool AND the page-table span with dead pages must not
    change the output — the clamped index maps never address past the last
    live page, so work tracks seq_lens, not capacity."""
    B, N, page = 2, 128, 32
    cache, q, (pc, pr, ps, pt) = _pool_setup(
        jax.random.PRNGKey(3), B, N, N, 32, 16, "fp8_e4m3", page,
        seq_lens=[50, 100])
    o_small, lse_small = mla_decode_paged_splitkv_pallas(
        *q, pc, pr, ps, pt, cache.seq_lens, softmax_scale=SCALE, num_splits=2)
    # double the logical span: extra table entries point at a garbage page
    n_pool = pc.shape[0]
    garbage = jnp.full((B, N // page), n_pool - 1, jnp.int32)
    pt_wide = jnp.concatenate([pt, garbage], axis=1)
    pc_dirty = pc.at[n_pool - 1].set(
        jnp.full(pc.shape[1:], 100.0).astype(pc.dtype))
    o_wide, lse_wide = mla_decode_paged_splitkv_pallas(
        *q, pc_dirty, pr, ps, pt_wide, cache.seq_lens, softmax_scale=SCALE,
        num_splits=2)
    np.testing.assert_allclose(np.asarray(o_small), np.asarray(o_wide),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(lse_small), np.asarray(lse_wide),
                               rtol=1e-6, atol=1e-6)


def test_benchmark_paged_blocks_visited_scales_with_seq_lens():
    """Acceptance: the paged sweep's effective-blocks-visited follows
    seq_lens, not the pool capacity, and splits shorten the critical path."""
    from benchmarks.kernel_perf import paged_splitkv_sweep
    rows = {(r["pool_capacity"], r["num_splits"]): r
            for r in paged_splitkv_sweep(pool_capacities=(32768, 131072),
                                         seq_len=8192)}
    r32, r128 = rows[(32768, 1)], rows[(131072, 1)]
    # 4x the pool capacity, same seq_lens -> same blocks visited
    assert r128["blocks_visited"] == r32["blocks_visited"] == 8192 // 128
    assert r128["total_blocks"] == 4 * r32["total_blocks"]
    assert r128["early_exit_savings"] > r32["early_exit_savings"]
    # splits shorten the critical path, not the bytes
    r32s8 = rows[(32768, 8)]
    assert r32s8["blocks_visited"] == r32["blocks_visited"]
    assert r32s8["critical_path_blocks"] == -(-r32["blocks_visited"] // 8)


def test_paged_cache_append_prefill_match_contiguous():
    """paged_mla_prefill + paged_mla_append reproduce the contiguous cache
    contents through the page-table gather (identity layout)."""
    B, max_len, d_c, d_r, page = 2, 96, 16, 8, 32
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=page)
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 4)
    S = 40
    ckv, kr = (jax.random.normal(ks[0], (B, S, d_c)),
               jax.random.normal(ks[1], (B, S, d_r)))
    c1, k1 = (jax.random.normal(ks[2], (B, d_c)),
              jax.random.normal(ks[3], (B, d_r)))

    contig = mla_prefill(init_mla_cache(cfg, B, max_len, d_c, d_r), cfg, ckv, kr)
    contig = mla_append(contig, cfg, c1, k1)
    paged = paged_mla_prefill(init_paged_mla_cache(cfg, B, max_len, d_c, d_r),
                              cfg, ckv, kr)
    paged = paged_mla_append(paged, cfg, c1, k1)

    gc, gr, gs = paged_gather(paged)
    np.testing.assert_array_equal(np.asarray(paged.seq_lens),
                                  np.asarray(contig.seq_lens))
    np.testing.assert_array_equal(np.asarray(gc, np.float32),
                                  np.asarray(contig.content, np.float32))
    np.testing.assert_array_equal(np.asarray(gr, np.float32),
                                  np.asarray(contig.rope, np.float32))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(contig.scale))


def test_paged_append_past_capacity_clamps_to_final_slot():
    """Appending beyond capacity must degrade like the contiguous cache —
    overwrite the FINAL slot — not corrupt the first slot of the last page
    (which holds a live mid-sequence token)."""
    B, d_c, d_r, page = 2, 8, 4, 32
    cfg = CacheConfig(fmt="none", page_size=page)
    pool = init_paged_mla_cache(cfg, B, 2 * page, d_c, d_r)   # capacity 64
    key = jax.random.PRNGKey(5)
    ckv = jax.random.normal(key, (B, 64, d_c))
    kr = jax.random.normal(key, (B, 64, d_r))
    pool = paged_mla_prefill(pool, cfg, ckv, kr)              # full
    sentinel_first_of_last_page = np.asarray(
        paged_gather(pool)[0], np.float32)[:, page]           # token 32
    pool = paged_mla_append(pool, cfg, jnp.ones((B, d_c)), jnp.ones((B, d_r)))
    gc, _, _ = paged_gather(pool)
    gc = np.asarray(gc, np.float32)
    # final slot overwritten, mid-sequence token untouched
    np.testing.assert_array_equal(gc[:, -1], np.ones((B, d_c), np.float32))
    np.testing.assert_array_equal(gc[:, page], sentinel_first_of_last_page)


def test_snapmla_layer_paged_matches_contiguous():
    """Public SnapMLA layer API with cfg.paged=True (prefill + decode through
    the real paged kernels) tracks the contiguous-cache layer closely; with
    num_splits=1 and full pages the underlying op sequence is the seed one."""
    from repro.core import mla as M
    from repro.core.snapmla import SnapMLAConfig, decode_step, init_cache, prefill

    cfg_mla = M.MLAConfig(d_model=96, n_heads=4, d_head=24, d_rope=12, d_c=48)
    params = M.init_mla_params(jax.random.PRNGKey(0), cfg_mla)
    B, S = 2, 30
    h = jax.random.normal(jax.random.PRNGKey(1), (B, S, 96))
    steps = jax.random.normal(jax.random.PRNGKey(2), (5, B, 96))

    outs = {}
    for paged in (False, True):
        cfg = SnapMLAConfig(mla=cfg_mla,
                            cache=CacheConfig(fmt="fp8_e4m3", page_size=32),
                            paged=paged, num_splits=2)
        cache = init_cache(cfg, B, 128)
        _, cache = prefill(params, cfg, h, cache)
        acc = []
        for t in range(5):
            o, cache = decode_step(params, cfg, steps[t], cache)
            acc.append(o)
        outs[paged] = np.asarray(jnp.stack(acc))
        assert int(cache.seq_lens[0]) == S + 5
    # contiguous path uses the fused-K-append kernel, paged the jnp append —
    # same quantization arithmetic, so outputs agree to float tolerance
    np.testing.assert_allclose(outs[True], outs[False], rtol=1e-4, atol=1e-5)


def test_model_paged_decode_token_exact_vs_contiguous():
    """End to end: kv_paged=True generation equals the contiguous cache
    generation token for token (identity page layout, same arithmetic)."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.launch.serve import generate
    from repro.models import transformer as T

    cfg = get_smoke_config("mla-7b")
    key = jax.random.PRNGKey(0)
    params = T.init_model(key, cfg)
    prompts = jax.random.randint(key, (2, 16), 0, cfg.vocab_size, jnp.int32)
    toks_contig, _ = generate(cfg, params, prompts, 5)
    cfg_paged = dataclasses.replace(cfg, kv_paged=True)
    toks_paged, _ = generate(cfg_paged, params, prompts, 5)
    np.testing.assert_array_equal(np.asarray(toks_contig),
                                  np.asarray(toks_paged))
