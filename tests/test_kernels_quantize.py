"""Fused token-preparation kernels (paper §3.3.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kvcache import CacheConfig, init_mla_cache, mla_prefill, mla_append
from repro.kernels.quantize import ref as R
from repro.kernels.quantize.ops import fused_k_append, fused_q_quant


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "int8"])
@pytest.mark.parametrize("B,H,d_c,d_r", [(1, 4, 32, 16), (3, 8, 64, 16)])
def test_fused_q_quant_matches_ref(fmt, B, H, d_c, d_r):
    q = jax.random.normal(jax.random.PRNGKey(B + H), (B, H, d_c + d_r)) * 4
    qc_k, qr_k, sq_k = fused_q_quant(q, d_c, fmt=fmt)
    qc_r, qr_r, sq_r = R.fused_q_quant_ref(q, d_c, fmt=fmt)
    np.testing.assert_allclose(np.asarray(qc_k, np.float32),
                               np.asarray(qc_r, np.float32), atol=1e-6)
    np.testing.assert_allclose(np.asarray(qr_k), np.asarray(qr_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sq_k), np.asarray(sq_r), rtol=1e-6)


def test_fused_k_append_matches_ref_and_is_paged():
    B, d_c, d_r, page, N = 3, 32, 16, 32, 128
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=page)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    cache = mla_prefill(init_mla_cache(cfg, B, N, d_c, d_r), cfg,
                        jax.random.normal(ks[0], (B, 70, d_c)),
                        jax.random.normal(ks[1], (B, 70, d_r)))
    c_new = jax.random.normal(ks[2], (B, d_c)) * 3
    r_new = jax.random.normal(ks[3], (B, d_r)) * 10
    out_k = fused_k_append(cache, c_new, r_new, page=page)
    out_r = fused_k_append(cache, c_new, r_new, page=page, use_kernel=False)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)
    # rows outside the written page untouched
    np.testing.assert_array_equal(np.asarray(out_k.content[:, :64], np.float32),
                                  np.asarray(cache.content[:, :64], np.float32))


def test_sequential_appends_equal_prefill():
    """Instant per-token quantization (decode) == bulk prefill quantization —
    the property that eliminates the paper's 'page tail' buffer management."""
    B, d_c, d_r, N, S = 2, 32, 16, 64, 40
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=16)
    key = jax.random.PRNGKey(1)
    c = jax.random.normal(key, (B, S, d_c)) * 2
    r = jax.random.normal(jax.random.PRNGKey(2), (B, S, d_r)) * 20
    bulk = mla_prefill(init_mla_cache(cfg, B, N, d_c, d_r), cfg, c, r)
    inc = init_mla_cache(cfg, B, N, d_c, d_r)
    for t in range(S):
        inc = fused_k_append(inc, c[:, t], r[:, t], page=16)
    np.testing.assert_allclose(np.asarray(bulk.content, np.float32),
                               np.asarray(inc.content, np.float32), atol=1e-6)
    np.testing.assert_allclose(np.asarray(bulk.scale), np.asarray(inc.scale),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(bulk.rope, np.float32),
                               np.asarray(inc.rope, np.float32),
                               rtol=2e-2, atol=2e-2)  # bf16 storage
