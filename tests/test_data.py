"""Data pipeline: determinism, host sharding, resumability."""
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, batch_iterator, host_slice, synth_batch

CFG = DataConfig(vocab_size=128, seq_len=16, global_batch=8, seed=7)


def test_deterministic_per_step():
    a = synth_batch(CFG, 3)
    b = synth_batch(CFG, 3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = synth_batch(CFG, 4)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_host_slices_partition_global_batch():
    full = synth_batch(CFG, 0)
    parts = [host_slice(CFG, 0, h, 4) for h in range(4)]
    glued = np.concatenate([np.asarray(p["tokens"]) for p in parts])
    np.testing.assert_array_equal(glued, np.asarray(full["tokens"]))


def test_iterator_resumes():
    it = batch_iterator(CFG, start_step=5)
    step, batch = next(it)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(batch["tokens"]),
                                  np.asarray(synth_batch(CFG, 5)["tokens"]))


def test_labels_are_shifted_tokens():
    b = synth_batch(CFG, 1)
    assert b["tokens"].shape == b["labels"].shape == (8, 16)
    # the underlying sequence is contiguous: labels[t] == tokens[t+1]
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))
