"""Sharding-rule unit tests: rank correctness, divisibility sanitization,
weight-stationary mode, and the attention-fallback policy from §Perf."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config, get_config
from repro.launch import sharding as SH
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(1)     # (n_devices, 1) ('data','model')


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "deepseek-v3-mla",
                                  "recurrentgemma-9b", "xlstm-1.3b"])
def test_param_pspecs_rank_matches(mesh, arch):
    cfg = get_smoke_config(arch)
    params = jax.eval_shape(
        lambda: T.init_model(jax.random.PRNGKey(0), cfg))
    specs = SH.param_pspecs(params, mesh)
    for leaf, spec in zip(jax.tree.leaves(params), jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))):
        assert len(spec) <= leaf.ndim, (leaf.shape, spec)


def test_sanitize_drops_nondivisible_axes():
    import numpy as np
    mesh = jax.make_mesh((1,), ("model",))

    class FakeMesh:
        shape = {"model": 16, "data": 4}
    ps = SH.sanitize_pspec(P("model", "data"), (49155, 2048), FakeMesh())
    assert ps == P(None, "data")          # 49155 % 16 != 0 -> replicated
    ps = SH.sanitize_pspec(P("model", None), (32, 8), FakeMesh())
    assert ps == P("model", None)


def test_weight_stationary_removes_dp_axes(mesh):
    cfg = get_smoke_config("mla-7b")
    params = jax.eval_shape(lambda: T.init_model(jax.random.PRNGKey(0), cfg))
    specs = SH.param_pspecs(params, mesh, weight_stationary=True)
    for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        assert "data" not in [a for part in spec if part
                              for a in (part if isinstance(part, tuple) else (part,))]


def test_attn_fallback_policy():
    """Heads not divisible by the model axis: train replicates, decode may
    shard head_dim (EXPERIMENTS §Perf: the 8.2x train collective fix)."""
    class FakeMesh:
        shape = {"model": 16, "data": 4}
        axis_names = ("data", "model")
    rules_train = SH._rules("data", "model", 16, attn_fallback="replicate")
    rules_serve = SH._rules("data", "model", 16, attn_fallback="shard_dh")
    shape = (3072, 24, 128)     # llama3.2-3b wq: H=24 not divisible by 16
    assert rules_train["wq"](shape) == P("data", None, None)
    assert rules_serve["wq"](shape) == P("data", None, "model")
    shape_ok = (3072, 32, 128)
    assert rules_train["wq"](shape_ok) == P("data", "model", None)
    # xLSTM contraction operands are never model-sharded
    assert rules_train["w_q"]((2048, 4, 512)) == P("data", None, None)
    assert rules_train["w_v"]((2048, 4, 512)) == P("data", None, "model")


def test_dp_axes_for_small_batch(mesh):
    big = SH.dp_axes_for(16 * SH.dp_size(mesh), mesh)
    assert big is not None
    assert SH.dp_axes_for(1, mesh) is None or SH.dp_size(mesh) == 1
