"""Decode-attention backend registry: resolution rules and supports
predicates, numerical agreement between backends on the same cache, and
model-level token-exactness of the kernel backends vs the einsum-twin path
across contiguous/paged × fused/step-loop generation. Plus the generation
satellites that ride the same serve path: temperature/top-k sampling in the
fused scan, EOS early-stop, and the exact page-aligned cache sizing."""
import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.kvcache import (CacheConfig, init_mla_cache,
                                init_paged_mla_cache, mla_prefill,
                                page_aligned_capacity, paged_mla_prefill)
from repro.kernels.mla_decode import backends as BK
from repro.kernels.mla_decode import ref as R
from repro.launch import steps as ST
from repro.launch.serve import _decode_capacity, generate, generate_fused
from repro.models import transformer as T

BACKENDS = ("jnp_ref", "jnp_paged_ref", "pallas_splitkv",
            "pallas_paged_splitkv", "shard_map")


# ---------------------------------------------------------------------------
# registry + resolution
# ---------------------------------------------------------------------------

def test_registry_contains_all_backends():
    assert set(BACKENDS) <= set(BK.backend_names())
    for name in BACKENDS:
        b = BK.get_backend(name)
        assert b.name == name and callable(b.decode) and callable(b.supports)


def test_get_backend_unknown_raises():
    with pytest.raises(ValueError, match="unknown decode backend"):
        BK.get_backend("cuda_flash")


def test_supports_layout_mismatch():
    ok, why = BK.get_backend("jnp_ref").supports(None, None, 2, paged=True)
    assert not ok and "contiguous" in why
    ok, _ = BK.get_backend("jnp_paged_ref").supports(None, None, 2, paged=True)
    assert ok
    ok, why = BK.get_backend("pallas_paged_splitkv").supports(
        None, None, 2, paged=False)
    assert not ok and "paged" in why


def test_supports_kernel_rejects_multi_device_mesh():
    mesh8 = types.SimpleNamespace(size=8)
    ok, why = BK.get_backend("pallas_splitkv").supports(None, mesh8, 2)
    assert not ok and "pjit" in why
    ok, _ = BK.get_backend("pallas_splitkv").supports(
        None, types.SimpleNamespace(size=1), 2)
    assert ok


def test_supports_shard_map_requires_mesh_and_divisibility():
    sm = BK.get_backend("shard_map")
    assert not sm.supports(None, None, 2, n_heads=4)[0]
    mesh = types.SimpleNamespace(size=2, shape={"model": 2})
    assert sm.supports(None, mesh, 2, n_heads=4)[0]
    ok, why = sm.supports(None, mesh, 2, n_heads=3)   # 3 % 2 != 0
    assert not ok and "divide" in why
    assert not sm.supports(None, mesh, 2, paged=True, n_heads=4)[0]


def test_resolve_auto_defaults_to_ref_twin():
    assert BK.resolve_backend("auto", paged=False, batch=2).name == "jnp_ref"
    assert BK.resolve_backend("auto", paged=True, batch=2).name \
        == "jnp_paged_ref"


def test_resolve_auto_use_kernels_selects_pallas():
    assert BK.resolve_backend("auto", paged=False, batch=2,
                              use_kernels=True).name == "pallas_splitkv"
    assert BK.resolve_backend("auto", paged=True, batch=2,
                              use_kernels=True).name == "pallas_paged_splitkv"
    # a multi-device pjit mesh degrades auto back to the ref twin (no raise)
    mesh8 = types.SimpleNamespace(size=8, shape={"model": 8})
    assert BK.resolve_backend("auto", paged=False, batch=2, n_heads=3,
                              mesh=mesh8, use_kernels=True).name == "jnp_ref"


def test_resolve_auto_prefers_shard_map_when_applicable():
    mesh = types.SimpleNamespace(size=2, shape={"model": 2})
    picked = BK.resolve_backend("auto", paged=False, batch=2, n_heads=4,
                                mesh=mesh, prefer_shard_map=True)
    assert picked.name == "shard_map"
    # not applicable (indivisible heads) -> quiet fallback, like the old
    # use_shard_map branch in transformer._mla_decode
    picked = BK.resolve_backend("auto", paged=False, batch=2, n_heads=3,
                                mesh=mesh, prefer_shard_map=True)
    assert picked.name == "jnp_ref"
    # paged caches never shard_map
    picked = BK.resolve_backend("auto", paged=True, batch=2, n_heads=4,
                                mesh=mesh, prefer_shard_map=True)
    assert picked.name == "jnp_paged_ref"


def test_resolve_aliases_follow_cache_layout():
    assert BK.resolve_backend("ref", paged=True, batch=2).name \
        == "jnp_paged_ref"
    assert BK.resolve_backend("kernel", paged=True, batch=2).name \
        == "pallas_paged_splitkv"
    assert BK.resolve_backend("kernel", paged=False, batch=2).name \
        == "pallas_splitkv"
    # exact registry names resolve too
    assert BK.resolve_backend("pallas_splitkv", paged=False, batch=2).name \
        == "pallas_splitkv"


def test_resolve_explicit_unsupported_raises():
    with pytest.raises(ValueError, match="shard_map"):
        BK.resolve_backend("shard-map", paged=False, batch=2, n_heads=4)
    mesh8 = types.SimpleNamespace(size=8)
    with pytest.raises(ValueError, match="pjit"):
        BK.resolve_backend("kernel", paged=False, batch=2, mesh=mesh8)
    with pytest.raises(ValueError, match="unknown decode backend"):
        BK.resolve_backend("triton", paged=False, batch=2)


# ---------------------------------------------------------------------------
# backend numerical agreement (direct uniform-signature calls)
# ---------------------------------------------------------------------------

def _setup(paged: bool, B=2, S=100, N=128, d_c=32, d_r=16, H=4, page=32,
           fmt="fp8_e4m3"):
    cfg = CacheConfig(fmt=fmt, page_size=page)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    init = init_paged_mla_cache if paged else init_mla_cache
    fill = paged_mla_prefill if paged else mla_prefill
    cache = fill(init(cfg, B, N, d_c, d_r), cfg,
                 jax.random.normal(ks[0], (B, S, d_c)),
                 jax.random.normal(ks[1], (B, S, d_r)))
    q = BK.DecodeQuery(*R.prepare_q(jax.random.normal(ks[2], (B, H, d_c)),
                                    jax.random.normal(ks[3], (B, H, d_r)),
                                    fmt))
    bcfg = BK.BackendConfig(softmax_scale=0.1, block_n=page, fmt=fmt,
                            num_splits=2)
    return q, cache, bcfg


@pytest.mark.parametrize("paged", [False, True])
def test_kernel_backend_matches_ref_backend(paged):
    """The uniform decode signature: ref and Pallas backends agree on the
    same cache to kernel tolerance, for both layouts and split counts."""
    q, cache, bcfg = _setup(paged)
    ref = BK.get_backend("jnp_paged_ref" if paged else "jnp_ref")
    ker = BK.get_backend("pallas_paged_splitkv" if paged
                         else "pallas_splitkv")
    for splits in (1, 2, 4):
        c = dataclasses.replace(bcfg, num_splits=splits)
        o_r = ref.decode(q, cache, c, None)
        o_k = ker.decode(q, cache, c, None)
        assert not np.isnan(np.asarray(o_k)).any()
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   rtol=1e-5, atol=1e-5)


def test_backend_decode_is_jittable():
    """backend.decode traces under jit — the property the model decode step
    relies on (the whole point of the registry)."""
    q, cache, bcfg = _setup(paged=False)
    ker = BK.get_backend("pallas_splitkv")
    o_jit = jax.jit(lambda q, c: ker.decode(q, c, bcfg, None))(q, cache)
    o_eager = ker.decode(q, cache, bcfg, None)
    np.testing.assert_allclose(np.asarray(o_jit), np.asarray(o_eager),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# model-level token-exactness: use_kernels vs the einsum twins
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fused", [False, True], ids=["step-loop", "fused"])
@pytest.mark.parametrize("paged", [False, True],
                         ids=["contiguous", "paged"])
def test_model_use_kernels_token_exact(paged, fused):
    """Acceptance matrix: generation with the Pallas kernels inside the
    jitted model decode (use_kernels=True under backend 'auto') is
    token-exact with the einsum-twin path, contiguous/paged × fused/step."""
    cfg = dataclasses.replace(get_smoke_config("mla-7b"), kv_paged=paged)
    key = jax.random.PRNGKey(0)
    params = T.init_model(key, cfg)
    prompts = jax.random.randint(key, (2, 16), 0, cfg.vocab_size, jnp.int32)
    gen_fn = generate_fused if fused else generate
    toks_ref, _ = gen_fn(cfg, params, prompts, 5)
    cfg_k = dataclasses.replace(cfg, use_kernels=True)
    toks_ker, _ = gen_fn(cfg_k, params, prompts, 5)
    np.testing.assert_array_equal(np.asarray(toks_ref), np.asarray(toks_ker))


def test_model_explicit_kernel_backend_matches_use_kernels():
    """decode_backend='kernel' (the serve --backend kernel spelling) runs the
    same path as use_kernels=True under 'auto'."""
    cfg = get_smoke_config("mla-7b")
    key = jax.random.PRNGKey(1)
    params = T.init_model(key, cfg)
    prompts = jax.random.randint(key, (2, 12), 0, cfg.vocab_size, jnp.int32)
    a, _ = generate(dataclasses.replace(cfg, use_kernels=True), params,
                    prompts, 4)
    b, _ = generate(dataclasses.replace(cfg, decode_backend="kernel",
                                        use_kernels=True), params, prompts, 4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# sampling + EOS satellites (generate_fused beyond greedy)
# ---------------------------------------------------------------------------

def test_sample_logits_greedy_is_argmax():
    logits = jax.random.normal(jax.random.PRNGKey(0), (3, 17))
    got = ST.sample_logits(logits, None)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.argmax(logits, -1)))
    assert got.dtype == jnp.int32


def test_sample_logits_top_k_stays_in_support():
    """Every draw lands inside the top-k set, and a tiny temperature
    concentrates on the argmax."""
    logits = jnp.array([[0.0, 5.0, 4.0, -1.0, 3.0, 2.0, 1.0, -2.0]])
    topk = {1, 2}                                     # top-2 indices
    for i in range(64):
        tok = int(ST.sample_logits(logits, jax.random.PRNGKey(i),
                                   temperature=1.0, top_k=2)[0])
        assert tok in topk
    cold = int(ST.sample_logits(logits, jax.random.PRNGKey(0),
                                temperature=1e-4, top_k=0)[0])
    assert cold == 1


def test_sample_logits_top_p_stays_in_nucleus():
    """Every nucleus draw lands inside the smallest token set whose
    cumulative probability reaches top_p (the most-probable token is always
    kept), and top_p composes after top_k."""
    # softmax of [5, 4, 3, ...] puts ~0.66 on idx 1, ~0.24 on idx 2: the 0.8
    # nucleus is exactly {1, 2}
    logits = jnp.array([[0.0, 5.0, 4.0, -1.0, 3.0, 2.0, 1.0, -2.0]])
    for i in range(64):
        tok = int(ST.sample_logits(logits, jax.random.PRNGKey(i),
                                   temperature=1.0, top_p=0.8)[0])
        assert tok in {1, 2}
    # a tiny top_p keeps only the argmax
    for i in range(16):
        tok = int(ST.sample_logits(logits, jax.random.PRNGKey(i),
                                   temperature=1.0, top_p=1e-6)[0])
        assert tok == 1
    # top_k=3 -> {1, 2, 4}; the 0.8 nucleus of the renormalized trio
    # (0.66 + 0.25 + 0.09) drops idx 4
    for i in range(64):
        tok = int(ST.sample_logits(logits, jax.random.PRNGKey(i),
                                   temperature=1.0, top_k=3, top_p=0.8)[0])
        assert tok in {1, 2}


def test_sample_logits_top_p_disabled_matches_plain():
    """top_p = 0 and top_p >= 1 are no-ops: identical draws to the plain
    temperature path, and greedy ignores top_p entirely."""
    logits = jax.random.normal(jax.random.PRNGKey(3), (4, 33))
    key = jax.random.PRNGKey(4)
    plain = ST.sample_logits(logits, key, temperature=0.7)
    for p in (0.0, 1.0, 2.0):
        got = ST.sample_logits(logits, key, temperature=0.7, top_p=p)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(plain))
    np.testing.assert_array_equal(
        np.asarray(ST.sample_logits(logits, None, top_p=0.5)),
        np.asarray(jnp.argmax(logits, -1)))


def test_fused_sampling_deterministic_per_seed():
    """temperature>0 threads ONE key through the scan carry: same seed ->
    identical tokens, and every token is a valid vocab id."""
    cfg = get_smoke_config("mla-7b")
    key = jax.random.PRNGKey(2)
    params = T.init_model(key, cfg)
    prompts = jax.random.randint(key, (2, 12), 0, cfg.vocab_size, jnp.int32)
    kw = dict(temperature=0.8, top_k=8, top_p=0.9, seed=7)
    a, _ = generate_fused(cfg, params, prompts, 6, **kw)
    b, _ = generate_fused(cfg, params, prompts, 6, **kw)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (2, 6)
    assert (np.asarray(a) >= 0).all() and (np.asarray(a) < cfg.vocab_size).all()
    # the step loop samples through the same sample_logits (incl. top_p)
    c, _ = generate(cfg, params, prompts, 6, **kw)
    d, _ = generate(cfg, params, prompts, 6, **kw)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(d))


@pytest.mark.parametrize("fused", [False, True], ids=["step-loop", "fused"])
def test_eos_pins_every_token_after_first_hit(fused):
    """EOS semantics on both generation paths: pick the token the greedy run
    emits mid-generation as eos_id and re-run — every slot after a row's
    first EOS must be EOS, shape stays [B, gen_steps]."""
    cfg = get_smoke_config("mla-7b")
    key = jax.random.PRNGKey(3)
    params = T.init_model(key, cfg)
    prompts = jax.random.randint(key, (2, 12), 0, cfg.vocab_size, jnp.int32)
    gen_fn = generate_fused if fused else generate
    free, _ = gen_fn(cfg, params, prompts, 6)
    eos = int(free[0, 2])
    toks, _ = gen_fn(cfg, params, prompts, 6, eos_id=eos)
    toks = np.asarray(toks)
    assert toks.shape == (2, 6)
    for row in toks:
        hits = np.flatnonzero(row == eos)
        if hits.size:
            assert (row[hits[0]:] == eos).all()
    # row 0 hits eos at step 2 by construction (greedy prefix is unchanged)
    assert (toks[0, 2:] == eos).all()


@pytest.mark.parametrize("paged", [False, True], ids=["contiguous", "paged"])
def test_fused_gate_finished_token_identical_and_freezes_lens(paged):
    """Finished-row gating in the fused scan: identical tokens with the gate
    on or off, but a row that hit EOS stops appending — its cache seq_lens
    freeze while unfinished rows keep growing (that frozen length is what
    lets the split-KV early exit stop streaming the row's KV blocks)."""
    cfg = dataclasses.replace(get_smoke_config("mla-7b"), kv_paged=paged)
    key = jax.random.PRNGKey(4)
    params = T.init_model(key, cfg)
    B, S, gen = 3, 16, 8
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    free, _ = generate(cfg, params, prompts, gen)
    eos = int(np.asarray(free)[0, 2])     # row 0 finishes at step 2
    max_len = _decode_capacity(cfg, S, gen)
    runs = {}
    for gate in (True, False):
        state = T.init_decode_state(cfg, B, max_len)
        logits, state = jax.jit(ST.make_prefill_step(cfg))(params, prompts,
                                                           state)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        fused = jax.jit(ST.make_fused_decode(cfg, gen - 1, eos_id=eos,
                                             gate_finished=gate),
                        donate_argnums=(2,))
        toks, state_out, ok = fused(params, tok, state,
                                    jnp.full((B,), S, jnp.int32))
        assert bool(ok), "gated finished rows must stay finite"
        lens = np.asarray(state_out["scanned"][0].seq_lens)[0]
        runs[gate] = (np.asarray(toks), lens)
    np.testing.assert_array_equal(runs[True][0], runs[False][0])
    gated, ungated = runs[True][1], runs[False][1]
    # ungated: every row appended every step; gated: row 0 froze after EOS
    assert (ungated == S + gen - 1).all()
    assert gated[0] < S + gen - 1
    # appends stop once the done mask is set (the step AFTER the first EOS):
    # resident tokens = prompt + out tokens up to and including the EOS slot
    out0 = np.concatenate([[int(np.asarray(free)[0, 0])], runs[True][0][0]])
    hit = int(np.flatnonzero(out0 == eos)[0])
    assert gated[0] == S + hit
    assert (gated[1:] == S + gen - 1).all()


def test_fused_gate_without_eos_is_bit_identical():
    """gate_finished with no eos_id is a no-op: the gated program must be
    BIT-identical to the ungated one (active mask all-true threads through
    every append unchanged)."""
    cfg = get_smoke_config("mla-7b")
    key = jax.random.PRNGKey(5)
    params = T.init_model(key, cfg)
    prompts = jax.random.randint(key, (2, 12), 0, cfg.vocab_size, jnp.int32)
    a, _ = generate_fused(cfg, params, prompts, 5)
    b, _ = generate(cfg, params, prompts, 5)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# exact page-aligned cache sizing (shared helper)
# ---------------------------------------------------------------------------

def test_generate_single_step_shapes_match():
    """gen_steps=1: both generation paths return [B, 1] (the step loop used
    to leak its warm-up token and return [B, 2])."""
    cfg = get_smoke_config("mla-7b")
    key = jax.random.PRNGKey(4)
    params = T.init_model(key, cfg)
    prompts = jax.random.randint(key, (2, 12), 0, cfg.vocab_size, jnp.int32)
    a, _ = generate(cfg, params, prompts, 1)
    b, _ = generate_fused(cfg, params, prompts, 1)
    assert a.shape == b.shape == (2, 1)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_capacity_is_exact_when_aligned():
    """S + gen already page-aligned must NOT grow by another page (the old
    serve sizing did), and unaligned sums round up to exactly one page."""
    cfg = get_smoke_config("mla-7b")           # page_size 16
    assert cfg.page_size == 16
    assert _decode_capacity(cfg, 16, 16) == 32
    assert _decode_capacity(cfg, 16, 17) == 48
    assert page_aligned_capacity(32, 16) == 32
    assert page_aligned_capacity(33, 16) == 48
    assert page_aligned_capacity(0, 16) == 16  # never a zero-capacity cache


def test_cache_initializers_share_capacity_rule():
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=32)
    contig = init_mla_cache(cfg, 2, 33, 8, 4)
    paged = init_paged_mla_cache(cfg, 2, 33, 8, 4)
    assert contig.capacity == paged.capacity == page_aligned_capacity(33, 32)
