"""Sink-aware precision guard (P-Cast-style): the first ``sink_tokens`` rows
of an MLA cache keep their raw latent c_kv in f32 alongside the quantized
pool, and every decode boundary substitutes them back so attention-sink
logits — where FP8 rounding hurts most — are computed against exact keys.

Gates: guard coherence across all three write paths (prefill, jnp append,
fused-append kernel), exact reconstruction through ``sink_patched_content``,
the unguarded no-op contract (``sink=None`` caches are structurally and
numerically untouched), end-to-end decode improvement on a sink-heavy
workload, and the benchmark grid's own gating.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kvcache import (CacheConfig, init_mla_cache, mla_append,
                                mla_prefill, sink_patched_content)

B, D_C, D_R, S_K = 2, 32, 16, 4


def _cfg(sink_tokens=S_K, fmt="fp8_e4m3"):
    return CacheConfig(fmt=fmt, page_size=32, sink_tokens=sink_tokens)


def _tokens(key, n):
    ks = jax.random.split(key, 2)
    return (jax.random.normal(ks[0], (B, n, D_C)) * 2.0,
            jax.random.normal(ks[1], (B, n, D_R)))


def test_unguarded_cache_is_structurally_unchanged():
    """sink_tokens=0 (the default everywhere) must produce sink=None, and
    ``sink_patched_content`` must return ``cache.content`` itself — the same
    object, not a copy — so unguarded traces are bit-for-bit the old ones."""
    cfg = _cfg(sink_tokens=0)
    cache = init_mla_cache(cfg, B, 64, D_C, D_R)
    assert cache.sink is None
    c_kv, k_r = _tokens(jax.random.PRNGKey(0), 8)
    cache = mla_prefill(cache, cfg, c_kv, k_r)
    assert cache.sink is None
    cache = mla_append(cache, cfg, c_kv[:, 0], k_r[:, 0])
    assert cache.sink is None
    assert sink_patched_content(cache) is cache.content


def test_prefill_sink_rows_reconstruct_exactly():
    """Guarded rows reconstruct the raw latent through the pipeline's own
    content*scale contract to f32 round-off; unguarded rows keep FP8 error."""
    cfg = _cfg()
    c_kv, k_r = _tokens(jax.random.PRNGKey(1), 16)
    cache = mla_prefill(init_mla_cache(cfg, B, 64, D_C, D_R), cfg, c_kv, k_r)
    assert cache.sink is not None and cache.sink.shape == (B, S_K, D_C)
    recon = sink_patched_content(cache).astype(jnp.float32) \
        * cache.scale[:, :, None]
    err_sink = float(jnp.max(jnp.abs(recon[:, :S_K] - c_kv[:, :S_K])))
    err_rest = float(jnp.max(jnp.abs(recon[:, S_K:16] - c_kv[:, S_K:])))
    assert err_sink < 1e-5, err_sink          # exact modulo one f32 divide
    assert err_rest > 1e-2, err_rest          # FP8 rounding still visible


def test_append_paths_keep_guard_coherent():
    """Token-by-token growth through ``mla_append`` and the fused-append
    kernel wrapper must leave the same sink state as one bulk prefill."""
    from repro.kernels.quantize.ops import fused_k_append

    cfg = _cfg()
    c_kv, k_r = _tokens(jax.random.PRNGKey(2), 8)
    bulk = mla_prefill(init_mla_cache(cfg, B, 64, D_C, D_R), cfg, c_kv, k_r)
    for use_fused in (False, True):
        cache = init_mla_cache(cfg, B, 64, D_C, D_R)
        for t in range(8):
            if use_fused:
                cache = fused_k_append(cache, c_kv[:, t], k_r[:, t],
                                       fmt=cfg.fmt, page=cfg.page_size)
            else:
                cache = mla_append(cache, cfg, c_kv[:, t], k_r[:, t])
        np.testing.assert_allclose(np.asarray(cache.sink),
                                   np.asarray(bulk.sink), rtol=0, atol=0)
        assert int(cache.seq_lens[0]) == 8


def test_gated_append_freezes_inactive_rows():
    """EOS-gated appends (active=False) must not advance the guard either:
    the inactive row's sink stays exactly as it was."""
    cfg = _cfg()
    c_kv, k_r = _tokens(jax.random.PRNGKey(3), 4)
    cache = init_mla_cache(cfg, B, 64, D_C, D_R)
    cache = mla_append(cache, cfg, c_kv[:, 0], k_r[:, 0])
    before = np.asarray(cache.sink).copy()
    active = jnp.asarray([True, False])
    cache = mla_append(cache, cfg, c_kv[:, 1], k_r[:, 1], active=active)
    after = np.asarray(cache.sink)
    np.testing.assert_allclose(after[1], before[1], rtol=0, atol=0)
    np.testing.assert_allclose(after[0, 1], np.asarray(c_kv[0, 1]),
                               rtol=0, atol=0)


def test_guard_capped_by_capacity_and_partial_prefill():
    """sink_tokens larger than the capacity clamps; a prefill shorter than
    the guard writes only its width (later appends fill the rest)."""
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=4, sink_tokens=64)
    cache = init_mla_cache(cfg, B, 8, D_C, D_R)
    assert cache.sink.shape[1] == 8           # clamped to capacity
    c_kv, k_r = _tokens(jax.random.PRNGKey(4), 2)
    cache = mla_prefill(cache, cfg, c_kv, k_r)
    np.testing.assert_allclose(np.asarray(cache.sink[:, :2]),
                               np.asarray(c_kv), rtol=0, atol=0)
    nxt, nr = _tokens(jax.random.PRNGKey(5), 1)
    cache = mla_append(cache, cfg, nxt[:, 0], nr[:, 0])
    np.testing.assert_allclose(np.asarray(cache.sink[:, 2]),
                               np.asarray(nxt[:, 0]), rtol=0, atol=0)


def test_decode_with_guard_beats_unguarded_on_sink_heavy_kv():
    """End to end through ``snapmla_decode``: on a cache whose first row
    carries an attention-sink-scale latent, arming the guard must shrink the
    decode output error vs the exact (fmt='none') oracle."""
    from repro.kernels.mla_decode import ref as R
    from repro.kernels.mla_decode.ops import snapmla_decode

    N, H = 64, 4
    key = jax.random.PRNGKey(6)
    ks = jax.random.split(key, 4)
    c_kv = jax.random.normal(ks[0], (B, N, D_C)) * 2.0
    c_kv = c_kv.at[:, 0].mul(100.0)           # the sink row dominates scale
    k_r = jax.random.normal(ks[1], (B, N, D_R))
    q_c8, q_r, sq = R.prepare_q(jax.random.normal(ks[2], (B, H, D_C)),
                                jax.random.normal(ks[3], (B, H, D_R)), "none")
    scale = 1.0 / float(np.sqrt(D_C + D_R))

    def decode(sink_tokens):
        cfg = _cfg(sink_tokens=sink_tokens)
        cache = mla_prefill(init_mla_cache(cfg, B, N, D_C, D_R), cfg,
                            c_kv, k_r)
        o, _ = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=scale,
                              block_n=32)
        return np.asarray(o)

    exact_cfg = CacheConfig(fmt="none", page_size=32)
    exact_cache = mla_prefill(init_mla_cache(exact_cfg, B, N, D_C, D_R),
                              exact_cfg, c_kv, k_r)
    o_exact, _ = snapmla_decode(q_c8, q_r, sq, exact_cache,
                                softmax_scale=scale, block_n=32, fmt="none")
    o_exact = np.asarray(o_exact)
    err_un = np.abs(decode(0) - o_exact).max()
    err_g = np.abs(decode(S_K) - o_exact).max()
    assert err_g < err_un * 0.5, (err_g, err_un)


def test_sink_guard_grid_gates():
    """The benchmark grid's own acceptance bits: guard never worse anywhere,
    strictly better max-logit error wherever a sink is present."""
    from benchmarks.numerics import sink_guard_grid

    rows = sink_guard_grid(contexts=(512,))
    assert rows and all(r["guard_ok"] for r in rows)
    for r in rows:
        if r["sink_present"]:
            assert r["max_logit_err_guarded"] < \
                0.5 * r["max_logit_err_unguarded"]
