"""Split-KV (flash-decoding) SnapMLA decode: kernel vs oracle parity, bit-
exactness of the num_splits=1 path, early-exit accounting, and token-exactness
of the fused scan-based generation loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import mla_decode_dequant_ref
from repro.core.kvcache import CacheConfig, init_mla_cache, mla_prefill
from repro.kernels.mla_decode import ref as R
from repro.kernels.mla_decode.kernel import (lse_combine_pallas,
                                             mla_decode_pallas,
                                             mla_decode_splitkv_pallas)
from repro.kernels.mla_decode.ops import default_num_splits, snapmla_decode

SCALE = 0.1
# ragged batch: empty, one-block (<= block_n), mid-block, block-aligned, full
RAGGED_LENS = [0, 20, 130, 192, 256]


def _setup(key, B, S, N, d_c, d_r, fmt, page, seq_lens=None, H=4):
    cfg = CacheConfig(fmt=fmt, page_size=page)
    ks = jax.random.split(key, 4)
    cache = mla_prefill(init_mla_cache(cfg, B, N, d_c, d_r), cfg,
                        jax.random.normal(ks[0], (B, S, d_c)) * 2,
                        jax.random.normal(ks[1], (B, S, d_r)) * 25)
    if seq_lens is not None:
        cache = cache._replace(seq_lens=jnp.asarray(seq_lens, jnp.int32))
    q_c8, q_r, sq = R.prepare_q(jax.random.normal(ks[2], (B, H, d_c)),
                                jax.random.normal(ks[3], (B, H, d_r)) * 5, fmt)
    args = (q_c8, q_r, sq, cache.content, cache.rope.astype(jnp.float32),
            cache.scale, cache.seq_lens)
    return cache, args


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "int8", "none"])
@pytest.mark.parametrize("num_splits", [1, 2, 4])
def test_splitkv_kernel_matches_ref_ragged(fmt, num_splits):
    """Kernel == jnp split+combine oracle on ragged lens (incl. 0, one-block),
    partials (o, lse, sigma_p) included."""
    B, N, bn = len(RAGGED_LENS), 256, 32
    _, args = _setup(jax.random.PRNGKey(0), B, N, N, 32, 16, fmt, bn,
                     seq_lens=RAGGED_LENS)
    o_k, lse_k, (op_k, lp_k, sp_k) = mla_decode_splitkv_pallas(
        *args, softmax_scale=SCALE, num_splits=num_splits, block_n=bn,
        fmt=fmt, return_partials=True)
    o_r, lse_r, (op_r, lp_r, sp_r) = R.snapmla_decode_splitkv_ref(
        *args, softmax_scale=SCALE, num_splits=num_splits, block_n=bn,
        fmt=fmt, return_partials=True)
    assert not np.isnan(np.asarray(o_k)).any()
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=1e-5, atol=1e-5)
    # lse of the empty row is the NEG_INF sentinel on both sides
    np.testing.assert_allclose(np.asarray(lse_k), np.asarray(lse_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sp_k), np.asarray(sp_r),
                               rtol=1e-6, atol=0)
    np.testing.assert_allclose(np.asarray(op_k), np.asarray(op_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lp_k), np.asarray(lp_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fmt,tol", [("fp8_e4m3", 0.06), ("int8", 0.03),
                                     ("none", 1e-4)])
def test_splitkv_vs_dequant_oracle(fmt, tol):
    """Splitting must not change accuracy: only P-quantization (whose rounding
    depends on the per-split max history) separates split-KV from the exact
    dequantize-first oracle; fmt='none' is quantization-free, hence tight."""
    B, N, bn = 4, 256, 64
    cache, args = _setup(jax.random.PRNGKey(3), B, 200, N, 64, 16, fmt, bn,
                         seq_lens=[50, 100, 200, 200], H=8)
    o_k, _ = mla_decode_splitkv_pallas(*args, softmax_scale=SCALE,
                                       num_splits=4, block_n=bn, fmt=fmt)
    q_c8, q_r, sq = args[:3]
    q_lat = q_c8.astype(jnp.float32) * sq[..., None]
    q_rd = q_r * sq[..., None]
    o_e = mla_decode_dequant_ref(q_lat, q_rd, cache, SCALE)
    rel = np.abs(np.asarray(o_k - o_e)).max() / np.abs(np.asarray(o_e)).max()
    assert rel < tol, rel


def test_splitkv_one_split_bit_identical_to_seed_kernel():
    """With every block live, num_splits=1 runs the identical op sequence as
    the seed kernel (shared block pipeline) -> bitwise-equal output."""
    B, N, bn = 2, 256, 32
    _, args = _setup(jax.random.PRNGKey(1), B, N, N, 32, 16, "fp8_e4m3", bn,
                     seq_lens=[N, N])
    o_s, lse_s = mla_decode_pallas(*args, softmax_scale=SCALE, block_n=bn)
    o_1, lse_1 = mla_decode_splitkv_pallas(*args, softmax_scale=SCALE,
                                           num_splits=1, block_n=bn)
    assert np.array_equal(np.asarray(o_s), np.asarray(o_1))
    assert np.array_equal(np.asarray(lse_s), np.asarray(lse_1))


def test_ops_num_splits_one_dispatches_bit_exact():
    """ops.snapmla_decode(num_splits=1) reproduces today's path bit-exactly
    on ragged lens (it dispatches to the seed kernel)."""
    B, N, bn = 3, 256, 32
    cache, args = _setup(jax.random.PRNGKey(2), B, N, N, 32, 16, "fp8_e4m3",
                         bn, seq_lens=[40, 100, 256])
    q_c8, q_r, sq = args[:3]
    o_seed, lse_seed = mla_decode_pallas(*args, softmax_scale=SCALE, block_n=bn)
    o_1, lse_1 = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=SCALE,
                                block_n=bn, num_splits=1)
    assert np.array_equal(np.asarray(o_seed), np.asarray(o_1))
    assert np.array_equal(np.asarray(lse_seed), np.asarray(lse_1))


@pytest.mark.parametrize("num_splits", [2, 4])
def test_splitkv_matches_single_pass_within_quant_tol(num_splits):
    """Split count only perturbs P-quantization rounding, never the math."""
    B, N, bn = 4, 256, 32
    _, args = _setup(jax.random.PRNGKey(4), B, N, N, 32, 16, "fp8_e4m3", bn,
                     seq_lens=[1, 32, 130, 256])
    o_1, _ = mla_decode_splitkv_pallas(*args, softmax_scale=SCALE,
                                       num_splits=1, block_n=bn)
    o_s, _ = mla_decode_splitkv_pallas(*args, softmax_scale=SCALE,
                                       num_splits=num_splits, block_n=bn)
    np.testing.assert_allclose(np.asarray(o_1), np.asarray(o_s),
                               rtol=0.05, atol=1e-4)


def test_lse_combine_neutral_partial_drops_out():
    """An empty split's (o=0, lse=NEG_INF) partial must not perturb the
    combine; all-empty rows stay NaN-free."""
    B, S, H, d_c = 2, 3, 4, 8
    key = jax.random.PRNGKey(5)
    o_p = jax.random.normal(key, (B, S, H, d_c))
    lse_p = jax.random.normal(jax.random.PRNGKey(6), (B, S, H))
    o, lse = lse_combine_pallas(o_p, lse_p)
    # append a neutral partial: result identical
    o_p2 = jnp.concatenate([o_p, jnp.zeros((B, 1, H, d_c))], axis=1)
    lse_p2 = jnp.concatenate([lse_p, jnp.full((B, 1, H), R.NEG_INF)], axis=1)
    o2, lse2 = lse_combine_pallas(o_p2, lse_p2)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse2), rtol=1e-6)
    # all-neutral: finite (sentinel), no NaN
    o3, lse3 = lse_combine_pallas(jnp.zeros((B, 2, H, d_c)),
                                  jnp.full((B, 2, H), R.NEG_INF))
    assert np.isfinite(np.asarray(o3)).all()
    assert not np.isnan(np.asarray(lse3)).any()


@pytest.mark.parametrize("num_splits", [2, 4])
def test_splitkv_parallel_ref_matches_single_pass(num_splits):
    """The einsum (serving) split form == the single-pass parallel form within
    quantization rounding on ragged lens — and exactly for fmt='none'."""
    B, N, bn = len(RAGGED_LENS), 256, 32
    for fmt, tol in [("fp8_e4m3", 0.05), ("none", 1e-5)]:
        _, args = _setup(jax.random.PRNGKey(8), B, N, N, 32, 16, fmt, bn,
                         seq_lens=RAGGED_LENS)
        o_1, lse_1 = R.snapmla_decode_parallel_ref(
            *args, softmax_scale=SCALE, block_n=bn, fmt=fmt)
        o_s, lse_s = R.snapmla_decode_splitkv_parallel_ref(
            *args, softmax_scale=SCALE, num_splits=num_splits, block_n=bn,
            fmt=fmt)
        assert not np.isnan(np.asarray(o_s)).any()
        # row 0 is empty: single-pass parallel_ref yields NaN there (softmax
        # over nothing), the split form yields the neutral 0/NEG_INF partial
        np.testing.assert_allclose(np.asarray(o_1)[1:], np.asarray(o_s)[1:],
                                   rtol=tol, atol=tol)
        np.testing.assert_allclose(np.asarray(lse_1)[1:], np.asarray(lse_s)[1:],
                                   rtol=1e-5, atol=1e-4)


def test_ops_clamps_oversized_fixed_splits():
    """A num_splits tuned for long contexts must still trace on a short cache
    (clamped to the block count instead of tripping the kernel assert)."""
    B, N, bn = 2, 64, 32                              # only 2 blocks
    cache, args = _setup(jax.random.PRNGKey(9), B, N, N, 32, 16, "fp8_e4m3",
                         bn, seq_lens=[30, 64])
    q_c8, q_r, sq = args[:3]
    o, _ = snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=SCALE,
                          block_n=bn, num_splits=8)
    assert not np.isnan(np.asarray(o)).any()


def test_default_num_splits_heuristic():
    assert default_num_splits(256) == 1
    assert default_num_splits(4096) == 1
    assert default_num_splits(8192) == 2
    assert default_num_splits(32768) == 8
    assert default_num_splits(131072) == 8           # capped
    # never exceeds the block count
    assert default_num_splits(16384, block_n=8192) == 2


def test_unaligned_cache_capacity_rejected():
    """The per-step jnp.pad is gone: misaligned capacity is a hard error."""
    B, N, bn = 2, 96, 64                             # 96 % 64 != 0
    cache, args = _setup(jax.random.PRNGKey(7), B, 96, N, 32, 16,
                         "fp8_e4m3", 32)             # cache built at page 32
    q_c8, q_r, sq = args[:3]
    with pytest.raises(ValueError, match="not a multiple"):
        snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=SCALE, block_n=bn)


def test_benchmark_blocks_visited_scales_with_seq_lens():
    """Acceptance: the kernel-perf sweep's blocks-visited follows seq_lens,
    not the padded cache capacity."""
    from benchmarks.kernel_perf import splitkv_sweep
    rows = {(r["context"], r["num_splits"]): r
            for r in splitkv_sweep(contexts=(32768, 131072), fill=0.25)}
    r32, r128 = rows[(32768, 1)], rows[(131072, 1)]
    assert r32["blocks_visited"] == -(-int(32768 * 0.25) // 128)
    assert r128["blocks_visited"] == 4 * r32["blocks_visited"]
    assert r32["blocks_visited"] < r32["total_blocks"]
    # splits shorten the critical path, not the bytes
    r32s8 = rows[(32768, 8)]
    assert r32s8["blocks_visited"] == r32["blocks_visited"]
    assert r32s8["critical_path_blocks"] == -(-r32["blocks_visited"] // 8)


def test_generate_fused_token_exact():
    """lax.scan-based generate_fused == per-step loop generate, token for
    token (greedy sampling inside the scan)."""
    from repro.configs import get_smoke_config
    from repro.launch.serve import generate, generate_fused
    from repro.models import transformer as T

    cfg = get_smoke_config("mla-7b")
    key = jax.random.PRNGKey(0)
    params = T.init_model(key, cfg)
    prompts = jax.random.randint(key, (2, 16), 0, cfg.vocab_size, jnp.int32)
    toks_loop, _ = generate(cfg, params, prompts, 6)
    toks_fused, _ = generate_fused(cfg, params, prompts, 6)
    assert toks_fused.shape == toks_loop.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(toks_loop), np.asarray(toks_fused))
