"""KV cache semantics: windows, masking, MLA append/prefill equivalence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kvcache import (CacheConfig, gqa_append, gqa_prefill,
                                init_gqa_cache, init_mla_cache, mla_append,
                                mla_prefill, paged_gather, init_paged_mla_pool)


def test_mla_append_equals_prefill():
    B, d_c, d_r, S = 2, 16, 8, 20
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=8)
    c = jax.random.normal(jax.random.PRNGKey(0), (B, S, d_c))
    r = jax.random.normal(jax.random.PRNGKey(1), (B, S, d_r)) * 10
    bulk = mla_prefill(init_mla_cache(cfg, B, 32, d_c, d_r), cfg, c, r)
    inc = init_mla_cache(cfg, B, 32, d_c, d_r)
    for t in range(S):
        inc = mla_append(inc, cfg, c[:, t], r[:, t])
    np.testing.assert_allclose(np.asarray(bulk.content, np.float32),
                               np.asarray(inc.content, np.float32))
    np.testing.assert_allclose(np.asarray(bulk.scale), np.asarray(inc.scale))
    assert int(inc.seq_lens[0]) == S


def test_window_ring_overwrites_old_slots():
    B, Hkv, dh, window = 1, 1, 4, 8
    cfg = CacheConfig(fmt="none", page_size=8, window=window)
    cache = init_gqa_cache(cfg, B, 64, Hkv, dh)
    assert cache.capacity == window
    for t in range(12):
        k = jnp.full((B, Hkv, dh), float(t))
        cache = gqa_append(cache, cfg, k, k)
    sp = np.asarray(cache.slot_pos[0])
    # slots hold positions 4..11 (last `window` tokens)
    assert sorted(sp.tolist()) == list(range(4, 12))
    # slot content matches position labels
    kv = np.asarray(cache.k[0, :, 0, 0], np.float32)
    assert np.allclose(kv, sp.astype(np.float32))


def test_bf16_cache_has_unit_scales():
    cfg = CacheConfig(fmt="none")
    cache = init_gqa_cache(cfg, 2, 16, 2, 4)
    assert cache.k.dtype == jnp.bfloat16
    assert np.all(np.asarray(cache.k_scale) == 1.0)


def test_paged_pool_gather_roundtrip():
    cfg = CacheConfig(fmt="fp8_e4m3", page_size=4)
    pool = init_paged_mla_pool(cfg, n_pages=8, max_pages_per_seq=2, batch=2,
                               d_c=6, d_r=4)
    pt = jnp.array([[3, 1], [0, 5]], jnp.int32)
    content = pool.content.at[3, 0, 0].set(7.0)
    pool = pool._replace(content=content, page_table=pt,
                         seq_lens=jnp.array([5, 8], jnp.int32))
    c, r, s = paged_gather(pool)
    assert c.shape == (2, 8, 6)
    assert float(c[0, 0, 0]) == 7.0
