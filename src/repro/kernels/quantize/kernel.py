"""Fused token-preparation Pallas kernels (paper §3.3.1, Layer 1).

* ``fused_q_quant_pallas`` — Fused-Q-Quant: per-(token,head) scale statistic,
  FP8/INT8 conversion, and Scale-Domain-Alignment (RoPE dims divided by the
  content scale) in ONE kernel — the paper replaces a three-kernel sequential
  workflow (statistics → quantize → copy) with this.

* ``fused_k_append_pallas`` — Fused-K-Append: quantization + alignment +
  non-contiguous cache write in one launch. The write position comes from a
  scalar-prefetched ``seq_lens`` vector that drives the *output BlockSpec
  index map*, so only the target 128-token page is DMA'd (the TPU analogue of
  the paper's PagedAttention-style fused writes — no full-cache traffic, no
  intermediate buffers, one kernel launch). Cache buffers are aliased
  input↔output so the untouched rows of the page pass through.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quant


def _cast_block(x, fmt):
    if fmt == "fp8_e4m3":
        return jnp.clip(x, -quant.FP8_MAX, quant.FP8_MAX).astype(jnp.float8_e4m3fn)
    if fmt == "int8":
        return jnp.clip(jnp.round(x), -127, 127).astype(jnp.int8)
    return x.astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# Fused-Q-Quant
# ---------------------------------------------------------------------------

def _q_quant_kernel(q_ref, qc_ref, qr_ref, sq_ref, *, d_c: int, fmt: str, qmax: float):
    q = q_ref[0].astype(jnp.float32)                  # [H, d_c + d_r]
    q_c, q_r = q[:, :d_c], q[:, d_c:]
    amax = jnp.max(jnp.abs(q_c), axis=-1)             # [H]
    sq = jnp.maximum(amax, quant.EPS) / qmax
    qc_ref[0] = _cast_block(q_c / sq[:, None], fmt)
    qr_ref[0] = q_r / sq[:, None]                     # domain alignment (Eq. 6)
    sq_ref[0] = sq


def fused_q_quant_pallas(
    q: jax.Array, d_c: int, *, fmt: str = "fp8_e4m3", interpret: bool = True
):
    """q [B, H, d_c + d_r] -> (q_c8, q_r_scaled f32, sigma_q)."""
    B, H, d = q.shape
    d_r = d - d_c
    qmax = quant.qmax_for(fmt) if fmt != "none" else 1.0
    kernel = functools.partial(_q_quant_kernel, d_c=d_c, fmt=fmt, qmax=qmax)
    out_dtype = quant.qdtype_for(fmt) if fmt != "none" else jnp.bfloat16
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, H, d), lambda b: (b, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, H, d_c), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, H, d_r), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, d_c), out_dtype),
            jax.ShapeDtypeStruct((B, H, d_r), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(q)


# ---------------------------------------------------------------------------
# Fused-K-Append
# ---------------------------------------------------------------------------

def _k_append_kernel(
    seq_lens_ref,           # scalar prefetch [B]
    ckv_ref,                # [1, d_c] new entry
    kr_ref,                 # [1, d_r]
    content_in_ref,         # [1, page, d_c] target page (aliased to output)
    rope_in_ref,            # [1, page, d_r]
    scale_in_ref,           # [1, page]
    content_ref, rope_ref, scale_ref,   # outputs (aliased)
    *,
    page: int,
    fmt: str,
    qmax: float,
):
    b = pl.program_id(0)
    slot = seq_lens_ref[b] % page                      # row within the page
    c = ckv_ref[0].astype(jnp.float32)                 # [d_c]
    r = kr_ref[0].astype(jnp.float32)                  # [d_r]
    amax = jnp.max(jnp.abs(c))
    s = jnp.maximum(amax, quant.EPS) / qmax

    row = jax.lax.broadcasted_iota(jnp.int32, (page, 1), 0)
    is_slot = row == slot                              # [page, 1]

    content_ref[0] = jnp.where(
        is_slot, _cast_block((c / s)[None, :], fmt).astype(content_in_ref.dtype),
        content_in_ref[0])
    rope_ref[0] = jnp.where(is_slot, (r / s)[None, :].astype(rope_in_ref.dtype),
                            rope_in_ref[0])
    scale_ref[0] = jnp.where(is_slot[:, 0], s, scale_in_ref[0])


def fused_k_append_pallas(
    content: jax.Array,    # [B, N, d_c] cache
    rope: jax.Array,       # [B, N, d_r]
    scale: jax.Array,      # [B, N]
    c_kv: jax.Array,       # [B, d_c]
    k_r: jax.Array,        # [B, d_r]
    seq_lens: jax.Array,   # [B] write positions
    *,
    page: int = 128,
    fmt: str = "fp8_e4m3",
    interpret: bool = True,
):
    B, N, d_c = content.shape
    d_r = rope.shape[-1]
    assert N % page == 0
    qmax = quant.qmax_for(fmt) if fmt != "none" else 1.0
    kernel = functools.partial(_k_append_kernel, page=page, fmt=fmt, qmax=qmax)

    page_of = lambda b, sl: sl[b] // page
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, d_c), lambda b, sl: (b, 0)),
            pl.BlockSpec((1, d_r), lambda b, sl: (b, 0)),
            # only the page containing the write slot is windowed in
            pl.BlockSpec((1, page, d_c), lambda b, sl: (b, page_of(b, sl), 0)),
            pl.BlockSpec((1, page, d_r), lambda b, sl: (b, page_of(b, sl), 0)),
            pl.BlockSpec((1, page), lambda b, sl: (b, page_of(b, sl))),
        ],
        out_specs=[
            pl.BlockSpec((1, page, d_c), lambda b, sl: (b, page_of(b, sl), 0)),
            pl.BlockSpec((1, page, d_r), lambda b, sl: (b, page_of(b, sl), 0)),
            pl.BlockSpec((1, page), lambda b, sl: (b, page_of(b, sl))),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(content.shape, content.dtype),
            jax.ShapeDtypeStruct(rope.shape, rope.dtype),
            jax.ShapeDtypeStruct(scale.shape, scale.dtype),
        ],
        # alias cache buffers in->out: rows outside the page are untouched,
        # rows inside pass through via the jnp.where above
        input_output_aliases={3: 0, 4: 1, 5: 2},
        interpret=interpret,
    )(seq_lens, c_kv, k_r, content, rope, scale)
