"""Pure-jnp oracles for the fused token-preparation kernels (paper §3.3.1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant


def fused_q_quant_ref(q: jax.Array, d_c: int, fmt: str = "fp8_e4m3"):
    """Fused-Q-Quant: per-(token,head) scale + cast + RoPE-domain alignment.

    q [B, H, d_c + d_r] f32 -> (q_c8 [B,H,d_c], q_r_scaled [B,H,d_r] f32,
    sigma_q [B,H] f32). One logical kernel (statistics + conversion + scale
    injection), replacing the paper's three-step sequential workflow.
    """
    q_c, q_r = q[..., :d_c], q[..., d_c:]
    raq = quant.quantize_rope_aware(q_c, q_r, fmt, rope_dtype=jnp.float32)
    return raq.q_content, raq.rope_scaled, raq.scale[..., 0]


def fused_k_append_ref(
    content: jax.Array,     # [B, N, d_c] cache (storage dtype)
    rope: jax.Array,        # [B, N, d_r]
    scale: jax.Array,       # [B, N]
    c_kv: jax.Array,        # [B, d_c] new latent entries (f32)
    k_r: jax.Array,         # [B, d_r]
    seq_lens: jax.Array,    # [B] write position
    fmt: str = "fp8_e4m3",
):
    """Fused-K-Append: quantize + scale-align + in-place cache write."""
    raq = quant.quantize_rope_aware(c_kv, k_r, fmt, rope_dtype=jnp.float32)

    def upd(buf, val, idx):
        return jax.lax.dynamic_update_slice(buf, val[None], (idx,) + (0,) * (buf.ndim - 1))

    content = jax.vmap(upd)(content, raq.q_content.astype(content.dtype), seq_lens)
    rope = jax.vmap(upd)(rope, raq.rope_scaled.astype(rope.dtype), seq_lens)
    scale = jax.vmap(upd)(scale, raq.scale[..., 0], seq_lens)
    return content, rope, scale
