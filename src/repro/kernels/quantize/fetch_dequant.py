"""Fused-Fetch-Dequant (paper §3.3.1, third operator) — Pallas TPU kernel.

For decode phases that need high-precision reuse of cached data (chunked
prefill, prefix caching), the paper fuses the fetch of quantized KV pages
with register-level dequantization, eliminating the two-step
load-then-dequantize round trip through memory.

TPU form: one pallas_call whose grid walks the cache pages; each page is
DMA'd (fp8 content + prescaled bf16 rope + per-token scales), dequantized in
VREGs, and written out as a contiguous BF16 [content | rope] chunk — the
operand layout the chunked-prefill attention consumes. The HBM read side is
the *quantized* bytes (the whole point: fetch traffic stays FP8-sized).

``chunked_prefill_attention`` uses it to attend a new prompt chunk against
the quantized prefix cache + itself, combining via flash-style lse math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.kvcache import MLACache, PagedMLAPool


def _fetch_dequant_kernel(content_ref, rope_ref, scale_ref, out_ref, *, d_c):
    c = content_ref[0].astype(jnp.float32)              # [page, d_c]
    r = rope_ref[0].astype(jnp.float32)                 # [page, d_r]
    s = scale_ref[0].astype(jnp.float32)[:, None]       # [page, 1]
    out_ref[0, :, :d_c] = (c * s).astype(out_ref.dtype)
    out_ref[0, :, d_c:] = (r * s).astype(out_ref.dtype)  # undo Eq.-6 prescale


def _paged_fetch_dequant_body(pt_ref, content_ref, rope_ref, scale_ref,
                              out_ref, *, d_c):
    """The paged body IS the contiguous body: the page table only feeds the
    BlockSpec index maps (where the DMA comes from), never the arithmetic."""
    del pt_ref  # only used by the index maps
    _fetch_dequant_kernel(content_ref, rope_ref, scale_ref, out_ref, d_c=d_c)


def _bounded_paged_fetch_body(cs_ref, pt_ref, content_ref, rope_ref,
                              scale_ref, out_ref, *, d_c, page):
    """Bounded-fetch body: pages at/above the chunk boundary are DEAD — their
    output block is zeroed without touching the pool operands (and the index
    maps repeat the last live page id, so the dead cells' DMAs are elided by
    the pipeline's unchanged-index rule: fetch traffic tracks ``chunk_start``,
    not the page-table span)."""
    del pt_ref  # only used by the index maps
    b = pl.program_id(0)
    j = pl.program_id(1)
    live = j * page < cs_ref[b]

    @pl.when(live)
    def _fetch():
        _fetch_dequant_kernel(content_ref, rope_ref, scale_ref, out_ref,
                              d_c=d_c)

    @pl.when(jnp.logical_not(live))
    def _dead():
        out_ref[0] = jnp.zeros_like(out_ref[0])


def fetch_dequant_pallas(cache: MLACache, *, page: int = 128,
                         out_dtype=jnp.bfloat16, interpret: bool = True):
    """MLACache -> dequantized [B, N, d_c + d_r] keys (content|rope) in bf16."""
    B, N, d_c = cache.content.shape
    d_r = cache.rope.shape[-1]
    assert N % page == 0
    kernel = functools.partial(_fetch_dequant_kernel, d_c=d_c)
    return pl.pallas_call(
        kernel,
        grid=(B, N // page),
        in_specs=[
            pl.BlockSpec((1, page, d_c), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, page, d_r), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, page), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, page, d_c + d_r), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, d_c + d_r), out_dtype),
        interpret=interpret,
    )(cache.content, cache.rope, cache.scale)


def fetch_dequant_ref(cache: MLACache, out_dtype=jnp.bfloat16):
    """Pure-jnp oracle."""
    c = cache.content.astype(jnp.float32) * cache.scale[..., None]
    r = cache.rope.astype(jnp.float32) * cache.scale[..., None]
    return jnp.concatenate([c, r], axis=-1).astype(out_dtype)


def paged_fetch_dequant_pallas(pool: PagedMLAPool, *,
                               chunk_start: jax.Array | None = None,
                               out_dtype=jnp.bfloat16,
                               interpret: bool = True):
    """Paged Fused-Fetch-Dequant: the page table is scalar-prefetched and
    drives the DMA source of each (batch, logical-page) grid cell — the same
    TPU-native PagedAttention addressing the paged decode kernels use, so
    chunked prefill reads the FP8 pool pages directly (no host gather, HBM
    fetch traffic stays quantized-width).

    ``chunk_start`` ([B] int32, optional) BOUNDS the fetch: only pages
    holding positions strictly below ``chunk_start[b]`` are gathered. Dead
    pages' index maps clamp to the last live page (same-index DMAs are
    elided by the Pallas pipeline) and their output blocks are zeroed under
    ``pl.when`` — so per-chunk DMA traffic is ``ceil(chunk_start/page)``
    pages, independent of the pool capacity ``P``. ``None`` keeps the
    original full-span gather.

    Returns dequantized keys [B, P*page, d_c + d_r] (content|rope) laid out
    in each sequence's LOGICAL order (row b of the page table flattened)."""
    n_pages, page, d_c = pool.content.shape
    d_r = pool.rope.shape[-1]
    B, P = pool.page_table.shape
    out_shape = jax.ShapeDtypeStruct((B, P * page, d_c + d_r), out_dtype)
    if chunk_start is None:
        kernel = functools.partial(_paged_fetch_dequant_body, d_c=d_c)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,      # page_table
            grid=(B, P),
            in_specs=[
                pl.BlockSpec((1, page, d_c), lambda b, j, pt: (pt[b, j], 0, 0)),
                pl.BlockSpec((1, page, d_r), lambda b, j, pt: (pt[b, j], 0, 0)),
                pl.BlockSpec((1, page), lambda b, j, pt: (pt[b, j], 0)),
            ],
            out_specs=pl.BlockSpec((1, page, d_c + d_r),
                                   lambda b, j, pt: (b, j, 0)),
        )
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(pool.page_table, pool.content, pool.rope, pool.scale)

    cs = chunk_start.astype(jnp.int32)

    def _live_page(j, cs_b):
        # last page holding a position < chunk_start (0 when none are live)
        last = jnp.maximum((cs_b + page - 1) // page - 1, 0)
        return jnp.minimum(j, last)

    kernel = functools.partial(_bounded_paged_fetch_body, d_c=d_c, page=page)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # chunk_start, page_table
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, page, d_c),
                         lambda b, j, cs, pt: (pt[b, _live_page(j, cs[b])],
                                               0, 0)),
            pl.BlockSpec((1, page, d_r),
                         lambda b, j, cs, pt: (pt[b, _live_page(j, cs[b])],
                                               0, 0)),
            pl.BlockSpec((1, page),
                         lambda b, j, cs, pt: (pt[b, _live_page(j, cs[b])],
                                               0)),
        ],
        out_specs=pl.BlockSpec((1, page, d_c + d_r),
                               lambda b, j, cs, pt: (b, j, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(cs, pool.page_table, pool.content, pool.rope, pool.scale)


def paged_fetch_dequant_ref(pool: PagedMLAPool, out_dtype=jnp.bfloat16,
                            chunk_start: jax.Array | None = None):
    """Pure-jnp oracle for the paged fetch: gather rows through the page
    table, dequantize, lay out logically [B, P*page, d_c + d_r]. With
    ``chunk_start``, mirrors the kernel's bounded fetch: pages wholly
    at/above the boundary come back zeroed (a straddling page is fetched in
    full — its tail is masked downstream by the attention's ``pre_ok``)."""
    c = pool.content[pool.page_table].astype(jnp.float32)   # [B, P, page, d_c]
    r = pool.rope[pool.page_table].astype(jnp.float32)
    s = pool.scale[pool.page_table].astype(jnp.float32)[..., None]
    B, P, page, d_c = c.shape
    kv = jnp.concatenate([c * s, r * s], axis=-1)
    if chunk_start is not None:
        live = ((jnp.arange(P) * page)[None, :]
                < chunk_start.astype(jnp.int32)[:, None])       # [B, P]
        kv = jnp.where(live[:, :, None, None], kv, 0.0)
    return kv.reshape(B, P * page, -1).astype(out_dtype)


def paged_chunked_prefill_attention(
    q_lat: jax.Array,        # [B, C, H, d_c] absorbed queries for the chunk
    q_rope: jax.Array,       # [B, C, H, d_r]
    pool: PagedMLAPool,      # quantized prefix pages (page table = per-row run)
    chunk_c_kv: jax.Array,   # [B, C, d_c] this chunk's latents (full precision)
    chunk_k_r: jax.Array,    # [B, C, d_r] this chunk's rope keys (RoPE'd)
    chunk_start: jax.Array,  # [B] first absolute position of the chunk
    valid: jax.Array,        # [B, C] False on the padded tail of a bucket
    *,
    softmax_scale: float,
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Attend a prompt chunk against [quantized paged prefix] + [itself].

    The engine's chunked-prefill attention: earlier chunks are read back
    from their already-quantized FP8 pool pages through the (paged)
    Fused-Fetch-Dequant path — no bf16 re-materialization of the prefix —
    while the chunk's OWN keys participate at full precision (they are
    resident in VREGs from the projection that just produced them; the
    quantized copy is only what lands in the pool for later chunks/decode).
    Scores from both sources share ONE softmax (mathematically the
    flash-style LSE combine, assembled directly), so for a first chunk the
    result is the plain full-precision causal attention.

    ``chunk_start`` is traced: one compiled program serves every chunk of a
    given (bucketed) width. Returns o_latent [B, C, H, d_c] (f32).
    """
    B, C, H, d_c = q_lat.shape
    # bounded fetch: only pages below the chunk boundary are DMA'd — per-chunk
    # fetch traffic tracks chunk_start, not the pool capacity
    kv = (paged_fetch_dequant_pallas(pool, chunk_start=chunk_start,
                                     interpret=interpret)
          if use_kernel
          else paged_fetch_dequant_ref(pool, chunk_start=chunk_start)
          ).astype(jnp.float32)
    q = jnp.concatenate([q_lat, q_rope], axis=-1).astype(jnp.float32)
    # prefix scores: every pool position strictly before the chunk is live
    n = kv.shape[1]
    s_pre = jnp.einsum("bchd,bnd->bchn", q, kv) * softmax_scale
    pre_ok = jnp.arange(n)[None, :] < chunk_start[:, None]          # [B, n]
    s_pre = jnp.where(pre_ok[:, None, None, :], s_pre, -jnp.inf)
    # in-chunk scores: full precision, causal within the chunk, padded tail
    # keys masked (padded QUERIES still see their causal prefix, so no row is
    # ever fully masked — their outputs are garbage and are never read)
    k_chunk = jnp.concatenate([chunk_c_kv, chunk_k_r],
                              axis=-1).astype(jnp.float32)
    s_chk = jnp.einsum("bchd,bkd->bchk", q, k_chunk) * softmax_scale
    causal = jnp.arange(C)[:, None] >= jnp.arange(C)[None, :]       # [C, C]
    chk_ok = causal[None] & valid[:, None, :]                       # [B, C, C]
    s_chk = jnp.where(chk_ok[:, :, None, :], s_chk, -jnp.inf)
    # one softmax across [prefix | chunk] — the LSE combine, assembled flat
    p = jax.nn.softmax(jnp.concatenate([s_pre, s_chk], axis=-1), axis=-1)
    o = jnp.einsum("bchn,bnd->bchd", p[..., :n], kv[..., :d_c])
    o = o + jnp.einsum("bchk,bkd->bchd", p[..., n:],
                       chunk_c_kv.astype(jnp.float32))
    return o


def paged_verify_attention(
    q_lat: jax.Array,        # [B, K, H, d_c] absorbed queries for the drafts
    q_rope: jax.Array,       # [B, K, H, d_r]
    pool: PagedMLAPool,      # quantized prefix pages
    draft_c_kv: jax.Array,   # [B, K, d_c] drafted-suffix latents (full prec.)
    draft_k_r: jax.Array,    # [B, K, d_r] drafted-suffix rope keys (RoPE'd)
    start: jax.Array,        # [B] absolute position of the first draft entry
    *,
    softmax_scale: float,
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Speculative-verify attention: [FP8 prefix] + [drafted suffix], one
    softmax.

    The verify step IS the chunked-prefill shape with the drafted K-token
    block in the chunk's seat: the committed prefix streams back through the
    bounded ``paged_fetch_dequant_pallas`` path (fetch traffic ∝
    ``ceil(start/page)`` pages, FP8-width), the drafts' own keys participate
    at full precision, and the causal mask within the block is the verify
    kernel's intra-block mask. Mixed-precision twin of running the drafts
    through the q_len > 1 split-KV kernel after ``paged_mla_prefill_at`` —
    they differ only by the suffix's P-quantization rounding, which is what
    the within-tolerance verify parity gates pin. Returns o_latent
    [B, K, H, d_c] (f32)."""
    valid = jnp.ones(draft_c_kv.shape[:2], bool)
    return paged_chunked_prefill_attention(
        q_lat, q_rope, pool, draft_c_kv, draft_k_r, start, valid,
        softmax_scale=softmax_scale, use_kernel=use_kernel,
        interpret=interpret)


def chunked_prefill_attention(
    q_lat: jax.Array,        # [B, C, H, d_c] absorbed queries for the chunk
    q_rope: jax.Array,       # [B, C, H, d_r]
    cache: MLACache,         # quantized prefix (seq_lens = prefix length)
    chunk_start: int | jax.Array,
    *,
    softmax_scale: float,
    page: int = 128,
    use_kernel: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Attend a prompt chunk against [quantized prefix] + [itself], causal.

    Returns o_latent [B, C, H, d_c] (f32). The prefix keys are produced by the
    Fused-Fetch-Dequant kernel (single fused pass over the FP8 cache).
    """
    B, C, H, d_c = q_lat.shape
    kv = (fetch_dequant_pallas(cache, page=page, interpret=interpret)
          if use_kernel else fetch_dequant_ref(cache)).astype(jnp.float32)
    q = jnp.concatenate([q_lat, q_rope], axis=-1).astype(jnp.float32)
    s = jnp.einsum("bchd,bnd->bchn", q, kv) * softmax_scale
    n = kv.shape[1]
    qpos = chunk_start + jnp.arange(C)
    valid = (jnp.arange(n)[None, :] < cache.seq_lens[:, None])[:, None, :] \
        & (jnp.arange(n)[None, None, :] <= qpos[None, :, None])
    s = jnp.where(valid[:, :, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)            # fully-masked rows
    content = kv[..., :d_c]
    return jnp.einsum("bchn,bnd->bchd", p, content)
