"""Fused-Fetch-Dequant (paper §3.3.1, third operator) — Pallas TPU kernel.

For decode phases that need high-precision reuse of cached data (chunked
prefill, prefix caching), the paper fuses the fetch of quantized KV pages
with register-level dequantization, eliminating the two-step
load-then-dequantize round trip through memory.

TPU form: one pallas_call whose grid walks the cache pages; each page is
DMA'd (fp8 content + prescaled bf16 rope + per-token scales), dequantized in
VREGs, and written out as a contiguous BF16 [content | rope] chunk — the
operand layout the chunked-prefill attention consumes. The HBM read side is
the *quantized* bytes (the whole point: fetch traffic stays FP8-sized).

``chunked_prefill_attention`` uses it to attend a new prompt chunk against
the quantized prefix cache + itself, combining via flash-style lse math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.kvcache import MLACache


def _fetch_dequant_kernel(content_ref, rope_ref, scale_ref, out_ref, *, d_c):
    c = content_ref[0].astype(jnp.float32)              # [page, d_c]
    r = rope_ref[0].astype(jnp.float32)                 # [page, d_r]
    s = scale_ref[0].astype(jnp.float32)[:, None]       # [page, 1]
    out_ref[0, :, :d_c] = (c * s).astype(out_ref.dtype)
    out_ref[0, :, d_c:] = (r * s).astype(out_ref.dtype)  # undo Eq.-6 prescale


def fetch_dequant_pallas(cache: MLACache, *, page: int = 128,
                         out_dtype=jnp.bfloat16, interpret: bool = True):
    """MLACache -> dequantized [B, N, d_c + d_r] keys (content|rope) in bf16."""
    B, N, d_c = cache.content.shape
    d_r = cache.rope.shape[-1]
    assert N % page == 0
    kernel = functools.partial(_fetch_dequant_kernel, d_c=d_c)
    return pl.pallas_call(
        kernel,
        grid=(B, N // page),
        in_specs=[
            pl.BlockSpec((1, page, d_c), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, page, d_r), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, page), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, page, d_c + d_r), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, d_c + d_r), out_dtype),
        interpret=interpret,
    )(cache.content, cache.rope, cache.scale)


def fetch_dequant_ref(cache: MLACache, out_dtype=jnp.bfloat16):
    """Pure-jnp oracle."""
    c = cache.content.astype(jnp.float32) * cache.scale[..., None]
    r = cache.rope.astype(jnp.float32) * cache.scale[..., None]
    return jnp.concatenate([c, r], axis=-1).astype(out_dtype)


def chunked_prefill_attention(
    q_lat: jax.Array,        # [B, C, H, d_c] absorbed queries for the chunk
    q_rope: jax.Array,       # [B, C, H, d_r]
    cache: MLACache,         # quantized prefix (seq_lens = prefix length)
    chunk_start: int | jax.Array,
    *,
    softmax_scale: float,
    page: int = 128,
    use_kernel: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Attend a prompt chunk against [quantized prefix] + [itself], causal.

    Returns o_latent [B, C, H, d_c] (f32). The prefix keys are produced by the
    Fused-Fetch-Dequant kernel (single fused pass over the FP8 cache).
    """
    B, C, H, d_c = q_lat.shape
    kv = (fetch_dequant_pallas(cache, page=page, interpret=interpret)
          if use_kernel else fetch_dequant_ref(cache)).astype(jnp.float32)
    q = jnp.concatenate([q_lat, q_rope], axis=-1).astype(jnp.float32)
    s = jnp.einsum("bchd,bnd->bchn", q, kv) * softmax_scale
    n = kv.shape[1]
    qpos = chunk_start + jnp.arange(C)
    valid = (jnp.arange(n)[None, :] < cache.seq_lens[:, None])[:, None, :] \
        & (jnp.arange(n)[None, None, :] <= qpos[None, :, None])
    s = jnp.where(valid[:, :, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)            # fully-masked rows
    content = kv[..., :d_c]
    return jnp.einsum("bchn,bnd->bchd", p, content)
