"""Jit'd wrappers for the fused token-preparation kernels."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.kvcache import MLACache, _sink_append
from repro.kernels.quantize import kernel as _k
from repro.kernels.quantize import ref as _ref


@partial(jax.jit, static_argnames=("d_c", "fmt", "use_kernel", "interpret"))
def fused_q_quant(q: jax.Array, d_c: int, *, fmt: str = "fp8_e4m3",
                  use_kernel: bool = True, interpret: bool = True):
    if use_kernel:
        return _k.fused_q_quant_pallas(q, d_c, fmt=fmt, interpret=interpret)
    return _ref.fused_q_quant_ref(q, d_c, fmt=fmt)


# NOTE: no donate_argnums here — the cache is aliased in->out inside the
# pallas_call already, and whole-pytree donation would invalidate seq_lens for
# eager callers; serve-step-level jit gets buffer reuse from XLA regardless.
@partial(jax.jit, static_argnames=("fmt", "page", "use_kernel", "interpret"))
def fused_k_append(cache: MLACache, c_kv: jax.Array, k_r: jax.Array, *,
                   fmt: str = "fp8_e4m3", page: int = 128,
                   use_kernel: bool = True, interpret: bool = True) -> MLACache:
    if use_kernel:
        content, rope, scale = _k.fused_k_append_pallas(
            cache.content, cache.rope, cache.scale, c_kv, k_r, cache.seq_lens,
            page=page, fmt=fmt, interpret=interpret)
    else:
        content, rope, scale = _ref.fused_k_append_ref(
            cache.content, cache.rope, cache.scale, c_kv, k_r, cache.seq_lens,
            fmt=fmt)
    return cache._replace(
        content=content, rope=rope, scale=scale,
        seq_lens=cache.seq_lens + 1,
        sink=_sink_append(cache, c_kv, cache.seq_lens, None))
