"""Pure-jnp oracle for the FP8 per-token quantized GQA decode pipeline.

This generalizes SnapMLA's Key Step 2 to GQA/MHA architectures (DESIGN.md
§Arch-applicability): K and V are per-token quantized post-RoPE; K's scale is
applied to the logits (scale along the QK *non-reduction* token dim — exact);
V's per-token scale lies along the PV reduction dim, so it is fused into the
probability block and handled by the same block-wise dynamic P quantization +
implicit dequantization as the MLA kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant


def gqa_decode_pipeline_ref(
    q: jax.Array,          # [B, H, dh] f32 (RoPE applied, high precision)
    k8: jax.Array,         # [B, N, Hkv, dh] storage dtype
    v8: jax.Array,         # [B, N, Hkv, dh]
    k_scale: jax.Array,    # [B, N, Hkv] f32
    v_scale: jax.Array,    # [B, N, Hkv] f32
    slot_pos: jax.Array,   # [B, N] int32 (-1 = empty slot)
    positions: jax.Array,  # [B] query absolute positions
    *,
    window: int = 0,
    block_n: int = 128,
    fmt: quant.QuantFormat = "fp8_e4m3",
    p_quant: bool = True,
) -> jax.Array:
    B, H, dh = q.shape
    N, Hkv = k8.shape[1], k8.shape[2]
    g = H // Hkv
    assert N % block_n == 0
    nblocks = N // block_n
    qmax = quant.qmax_for(fmt) if fmt != "none" else 1.0
    sm_scale = 1.0 / (dh ** 0.5)

    def one_batch(q_b, k_b, v_b, ks_b, vs_b, sp_b, pos_b):
        qg = q_b.reshape(Hkv, g, dh).astype(jnp.float32)

        def body(carry, j):
            m, l, sp, acc = carry
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, j * block_n, block_n, 0)
            k, v = sl(k_b).astype(jnp.float32), sl(v_b).astype(jnp.float32)
            ks, vs, spos = sl(ks_b), sl(vs_b), sl(sp_b)
            s = jnp.einsum("hgd,nhd->hgn", qg, k) * ks.T[:, None, :] * sm_scale
            valid = (spos >= 0) & (spos <= pos_b)
            if window:
                valid &= spos > pos_b - window
            s = jnp.where(valid[None, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            e = jnp.exp(s - m_new[..., None])
            p_fused = e * vs.T[:, None, :]
            if p_quant and fmt != "none":
                amax = jnp.max(jnp.abs(p_fused), axis=-1)
                sp_new = jnp.maximum(amax, quant.EPS) / qmax
                p8 = quant._cast(p_fused / sp_new[..., None], fmt).astype(jnp.float32)
            else:
                sp_new = jnp.ones_like(m_new)
                p8 = p_fused
            corr = jnp.exp(m - m_new) * (sp / sp_new)
            l_new = l * corr + jnp.sum(e, axis=-1) / sp_new
            pv = jnp.einsum("hgn,nhd->hgd", p8, v)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, sp_new, acc_new), None

        init = (
            jnp.full((Hkv, g), -jnp.inf, jnp.float32),
            jnp.zeros((Hkv, g), jnp.float32),
            jnp.ones((Hkv, g), jnp.float32),
            jnp.zeros((Hkv, g, dh), jnp.float32),
        )
        (m, l, sp, acc), _ = jax.lax.scan(body, init, jnp.arange(nblocks))
        return (acc / l[..., None]).reshape(H, dh)

    return jax.vmap(one_batch)(q, k8, v8, k_scale, v_scale, slot_pos, positions)


def gqa_decode_parallel_ref(
    q: jax.Array,          # [B, H, dh]
    k8: jax.Array,         # [B, N, Hkv, dh]
    v8: jax.Array,
    k_scale: jax.Array,    # [B, N, Hkv]
    v_scale: jax.Array,
    slot_pos: jax.Array,   # [B, N]
    positions: jax.Array,  # [B]
    *,
    window: int = 0,
    block_n: int = 128,
    fmt: quant.QuantFormat = "fp8_e4m3",
) -> jax.Array:
    """Parallel (flash-combine) form of the quantized GQA decode pipeline —
    identical math to ``gqa_decode_pipeline_ref`` (verified in tests), but
    while-loop-free: the preferred pjit serve-path lowering and exact under
    HLO cost analysis."""
    B, H, dh = q.shape
    N, Hkv = k8.shape[1], k8.shape[2]
    g = H // Hkv
    assert N % block_n == 0
    nb = N // block_n
    qmax = quant.qmax_for(fmt) if fmt != "none" else 1.0

    qg = q.reshape(B, Hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bnhd->bhgn", qg, k8.astype(jnp.float32))
    s = s * jnp.transpose(k_scale, (0, 2, 1))[:, :, None, :] / (dh ** 0.5)
    valid = (slot_pos >= 0) & (slot_pos <= positions[:, None])
    if window:
        valid &= slot_pos > positions[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)

    sb = s.reshape(B, Hkv, g, nb, block_n)
    m_k = jnp.max(sb, axis=-1)                                  # [B,Hkv,g,nb]
    e = jnp.where(jnp.isfinite(sb), jnp.exp(sb - m_k[..., None]), 0.0)
    vsb = jnp.transpose(v_scale, (0, 2, 1)).reshape(B, Hkv, 1, nb, block_n)
    p_fused = e * vsb
    amax = jnp.max(jnp.abs(p_fused), axis=-1)
    sp = jnp.maximum(amax, quant.EPS) / qmax
    if fmt != "none":
        p8 = quant._cast(p_fused / sp[..., None], fmt).astype(jnp.float32)
    else:
        sp = jnp.ones_like(sp)
        p8 = p_fused
    vb = jnp.transpose(v8.astype(jnp.float32), (0, 2, 1, 3)).reshape(
        B, Hkv, nb, block_n, dh)
    o_k = jnp.einsum("bhgkn,bhknd->bhgkd", p8, vb)
    l_k = jnp.sum(e, axis=-1)
    m_star = jnp.max(m_k, axis=-1, keepdims=True)
    w = jnp.exp(m_k - m_star)
    num = jnp.einsum("bhgk,bhgkd->bhgd", w * sp, o_k)
    den = jnp.einsum("bhgk,bhgk->bhg", w, l_k)
    return (num / den[..., None]).reshape(B, H, dh)
