"""FP8 per-token quantized GQA decode — Pallas TPU kernel.

SnapMLA Key Step 2 generalized to GQA (see gqa_decode/ref.py). Same scratch-
carried online-softmax structure as the MLA kernel; supports sliding-window
(ring-buffer) caches through per-slot absolute positions, which covers
mixtral (SWA), gemma3 local layers, and recurrentgemma local attention.

Block layout: KV blocks of ``block_n`` tokens; the full [Hkv, dh] head dim is
kept resident (dh = 128 is MXU-lane aligned; Hkv ≤ 16 for all assigned archs,
so a 128-token fp8 K block is ≤ 128*16*128 = 256 KiB in VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quant

NEG_INF = -1e30


def _gqa_decode_kernel(
    positions_ref,            # scalar prefetch: [B] int32 query positions
    q_ref,                    # [1, H, dh] f32
    k_ref, v_ref,             # [1, bn, Hkv, dh] storage dtype
    ks_ref, vs_ref,           # [1, bn, Hkv] f32
    sp_ref_in,                # [1, bn] int32 slot positions
    o_ref,                    # [1, H, dh] f32
    m_ref, l_ref, sp_ref, acc_ref,
    *,
    n_kv: int,
    block_n: int,
    window: int,
    fmt: str,
    qmax: float,
):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        sp_ref[...] = jnp.ones_like(sp_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    H, dh = q_ref.shape[1], q_ref.shape[2]
    g = H // n_kv
    qg = q_ref[0].astype(jnp.float32).reshape(n_kv, g, dh)
    k = k_ref[0].astype(jnp.float32)                   # [bn, Hkv, dh]
    v = v_ref[0].astype(jnp.float32)
    ks = ks_ref[0].astype(jnp.float32)                 # [bn, Hkv]
    vs = vs_ref[0].astype(jnp.float32)
    spos = sp_ref_in[0]                                # [bn]
    pos_b = positions_ref[b]

    # QK: batched over kv heads; K dequant via per-token scale on the logits
    kt = jnp.transpose(k, (1, 0, 2))                   # [Hkv, bn, dh]
    s = jax.lax.dot_general(qg, kt, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)   # [Hkv, g, bn]
    s = s * ks.T[:, None, :] * (1.0 / (dh ** 0.5))

    valid = (spos >= 0) & (spos <= pos_b)
    if window:
        valid = valid & (spos > pos_b - window)
    s = jnp.where(valid[None, None, :], s, NEG_INF)

    m_prev, l_prev, spp = m_ref[...], l_ref[...], sp_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))   # [Hkv, g]
    e = jnp.exp(s - m_new[..., None])
    e = jnp.where(valid[None, None, :], e, 0.0)

    # scale fusion + block-wise dynamic P quantization
    p_fused = e * vs.T[:, None, :]
    amax = jnp.max(jnp.abs(p_fused), axis=-1)
    if fmt == "fp8_e4m3":
        sp_new = jnp.maximum(amax, quant.EPS) / qmax
        p8 = jnp.clip(p_fused / sp_new[..., None], -quant.FP8_MAX, quant.FP8_MAX)
        p8 = p8.astype(jnp.float8_e4m3fn).astype(jnp.float32)
    elif fmt == "int8":
        sp_new = jnp.maximum(amax, quant.EPS) / qmax
        p8 = jnp.clip(jnp.round(p_fused / sp_new[..., None]), -127, 127)
        p8 = p8.astype(jnp.int8).astype(jnp.float32)
    else:
        sp_new = jnp.ones_like(amax)
        p8 = p_fused

    corr = jnp.exp(m_prev - m_new) * (spp / sp_new)
    l_ref[...] = l_prev * corr + jnp.sum(e, axis=-1) / sp_new
    vt = jnp.transpose(v, (1, 0, 2))                   # [Hkv, bn, dh]
    pv = jax.lax.dot_general(p8, vt, (((2,), (1,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)  # [Hkv, g, dh]
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv
    m_ref[...] = m_new
    sp_ref[...] = sp_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _finalize():
        o = acc_ref[...] / l_ref[...][..., None]
        o_ref[0] = o.reshape(H, dh)


def gqa_decode_pallas(
    q: jax.Array,           # [B, H, dh] f32
    k8: jax.Array,          # [B, N, Hkv, dh]
    v8: jax.Array,
    k_scale: jax.Array,     # [B, N, Hkv]
    v_scale: jax.Array,
    slot_pos: jax.Array,    # [B, N] int32
    positions: jax.Array,   # [B] int32
    *,
    window: int = 0,
    block_n: int = 128,
    fmt: str = "fp8_e4m3",
    interpret: bool = True,
) -> jax.Array:
    B, H, dh = q.shape
    N, Hkv = k8.shape[1], k8.shape[2]
    assert N % block_n == 0, (N, block_n)
    qmax = quant.qmax_for(fmt) if fmt != "none" else 1.0

    kernel = functools.partial(
        _gqa_decode_kernel, n_kv=Hkv, block_n=block_n, window=window,
        fmt=fmt, qmax=qmax)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, N // block_n),
        in_specs=[
            pl.BlockSpec((1, H, dh), lambda b, j, p: (b, 0, 0)),
            pl.BlockSpec((1, block_n, Hkv, dh), lambda b, j, p: (b, j, 0, 0)),
            pl.BlockSpec((1, block_n, Hkv, dh), lambda b, j, p: (b, j, 0, 0)),
            pl.BlockSpec((1, block_n, Hkv), lambda b, j, p: (b, j, 0)),
            pl.BlockSpec((1, block_n, Hkv), lambda b, j, p: (b, j, 0)),
            pl.BlockSpec((1, block_n), lambda b, j, p: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, H, dh), lambda b, j, p: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, H // Hkv), jnp.float32),
            pltpu.VMEM((Hkv, H // Hkv), jnp.float32),
            pltpu.VMEM((Hkv, H // Hkv), jnp.float32),
            pltpu.VMEM((Hkv, H // Hkv, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
        interpret=interpret,
    )(positions, q, k8, v8, k_scale, v_scale, slot_pos)
