"""Jit'd public wrapper: FP8 quantized GQA decode over a GQACache."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.kvcache import GQACache
from repro.kernels.gqa_decode import kernel as _k
from repro.kernels.gqa_decode import ref as _ref


@partial(jax.jit, static_argnames=("window", "block_n", "fmt", "use_kernel", "interpret"))
def gqa_decode(
    q: jax.Array,            # [B, H, dh] (RoPE applied)
    cache: GQACache,
    positions: jax.Array,    # [B]
    *,
    window: int = 0,
    block_n: int = 128,
    fmt: str = "fp8_e4m3",
    use_kernel: bool = True,
    interpret: bool = True,
) -> jax.Array:
    N = cache.k.shape[1]
    pad = (-N) % block_n
    k8, v8, ks, vs, sp = cache.k, cache.v, cache.k_scale, cache.v_scale, cache.slot_pos
    if pad:
        pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        k8, v8 = jnp.pad(k8, pad4), jnp.pad(v8, pad4)
        ks = jnp.pad(ks, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        vs = jnp.pad(vs, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        sp = jnp.pad(sp, ((0, 0), (0, pad)), constant_values=-1)
    q = q.astype(jnp.float32)
    if use_kernel:
        return _k.gqa_decode_pallas(
            q, k8, v8, ks, vs, sp, positions,
            window=window, block_n=block_n, fmt=fmt, interpret=interpret)
    return _ref.gqa_decode_pipeline_ref(
        q, k8, v8, ks, vs, sp, positions,
        window=window, block_n=block_n, fmt=fmt)
