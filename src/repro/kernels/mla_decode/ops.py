"""Jit'd public wrappers for the SnapMLA MLA decode kernel.

``snapmla_decode`` consumes a quantized MLACache directly; selects between the
single-pass kernel, the split-KV (flash-decoding) kernel, and the pure-jnp
reference paths. ``snapmla_decode_paged`` is the same dispatch over a
``PagedMLAPool`` (serial-page kernel vs paged split-KV kernel vs paged
oracle). ``num_splits=None`` resolves through ``resolve_num_splits`` — the
profile-driven autotuner (``autotune.SplitProfile``, measured sweeps keyed on
(capacity, block_n, batch), emitted by the benchmarks as a JSON artifact)
with ``default_num_splits``'s context-length heuristic as fallback. On CPU
the kernels run in interpret mode; on TPU set interpret=False.

Cache alignment: the cache capacity must be a multiple of ``block_n``
(``init_mla_cache`` rounds ``max_len`` up to the page size, so this holds by
construction) — the former per-step ``jnp.pad`` of the whole cache was an
O(max_len) HBM copy on every decode step and has been removed.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.kvcache import MLACache, PagedMLAPool, sink_patched_content
from repro.kernels.mla_decode import autotune as _autotune
from repro.kernels.mla_decode import kernel as _k
from repro.kernels.mla_decode import ref as _ref
from repro.kernels.mla_decode.autotune import SplitConfig

# Split sizing: aim for splits of ~SPLIT_TARGET_TOKENS so each split amortizes
# its combine cost, capped at MAX_SPLITS partial buffers.
SPLIT_TARGET_TOKENS = 4096
MAX_SPLITS = 8

# Contiguous-cache default KV block size (the paged kernels' block size is
# structurally the physical page, never this).
DEFAULT_BLOCK_N = 128


def default_num_splits(context_len: int, block_n: int = 128,
                       target_tokens: int = SPLIT_TARGET_TOKENS,
                       max_splits: int = MAX_SPLITS) -> int:
    """num_splits heuristic keyed on context length (cache capacity).

    Short contexts (< 2 * target) stay single-pass — bit-exact with the seed
    kernel and no combine overhead. Longer contexts get the largest power of
    two <= context/target, capped at ``max_splits`` and at the block count.

    This is the *fallback* of the profile-driven autotuner: when the measured
    split profile (``autotune.SplitProfile``) has an entry for the exact
    (capacity, block_n, batch), that measurement wins.
    """
    nblocks = max(1, -(-context_len // block_n))
    s = 1
    while s * 2 <= min(max_splits, context_len // target_tokens, nblocks):
        s *= 2
    return s


def resolve_num_splits(requested: int | None, capacity: int,
                       block_n: int, batch: int | None = None,
                       layout: str = "contiguous",
                       rescale: str = "fma") -> int:
    """Single resolution rule for every decode backend (kernel, pjit ref,
    shard_map ref, paged pool): None/0 = auto — a measured split-profile hit
    for (capacity, block_n, batch) under the cache ``layout`` and the
    kernel's ``rescale`` mode if the autotuner cache has one (exact key,
    else nearest-batch interpolation), else the context-length heuristic.
    AMLA plans come only from AMLA-timed sweeps; an un-swept rescale falls
    back to the heuristic rather than borrowing FMA timings. Fixed counts
    are clamped to the block count so a config tuned for long contexts still
    traces on a short cache."""
    nblocks = max(1, capacity // block_n)
    if requested:
        splits = requested
    else:
        splits = _autotune.tuned_num_splits(capacity, block_n, batch, layout,
                                            rescale)
        if splits is None:
            splits = default_num_splits(capacity, block_n)
    return max(1, min(splits, nblocks))


def resolve_split_config(num_splits: int | None, block_n: int | None,
                         capacity: int, *, batch: int | None = None,
                         layout: str = "contiguous",
                         page_size: int | None = None,
                         rescale: str = "fma") -> SplitConfig:
    """Joint (num_splits, block_n) resolution — the 2D generalization of
    ``resolve_num_splits`` (which stays as the fixed-block_n rule every
    resolved plan funnels through).

      * ``layout == "paged"``: block_n is STRUCTURAL — it must equal the
        physical page size; only num_splits is tunable.
      * explicit ``block_n``: splits resolve at that block size (profile hit
        for the (capacity, block_n, batch) key, else heuristic).
      * ``block_n`` None/0 (auto): the measured joint plan from the v2
        profile — the fastest (num_splits, block_n) recorded across every
        swept block_n at this (capacity, batch, layout) — else the
        DEFAULT_BLOCK_N heuristic. A profile block_n that does not divide
        this cache's capacity is ignored (profiles travel across shapes).
    """
    if layout == "paged":
        if page_size is None:
            raise ValueError("paged split resolution needs page_size "
                             "(block_n is structurally the physical page)")
        if block_n and block_n != page_size:
            raise ValueError(
                f"paged caches fix block_n to the page size ({page_size}); "
                f"got block_n={block_n} — repage the pool instead")
        return SplitConfig(
            resolve_num_splits(num_splits, capacity, page_size, batch,
                               layout, rescale), page_size)
    if block_n:
        return SplitConfig(
            resolve_num_splits(num_splits, capacity, block_n, batch, layout,
                               rescale),
            block_n)
    tuned = _autotune.tuned_split_config(capacity, batch, layout, rescale)
    if tuned is not None and capacity % tuned.block_n == 0:
        nblocks = max(1, capacity // tuned.block_n)
        splits = num_splits if num_splits else tuned.num_splits
        return SplitConfig(max(1, min(splits, nblocks)), tuned.block_n)
    bn = DEFAULT_BLOCK_N if capacity % DEFAULT_BLOCK_N == 0 \
        else max(b for b in (64, 32, 16, 8, 4, 2, 1) if capacity % b == 0)
    return SplitConfig(
        resolve_num_splits(num_splits, capacity, bn, batch, layout, rescale),
        bn)


def _check_alignment(n: int, block_n: int) -> None:
    if n % block_n:
        raise ValueError(
            f"cache capacity {n} is not a multiple of block_n={block_n}; "
            "allocate caches with init_mla_cache (it rounds max_len up to the "
            "page size) so the decode kernel never re-pads the cache per step")


def snapmla_decode(
    q_c8: jax.Array,
    q_r: jax.Array,
    sigma_q: jax.Array,
    cache: MLACache,
    *,
    softmax_scale: float,
    block_n: int = 128,
    fmt: str = "fp8_e4m3",
    num_splits: int | None = None,
    use_kernel: bool = True,
    interpret: bool = True,
    rescale: str = "fma",
) -> tuple[jax.Array, jax.Array]:
    """Decode one token per sequence. Returns (o_latent [B,H,d_c] f32, lse).

    Split resolution happens OUTSIDE the jitted impl (whose jit cache keys on
    the *resolved* count), so an in-process profile update — e.g. the
    benchmarks calling ``emit_split_profile`` — takes effect on the next
    direct call instead of being shadowed by an executable traced under the
    old plan. (Callers that close over this inside their own jit still pin
    the plan at their trace time, as any static argument is.)"""
    N = cache.content.shape[1]
    _check_alignment(N, block_n)
    splits = resolve_num_splits(num_splits, N, block_n, batch=q_c8.shape[0],
                                rescale=rescale)
    return _snapmla_decode_impl(
        q_c8, q_r, sigma_q, cache, softmax_scale=softmax_scale,
        block_n=block_n, fmt=fmt, num_splits=splits, use_kernel=use_kernel,
        interpret=interpret, rescale=rescale)


@partial(jax.jit, static_argnames=("softmax_scale", "block_n", "fmt",
                                   "num_splits", "use_kernel", "interpret",
                                   "rescale"))
def _snapmla_decode_impl(
    q_c8: jax.Array,
    q_r: jax.Array,
    sigma_q: jax.Array,
    cache: MLACache,
    *,
    softmax_scale: float,
    block_n: int,
    fmt: str,
    num_splits: int,
    use_kernel: bool,
    interpret: bool,
    rescale: str = "fma",
) -> tuple[jax.Array, jax.Array]:
    splits = num_splits
    # P-Cast sink guard: substitute the guarded prefix rows in full precision
    # (no-op passthrough on unguarded caches — same jit trace as the seed).
    args = (q_c8, q_r.astype(jnp.float32), sigma_q,
            sink_patched_content(cache),
            cache.rope.astype(jnp.float32), cache.scale, cache.seq_lens)
    if use_kernel:
        # rank-4 (q_len > 1 verify) queries always take the split-KV kernel —
        # it carries the per-row causal limit; num_splits = 1 is one split.
        if splits == 1 and q_c8.ndim == 3:
            return _k.mla_decode_pallas(
                *args, softmax_scale=softmax_scale, block_n=block_n, fmt=fmt,
                interpret=interpret, rescale=rescale)
        return _k.mla_decode_splitkv_pallas(
            *args, softmax_scale=softmax_scale, num_splits=splits,
            block_n=block_n, fmt=fmt, interpret=interpret, rescale=rescale)
    if splits == 1:
        return _ref.snapmla_decode_pipeline_ref(
            *args, softmax_scale=softmax_scale, block_n=block_n, fmt=fmt,
            rescale=rescale)
    return _ref.snapmla_decode_splitkv_ref(
        *args, softmax_scale=softmax_scale, num_splits=splits,
        block_n=block_n, fmt=fmt, rescale=rescale)


def snapmla_decode_paged(
    q_c8: jax.Array,
    q_r: jax.Array,
    sigma_q: jax.Array,
    pool: PagedMLAPool,
    *,
    softmax_scale: float,
    fmt: str = "fp8_e4m3",
    num_splits: int | None = None,
    use_kernel: bool = True,
    interpret: bool = True,
    rescale: str = "fma",
) -> tuple[jax.Array, jax.Array]:
    """Decode one token per sequence against a paged pool.

    ``num_splits`` follows the same resolution rule as the contiguous path
    (None/0 = autotuner profile -> heuristic; 1 = the seed serial-page
    kernel, bit-exact; >1 = the paged split-KV kernel with block-level early
    exit) and, like ``snapmla_decode``, resolves outside the jitted impl so
    profile updates aren't shadowed by the jit cache. Capacity for
    resolution is the per-sequence page-table span ``P * page`` — the pool
    may be much larger.

    Page-table rows are arbitrary per-slot mappings: batch-owned strided
    runs and the serving engine's allocator-written rows (shared refcounted
    prefix pages, idle slots parked on the page-0 scratch page) go through
    the identical kernel path — only entries below ``seq_lens`` are read.
    """
    page = pool.content.shape[1]
    capacity = pool.page_table.shape[1] * page
    splits = resolve_num_splits(num_splits, capacity, page,
                                batch=q_c8.shape[0], layout="paged",
                                rescale=rescale)
    return _snapmla_decode_paged_impl(
        q_c8, q_r, sigma_q, pool, softmax_scale=softmax_scale, fmt=fmt,
        num_splits=splits, use_kernel=use_kernel, interpret=interpret,
        rescale=rescale)


@partial(jax.jit, static_argnames=("softmax_scale", "fmt", "num_splits",
                                   "use_kernel", "interpret", "rescale"))
def _snapmla_decode_paged_impl(
    q_c8: jax.Array,
    q_r: jax.Array,
    sigma_q: jax.Array,
    pool: PagedMLAPool,
    *,
    softmax_scale: float,
    fmt: str,
    num_splits: int,
    use_kernel: bool,
    interpret: bool,
    rescale: str = "fma",
) -> tuple[jax.Array, jax.Array]:
    splits = num_splits
    args = (q_c8, q_r.astype(jnp.float32), sigma_q,
            pool.content, pool.rope.astype(jnp.float32), pool.scale,
            pool.page_table, pool.seq_lens)
    if use_kernel:
        # rank-4 (q_len > 1 verify) queries always take the split-KV kernel
        if splits == 1 and q_c8.ndim == 3:
            return _k.mla_decode_paged_pallas(
                *args, softmax_scale=softmax_scale, fmt=fmt,
                interpret=interpret, rescale=rescale)
        return _k.mla_decode_paged_splitkv_pallas(
            *args, softmax_scale=softmax_scale, num_splits=splits, fmt=fmt,
            interpret=interpret, rescale=rescale)
    return _ref.snapmla_decode_paged_splitkv_ref(
        *args, softmax_scale=softmax_scale, num_splits=splits, fmt=fmt,
        rescale=rescale)
