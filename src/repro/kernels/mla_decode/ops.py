"""Jit'd public wrappers for the SnapMLA MLA decode kernel.

``snapmla_decode`` consumes a quantized MLACache directly; handles padding to
block multiples and selects kernel vs pure-jnp reference path. On CPU the
kernel runs in interpret mode; on TPU set interpret=False.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.kvcache import MLACache, PagedMLAPool
from repro.kernels.mla_decode import kernel as _k
from repro.kernels.mla_decode import ref as _ref


@partial(jax.jit, static_argnames=("softmax_scale", "block_n", "fmt", "use_kernel", "interpret"))
def snapmla_decode(
    q_c8: jax.Array,
    q_r: jax.Array,
    sigma_q: jax.Array,
    cache: MLACache,
    *,
    softmax_scale: float,
    block_n: int = 128,
    fmt: str = "fp8_e4m3",
    use_kernel: bool = True,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Decode one token per sequence. Returns (o_latent [B,H,d_c] f32, lse)."""
    N = cache.content.shape[1]
    pad = (-N) % block_n
    content, rope, scale = cache.content, cache.rope, cache.scale
    if pad:
        content = jnp.pad(content, ((0, 0), (0, pad), (0, 0)))
        rope = jnp.pad(rope, ((0, 0), (0, pad), (0, 0)))
        scale = jnp.pad(scale, ((0, 0), (0, pad)), constant_values=1.0)
    args = (q_c8, q_r.astype(jnp.float32), sigma_q, content,
            rope.astype(jnp.float32), scale, cache.seq_lens)
    if use_kernel:
        return _k.mla_decode_pallas(
            *args, softmax_scale=softmax_scale, block_n=block_n, fmt=fmt,
            interpret=interpret)
    return _ref.snapmla_decode_pipeline_ref(
        *args, softmax_scale=softmax_scale, block_n=block_n, fmt=fmt)


@partial(jax.jit, static_argnames=("softmax_scale", "fmt", "interpret"))
def snapmla_decode_paged(
    q_c8: jax.Array,
    q_r: jax.Array,
    sigma_q: jax.Array,
    pool: PagedMLAPool,
    *,
    softmax_scale: float,
    fmt: str = "fp8_e4m3",
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    return _k.mla_decode_paged_pallas(
        q_c8, q_r.astype(jnp.float32), sigma_q,
        pool.content, pool.rope.astype(jnp.float32), pool.scale,
        pool.page_table, pool.seq_lens,
        softmax_scale=softmax_scale, fmt=fmt, interpret=interpret)
