"""Jit'd public wrappers for the SnapMLA MLA decode kernel.

``snapmla_decode`` consumes a quantized MLACache directly; selects between the
single-pass kernel, the split-KV (flash-decoding) kernel, and the pure-jnp
reference paths. ``num_splits=None`` applies ``default_num_splits`` — a
context-length heuristic that keeps short contexts on the single-pass path
(bit-exact with the seed kernel) and cuts long contexts into sequence-parallel
splits. On CPU the kernels run in interpret mode; on TPU set interpret=False.

Cache alignment: the cache capacity must be a multiple of ``block_n``
(``init_mla_cache`` rounds ``max_len`` up to the page size, so this holds by
construction) — the former per-step ``jnp.pad`` of the whole cache was an
O(max_len) HBM copy on every decode step and has been removed.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.kvcache import MLACache, PagedMLAPool
from repro.kernels.mla_decode import kernel as _k
from repro.kernels.mla_decode import ref as _ref

# Split sizing: aim for splits of ~SPLIT_TARGET_TOKENS so each split amortizes
# its combine cost, capped at MAX_SPLITS partial buffers.
SPLIT_TARGET_TOKENS = 4096
MAX_SPLITS = 8


def default_num_splits(context_len: int, block_n: int = 128,
                       target_tokens: int = SPLIT_TARGET_TOKENS,
                       max_splits: int = MAX_SPLITS) -> int:
    """num_splits heuristic keyed on context length (cache capacity).

    Short contexts (< 2 * target) stay single-pass — bit-exact with the seed
    kernel and no combine overhead. Longer contexts get the largest power of
    two <= context/target, capped at ``max_splits`` and at the block count.
    """
    nblocks = max(1, -(-context_len // block_n))
    s = 1
    while s * 2 <= min(max_splits, context_len // target_tokens, nblocks):
        s *= 2
    return s


def resolve_num_splits(requested: int | None, capacity: int,
                       block_n: int) -> int:
    """Single resolution rule for every decode path (kernel, pjit ref,
    shard_map ref): None/0 = auto heuristic; fixed counts are clamped to the
    block count so a config tuned for long contexts still traces on a short
    cache."""
    splits = requested if requested else default_num_splits(capacity, block_n)
    return max(1, min(splits, capacity // block_n))


def _check_alignment(n: int, block_n: int) -> None:
    if n % block_n:
        raise ValueError(
            f"cache capacity {n} is not a multiple of block_n={block_n}; "
            "allocate caches with init_mla_cache (it rounds max_len up to the "
            "page size) so the decode kernel never re-pads the cache per step")


@partial(jax.jit, static_argnames=("softmax_scale", "block_n", "fmt",
                                   "num_splits", "use_kernel", "interpret"))
def snapmla_decode(
    q_c8: jax.Array,
    q_r: jax.Array,
    sigma_q: jax.Array,
    cache: MLACache,
    *,
    softmax_scale: float,
    block_n: int = 128,
    fmt: str = "fp8_e4m3",
    num_splits: int | None = None,
    use_kernel: bool = True,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Decode one token per sequence. Returns (o_latent [B,H,d_c] f32, lse)."""
    N = cache.content.shape[1]
    _check_alignment(N, block_n)
    splits = resolve_num_splits(num_splits, N, block_n)
    args = (q_c8, q_r.astype(jnp.float32), sigma_q, cache.content,
            cache.rope.astype(jnp.float32), cache.scale, cache.seq_lens)
    if use_kernel:
        if splits == 1:
            return _k.mla_decode_pallas(
                *args, softmax_scale=softmax_scale, block_n=block_n, fmt=fmt,
                interpret=interpret)
        return _k.mla_decode_splitkv_pallas(
            *args, softmax_scale=softmax_scale, num_splits=splits,
            block_n=block_n, fmt=fmt, interpret=interpret)
    if splits == 1:
        return _ref.snapmla_decode_pipeline_ref(
            *args, softmax_scale=softmax_scale, block_n=block_n, fmt=fmt)
    return _ref.snapmla_decode_splitkv_ref(
        *args, softmax_scale=softmax_scale, num_splits=splits,
        block_n=block_n, fmt=fmt)


@partial(jax.jit, static_argnames=("softmax_scale", "fmt", "interpret"))
def snapmla_decode_paged(
    q_c8: jax.Array,
    q_r: jax.Array,
    sigma_q: jax.Array,
    pool: PagedMLAPool,
    *,
    softmax_scale: float,
    fmt: str = "fp8_e4m3",
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    return _k.mla_decode_paged_pallas(
        q_c8, q_r.astype(jnp.float32), sigma_q,
        pool.content, pool.rope.astype(jnp.float32), pool.scale,
        pool.page_table, pool.seq_lens,
        softmax_scale=softmax_scale, fmt=fmt, interpret=interpret)
