"""Unified decode-attention backend registry.

Every way this repo can compute one step of SnapMLA decode attention is a
named :class:`DecodeBackend` with ONE uniform signature

    backend.decode(q: DecodeQuery, cache, cfg: BackendConfig, ctx=None)
        -> o_latent [B, H, d_c] f32

plus a ``supports(cfg, mesh, batch, ...)`` predicate, and
:func:`resolve_backend` is the single kernel-selection rule every caller
routes through (``transformer._mla_decode``, ``core.snapmla.decode_step``,
and — via the model config — ``launch/steps.py`` / ``serve --backend``).

Backends:

  jnp_ref               contiguous MLACache, parallel (einsum) pipeline refs —
                        the pjit/cost-analysis-friendly twin
  jnp_paged_ref         PagedMLAPool, page-table gather + the same refs
                        (materializes the full page-table span; reference only)
                        — page-table rows are arbitrary per-slot mappings, so
                        batch-owned pools and the serving engine's
                        allocator-owned (prefix-shared) tables both work
  pallas_splitkv        contiguous Pallas kernels (single-pass or split-KV,
                        interpret mode on CPU, compiled on TPU)
  pallas_paged_splitkv  paged Pallas kernels — scalar-prefetched page-table
                        index maps, HBM traffic proportional to seq_lens
  shard_map             collective-free shard_map region over dp x model
                        (contiguous caches, requires a mesh + divisibility)

``num_splits`` resolution stays in ``ops.resolve_num_splits`` (profile
autotuner -> heuristic) and is applied inside each backend, so the split plan
is chosen per (capacity, block_n, batch, layout) regardless of which backend
runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.kvcache import (MLACache, PagedMLAPool, paged_gather,
                                sink_patched_content)
from repro.kernels.mla_decode import ops as _ops
from repro.kernels.mla_decode import ref as _ref


class DecodeQuery(NamedTuple):
    """Prepared decode query (post Fused-Q-Quant / ``ref.prepare_q``).

    Rank-3 ``[B, H, ...]`` is the one-token decode shape; rank-4
    ``[B, q_len, H, ...]`` is the speculative-verify block (the q_len query
    rows are the LAST q_len positions of each sequence, causally masked) —
    kernel and ref backends accept both, shard_map rejects q_len > 1."""

    q_c8: jax.Array      # [B, (q_len,) H, d_c] quantized content query
    q_r: jax.Array       # [B, (q_len,) H, d_r] rope query, / sigma_q
    sigma_q: jax.Array   # [B, (q_len,) H] per-(token, head) content scale

    @property
    def q_len(self) -> int:
        return self.q_c8.shape[1] if self.q_c8.ndim == 4 else 1


@dataclasses.dataclass(frozen=True)
class BackendConfig:
    """Static (trace-time) decode-attention parameters shared by every
    backend. ``num_splits`` None/0 = autotuner profile -> heuristic;
    ``block_n`` 0 = joint 2D (num_splits, block_n) plan from the v2 profile
    (contiguous caches only — paged block_n is structurally the page size);
    ``interpret`` None = interpret on CPU, compiled on TPU; ``rescale``
    "fma" = the exact per-block FMA rescale, "amla" = the AMLA exponent-add
    (combine-free split-KV emission) fast path."""

    softmax_scale: float
    block_n: int = 128
    fmt: str = "fp8_e4m3"
    num_splits: int | None = None
    interpret: bool | None = None
    rescale: str = "fma"

    def resolved_interpret(self) -> bool:
        if self.interpret is None:
            return jax.default_backend() != "tpu"
        return self.interpret


def _split_plan(cfg: BackendConfig, capacity: int, batch: int,
                layout: str, page_size: int | None = None) -> _ops.SplitConfig:
    """The one place every backend resolves its (num_splits, block_n) plan."""
    return _ops.resolve_split_config(
        cfg.num_splits, cfg.block_n if layout == "contiguous" else None,
        capacity, batch=batch, layout=layout, page_size=page_size,
        rescale=cfg.rescale)


@dataclasses.dataclass(frozen=True)
class DecodeBackend:
    """A named decode-attention implementation.

    ``decode(q, cache, cfg, ctx)`` computes o_latent; ``supports(cfg, mesh,
    batch, paged=..., n_heads=..., dp=...)`` returns (ok, reason) — the
    predicate ``resolve_backend`` consults before dispatching."""

    name: str
    layout: str            # "contiguous" | "paged" — the cache type consumed
    kind: str              # "ref" | "kernel" | "shard_map"
    decode: Callable[..., jax.Array]
    supports: Callable[..., tuple[bool, str]]


_REGISTRY: dict[str, DecodeBackend] = {}


def register(backend: DecodeBackend) -> DecodeBackend:
    if backend.name in _REGISTRY:
        raise ValueError(f"decode backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> DecodeBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown decode backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def backend_names() -> list[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# supports predicates
# ---------------------------------------------------------------------------

def _layout_ok(layout: str, paged: bool) -> tuple[bool, str]:
    want_paged = layout == "paged"
    if paged != want_paged:
        have = "PagedMLAPool" if paged else "MLACache"
        need = "a paged pool" if want_paged else "a contiguous MLACache"
        return False, f"consumes {need}, cache is a {have}"
    return True, ""


def _supports_ref(layout):
    def supports(cfg=None, mesh=None, batch=None, *, paged=False,
                 n_heads=None, dp=None, q_len=None):
        return _layout_ok(layout, paged)
    return supports


def _supports_kernel(layout):
    def supports(cfg=None, mesh=None, batch=None, *, paged=False,
                 n_heads=None, dp=None, q_len=None):
        ok, why = _layout_ok(layout, paged)
        if not ok:
            return ok, why
        if mesh is not None and mesh.size > 1:
            return False, ("Pallas decode kernels run per device; under a "
                           f"{mesh.size}-device pjit mesh use the jnp_ref "
                           "pjit twin (or the shard_map backend)")
        return True, ""
    return supports


def _supports_shard_map(cfg=None, mesh=None, batch=None, *, paged=False,
                        n_heads=None, dp=None, q_len=None):
    ok, why = _layout_ok("contiguous", paged)
    if not ok:
        return ok, why
    if q_len is not None and q_len > 1:
        return False, ("the shard_map region computes one query token per "
                       f"slot; q_len={q_len} verify blocks need the kernel "
                       "or jnp_ref backends")
    if mesh is None:
        return False, "requires a device mesh (SHARD_CTX / dryrun variants)"
    from repro.core.distributed_decode import shard_map_applicable
    if batch is None or n_heads is None:
        return False, "requires static batch and n_heads for divisibility"
    if not shard_map_applicable(mesh, dp, batch, n_heads):
        return False, (f"batch={batch} / n_heads={n_heads} do not divide the "
                       "(dp, model) mesh axes")
    return True, ""


# ---------------------------------------------------------------------------
# decode implementations (uniform signature)
# ---------------------------------------------------------------------------

def _jnp_ref_decode(q: DecodeQuery, cache: MLACache, cfg: BackendConfig,
                    ctx: Any = None) -> jax.Array:
    plan = _split_plan(cfg, cache.capacity, q.q_c8.shape[0], "contiguous")
    o, _lse = _ref.snapmla_decode_parallel_any(
        q.q_c8, q.q_r.astype(jnp.float32), q.sigma_q,
        sink_patched_content(cache),
        cache.rope.astype(jnp.float32), cache.scale, cache.seq_lens,
        softmax_scale=cfg.softmax_scale, num_splits=plan.num_splits,
        block_n=plan.block_n, fmt=cfg.fmt)
    return o


def _jnp_paged_ref_decode(q: DecodeQuery, pool: PagedMLAPool,
                          cfg: BackendConfig, ctx: Any = None) -> jax.Array:
    page = pool.page_size
    plan = _split_plan(cfg, pool.capacity, q.q_c8.shape[0], "paged",
                       page_size=page)
    splits = plan.num_splits
    content, rope, scale = paged_gather(pool)
    o, _lse = _ref.snapmla_decode_parallel_any(
        q.q_c8, q.q_r.astype(jnp.float32), q.sigma_q, content,
        rope.astype(jnp.float32), scale, pool.seq_lens,
        softmax_scale=cfg.softmax_scale, num_splits=splits, block_n=page,
        fmt=cfg.fmt)
    return o


def _pallas_decode(q: DecodeQuery, cache: MLACache, cfg: BackendConfig,
                   ctx: Any = None) -> jax.Array:
    plan = _split_plan(cfg, cache.capacity, q.q_c8.shape[0], "contiguous")
    o, _lse = _ops.snapmla_decode(
        q.q_c8, q.q_r, q.sigma_q, cache, softmax_scale=cfg.softmax_scale,
        block_n=plan.block_n, fmt=cfg.fmt, num_splits=plan.num_splits,
        use_kernel=True, interpret=cfg.resolved_interpret(),
        rescale=cfg.rescale)
    return o


def _pallas_paged_decode(q: DecodeQuery, pool: PagedMLAPool,
                         cfg: BackendConfig, ctx: Any = None) -> jax.Array:
    o, _lse = _ops.snapmla_decode_paged(
        q.q_c8, q.q_r, q.sigma_q, pool, softmax_scale=cfg.softmax_scale,
        fmt=cfg.fmt, num_splits=cfg.num_splits, use_kernel=True,
        interpret=cfg.resolved_interpret(), rescale=cfg.rescale)
    return o


def _shard_map_decode(q: DecodeQuery, cache: MLACache, cfg: BackendConfig,
                      ctx: Any = None) -> jax.Array:
    if q.q_c8.ndim == 4:
        raise ValueError("shard_map backend does not take q_len > 1 verify "
                         "blocks; resolve with q_len to route elsewhere")
    if not ctx or ctx.get("mesh") is None:
        raise ValueError("shard_map backend needs ctx={'mesh': ..., 'dp': ...}")
    from repro.core.distributed_decode import mla_decode_shard_map
    plan = _split_plan(cfg, cache.capacity, q.q_c8.shape[0], "contiguous")
    return mla_decode_shard_map(
        ctx["mesh"], ctx.get("dp"), q.q_c8, q.q_r, q.sigma_q, cache,
        softmax_scale=cfg.softmax_scale, block_n=plan.block_n, fmt=cfg.fmt,
        num_splits=plan.num_splits)


register(DecodeBackend("jnp_ref", "contiguous", "ref",
                       _jnp_ref_decode, _supports_ref("contiguous")))
register(DecodeBackend("jnp_paged_ref", "paged", "ref",
                       _jnp_paged_ref_decode, _supports_ref("paged")))
register(DecodeBackend("pallas_splitkv", "contiguous", "kernel",
                       _pallas_decode, _supports_kernel("contiguous")))
register(DecodeBackend("pallas_paged_splitkv", "paged", "kernel",
                       _pallas_paged_decode, _supports_kernel("paged")))
register(DecodeBackend("shard_map", "contiguous", "shard_map",
                       _shard_map_decode, _supports_shard_map))


# ---------------------------------------------------------------------------
# analytic dispatch cost (telemetry annotation; see obs/)
# ---------------------------------------------------------------------------

# v5e hardware constants (same figures as benchmarks/kernel_perf.py — pure
# modeled numbers, deterministic on any machine)
_V5E_HBM = 819e9          # bytes/s
_V5E_BF16 = 197e12        # FLOP/s


def token_cost(fmt: str, d_c: int, d_r: int, heads: int
               ) -> tuple[int, int]:
    """(bytes streamed, FLOPs computed) per CACHED TOKEN of one decode
    dispatch: quantized content byte/elem + bf16 rope + f32 per-token
    scale, QK + PV per head — the Eq. 12–13 pipeline's traffic model."""
    if fmt == "none":
        bytes_tok = (d_c + d_r) * 2
    else:
        bytes_tok = d_c * 1 + d_r * 2 + 4
    flops_tok = (2 * (d_c + d_r) + 2 * d_c) * heads
    return bytes_tok, flops_tok


def dispatch_cost(backend: "DecodeBackend | str", *, tokens_visited: int,
                  tokens_full: int, heads: int, d_c: int, d_r: int,
                  fmt: str) -> dict:
    """Analytic bytes/FLOPs annotation for ONE decode dispatch.

    ``tokens_visited`` is the KV-token work the split-KV early exit
    actually touches (``sum(seq_lens)``, which the engine's blocks-visited
    counters already track); ``tokens_full`` is the dense full-span sweep.
    Kernel backends stream only the visited tokens; the paged REF backend
    materializes the whole page-table span (``paged_gather``), so its
    modeled traffic is the full sweep — the annotation makes that
    structural difference visible per step. ``achieved_fraction`` is
    roofline-minimum bytes over modeled bytes: 1.0 = the dispatch streams
    exactly the live context, lower = dead traffic."""
    b = get_backend(backend) if isinstance(backend, str) else backend
    bytes_tok, flops_tok = token_cost(fmt, d_c, d_r, heads)
    streamed = tokens_full if (b.kind == "ref" and b.layout == "paged") \
        else tokens_visited
    streamed = max(streamed, tokens_visited)
    model_bytes = streamed * bytes_tok
    min_bytes = tokens_visited * bytes_tok
    flops = tokens_visited * flops_tok
    t_model_s = max(model_bytes / _V5E_HBM, flops / _V5E_BF16)
    return {
        "backend": b.name,
        "bytes": model_bytes,
        "bytes_min": min_bytes,
        "flops": flops,
        "achieved_fraction": (min_bytes / model_bytes
                              if model_bytes else 1.0),
        "t_model_us": t_model_s * 1e6,
    }


# ---------------------------------------------------------------------------
# resolution — the ONE decode-dispatch decision point
# ---------------------------------------------------------------------------

def canonical_name(request: str, paged: bool) -> str:
    """Map a user-facing request ('ref' / 'kernel' / 'shard-map' or an exact
    registry name) to a registry name for the given cache layout."""
    if request == "ref":
        return "jnp_paged_ref" if paged else "jnp_ref"
    if request == "kernel":
        return "pallas_paged_splitkv" if paged else "pallas_splitkv"
    if request == "shard-map":
        return "shard_map"
    return request


def resolve_backend(request: str = "auto", *, paged: bool = False,
                    batch: int | None = None, n_heads: int | None = None,
                    mesh=None, dp=None, use_kernels: bool = False,
                    prefer_shard_map: bool = False,
                    cfg: BackendConfig | None = None,
                    q_len: int | None = None) -> DecodeBackend:
    """Pick the decode backend. Static (trace-time) decision.

    ``request`` is ``serve --backend``'s vocabulary — "auto", "ref",
    "kernel", "shard-map" — or an exact registry name. "auto" prefers, in
    order: the shard_map collective-free region (when a mesh context asked
    for it and the shapes divide), the Pallas kernels (when ``use_kernels``
    and no multi-device pjit mesh is in the way), else the jnp pjit twin —
    auto never fails, it degrades to the reference path. An explicit request
    whose ``supports`` predicate rejects the configuration raises at trace
    time with the reason. ``q_len`` > 1 (the speculative-verify block shape)
    routes away from backends that only take one query token per slot
    (shard_map) — under "auto" it silently degrades, an explicit request
    raises.
    """
    kw = dict(paged=paged, n_heads=n_heads, dp=dp, q_len=q_len)
    if request in (None, "", "auto"):
        if prefer_shard_map:
            sm = get_backend("shard_map")
            if sm.supports(cfg, mesh, batch, **kw)[0]:
                return sm
        if use_kernels:
            k = get_backend(canonical_name("kernel", paged))
            if k.supports(cfg, mesh, batch, **kw)[0]:
                return k
        return get_backend(canonical_name("ref", paged))
    backend = get_backend(canonical_name(request, paged))
    ok, why = backend.supports(cfg, mesh, batch, **kw)
    if not ok:
        raise ValueError(f"decode backend {backend.name!r} (requested "
                         f"{request!r}) unsupported here: {why}")
    return backend
