"""SnapMLA FP8 MLA decode — Pallas TPU kernel (the paper's flagship kernel).

Implements the full quantized decode pipeline of §3.2.3 inside one
``pl.pallas_call``:

  grid = (batch, kv_blocks) — the KV-block loop is the *innermost, sequential*
  grid dimension, so the scale-aware online-softmax state (m, l, sigma_p, acc)
  lives in VMEM scratch and is carried across grid steps. On TPU the grid is
  executed in order by construction, which gives us the paper's Appendix-E
  "monotonic scale progression" for free (no dual-warp-group inversion exists
  to cause the bidirectional-rescale hazard).

  Per KV block (block_n = 128 tokens — §3.3.2's cache-line-aligned tile):
    1. QK with pre-scaled domain alignment (Key Step 1): one uniform
       content+rope dot, one rescale by sigma_q ⊗ sigma_k.
    2. Online softmax max/renormalization.
    3. Scale fusion p~ = e ⊙ sigma_k (V ≡ latent cache in absorbed MLA).
    4. Block-wise dynamic P quantization (sigma_p = max|p~|/qmax).
    5. FP8 PV "GEMM" + implicit dequantization via Eq. 12-13 accumulation.

TPU adaptation notes (DESIGN.md §2): FP8 here is the *storage* dtype — blocks
are upcast to f32 on load inside the kernel (v5e has no FP8 MXU; the win is
HBM bytes, which is what decode attention is bound by at small head counts).
The paged variant uses a scalar-prefetched page table in the BlockSpec index
maps — the TPU-native PagedAttention (replaces the paper's TMA-driven
Fused-K-Append read path).

Validated in interpret mode against ref.snapmla_decode_pipeline_ref (exact
same arithmetic) and core.attention.mla_decode_dequant_ref (quantization
error bound).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quant

NEG_INF = -1e30


def _quantize_block(p_fused, fmt: str, qmax: float):
    amax = jnp.max(jnp.abs(p_fused), axis=-1)
    sp = jnp.maximum(amax, quant.EPS) / qmax
    if fmt == "fp8_e4m3":
        p8 = jnp.clip(p_fused / sp[:, None], -quant.FP8_MAX, quant.FP8_MAX)
        p8 = p8.astype(jnp.float8_e4m3fn).astype(jnp.float32)
    elif fmt == "int8":
        p8 = jnp.clip(jnp.round(p_fused / sp[:, None]), -127, 127)
        p8 = p8.astype(jnp.int8).astype(jnp.float32)
    else:  # "none": scale-fused but unquantized (BF16-pipeline baseline)
        sp = jnp.ones_like(sp)
        p8 = p_fused
    return p8, sp


def _mla_decode_kernel(
    # scalar prefetch
    seq_lens_ref,           # [B] int32
    # inputs (VMEM blocks)
    q_c_ref,                # [1, H, d_c]  storage dtype
    q_r_ref,                # [1, H, d_r]  f32 (pre-divided by sigma_q)
    sigma_q_ref,            # [1, H]       f32
    content_ref,            # [1, bn, d_c] storage dtype (or [bn, d_c] paged)
    rope_ref,               # [1, bn, d_r] f32/bf16 (pre-divided by sigma_k)
    sigma_k_ref,            # [1, bn]      f32
    # outputs
    o_ref,                  # [1, H, d_c]  f32
    lse_ref,                # [1, H]       f32
    # scratch
    m_ref, l_ref, sp_ref,   # [H]
    acc_ref,                # [H, d_c]
    *,
    softmax_scale: float,
    block_n: int,
    fmt: str,
    qmax: float,
    paged: bool,
):
    b = pl.program_id(0)
    j = pl.program_id(1)
    nblocks = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        sp_ref[...] = jnp.ones_like(sp_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qc = q_c_ref[0].astype(jnp.float32)              # [H, d_c]
    qr = q_r_ref[0].astype(jnp.float32)              # [H, d_r]
    sq = sigma_q_ref[0].astype(jnp.float32)          # [H]
    if paged:
        c = content_ref[...].astype(jnp.float32)     # [bn, d_c]
        r = rope_ref[...].astype(jnp.float32)        # [bn, d_r]
        sk = sigma_k_ref[...].astype(jnp.float32)    # [bn]
    else:
        c = content_ref[0].astype(jnp.float32)
        r = rope_ref[0].astype(jnp.float32)
        sk = sigma_k_ref[0].astype(jnp.float32)

    # --- Key Step 1: uniform QK + single rescale -------------------------
    s = jax.lax.dot_general(qc, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s += jax.lax.dot_general(qr, r, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    s = s * (sq[:, None] * sk[None, :]) * softmax_scale            # [H, bn]

    tok = j * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = tok < seq_lens_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    # --- online softmax ---------------------------------------------------
    m_prev, l_prev, sp_prev = m_ref[...], l_ref[...], sp_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))               # [H]
    e = jnp.exp(s - m_new[:, None])
    e = jnp.where(valid, e, 0.0)

    # --- Key Step 2: scale fusion + block-wise dynamic P quantization -----
    p_fused = e * sk[None, :]
    p8, sp_new = _quantize_block(p_fused, fmt, qmax)

    # --- implicit dequantization (Eqs. 12-13) ------------------------------
    corr = jnp.exp(m_prev - m_new) * (sp_prev / sp_new)            # [H]
    l_ref[...] = l_prev * corr + jnp.sum(e, axis=-1) / sp_new
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p8, c, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    sp_ref[...] = sp_new

    @pl.when(j == nblocks - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = acc_ref[...] / l[:, None]                       # sigma_p cancels
        lse_ref[0] = m_ref[...] + jnp.log(sp_ref[...] * l)


def mla_decode_pallas(
    q_c8: jax.Array,        # [B, H, d_c] storage dtype
    q_r: jax.Array,         # [B, H, d_r] f32 (pre-divided by sigma_q)
    sigma_q: jax.Array,     # [B, H] f32
    content: jax.Array,     # [B, N, d_c]
    rope: jax.Array,        # [B, N, d_r]
    sigma_k: jax.Array,     # [B, N] f32
    seq_lens: jax.Array,    # [B] int32
    *,
    softmax_scale: float,
    block_n: int = 128,
    fmt: str = "fp8_e4m3",
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Contiguous-cache SnapMLA decode. Returns (o [B,H,d_c] f32, lse [B,H])."""
    B, H, d_c = q_c8.shape
    d_r = q_r.shape[-1]
    N = content.shape[1]
    assert N % block_n == 0, (N, block_n)
    nblocks = N // block_n
    qmax = quant.qmax_for(fmt) if fmt != "none" else 1.0

    kernel = functools.partial(
        _mla_decode_kernel, softmax_scale=softmax_scale, block_n=block_n,
        fmt=fmt, qmax=qmax, paged=False)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nblocks),
        in_specs=[
            pl.BlockSpec((1, H, d_c), lambda b, j, sl: (b, 0, 0)),
            pl.BlockSpec((1, H, d_r), lambda b, j, sl: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, j, sl: (b, 0)),
            pl.BlockSpec((1, block_n, d_c), lambda b, j, sl: (b, j, 0)),
            pl.BlockSpec((1, block_n, d_r), lambda b, j, sl: (b, j, 0)),
            pl.BlockSpec((1, block_n), lambda b, j, sl: (b, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, d_c), lambda b, j, sl: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, j, sl: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, d_c), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, d_c), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(seq_lens, q_c8, q_r, sigma_q, content, rope, sigma_k)


def mla_decode_paged_pallas(
    q_c8: jax.Array,        # [B, H, d_c]
    q_r: jax.Array,         # [B, H, d_r]
    sigma_q: jax.Array,     # [B, H]
    content_pool: jax.Array,  # [n_pages, page, d_c]
    rope_pool: jax.Array,     # [n_pages, page, d_r]
    scale_pool: jax.Array,    # [n_pages, page]
    page_table: jax.Array,    # [B, P] int32
    seq_lens: jax.Array,      # [B]
    *,
    softmax_scale: float,
    fmt: str = "fp8_e4m3",
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Paged-pool SnapMLA decode: the page table is scalar-prefetched and
    drives the BlockSpec index maps (TPU-native PagedAttention)."""
    B, H, d_c = q_c8.shape
    d_r = q_r.shape[-1]
    n_pages, page, _ = content_pool.shape
    P = page_table.shape[1]
    qmax = quant.qmax_for(fmt) if fmt != "none" else 1.0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # seq_lens, page_table
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, H, d_c), lambda b, j, sl, pt: (b, 0, 0)),
            pl.BlockSpec((1, H, d_r), lambda b, j, sl, pt: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, j, sl, pt: (b, 0)),
            # the page table drives the DMA source: TPU-native PagedAttention
            pl.BlockSpec((1, page, d_c), lambda b, j, sl, pt: (pt[b, j], 0, 0)),
            pl.BlockSpec((1, page, d_r), lambda b, j, sl, pt: (pt[b, j], 0, 0)),
            pl.BlockSpec((1, page), lambda b, j, sl, pt: (pt[b, j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, d_c), lambda b, j, sl, pt: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, j, sl, pt: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, d_c), jnp.float32),
        ],
    )

    def kernel_paged(sl_ref, pt_ref, *rest):
        return _paged_body(sl_ref, pt_ref, *rest,
                           softmax_scale=softmax_scale, page=page, fmt=fmt, qmax=qmax)

    return pl.pallas_call(
        kernel_paged,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, d_c), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(seq_lens, page_table, q_c8, q_r, sigma_q, content_pool, rope_pool, scale_pool)


def _paged_body(seq_lens_ref, page_table_ref, q_c_ref, q_r_ref, sigma_q_ref,
                content_ref, rope_ref, sigma_k_ref, o_ref, lse_ref,
                m_ref, l_ref, sp_ref, acc_ref, *,
                softmax_scale, page, fmt, qmax):
    # identical math to _mla_decode_kernel, with 3D (1, page, d) blocks
    del page_table_ref  # only used by the index maps
    _mla_decode_kernel(
        seq_lens_ref, q_c_ref, q_r_ref, sigma_q_ref,
        content_ref, rope_ref, sigma_k_ref, o_ref, lse_ref,
        m_ref, l_ref, sp_ref, acc_ref,
        softmax_scale=softmax_scale, block_n=page, fmt=fmt, qmax=qmax,
        paged=False)
