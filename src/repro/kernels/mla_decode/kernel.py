"""SnapMLA FP8 MLA decode — Pallas TPU kernel (the paper's flagship kernel).

Implements the full quantized decode pipeline of §3.2.3 inside one
``pl.pallas_call``:

  grid = (batch, kv_blocks) — the KV-block loop is the *innermost, sequential*
  grid dimension, so the scale-aware online-softmax state (m, l, sigma_p, acc)
  lives in VMEM scratch and is carried across grid steps. On TPU the grid is
  executed in order by construction, which gives us the paper's Appendix-E
  "monotonic scale progression" for free (no dual-warp-group inversion exists
  to cause the bidirectional-rescale hazard).

  Per KV block (block_n = 128 tokens — §3.3.2's cache-line-aligned tile):
    1. QK with pre-scaled domain alignment (Key Step 1): one uniform
       content+rope dot, one rescale by sigma_q ⊗ sigma_k.
    2. Online softmax max/renormalization.
    3. Scale fusion p~ = e ⊙ sigma_k (V ≡ latent cache in absorbed MLA).
    4. Block-wise dynamic P quantization (sigma_p = max|p~|/qmax).
    5. FP8 PV "GEMM" + implicit dequantization via Eq. 12-13 accumulation.

Split-KV (flash-decoding) variant — ``mla_decode_splitkv_pallas``:

  grid = (batch, num_splits, kv_blocks_per_split) with the block loop still
  innermost and sequential. Each split runs the exact same scale-fused FP8
  block pipeline over its KV slice and emits partial (o, lse, sigma_p); a
  second ``lse_combine_pallas`` kernel merges the partials with the standard
  max-shift LSE rescale. The Appendix-E "monotonic scale progression"
  argument restated for the split grid: scale monotonicity is only required
  *within* one online-softmax accumulation chain (it is what makes the
  Eq. 12-13 rescale factors sp_prev/sp_new well-conditioned), and under the
  split grid each chain is confined to one (batch, split) cell whose block
  loop is still executed in order by the sequential innermost grid dimension
  — so the per-chain progression is preserved verbatim. *Across* splits no
  ordering is needed at all: each split's sigma_p is carried into its partial
  scale-carrying LSE (lse_s = m_s + log(sigma_p_s * l~_s), with o_s already
  normalized so sigma_p cancels elementwise), and the combine is an
  order-free sum of exp(lse_s - max lse) weights — the implicit
  dequantization of Eqs. 12-13 stays exact under any split interleaving.

  Block-level early exit: ``seq_lens`` is scalar-prefetched, so the BlockSpec
  index maps clamp every out-of-range block index to the last live block of
  that sequence — the pipeline then re-"fetches" an already-resident block
  (Pallas elides the DMA when the index is unchanged) and ``pl.when`` skips
  the compute. HBM traffic therefore scales with ``seq_lens``, not with the
  padded cache capacity.

  q_len > 1 (the speculative-verify shape): both split-KV wrappers accept a
  rank-4 ``[B, q_len, H, ...]`` query block — the q_len rows are the LAST
  q_len positions of each sequence, flattened head-major into ``q_len * H``
  kernel rows (each row carries its own online-softmax state, so the body is
  unchanged except for a per-row causal limit ``seq_len - (q_len-1) + t`` in
  place of the scalar and the dead-row neutrality guard in
  ``_block_pipeline``). q_len = 1 passes the scalar limit exactly as before
  — bit-identical to the PR 8 kernel by literal trace identity.

Paged split-KV — ``mla_decode_paged_splitkv_pallas``: the same split grid and
  per-split partial/combine layout over a page pool; the scalar-prefetched
  page table only relocates each block's DMA source, so the contiguous and
  paged variants share one kernel body, one early-exit predicate, and one
  combine path (``_splitkv_partials_call`` + ``lse_combine_pallas``). HBM
  traffic is proportional to ``seq_lens``, not pool capacity.

TPU adaptation notes (DESIGN.md §2): FP8 here is the *storage* dtype — blocks
are upcast to f32 on load inside the kernel (v5e has no FP8 MXU; the win is
HBM bytes, which is what decode attention is bound by at small head counts).
The paged variant uses a scalar-prefetched page table in the BlockSpec index
maps — the TPU-native PagedAttention (replaces the paper's TMA-driven
Fused-K-Append read path).

Validated in interpret mode against ref.snapmla_decode_pipeline_ref /
ref.snapmla_decode_splitkv_ref (exact same arithmetic) and
core.attention.mla_decode_dequant_ref (quantization error bound).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import quant
from repro.kernels.mla_decode import amla

NEG_INF = -1e30


def _quantize_block(p_fused, fmt: str, qmax: float):
    amax = jnp.max(jnp.abs(p_fused), axis=-1)
    sp = jnp.maximum(amax, quant.EPS) / qmax
    if fmt == "fp8_e4m3":
        p8 = jnp.clip(p_fused / sp[:, None], -quant.FP8_MAX, quant.FP8_MAX)
        p8 = p8.astype(jnp.float8_e4m3fn).astype(jnp.float32)
    elif fmt == "int8":
        p8 = jnp.clip(jnp.round(p_fused / sp[:, None]), -127, 127)
        p8 = p8.astype(jnp.int8).astype(jnp.float32)
    else:  # "none": scale-fused but unquantized (BF16-pipeline baseline)
        sp = jnp.ones_like(sp)
        p8 = p_fused
    return p8, sp


def _block_pipeline(qc, qr, sq, c, r, sk, tok0, seq_len,
                    m_ref, l_ref, sp_ref, acc_ref, *,
                    softmax_scale: float, fmt: str, qmax: float,
                    rescale: str = "fma", row_guard: bool = False):
    """One KV block of the scale-fused FP8 pipeline (steps 1-5 of §3.2.3).

    Shared verbatim between the single-pass, split-KV, and paged kernels so
    their per-block arithmetic is bit-identical. ``tok0`` is the absolute
    token index of the block's first entry; state is carried in VMEM scratch.

    ``seq_len`` is either a scalar (every query row sees the same KV prefix —
    the decode case) or a ``[rows, 1]`` per-row limit (the ``q_len > 1``
    verify case, where row ``t`` of the causally-masked query block attends
    only tokens ``< seq_len - (q_len - 1) + t``); it broadcasts against the
    ``[rows, block_n]`` token grid either way, so the masking site is shared.

    ``rescale`` selects the cross-block accumulator rescale:

      * ``"fma"`` (default, exact): the Eq. 12-13 max-shift FMA —
        ``corr = exp(m_prev - m_new) * (sp_prev / sp_new)``.
      * ``"amla"``: the running max and sigma_p live on the power-of-two grid
        (``m = i*ln2``, ``sigma_p = 2^e``; m_ref carries i, sp_ref carries e)
        so every rescale factor is an exact ``2^k`` applied via an integer
        add on the accumulator exponent bits (``amla.exp2_mul``) — no exp,
        no FMA on the [H, d_c] accumulator.

    ``row_guard`` (the q_len > 1 paths only): a row that is fully masked in a
    live block must leave its carried state EXACTLY unchanged. Without the
    guard such a row would still rescale by ``sp_prev / sp_new`` with
    ``sp_new`` floored at ``EPS / qmax`` — mathematically a no-op (it cancels
    in o = acc / l) but numerically an overflow hazard and a bit-identity
    breaker vs the q_len = 1 kernel. The guard pins ``sp_new`` (FMA) /
    ``e_new`` (AMLA) to the carried value on dead rows, making the rescale
    factor exactly 1 (FMA) / exactly ``2^0`` (AMLA) and every additive
    contribution exactly 0.
    """
    # --- Key Step 1: uniform QK + single rescale -------------------------
    s = jax.lax.dot_general(qc, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s += jax.lax.dot_general(qr, r, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    s = s * (sq[:, None] * sk[None, :]) * softmax_scale            # [H, bn]

    tok = tok0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = tok < seq_len
    s = jnp.where(valid, s, NEG_INF)
    row_live = jnp.any(valid, axis=-1) if row_guard else None

    if rescale == "amla":
        i_prev, l_prev, e_prev = m_ref[...], l_ref[...], sp_ref[...]
        # max snapped UP onto the log2 grid: monotone, and e <= 1 below
        i_new = jnp.maximum(i_prev,
                            jnp.ceil(jnp.max(s, axis=-1) * amla.LOG2E))
        e = jnp.exp(s - (i_new * amla.LN2)[:, None])
        e = jnp.where(valid, e, 0.0)
        p_fused = e * sk[None, :]
        p8, e_new = amla.quantize_block_pow2(p_fused, fmt, qmax)
        if row_guard:
            e_new = jnp.where(row_live, e_new, e_prev)
        # corr = 2^k with k = (i_prev - i_new) + (e_prev - e_new): a pure
        # integer exponent add on the accumulator (l_prev == 0 -> no state
        # yet, k pinned to 0 so the sentinel i_prev never reaches int32)
        k = jnp.where(l_prev > 0.0,
                      (i_prev - i_new) + (e_prev - e_new),
                      0.0).astype(jnp.int32)                       # [H]
        l_ref[...] = (amla.exp2_mul(l_prev, k)
                      + amla.exp2_mul(jnp.sum(e, axis=-1),
                                      -e_new.astype(jnp.int32)))
        acc_ref[...] = amla.exp2_mul(acc_ref[...], k[:, None]) + \
            jax.lax.dot_general(p8, c, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_ref[...] = i_new
        sp_ref[...] = e_new
        return

    # --- online softmax ---------------------------------------------------
    m_prev, l_prev, sp_prev = m_ref[...], l_ref[...], sp_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))               # [H]
    e = jnp.exp(s - m_new[:, None])
    e = jnp.where(valid, e, 0.0)

    # --- Key Step 2: scale fusion + block-wise dynamic P quantization -----
    p_fused = e * sk[None, :]
    p8, sp_new = _quantize_block(p_fused, fmt, qmax)
    if row_guard:
        sp_new = jnp.where(row_live, sp_new, sp_prev)

    # --- implicit dequantization (Eqs. 12-13) ------------------------------
    corr = jnp.exp(m_prev - m_new) * (sp_prev / sp_new)            # [H]
    l_ref[...] = l_prev * corr + jnp.sum(e, axis=-1) / sp_new
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p8, c, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    sp_ref[...] = sp_new


def _init_state(m_ref, l_ref, sp_ref, acc_ref):
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    sp_ref[...] = jnp.ones_like(sp_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def _mla_decode_kernel(
    # scalar prefetch
    seq_lens_ref,           # [B] int32
    # inputs (VMEM blocks)
    q_c_ref,                # [1, H, d_c]  storage dtype
    q_r_ref,                # [1, H, d_r]  f32 (pre-divided by sigma_q)
    sigma_q_ref,            # [1, H]       f32
    content_ref,            # [1, bn, d_c] storage dtype (or [bn, d_c] paged)
    rope_ref,               # [1, bn, d_r] f32/bf16 (pre-divided by sigma_k)
    sigma_k_ref,            # [1, bn]      f32
    # outputs
    o_ref,                  # [1, H, d_c]  f32
    lse_ref,                # [1, H]       f32
    # scratch
    m_ref, l_ref, sp_ref,   # [H]
    acc_ref,                # [H, d_c]
    *,
    softmax_scale: float,
    block_n: int,
    fmt: str,
    qmax: float,
    paged: bool,
    rescale: str = "fma",
):
    b = pl.program_id(0)
    j = pl.program_id(1)
    nblocks = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        _init_state(m_ref, l_ref, sp_ref, acc_ref)

    qc = q_c_ref[0].astype(jnp.float32)              # [H, d_c]
    qr = q_r_ref[0].astype(jnp.float32)              # [H, d_r]
    sq = sigma_q_ref[0].astype(jnp.float32)          # [H]
    if paged:
        c = content_ref[...].astype(jnp.float32)     # [bn, d_c]
        r = rope_ref[...].astype(jnp.float32)        # [bn, d_r]
        sk = sigma_k_ref[...].astype(jnp.float32)    # [bn]
    else:
        c = content_ref[0].astype(jnp.float32)
        r = rope_ref[0].astype(jnp.float32)
        sk = sigma_k_ref[0].astype(jnp.float32)

    _block_pipeline(qc, qr, sq, c, r, sk, j * block_n, seq_lens_ref[b],
                    m_ref, l_ref, sp_ref, acc_ref,
                    softmax_scale=softmax_scale, fmt=fmt, qmax=qmax,
                    rescale=rescale)

    @pl.when(j == nblocks - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = acc_ref[...] / l[:, None]                       # sigma_p cancels
        if rescale == "amla":
            # m_ref/sp_ref hold the integer exponents i and e: the scale-
            # carrying LSE is (i + e) * ln2 + log(l~)
            lse_ref[0] = (m_ref[...] + sp_ref[...]) * amla.LN2 + jnp.log(l)
        else:
            lse_ref[0] = m_ref[...] + jnp.log(sp_ref[...] * l)


def mla_decode_pallas(
    q_c8: jax.Array,        # [B, H, d_c] storage dtype
    q_r: jax.Array,         # [B, H, d_r] f32 (pre-divided by sigma_q)
    sigma_q: jax.Array,     # [B, H] f32
    content: jax.Array,     # [B, N, d_c]
    rope: jax.Array,        # [B, N, d_r]
    sigma_k: jax.Array,     # [B, N] f32
    seq_lens: jax.Array,    # [B] int32
    *,
    softmax_scale: float,
    block_n: int = 128,
    fmt: str = "fp8_e4m3",
    interpret: bool = True,
    rescale: str = "fma",
) -> tuple[jax.Array, jax.Array]:
    """Contiguous-cache SnapMLA decode. Returns (o [B,H,d_c] f32, lse [B,H])."""
    B, H, d_c = q_c8.shape
    d_r = q_r.shape[-1]
    N = content.shape[1]
    assert N % block_n == 0, (N, block_n)
    nblocks = N // block_n
    qmax = quant.qmax_for(fmt) if fmt != "none" else 1.0

    kernel = functools.partial(
        _mla_decode_kernel, softmax_scale=softmax_scale, block_n=block_n,
        fmt=fmt, qmax=qmax, paged=False, rescale=rescale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nblocks),
        in_specs=[
            pl.BlockSpec((1, H, d_c), lambda b, j, sl: (b, 0, 0)),
            pl.BlockSpec((1, H, d_r), lambda b, j, sl: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, j, sl: (b, 0)),
            pl.BlockSpec((1, block_n, d_c), lambda b, j, sl: (b, j, 0)),
            pl.BlockSpec((1, block_n, d_r), lambda b, j, sl: (b, j, 0)),
            pl.BlockSpec((1, block_n), lambda b, j, sl: (b, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, d_c), lambda b, j, sl: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, j, sl: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, d_c), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, d_c), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(seq_lens, q_c8, q_r, sigma_q, content, rope, sigma_k)


# ---------------------------------------------------------------------------
# Split-KV (flash-decoding) variant
# ---------------------------------------------------------------------------

def _mla_decode_splitkv_kernel(
    # scalar prefetch
    seq_lens_ref,           # [B] int32
    # inputs (VMEM blocks)
    q_c_ref,                # [1, R, d_c]   R = q_len * H query rows
    q_r_ref,                # [1, R, d_r]
    sigma_q_ref,            # [1, R]
    content_ref,            # [1, bn, d_c]
    rope_ref,               # [1, bn, d_r]
    sigma_k_ref,            # [1, bn]
    # outputs (per-split partials)
    o_ref,                  # [1, 1, R, d_c] f32
    lse_ref,                # [1, 1, R]      f32 (scale-carrying LSE)
    sp_ref_out,             # [1, 1, R]      f32 (final per-split sigma_p)
    # scratch
    m_ref, l_ref, sp_ref,   # [R]
    acc_ref,                # [R, d_c]
    *,
    softmax_scale: float,
    block_n: int,
    blocks_per_split: int,
    fmt: str,
    qmax: float,
    rescale: str = "fma",
    q_len: int = 1,
    heads: int | None = None,
):
    b = pl.program_id(0)
    s_id = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        _init_state(m_ref, l_ref, sp_ref, acc_ref)

    # Block-level early exit: blocks whose first token is past seq_len carry
    # no valid entries (valid tokens are a prefix), so skip their compute
    # entirely. Their DMA was already elided by the clamped index map.
    g = s_id * blocks_per_split + j                    # global KV block index
    live = g * block_n < seq_lens_ref[b]

    @pl.when(live)
    def _compute():
        qc = q_c_ref[0].astype(jnp.float32)
        qr = q_r_ref[0].astype(jnp.float32)
        sq = sigma_q_ref[0].astype(jnp.float32)
        c = content_ref[0].astype(jnp.float32)
        r = rope_ref[0].astype(jnp.float32)
        sk = sigma_k_ref[0].astype(jnp.float32)
        if q_len == 1:
            # the decode fast path: a SCALAR limit, no row guard — the trace
            # (and hence the emitted kernel) is literally the PR 8 kernel's,
            # so q_len = 1 through this body is bit-identical to it.
            limit = seq_lens_ref[b]
        else:
            # causal intra-block mask: the q_len query rows are the LAST
            # q_len positions of the sequence, head-major within a position
            # (row = t * heads + h), so row t's KV prefix ends at
            # seq_len - (q_len - 1) + t. Rows whose limit is <= 0 (idle
            # slots, over-drafted tails) stay on their neutral init state
            # via the row guard and publish the empty-split partial.
            t = jax.lax.broadcasted_iota(
                jnp.int32, (q_len * heads, 1), 0) // heads
            limit = seq_lens_ref[b] - (q_len - 1) + t
        _block_pipeline(qc, qr, sq, c, r, sk, g * block_n, limit,
                        m_ref, l_ref, sp_ref, acc_ref,
                        softmax_scale=softmax_scale, fmt=fmt, qmax=qmax,
                        rescale=rescale, row_guard=q_len > 1)

    @pl.when(j == blocks_per_split - 1)
    def _finalize():
        l = l_ref[...]
        has = l > 0.0
        if rescale == "amla":
            # COMBINE-FREE emission: the partial is published UNNORMALIZED —
            # raw accumulator in the o slot, raw l~ in the lse slot, and the
            # split's integer grid exponent g = i + e in the sigma_p slot
            # (exp(m_s) * sigma_p_s == 2^(i_s + e_s) exactly). The combine
            # then needs no per-split normalization and no exp: cross-split
            # rescaling is a pure integer exponent add. Empty splits publish
            # (0, 0, 0) and contribute nothing.
            o_ref[0, 0] = acc_ref[...]
            lse_ref[0, 0] = l
            sp_ref_out[0, 0] = jnp.where(has, m_ref[...] + sp_ref[...], 0.0)
        else:
            # Empty splits (no live block touched the state) publish a
            # neutral partial: o = 0, lse = NEG_INF — the combine weight
            # exp(lse - m*) then vanishes. l > 0 iff at least one valid
            # token was accumulated.
            safe_l = jnp.where(has, l, 1.0)
            o_ref[0, 0] = jnp.where(has[:, None],
                                    acc_ref[...] / safe_l[:, None], 0.0)
            lse_ref[0, 0] = jnp.where(
                has, m_ref[...] + jnp.log(sp_ref[...] * safe_l), NEG_INF)
            sp_ref_out[0, 0] = sp_ref[...]


def _clamped_block_index(seq_lens_ref, b, s_id, j, blocks_per_split, block_n):
    """Global block index for (split, block), clamped to the last live block of
    sequence ``b`` so dead blocks re-address an already-resident page (the
    Pallas pipeline elides the DMA when the index map output is unchanged)."""
    g = s_id * blocks_per_split + j
    last_live = jnp.maximum((seq_lens_ref[b] + block_n - 1) // block_n - 1, 0)
    return jnp.minimum(g, last_live)


def _splitkv_partials_call(
    kernel_body,
    *,
    grid: tuple,
    in_specs: list,
    num_scalar_prefetch: int,
    B: int,
    num_splits: int,
    H: int,
    d_c: int,
    interpret: bool,
    operands: tuple,
):
    """One shared split/combine code path for BOTH the contiguous and the paged
    split-KV kernels: identical per-split partial layout ([B, S, H, ...] with
    the scale-carrying LSE), identical VMEM scratch for the online-softmax
    state, identical pallas_call plumbing. Callers differ only in their grid,
    input BlockSpecs (clamped contiguous block index vs page-table lookup) and
    scalar-prefetch operands. Returns the raw (o, lse, sigma_p) partials."""
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_scalar_prefetch,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, H, d_c), lambda b, s, j, *_: (b, s, 0, 0)),
            pl.BlockSpec((1, 1, H), lambda b, s, j, *_: (b, s, 0)),
            pl.BlockSpec((1, 1, H), lambda b, s, j, *_: (b, s, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, d_c), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel_body,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, num_splits, H, d_c), jnp.float32),
            jax.ShapeDtypeStruct((B, num_splits, H), jnp.float32),
            jax.ShapeDtypeStruct((B, num_splits, H), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)


def _flatten_q(q_c8, q_r, sigma_q):
    """[B, q_len, H, ...] query block -> head-major rows [B, q_len*H, ...].

    The kernel bodies treat the row axis exactly like the head axis (every
    row has independent online-softmax state), so a q_len > 1 query block is
    just "more heads" plus a per-row causal limit. Rank-3 queries pass
    through untouched (q_len = None marks the rank-3 no-op so rank-4 inputs
    — even with q_len == 1 — come back rank-4)."""
    if q_c8.ndim == 3:
        return q_c8, q_r, sigma_q, None, q_c8.shape[1]
    B, q_len, H = q_c8.shape[:3]
    return (q_c8.reshape(B, q_len * H, -1), q_r.reshape(B, q_len * H, -1),
            sigma_q.reshape(B, q_len * H), q_len, H)


def _unflatten_rows(q_len, H, o, lse, partials):
    """Undo ``_flatten_q`` on the outputs: rows -> [q_len, H] axes."""
    if q_len is None:
        return o, lse, partials
    B = o.shape[0]
    o = o.reshape(B, q_len, H, -1)
    lse = lse.reshape(B, q_len, H)
    if partials is not None:
        o_p, lse_p, sp_p = partials
        S = o_p.shape[1]
        partials = (o_p.reshape(B, S, q_len, H, -1),
                    lse_p.reshape(B, S, q_len, H),
                    sp_p.reshape(B, S, q_len, H))
    return o, lse, partials


def mla_decode_splitkv_pallas(
    q_c8: jax.Array,        # [B, H, d_c] or [B, q_len, H, d_c] storage dtype
    q_r: jax.Array,         # [..., d_r] f32 (pre-divided by sigma_q)
    sigma_q: jax.Array,     # [B, H] or [B, q_len, H] f32
    content: jax.Array,     # [B, N, d_c]
    rope: jax.Array,        # [B, N, d_r]
    sigma_k: jax.Array,     # [B, N] f32
    seq_lens: jax.Array,    # [B] int32
    *,
    softmax_scale: float,
    num_splits: int,
    block_n: int = 128,
    fmt: str = "fp8_e4m3",
    interpret: bool = True,
    return_partials: bool = False,
    rescale: str = "fma",
):
    """Sequence-parallel (flash-decoding) SnapMLA decode.

    Grid (batch, num_splits, kv_blocks_per_split): each split runs the
    scale-fused FP8 pipeline over its KV slice and emits partial
    (o, lse, sigma_p); ``lse_combine_pallas`` (or, under
    ``rescale="amla"``, the exponent-add ``amla_combine_pallas`` over
    unnormalized partials) merges them. Returns (o [B,H,d_c] f32,
    lse [B,H]) — plus the raw partials when ``return_partials`` (for
    oracles/telemetry).

    A rank-4 ``[B, q_len, H, ...]`` query block runs the q_len > 1 verify
    path: rows are the LAST q_len positions of each sequence under a causal
    intra-block mask (row t attends tokens < seq_lens - (q_len-1) + t), and
    outputs/partials come back with the extra q_len axis
    (o [B,q_len,H,d_c], lse [B,q_len,H], partials [B,S,q_len,H,...]).
    """
    q_c8, q_r, sigma_q, q_len, H = _flatten_q(q_c8, q_r, sigma_q)
    B, R, d_c = q_c8.shape
    d_r = q_r.shape[-1]
    N = content.shape[1]
    assert N % block_n == 0, (N, block_n)
    nblocks = N // block_n
    assert 1 <= num_splits <= nblocks, (num_splits, nblocks)
    blocks_per_split = (nblocks + num_splits - 1) // num_splits
    qmax = quant.qmax_for(fmt) if fmt != "none" else 1.0

    kernel = functools.partial(
        _mla_decode_splitkv_kernel, softmax_scale=softmax_scale,
        block_n=block_n, blocks_per_split=blocks_per_split, fmt=fmt,
        qmax=qmax, rescale=rescale, q_len=q_len or 1, heads=H)

    def kv_idx(b, s, j, sl):
        return (b, _clamped_block_index(sl, b, s, j, blocks_per_split, block_n), 0)

    def sk_idx(b, s, j, sl):
        return (b, _clamped_block_index(sl, b, s, j, blocks_per_split, block_n))

    o_p, lse_p, sp_p = _splitkv_partials_call(
        kernel,
        grid=(B, num_splits, blocks_per_split),
        in_specs=[
            pl.BlockSpec((1, R, d_c), lambda b, s, j, sl: (b, 0, 0)),
            pl.BlockSpec((1, R, d_r), lambda b, s, j, sl: (b, 0, 0)),
            pl.BlockSpec((1, R), lambda b, s, j, sl: (b, 0)),
            pl.BlockSpec((1, block_n, d_c), kv_idx),
            pl.BlockSpec((1, block_n, d_r), kv_idx),
            pl.BlockSpec((1, block_n), sk_idx),
        ],
        num_scalar_prefetch=1,
        B=B, num_splits=num_splits, H=R, d_c=d_c, interpret=interpret,
        operands=(seq_lens, q_c8, q_r, sigma_q, content, rope, sigma_k),
    )

    if rescale == "amla":
        o, lse = amla_combine_pallas(o_p, lse_p, sp_p, interpret=interpret)
    else:
        o, lse = lse_combine_pallas(o_p, lse_p, interpret=interpret)
    o, lse, partials = _unflatten_rows(q_len, H, o, lse, (o_p, lse_p, sp_p))
    if return_partials:
        return o, lse, partials
    return o, lse


def _lse_combine_kernel(o_p_ref, lse_p_ref, o_ref, lse_ref):
    """Max-shift LSE combine of per-split partials (one batch row per step).

    The per-split sigma_p is carried inside the scale-carrying partial LSE
    (lse_s = m_s + log(sigma_p_s * l~_s) with o_s = acc_s / l~_s, so sigma_p
    cancels elementwise in o_s and survives only in the weight) — making the
    standard flash-decoding combine exact for the quantized pipeline.
    """
    lse_p = lse_p_ref[0]                               # [S, H]
    o_p = o_p_ref[0]                                   # [S, H, d_c]
    m_star = jnp.max(lse_p, axis=0)                    # [H]
    w = jnp.exp(lse_p - m_star[None, :])               # [S, H]
    den = jnp.sum(w, axis=0)                           # [H]
    num = jnp.sum(w[:, :, None] * o_p, axis=0)         # [H, d_c]
    o_ref[0] = num / den[:, None]
    lse_ref[0] = m_star + jnp.log(den)


def lse_combine_pallas(
    o_partial: jax.Array,     # [B, S, H, d_c] f32
    lse_partial: jax.Array,   # [B, S, H] f32 (scale-carrying, NEG_INF if empty)
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Combine split-KV partials: returns (o [B,H,d_c], lse [B,H])."""
    B, S, H, d_c = o_partial.shape
    return pl.pallas_call(
        _lse_combine_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, S, H, d_c), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, S, H), lambda b: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, d_c), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, d_c), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(o_partial, lse_partial)


def _amla_combine_kernel(acc_p_ref, l_p_ref, g_p_ref, o_ref, lse_ref):
    """Exponent-add combine of UNNORMALIZED AMLA partials (one batch row).

    Split s's true (unnormalized) softmax numerator/denominator are
    ``2^g_s * acc_s`` and ``2^g_s * l_s`` with the integer grid exponent
    ``g_s = i_s + e_s`` (exp(m_s) * sigma_p_s == 2^g_s exactly). The
    max-shift therefore needs no exp at all: shift every split onto the
    hottest grid point K* = max g_s by adding ``(g_s - K*) << 23`` to the
    accumulator exponent bits, then sum. Replaces lse_combine's
    ``w = exp(lse_s - m*)`` FMA weights with integer adds; the single
    division and log happen once, on the combined result.
    """
    acc_p = acc_p_ref[0]                               # [S, H, d_c]
    l_p = l_p_ref[0]                                   # [S, H]
    g_p = g_p_ref[0]                                   # [S, H]
    has = l_p > 0.0
    k_star = jnp.max(jnp.where(has, g_p, NEG_INF), axis=0)       # [H]
    k = jnp.where(has, g_p - k_star[None, :], 0.0).astype(jnp.int32)
    den = jnp.sum(amla.exp2_mul(l_p, k), axis=0)                 # [H]
    num = jnp.sum(amla.exp2_mul(acc_p, k[:, :, None]), axis=0)   # [H, d_c]
    o_ref[0] = num / den[:, None]
    lse_ref[0] = k_star * amla.LN2 + jnp.log(den)


def amla_combine_pallas(
    acc_partial: jax.Array,   # [B, S, H, d_c] f32 UNNORMALIZED accumulators
    l_partial: jax.Array,     # [B, S, H] f32 raw l~ (0 if empty)
    g_partial: jax.Array,     # [B, S, H] f32 integer grid exponents i + e
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Combine AMLA split-KV partials: returns (o [B,H,d_c], lse [B,H])."""
    B, S, H, d_c = acc_partial.shape
    return pl.pallas_call(
        _amla_combine_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, S, H, d_c), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, S, H), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, S, H), lambda b: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, d_c), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, d_c), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(acc_partial, l_partial, g_partial)


def mla_decode_paged_pallas(
    q_c8: jax.Array,        # [B, H, d_c]
    q_r: jax.Array,         # [B, H, d_r]
    sigma_q: jax.Array,     # [B, H]
    content_pool: jax.Array,  # [n_pages, page, d_c]
    rope_pool: jax.Array,     # [n_pages, page, d_r]
    scale_pool: jax.Array,    # [n_pages, page]
    page_table: jax.Array,    # [B, P] int32
    seq_lens: jax.Array,      # [B]
    *,
    softmax_scale: float,
    fmt: str = "fp8_e4m3",
    interpret: bool = True,
    rescale: str = "fma",
) -> tuple[jax.Array, jax.Array]:
    """Paged-pool SnapMLA decode: the page table is scalar-prefetched and
    drives the BlockSpec index maps (TPU-native PagedAttention)."""
    B, H, d_c = q_c8.shape
    d_r = q_r.shape[-1]
    n_pages, page, _ = content_pool.shape
    P = page_table.shape[1]
    qmax = quant.qmax_for(fmt) if fmt != "none" else 1.0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # seq_lens, page_table
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, H, d_c), lambda b, j, sl, pt: (b, 0, 0)),
            pl.BlockSpec((1, H, d_r), lambda b, j, sl, pt: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, j, sl, pt: (b, 0)),
            # the page table drives the DMA source: TPU-native PagedAttention
            pl.BlockSpec((1, page, d_c), lambda b, j, sl, pt: (pt[b, j], 0, 0)),
            pl.BlockSpec((1, page, d_r), lambda b, j, sl, pt: (pt[b, j], 0, 0)),
            pl.BlockSpec((1, page), lambda b, j, sl, pt: (pt[b, j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, H, d_c), lambda b, j, sl, pt: (b, 0, 0)),
            pl.BlockSpec((1, H), lambda b, j, sl, pt: (b, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, d_c), jnp.float32),
        ],
    )

    def kernel_paged(sl_ref, pt_ref, *rest):
        return _paged_body(sl_ref, pt_ref, *rest,
                           softmax_scale=softmax_scale, page=page, fmt=fmt,
                           qmax=qmax, rescale=rescale)

    return pl.pallas_call(
        kernel_paged,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, d_c), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(seq_lens, page_table, q_c8, q_r, sigma_q, content_pool, rope_pool, scale_pool)


def _paged_body(seq_lens_ref, page_table_ref, q_c_ref, q_r_ref, sigma_q_ref,
                content_ref, rope_ref, sigma_k_ref, o_ref, lse_ref,
                m_ref, l_ref, sp_ref, acc_ref, *,
                softmax_scale, page, fmt, qmax, rescale="fma"):
    # identical math to _mla_decode_kernel, with 3D (1, page, d) blocks
    del page_table_ref  # only used by the index maps
    _mla_decode_kernel(
        seq_lens_ref, q_c_ref, q_r_ref, sigma_q_ref,
        content_ref, rope_ref, sigma_k_ref, o_ref, lse_ref,
        m_ref, l_ref, sp_ref, acc_ref,
        softmax_scale=softmax_scale, block_n=page, fmt=fmt, qmax=qmax,
        paged=False, rescale=rescale)


# ---------------------------------------------------------------------------
# Paged split-KV (flash-decoding over a page pool)
# ---------------------------------------------------------------------------

def _paged_splitkv_body(seq_lens_ref, page_table_ref, *rest, **kw):
    """The paged split-KV kernel body IS the contiguous split-KV body: the page
    table only feeds the BlockSpec index maps (where the DMA source comes
    from), never the arithmetic — so both variants share one block pipeline,
    one early-exit predicate, and one partial-emission epilogue verbatim."""
    del page_table_ref  # only used by the index maps
    _mla_decode_splitkv_kernel(seq_lens_ref, *rest, **kw)


def _clamped_page_id(seq_lens_ref, page_table_ref, b, s_id, j,
                     pages_per_split, page):
    """Page-pool DMA source for (split, page-slot): the logical page index is
    clamped to the sequence's last live page (dead slots re-address an
    already-resident pool page, eliding the DMA — the paged analogue of
    ``_clamped_block_index``), then translated through the page table."""
    g = _clamped_block_index(seq_lens_ref, b, s_id, j, pages_per_split, page)
    return page_table_ref[b, g]


def mla_decode_paged_splitkv_pallas(
    q_c8: jax.Array,          # [B, H, d_c] or [B, q_len, H, d_c] storage dtype
    q_r: jax.Array,           # [..., d_r] f32 (pre-divided by sigma_q)
    sigma_q: jax.Array,       # [B, H] or [B, q_len, H] f32
    content_pool: jax.Array,  # [n_pages, page, d_c]
    rope_pool: jax.Array,     # [n_pages, page, d_r]
    scale_pool: jax.Array,    # [n_pages, page]
    page_table: jax.Array,    # [B, P] int32
    seq_lens: jax.Array,      # [B]
    *,
    softmax_scale: float,
    num_splits: int,
    fmt: str = "fp8_e4m3",
    interpret: bool = True,
    return_partials: bool = False,
    rescale: str = "fma",
):
    """Paged + split-KV SnapMLA decode: sequence parallelism over a page pool.

    Grid (batch, num_splits, pages_per_split): the logical page axis of each
    sequence (its page-table row) is cut into ``num_splits`` contiguous
    slices; each slice runs the scale-fused FP8 block pipeline over its pages
    — DMA sources resolved through the scalar-prefetched page table, dead
    slots clamped to the last live page so their DMA is elided and ``pl.when``
    skips their compute — and emits partial (o, lse, sigma_p) merged by
    ``lse_combine_pallas``. HBM traffic scales with ``seq_lens``, not with
    pool capacity. Returns (o [B,H,d_c] f32, lse [B,H]); plus raw partials
    when ``return_partials``.

    Rank-4 ``[B, q_len, H, ...]`` queries run the q_len > 1 verify path with
    the causal intra-block mask, exactly as in ``mla_decode_splitkv_pallas``
    (the paged body IS the contiguous body), and return the extra q_len axis.
    """
    q_c8, q_r, sigma_q, q_len, H = _flatten_q(q_c8, q_r, sigma_q)
    B, R, d_c = q_c8.shape
    d_r = q_r.shape[-1]
    page = content_pool.shape[1]
    P = page_table.shape[1]
    assert 1 <= num_splits <= P, (num_splits, P)
    pages_per_split = (P + num_splits - 1) // num_splits
    qmax = quant.qmax_for(fmt) if fmt != "none" else 1.0

    kernel = functools.partial(
        _paged_splitkv_body, softmax_scale=softmax_scale, block_n=page,
        blocks_per_split=pages_per_split, fmt=fmt, qmax=qmax, rescale=rescale,
        q_len=q_len or 1, heads=H)

    def kv_idx(b, s, j, sl, pt):
        return (_clamped_page_id(sl, pt, b, s, j, pages_per_split, page), 0, 0)

    def sk_idx(b, s, j, sl, pt):
        return (_clamped_page_id(sl, pt, b, s, j, pages_per_split, page), 0)

    o_p, lse_p, sp_p = _splitkv_partials_call(
        kernel,
        grid=(B, num_splits, pages_per_split),
        in_specs=[
            pl.BlockSpec((1, R, d_c), lambda b, s, j, sl, pt: (b, 0, 0)),
            pl.BlockSpec((1, R, d_r), lambda b, s, j, sl, pt: (b, 0, 0)),
            pl.BlockSpec((1, R), lambda b, s, j, sl, pt: (b, 0)),
            pl.BlockSpec((1, page, d_c), kv_idx),
            pl.BlockSpec((1, page, d_r), kv_idx),
            pl.BlockSpec((1, page), sk_idx),
        ],
        num_scalar_prefetch=2,      # seq_lens, page_table
        B=B, num_splits=num_splits, H=R, d_c=d_c, interpret=interpret,
        operands=(seq_lens, page_table, q_c8, q_r, sigma_q,
                  content_pool, rope_pool, scale_pool),
    )

    if rescale == "amla":
        o, lse = amla_combine_pallas(o_p, lse_p, sp_p, interpret=interpret)
    else:
        o, lse = lse_combine_pallas(o_p, lse_p, interpret=interpret)
    o, lse, partials = _unflatten_rows(q_len, H, o, lse, (o_p, lse_p, sp_p))
    if return_partials:
        return o, lse, partials
    return o, lse
