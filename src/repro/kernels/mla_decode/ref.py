"""Pure-jnp oracle for the SnapMLA FP8 MLA decode pipeline.

Two references:

  * ``snapmla_decode_pipeline_ref`` — bit-faithful emulation of the quantized
    block-wise pipeline (paper §3.2.3 + Appendix D, Eqs. 12-13): online
    softmax, per-token V-scale fusion, block-wise dynamic P quantization, and
    implicit dequantization via scale-aware accumulation. The Pallas kernel
    must match this to ~1e-5 (same arithmetic, different schedule).
  * the exact dequantize-first oracle lives in core/attention.py
    (``mla_decode_dequant_ref``) and bounds the *quantization* error.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant


def snapmla_decode_pipeline_ref(
    q_c8: jax.Array,       # [B, H, d_c] quantized content query (storage dtype)
    q_r: jax.Array,        # [B, H, d_r] rope query, PRE-DIVIDED by sigma_q
    sigma_q: jax.Array,    # [B, H] per-(token,head) content scale of q
    content: jax.Array,    # [B, N, d_c] quantized latent cache (storage dtype)
    rope: jax.Array,       # [B, N, d_r] rope keys, PRE-DIVIDED by sigma_k
    sigma_k: jax.Array,    # [B, N] per-token content scale of the cache
    seq_lens: jax.Array,   # [B]
    *,
    softmax_scale: float,
    block_n: int = 128,
    fmt: quant.QuantFormat = "fp8_e4m3",
    p_quant: bool = True,  # False => scale-fused but unquantized P (ablation)
) -> tuple[jax.Array, jax.Array]:
    """Returns (o [B, H, d_c] f32, lse [B, H] f32)."""
    B, H, d_c = q_c8.shape
    N = content.shape[1]
    assert N % block_n == 0, (N, block_n)
    nblocks = N // block_n
    qmax = quant.qmax_for(fmt) if fmt != "none" else 1.0

    qc = q_c8.astype(jnp.float32)
    qr = q_r.astype(jnp.float32)

    def one_batch(qc_b, qr_b, sq_b, c_b, r_b, sk_b, n_b):
        # Key Step 1: uniform QK over [content | rope] then ONE rescale by
        # sigma_q * sigma_k (the rope parts are pre-divided by the scales).
        def body(carry, j):
            m, l, sp, acc = carry
            sl = jax.lax.dynamic_slice_in_dim(c_b, j * block_n, block_n, 0)
            rl = jax.lax.dynamic_slice_in_dim(r_b, j * block_n, block_n, 0)
            sk = jax.lax.dynamic_slice_in_dim(sk_b, j * block_n, block_n, 0)
            s = (qc_b @ sl.astype(jnp.float32).T + qr_b @ rl.astype(jnp.float32).T)
            s = s * (sq_b[:, None] * sk[None, :]) * softmax_scale     # [H, bn]
            tok = j * block_n + jnp.arange(block_n)
            s = jnp.where(tok[None, :] < n_b, s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))               # [H]
            e = jnp.exp(s - m_new[:, None])                           # [H, bn]
            # Key Step 2: fuse per-token V scale (V == latent content cache).
            p_fused = e * sk[None, :]
            if p_quant and fmt != "none":
                amax = jnp.max(jnp.abs(p_fused), axis=-1)
                sp_new = jnp.maximum(amax, quant.EPS) / qmax          # [H]
                p8 = quant._cast(p_fused / sp_new[:, None], fmt).astype(jnp.float32)
            else:
                sp_new = jnp.ones_like(m_new)
                p8 = p_fused
            corr = jnp.exp(m - m_new) * (sp / sp_new)                 # Eq. 12/13
            l_new = l * corr + jnp.sum(e, axis=-1) / sp_new
            acc_new = acc * corr[:, None] + p8 @ sl.astype(jnp.float32)
            return (m_new, l_new, sp_new, acc_new), None

        init = (
            jnp.full((H,), -jnp.inf, jnp.float32),
            jnp.zeros((H,), jnp.float32),
            jnp.ones((H,), jnp.float32),
            jnp.zeros((H, d_c), jnp.float32),
        )
        (m, l, sp, acc), _ = jax.lax.scan(body, init, jnp.arange(nblocks))
        o = acc / l[:, None]                                           # sigma_p cancels
        lse = m + jnp.log(sp * l)
        return o, lse

    return jax.vmap(one_batch)(qc, qr, sigma_q.astype(jnp.float32),
                               content, rope, sigma_k.astype(jnp.float32), seq_lens)


def snapmla_decode_parallel_ref(
    q_c8: jax.Array,       # [B, H, d_c]
    q_r: jax.Array,        # [B, H, d_r] (pre-divided by sigma_q)
    sigma_q: jax.Array,    # [B, H]
    content: jax.Array,    # [B, N, d_c]
    rope: jax.Array,       # [B, N, d_r] (pre-divided by sigma_k)
    sigma_k: jax.Array,    # [B, N]
    seq_lens: jax.Array,   # [B]
    *,
    softmax_scale: float,
    block_n: int = 128,
    fmt: quant.QuantFormat = "fp8_e4m3",
) -> tuple[jax.Array, jax.Array]:
    """Parallel (two-pass flash-combine) form of the SnapMLA pipeline.

    Mathematically identical to ``snapmla_decode_pipeline_ref`` (the online
    accumulation is just an incremental evaluation of this combine; the
    per-block sigma_p quantization is applied identically), but expressed as
    batched einsums over all KV blocks at once — the preferred XLA lowering
    for the pjit serve path, and while-loop-free so ``cost_analysis`` counts
    every byte/FLOP (see launch/dryrun.py). Verified equal in tests.
    """
    B, H, d_c = q_c8.shape
    N = content.shape[1]
    assert N % block_n == 0
    nb = N // block_n
    qmax = quant.qmax_for(fmt) if fmt != "none" else 1.0

    qc = q_c8.astype(jnp.float32)
    qr = q_r.astype(jnp.float32)
    # one uniform QK over [content | rope] + single rescale (Key Step 1)
    s = (jnp.einsum("bhc,bnc->bhn", qc, content.astype(jnp.float32))
         + jnp.einsum("bhr,bnr->bhn", qr, rope.astype(jnp.float32)))
    s = s * (sigma_q[:, :, None] * sigma_k[:, None, :]) * softmax_scale
    mask = jnp.arange(N)[None, None, :] < seq_lens[:, None, None]
    s = jnp.where(mask, s, -jnp.inf)

    sb = s.reshape(B, H, nb, block_n)
    m_k = jnp.max(sb, axis=-1)                                   # [B,H,nb]
    e = jnp.exp(sb - m_k[..., None])
    e = jnp.where(jnp.isfinite(sb), e, 0.0)
    # Key Step 2: fuse per-token V scale, block-wise dynamic quantization
    skb = sigma_k.reshape(B, 1, nb, block_n)
    p_fused = e * skb
    amax = jnp.max(jnp.abs(p_fused), axis=-1)
    sp = jnp.maximum(amax, quant.EPS) / qmax
    if fmt != "none":
        p8 = quant._cast(p_fused / sp[..., None], fmt).astype(jnp.float32)
    else:
        sp = jnp.ones_like(sp)
        p8 = p_fused
    # per-block FP8 PV over the shared latent cache
    vb = content.astype(jnp.float32).reshape(B, nb, block_n, d_c)
    o_k = jnp.einsum("bhkn,bknc->bhkc", p8, vb)                  # [B,H,nb,dc]
    l_k = jnp.sum(e, axis=-1)                                    # [B,H,nb]
    # flash combine (identical to the telescoped Eq. 12-13 accumulation)
    m_star = jnp.max(m_k, axis=-1, keepdims=True)
    w = jnp.exp(m_k - m_star)                                    # [B,H,nb]
    num = jnp.einsum("bhk,bhkc->bhc", w * sp, o_k)
    den = jnp.einsum("bhk,bhk->bh", w, l_k)
    o = num / den[..., None]
    lse = m_star[..., 0] + jnp.log(den)
    return o, lse


def prepare_q(q_c: jax.Array, q_r: jax.Array, fmt: quant.QuantFormat = "fp8_e4m3"):
    """Fused-Q-Quant reference: per-(token,head) scale + cast + rope prescale.

    q_c [B, H, d_c] f32, q_r [B, H, d_r] -> (q_c8, q_r_scaled, sigma_q [B, H]).
    """
    if fmt == "none":
        return q_c.astype(jnp.bfloat16), q_r.astype(jnp.float32), jnp.ones(q_c.shape[:-1], jnp.float32)
    raq = quant.quantize_rope_aware(q_c, q_r, fmt, rope_dtype=jnp.float32)
    return raq.q_content, raq.rope_scaled, raq.scale[..., 0]
