"""Pure-jnp oracle for the SnapMLA FP8 MLA decode pipeline.

Three references:

  * ``snapmla_decode_pipeline_ref`` — bit-faithful emulation of the quantized
    block-wise pipeline (paper §3.2.3 + Appendix D, Eqs. 12-13): online
    softmax, per-token V-scale fusion, block-wise dynamic P quantization, and
    implicit dequantization via scale-aware accumulation. The Pallas kernel
    must match this to ~1e-5 (same arithmetic, different schedule).
  * ``snapmla_decode_splitkv_ref`` — split-KV (flash-decoding) oracle: runs the
    pipeline independently per KV split, then merges the per-split
    (o, lse, sigma_p) partials with ``lse_combine_ref``. The split-KV Pallas
    kernel must match this to ~1e-5.
  * the exact dequantize-first oracle lives in core/attention.py
    (``mla_decode_dequant_ref``) and bounds the *quantization* error.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels.mla_decode import amla

# Finite -inf sentinel (matches the kernel): keeps empty-split combines
# NaN-free — NEG_INF - NEG_INF == 0, unlike IEEE -inf.
NEG_INF = -1e30


def _verify_rows(decode_row, q_c8, q_r, sigma_q, seq_lens, *,
                 stack_axis: int = 1, partial_axis: int = 2):
    """q_len > 1 oracle scaffolding: run a q_len = 1 oracle once per query
    row under the causal verify contract — the q_len rows are the LAST q_len
    positions of the sequence, so row ``t`` decodes at
    ``seq_lens - (q_len - 1) + t`` — and stack the per-row results.

    This is EXACT (not merely within tolerance): each row's online-softmax /
    sigma_p history through the generalized kernel is independent of every
    other row's, so the q_len > 1 kernel computes literally q_len interleaved
    copies of the q_len = 1 pipeline. Rows whose limit is <= 0 come back NaN
    from the oracles (all-masked softmax) where the kernel publishes the
    neutral 0 — callers that can see such rows (idle slots, over-drafted
    tails) discard them either way.

    ``decode_row(q_c8_t, q_r_t, sigma_q_t, seq_lens_t) -> tuple of arrays``;
    outputs are stacked at ``stack_axis`` except a trailing partials tuple
    (detected as a tuple) whose members stack at ``partial_axis``."""
    q_len = q_c8.shape[1]
    per_row = [decode_row(q_c8[:, t], q_r[:, t], sigma_q[:, t],
                          seq_lens - (q_len - 1 - t)) for t in range(q_len)]
    out = []
    for parts in zip(*per_row):
        if isinstance(parts[0], tuple):
            out.append(tuple(jnp.stack(ps, axis=partial_axis)
                             for ps in zip(*parts)))
        else:
            out.append(jnp.stack(parts, axis=stack_axis))
    return tuple(out)


def snapmla_decode_pipeline_ref(
    q_c8: jax.Array,       # [B, H, d_c] quantized content query (storage dtype)
    q_r: jax.Array,        # [B, H, d_r] rope query, PRE-DIVIDED by sigma_q
    sigma_q: jax.Array,    # [B, H] per-(token,head) content scale of q
    content: jax.Array,    # [B, N, d_c] quantized latent cache (storage dtype)
    rope: jax.Array,       # [B, N, d_r] rope keys, PRE-DIVIDED by sigma_k
    sigma_k: jax.Array,    # [B, N] per-token content scale of the cache
    seq_lens: jax.Array,   # [B]
    *,
    softmax_scale: float,
    block_n: int = 128,
    fmt: quant.QuantFormat = "fp8_e4m3",
    p_quant: bool = True,  # False => scale-fused but unquantized P (ablation)
    return_sigma_p: bool = False,
    skip_dead_blocks: bool = False,  # mirror the kernel's pl.when early exit
    rescale: str = "fma",
    return_raw: bool = False,  # AMLA: return (acc, l~, g) unnormalized
) -> tuple[jax.Array, ...]:
    """Returns (o [B, H, d_c] f32, lse [B, H] f32) — plus the final per-head
    sigma_p [B, H] when ``return_sigma_p`` (split-KV partial telemetry).

    ``skip_dead_blocks`` freezes the carried state on blocks with no valid
    token (instead of running their sigma_p rescale on zeros), matching the
    split-KV kernel's block-level early exit bit-for-bit on live blocks.

    ``rescale="amla"`` mirrors the kernel's exponent-add mode: the running
    max and sigma_p are snapped onto the power-of-two grid (the carried m
    holds the integer i with m = i*ln2, the carried sp holds the integer
    sigma_p exponent e) and every cross-block rescale is an exact 2^k
    applied through ``amla.exp2_mul`` — the SAME helper the kernel uses, so
    kernel-vs-ref parity holds like in FMA mode. ``return_raw`` (AMLA only)
    returns the unnormalized (acc, l~, g = i + e) the combine-free split
    emission publishes.

    A rank-4 ``[B, q_len, H, ...]`` query block runs the verify contract (the
    q_len rows are the last q_len positions; row t decodes at
    ``seq_lens - (q_len-1) + t``) one row at a time — exact, because each
    row's pipeline state is independent."""
    if q_c8.ndim == 4:
        assert not (return_sigma_p or return_raw), \
            "q_len > 1 oracles return (o, lse) only"
        return _verify_rows(
            lambda qc, qr, sq, sl: snapmla_decode_pipeline_ref(
                qc, qr, sq, content, rope, sigma_k, sl,
                softmax_scale=softmax_scale, block_n=block_n, fmt=fmt,
                p_quant=p_quant, skip_dead_blocks=skip_dead_blocks,
                rescale=rescale),
            q_c8, q_r, sigma_q, seq_lens)
    B, H, d_c = q_c8.shape
    N = content.shape[1]
    assert N % block_n == 0, (N, block_n)
    nblocks = N // block_n
    qmax = quant.qmax_for(fmt) if fmt != "none" else 1.0
    eff_fmt = fmt if p_quant else "none"

    qc = q_c8.astype(jnp.float32)
    qr = q_r.astype(jnp.float32)

    def one_batch(qc_b, qr_b, sq_b, c_b, r_b, sk_b, n_b):
        # Key Step 1: uniform QK over [content | rope] then ONE rescale by
        # sigma_q * sigma_k (the rope parts are pre-divided by the scales).
        def body(carry, j):
            m, l, sp, acc = carry
            sl = jax.lax.dynamic_slice_in_dim(c_b, j * block_n, block_n, 0)
            rl = jax.lax.dynamic_slice_in_dim(r_b, j * block_n, block_n, 0)
            sk = jax.lax.dynamic_slice_in_dim(sk_b, j * block_n, block_n, 0)
            s = (qc_b @ sl.astype(jnp.float32).T + qr_b @ rl.astype(jnp.float32).T)
            s = s * (sq_b[:, None] * sk[None, :]) * softmax_scale     # [H, bn]
            tok = j * block_n + jnp.arange(block_n)
            s = jnp.where(tok[None, :] < n_b, s, -jnp.inf)
            if rescale == "amla":
                # power-of-two grid: m carries i, sp carries e (see kernel)
                m_new = jnp.maximum(m, jnp.ceil(jnp.max(s, axis=-1)
                                                * amla.LOG2E))
                e = jnp.exp(s - (m_new * amla.LN2)[:, None])
                p_fused = e * sk[None, :]
                p8, sp_new = amla.quantize_block_pow2(p_fused, eff_fmt, qmax)
                k = jnp.where(l > 0.0, (m - m_new) + (sp - sp_new),
                              0.0).astype(jnp.int32)
                l_new = (amla.exp2_mul(l, k)
                         + amla.exp2_mul(jnp.sum(e, axis=-1),
                                         -sp_new.astype(jnp.int32)))
                acc_new = (amla.exp2_mul(acc, k[:, None])
                           + p8 @ sl.astype(jnp.float32))
            else:
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))           # [H]
                e = jnp.exp(s - m_new[:, None])                       # [H, bn]
                # Key Step 2: fuse per-token V scale (V == latent cache).
                p_fused = e * sk[None, :]
                if p_quant and fmt != "none":
                    amax = jnp.max(jnp.abs(p_fused), axis=-1)
                    sp_new = jnp.maximum(amax, quant.EPS) / qmax      # [H]
                    p8 = quant._cast(p_fused / sp_new[:, None],
                                     fmt).astype(jnp.float32)
                else:
                    sp_new = jnp.ones_like(m_new)
                    p8 = p_fused
                corr = jnp.exp(m - m_new) * (sp / sp_new)             # Eq. 12/13
                l_new = l * corr + jnp.sum(e, axis=-1) / sp_new
                acc_new = acc * corr[:, None] + p8 @ sl.astype(jnp.float32)
            if skip_dead_blocks:
                live = j * block_n < n_b
                m_new = jnp.where(live, m_new, m)
                l_new = jnp.where(live, l_new, l)
                sp_new = jnp.where(live, sp_new, sp)
                acc_new = jnp.where(live, acc_new, acc)
            return (m_new, l_new, sp_new, acc_new), None

        init = (
            jnp.full((H,), -jnp.inf, jnp.float32),
            jnp.zeros((H,), jnp.float32),
            jnp.ones((H,), jnp.float32),
            jnp.zeros((H, d_c), jnp.float32),
        )
        (m, l, sp, acc), _ = jax.lax.scan(body, init, jnp.arange(nblocks))
        return m, l, sp, acc

    m, l, sp, acc = jax.vmap(one_batch)(
        qc, qr, sigma_q.astype(jnp.float32), content, rope,
        sigma_k.astype(jnp.float32), seq_lens)
    if rescale == "amla":
        g = m + sp                                     # integer grid exponent
        if return_raw:
            return acc, l, g
        o = acc / l[..., None]                         # sigma_p cancels
        lse = g * amla.LN2 + jnp.log(l)
    else:
        o = acc / l[..., None]                         # sigma_p cancels
        lse = m + jnp.log(sp * l)
    if return_sigma_p:
        return o, lse, sp
    return o, lse


def lse_combine_ref(
    o_partial: jax.Array,     # [B, S, H, d_c] per-split normalized outputs
    lse_partial: jax.Array,   # [B, S, H] scale-carrying LSE (NEG_INF if empty)
) -> tuple[jax.Array, jax.Array]:
    """Max-shift LSE combine of split-KV partials (flash-decoding rescale).

    Exact for the quantized pipeline because each split's sigma_p is carried
    inside its scale-carrying LSE: lse_s = m_s + log(sigma_p_s * l~_s) where
    l~_s and acc_s live in the split's final quantized domain, so the true
    softmax denominator of split s is exp(lse_s) and sigma_p has already
    cancelled elementwise in o_s = acc_s / l~_s (Eqs. 12-13 telescoped).
    """
    m_star = jnp.max(lse_partial, axis=1)                       # [B, H]
    w = jnp.exp(lse_partial - m_star[:, None, :])               # [B, S, H]
    den = jnp.sum(w, axis=1)                                    # [B, H]
    num = jnp.einsum("bsh,bshc->bhc", w, o_partial)
    return num / den[..., None], m_star + jnp.log(den)


def amla_combine_ref(
    acc_partial: jax.Array,   # [B, S, H, d_c] UNNORMALIZED per-split acc
    l_partial: jax.Array,     # [B, S, H] raw l~ (0 if split empty)
    g_partial: jax.Array,     # [B, S, H] integer grid exponent g = i + e
) -> tuple[jax.Array, jax.Array]:
    """Combine-free AMLA merge: exponent-add shift onto K* = max g, sum.

    Each split publishes its accumulator state verbatim — no per-split
    division, no exp. Because every split's implicit scale is the exact
    power of two ``2^g`` (``exp(m_s) * sigma_p_s == 2^(i_s + e_s)``), the
    cross-split alignment is ``exp2_mul(x, g_s - K*)`` — a pure integer
    exponent add, exact. One division + one log at the very end.
    """
    has = l_partial > 0.0
    k_star = jnp.max(jnp.where(has, g_partial, NEG_INF), axis=1)   # [B, H]
    k = jnp.where(has, g_partial - k_star[:, None, :], 0.0).astype(jnp.int32)
    den = jnp.sum(amla.exp2_mul(l_partial, k), axis=1)             # [B, H]
    num = jnp.sum(amla.exp2_mul(acc_partial, k[..., None]), axis=1)
    return num / den[..., None], k_star * amla.LN2 + jnp.log(den)


def _split_partials(decode_one_split, content, rope, sigma_k, seq_lens,
                    num_splits: int, block_n: int,
                    neutral=(0.0, NEG_INF, 1.0)):
    """Shared split-KV scaffolding: cut the KV axis into ``num_splits``
    contiguous slices of whole blocks (padding the tail slice), run
    ``decode_one_split(content, rope, sigma_k, local_len)`` per slice —
    returning (o, lse, sigma_p) partials — and neutralize empty slices
    with ``neutral`` (default (o = 0, lse = NEG_INF, sigma_p = 1); the
    AMLA combine-free path passes all-zeros)."""
    N = content.shape[1]
    assert N % block_n == 0, (N, block_n)
    nblocks = N // block_n
    assert 1 <= num_splits <= nblocks, (num_splits, nblocks)
    blocks_per_split = -(-nblocks // num_splits)
    split_tokens = blocks_per_split * block_n
    pad = num_splits * split_tokens - N
    if pad:
        content = jnp.pad(content.astype(jnp.float32), ((0, 0), (0, pad), (0, 0))
                          ).astype(content.dtype)
        rope = jnp.pad(rope, ((0, 0), (0, pad), (0, 0)))
        sigma_k = jnp.pad(sigma_k, ((0, 0), (0, pad)), constant_values=1.0)

    o_parts, lse_parts, sp_parts = [], [], []
    for s in range(num_splits):
        lo = s * split_tokens
        local_len = jnp.clip(seq_lens - lo, 0, split_tokens)
        o_s, lse_s, sp_s = decode_one_split(
            content[:, lo:lo + split_tokens], rope[:, lo:lo + split_tokens],
            sigma_k[:, lo:lo + split_tokens], local_len)
        empty = local_len <= 0                                   # [B]
        if neutral[1] == NEG_INF:
            lse_s = jnp.nan_to_num(lse_s, neginf=NEG_INF)
        o_parts.append(jnp.where(empty[:, None, None], neutral[0], o_s))
        lse_parts.append(jnp.where(empty[:, None], neutral[1], lse_s))
        sp_parts.append(jnp.where(empty[:, None], neutral[2], sp_s))
    return (jnp.stack(o_parts, axis=1), jnp.stack(lse_parts, axis=1),
            jnp.stack(sp_parts, axis=1))


def snapmla_decode_splitkv_ref(
    q_c8: jax.Array,       # [B, H, d_c]
    q_r: jax.Array,        # [B, H, d_r] (pre-divided by sigma_q)
    sigma_q: jax.Array,    # [B, H]
    content: jax.Array,    # [B, N, d_c]
    rope: jax.Array,       # [B, N, d_r] (pre-divided by sigma_k)
    sigma_k: jax.Array,    # [B, N]
    seq_lens: jax.Array,   # [B]
    *,
    softmax_scale: float,
    num_splits: int,
    block_n: int = 128,
    fmt: quant.QuantFormat = "fp8_e4m3",
    return_partials: bool = False,
    rescale: str = "fma",
):
    """Split-KV (flash-decoding) oracle: per-split pipeline + LSE combine.

    Mirrors ``kernel.mla_decode_splitkv_pallas``: each slice runs the full
    quantized pipeline with its local ragged length and the kernel's
    dead-block early exit. The per-block sigma_p quantization decisions
    depend on the split's running max history, so num_splits > 1 differs
    from the single-pass pipeline only at quantization-rounding level (and
    is exact for fmt == "none").

    ``rescale="amla"`` uses the combine-free merge: splits publish raw
    (acc, l~, g) and ``amla_combine_ref`` aligns on the 2^k grid.

    Rank-4 queries run per-row under the verify contract (see
    ``_verify_rows``) — exact, with partials stacked to [B, S, q_len, H, ...]
    matching the generalized kernel's layout."""
    if q_c8.ndim == 4:
        return _verify_rows(
            lambda qc, qr, sq, sl: snapmla_decode_splitkv_ref(
                qc, qr, sq, content, rope, sigma_k, sl,
                softmax_scale=softmax_scale, num_splits=num_splits,
                block_n=block_n, fmt=fmt, return_partials=return_partials,
                rescale=rescale),
            q_c8, q_r, sigma_q, seq_lens)
    if rescale == "amla":
        def one_split(c, r, sk, local_len):
            return snapmla_decode_pipeline_ref(
                q_c8, q_r, sigma_q, c, r, sk, local_len,
                softmax_scale=softmax_scale, block_n=block_n, fmt=fmt,
                skip_dead_blocks=True, rescale="amla", return_raw=True)

        acc_p, l_p, g_p = _split_partials(one_split, content, rope, sigma_k,
                                          seq_lens, num_splits, block_n,
                                          neutral=(0.0, 0.0, 0.0))
        # _split_partials stacks (o, lse, sp)-shaped outputs; in raw mode the
        # slots carry (acc, l~, g) — reorder to the combine's convention.
        o, lse = amla_combine_ref(acc_p, l_p, g_p)
        if return_partials:
            return o, lse, (acc_p, l_p, g_p)
        return o, lse

    def one_split(c, r, sk, local_len):
        return snapmla_decode_pipeline_ref(
            q_c8, q_r, sigma_q, c, r, sk, local_len,
            softmax_scale=softmax_scale, block_n=block_n, fmt=fmt,
            return_sigma_p=True, skip_dead_blocks=True)

    o_p, lse_p, sp_p = _split_partials(one_split, content, rope, sigma_k,
                                       seq_lens, num_splits, block_n)
    o, lse = lse_combine_ref(o_p, lse_p)
    if return_partials:
        return o, lse, (o_p, lse_p, sp_p)
    return o, lse


def gather_paged_view(content_pool, rope_pool, scale_pool, page_table):
    """Contiguous [B, P*page, ...] view of a page pool through its page table.

    The split-KV kernel's page axis and the contiguous kernel's block axis
    coincide under this gather (block_n == page), so the paged oracles are the
    contiguous oracles applied to the gathered view."""
    c = content_pool[page_table]                        # [B, P, page, d_c]
    r = rope_pool[page_table]
    s = scale_pool[page_table]
    B, P, page = s.shape
    return (c.reshape(B, P * page, -1), r.reshape(B, P * page, -1),
            s.reshape(B, P * page))


def snapmla_decode_paged_splitkv_ref(
    q_c8: jax.Array,          # [B, H, d_c]
    q_r: jax.Array,           # [B, H, d_r] (pre-divided by sigma_q)
    sigma_q: jax.Array,       # [B, H]
    content_pool: jax.Array,  # [n_pages, page, d_c]
    rope_pool: jax.Array,     # [n_pages, page, d_r]
    scale_pool: jax.Array,    # [n_pages, page]
    page_table: jax.Array,    # [B, P] int32
    seq_lens: jax.Array,      # [B]
    *,
    softmax_scale: float,
    num_splits: int,
    fmt: quant.QuantFormat = "fp8_e4m3",
    return_partials: bool = False,
    rescale: str = "fma",
):
    """Paged split-KV oracle: page-table gather + the contiguous split-KV
    oracle at block_n == page. Parity target for
    ``kernel.mla_decode_paged_splitkv_pallas`` — the kernel resolves each
    logical page through the scalar-prefetched page table at DMA time, the
    oracle resolves the whole table up front; the per-split quantized
    arithmetic (and hence every sigma_p rounding decision) is identical
    because both walk the same pages in the same split partition."""
    page = content_pool.shape[1]
    c, r, s = gather_paged_view(content_pool, rope_pool, scale_pool, page_table)
    return snapmla_decode_splitkv_ref(
        q_c8, q_r, sigma_q, c, r.astype(jnp.float32), s, seq_lens,
        softmax_scale=softmax_scale, num_splits=num_splits, block_n=page,
        fmt=fmt, return_partials=return_partials, rescale=rescale)


def snapmla_decode_parallel_ref(
    q_c8: jax.Array,       # [B, H, d_c]
    q_r: jax.Array,        # [B, H, d_r] (pre-divided by sigma_q)
    sigma_q: jax.Array,    # [B, H]
    content: jax.Array,    # [B, N, d_c]
    rope: jax.Array,       # [B, N, d_r] (pre-divided by sigma_k)
    sigma_k: jax.Array,    # [B, N]
    seq_lens: jax.Array,   # [B]
    *,
    softmax_scale: float,
    block_n: int = 128,
    fmt: quant.QuantFormat = "fp8_e4m3",
) -> tuple[jax.Array, jax.Array]:
    """Parallel (two-pass flash-combine) form of the SnapMLA pipeline.

    Mathematically identical to ``snapmla_decode_pipeline_ref`` (the online
    accumulation is just an incremental evaluation of this combine; the
    per-block sigma_p quantization is applied identically), but expressed as
    batched einsums over all KV blocks at once — the preferred XLA lowering
    for the pjit serve path, and while-loop-free so ``cost_analysis`` counts
    every byte/FLOP (see launch/dryrun.py). Verified equal in tests.
    """
    B, H, d_c = q_c8.shape
    N = content.shape[1]
    assert N % block_n == 0
    nb = N // block_n
    qmax = quant.qmax_for(fmt) if fmt != "none" else 1.0

    qc = q_c8.astype(jnp.float32)
    qr = q_r.astype(jnp.float32)
    # one uniform QK over [content | rope] + single rescale (Key Step 1)
    s = (jnp.einsum("bhc,bnc->bhn", qc, content.astype(jnp.float32))
         + jnp.einsum("bhr,bnr->bhn", qr, rope.astype(jnp.float32)))
    s = s * (sigma_q[:, :, None] * sigma_k[:, None, :]) * softmax_scale
    mask = jnp.arange(N)[None, None, :] < seq_lens[:, None, None]
    s = jnp.where(mask, s, -jnp.inf)

    sb = s.reshape(B, H, nb, block_n)
    m_k = jnp.max(sb, axis=-1)                                   # [B,H,nb]
    e = jnp.exp(sb - m_k[..., None])
    e = jnp.where(jnp.isfinite(sb), e, 0.0)
    # Key Step 2: fuse per-token V scale, block-wise dynamic quantization
    skb = sigma_k.reshape(B, 1, nb, block_n)
    p_fused = e * skb
    amax = jnp.max(jnp.abs(p_fused), axis=-1)
    sp = jnp.maximum(amax, quant.EPS) / qmax
    if fmt != "none":
        p8 = quant._cast(p_fused / sp[..., None], fmt).astype(jnp.float32)
    else:
        sp = jnp.ones_like(sp)
        p8 = p_fused
    # per-block FP8 PV over the shared latent cache
    vb = content.astype(jnp.float32).reshape(B, nb, block_n, d_c)
    o_k = jnp.einsum("bhkn,bknc->bhkc", p8, vb)                  # [B,H,nb,dc]
    l_k = jnp.sum(e, axis=-1)                                    # [B,H,nb]
    # flash combine (identical to the telescoped Eq. 12-13 accumulation)
    m_star = jnp.max(m_k, axis=-1, keepdims=True)
    w = jnp.exp(m_k - m_star)                                    # [B,H,nb]
    num = jnp.einsum("bhk,bhkc->bhc", w * sp, o_k)
    den = jnp.einsum("bhk,bhk->bh", w, l_k)
    o = num / den[..., None]
    lse = m_star[..., 0] + jnp.log(den)
    return o, lse


def snapmla_decode_splitkv_parallel_ref(
    q_c8: jax.Array,       # [B, H, d_c]
    q_r: jax.Array,        # [B, H, d_r] (pre-divided by sigma_q)
    sigma_q: jax.Array,    # [B, H]
    content: jax.Array,    # [B, N, d_c]
    rope: jax.Array,       # [B, N, d_r] (pre-divided by sigma_k)
    sigma_k: jax.Array,    # [B, N]
    seq_lens: jax.Array,   # [B]
    *,
    softmax_scale: float,
    num_splits: int,
    block_n: int = 128,
    fmt: quant.QuantFormat = "fp8_e4m3",
) -> tuple[jax.Array, jax.Array]:
    """Split-KV in the *parallel* (einsum, while-loop-free) form.

    The serving/pjit twin of ``snapmla_decode_splitkv_ref``: per split the
    two-pass flash form of the pipeline runs as batched einsums (no lax.scan,
    so XLA parallelizes freely and HLO cost_analysis counts every byte/FLOP —
    same rationale as ``snapmla_decode_parallel_ref``), then the per-split
    partials merge through the same ``lse_combine_ref``. Empty splits emit
    the neutral (o=0, lse=NEG_INF) partial.
    """
    def one_split(c, r, sk, local_len):
        o_s, lse_s = snapmla_decode_parallel_ref(
            q_c8, q_r, sigma_q, c, r, sk, local_len,
            softmax_scale=softmax_scale, block_n=block_n, fmt=fmt)
        return o_s, lse_s, jnp.ones_like(lse_s)  # sigma_p folded into lse

    o_p, lse_p, _ = _split_partials(one_split, content, rope, sigma_k,
                                    seq_lens, num_splits, block_n)
    return lse_combine_ref(o_p, lse_p)


def snapmla_decode_parallel_any(
    q_c8: jax.Array,
    q_r: jax.Array,
    sigma_q: jax.Array,
    content: jax.Array,
    rope: jax.Array,
    sigma_k: jax.Array,
    seq_lens: jax.Array,
    *,
    softmax_scale: float,
    num_splits: int = 1,
    block_n: int = 128,
    fmt: quant.QuantFormat = "fp8_e4m3",
) -> tuple[jax.Array, jax.Array]:
    """Parallel (einsum, while-loop-free) pipeline for any split count.

    The single entry point for the pjit-twin decode paths (the ``jnp_ref``
    backends and the shard_map local region): ``num_splits == 1`` is the plain
    two-pass flash form, ``> 1`` the split-KV form with the LSE combine —
    callers no longer duplicate that branch. Rank-4 queries run per-row under
    the verify contract (row t of the last-q_len block decodes at
    ``seq_lens - (q_len-1) + t``) — the jnp verify twin of the generalized
    split-KV kernels."""
    if q_c8.ndim == 4:
        return _verify_rows(
            lambda qc, qr, sq, sl: snapmla_decode_parallel_any(
                qc, qr, sq, content, rope, sigma_k, sl,
                softmax_scale=softmax_scale, num_splits=num_splits,
                block_n=block_n, fmt=fmt),
            q_c8, q_r, sigma_q, seq_lens)
    if num_splits > 1:
        return snapmla_decode_splitkv_parallel_ref(
            q_c8, q_r, sigma_q, content, rope, sigma_k, seq_lens,
            softmax_scale=softmax_scale, num_splits=num_splits,
            block_n=block_n, fmt=fmt)
    return snapmla_decode_parallel_ref(
        q_c8, q_r, sigma_q, content, rope, sigma_k, seq_lens,
        softmax_scale=softmax_scale, block_n=block_n, fmt=fmt)


def prepare_q(q_c: jax.Array, q_r: jax.Array, fmt: quant.QuantFormat = "fp8_e4m3"):
    """Fused-Q-Quant reference: per-(token,head) scale + cast + rope prescale.

    q_c [B, H, d_c] f32, q_r [B, H, d_r] -> (q_c8, q_r_scaled, sigma_q [B, H]).
    """
    if fmt == "none":
        return (q_c.astype(jnp.bfloat16), q_r.astype(jnp.float32),
                jnp.ones(q_c.shape[:-1], jnp.float32))
    raq = quant.quantize_rope_aware(q_c, q_r, fmt, rope_dtype=jnp.float32)
    return raq.q_content, raq.rope_scaled, raq.scale[..., 0]
