"""Profile-driven ``num_splits`` autotuner for the split-KV decode kernels.

Replaces the static context-length heuristic (``ops.default_num_splits``) as
the *primary* source of split counts: a small measured-sweep cache keyed on
``(capacity, block_n, batch)`` — the three shape parameters that move the
split/combine trade-off — persisted to a JSON artifact that the benchmarks
emit (``benchmarks/kernel_perf.py::emit_split_profile``). Resolution order in
``ops.resolve_num_splits``:

  1. exact profile hit for (capacity, block_n, batch)   -> measured best
  2. nearest-batch hit: an entry with the same capacity, block_n, and layout
     at a different batch -> its best (nearest in log-batch; the trade-off
     scales roughly with batch ratio, so 64 is "closer" to 128 than to 8)
  3. no usable entry / no profile file                  -> heuristic fallback

The profile file format (version 2); the key grows a "/paged" suffix for
sweeps measured on the paged kernel (contiguous and paged plans never mix)
and an "/amla" suffix for sweeps timed under the combine-free AMLA rescale
(FMA, the default, keeps the bare key — existing profiles stay exact hits).
"best" prefers smaller split counts within WIN_MARGIN so measurement
jitter can't flip a plan away from the bit-exact single-pass path:

    {
      "version": 2,
      "entries": {
        "<capacity>/<block_n>/<batch>": {
          "best": 4,
          "best_us": 421.9,
          "measured_us": {"1": 812.3, "2": 530.1, "4": 421.9, "8": 455.0}
        },
        "<capacity>/<block_n>/<batch>/paged": {...}
      }
    }

v2 over v1: each entry records ``best_us`` — the measured time OF the
recorded best — so entries at DIFFERENT block_n for the same
(capacity, batch, layout) are comparable and the joint 2D
``(num_splits, block_n)`` plan (``lookup_config`` / ``tuned_split_config``)
falls out of the same flat key space. v1 files still load: ``best_us`` is
derived from the v1 entry's own ``measured_us[best]`` on demand, so an
existing ``BENCH_splits_profile.json`` keeps driving plans unchanged.

The default artifact path is ``BENCH_splits_profile.json`` at the repo root
(next to BENCH_splitkv.json); override with ``SNAPMLA_SPLIT_PROFILE``. The
module-level singleton loads it lazily once; ``reset()`` drops it (tests).
"""
from __future__ import annotations

import json
import os
import pathlib
import time
from typing import NamedTuple

PROFILE_ENV = "SNAPMLA_SPLIT_PROFILE"
PROFILE_VERSION = 2
_LOADABLE_VERSIONS = (1, 2)    # v1 entries are a strict subset of v2's


class SplitConfig(NamedTuple):
    """A joint split-KV plan: how many splits, at which KV block size."""

    num_splits: int
    block_n: int

# Anchored at the repo root (autotune.py is src/repro/kernels/mla_decode/),
# NOT the process CWD — `serve` launched from any directory and `pytest` from
# the repo root must agree on which profile (if any) is in effect.
DEFAULT_PROFILE = (pathlib.Path(__file__).resolve().parents[4]
                   / "BENCH_splits_profile.json")


def profile_path() -> pathlib.Path:
    override = os.environ.get(PROFILE_ENV)
    return pathlib.Path(override) if override else DEFAULT_PROFILE


def _key(capacity: int, block_n: int, batch: int, layout: str,
         rescale: str = "fma") -> str:
    base = f"{int(capacity)}/{int(block_n)}/{int(batch)}"
    if layout != "contiguous":
        base = f"{base}/{layout}"
    # the FMA rescale is the default path and keeps the PR-8 key shape, so
    # existing profile files stay exact hits; AMLA sweeps get their own
    # suffix — the two emission paths' timings never drive each other
    return base if rescale == "fma" else f"{base}/{rescale}"


def _parse_key(key: str) -> tuple[int, int, int, str, str] | None:
    """Inverse of ``_key``: '<cap>/<bn>/<batch>[/<layout>][/amla]' ->
    (capacity, block_n, batch, layout, rescale), or None for malformed keys
    (hand-edited files must not crash resolution)."""
    parts = key.split("/")
    rescale = "fma"
    if parts and parts[-1] == "amla":
        rescale = parts.pop()
    if len(parts) == 3:
        parts = parts + ["contiguous"]
    if len(parts) != 4:
        return None
    try:
        return int(parts[0]), int(parts[1]), int(parts[2]), parts[3], rescale
    except ValueError:
        return None


# A smaller split count must be beaten by at least this margin before a larger
# one is recorded as "best": ties within measurement noise go to fewer splits,
# so num_splits=1 (the bit-exact seed path) is only abandoned for a real win
# and re-measuring doesn't flip the plan on jitter.
WIN_MARGIN = 0.05


def _pick_best(measured_us: dict[int, float]) -> int:
    best = None
    for s in sorted(measured_us):
        if best is None or measured_us[s] < measured_us[best] * (1 - WIN_MARGIN):
            best = s
    return best


def _entry_best_us(entry: dict) -> float | None:
    """Measured microseconds of an entry's recorded best — v2 entries carry
    it as ``best_us``; for v1 entries it is derived from the entry's own
    sweep (``measured_us[best]``). None for malformed entries."""
    try:
        if "best_us" in entry:
            return float(entry["best_us"])
        return float(entry["measured_us"][str(int(entry["best"]))])
    except (TypeError, KeyError, ValueError):
        return None


class SplitProfile:
    """In-memory measured-sweep cache: (capacity, block_n, batch, layout) ->
    best num_splits, with the raw measured microseconds kept for the
    benchmarks. ``layout`` separates the contiguous and paged kernels — their
    DMA patterns differ, so a best measured on one never drives the other."""

    def __init__(self, entries: dict | None = None):
        self.entries: dict[str, dict] = dict(entries or {})

    # -- queries ----------------------------------------------------------
    def lookup(self, capacity: int, block_n: int, batch: int | None,
               layout: str = "contiguous", rescale: str = "fma") -> int | None:
        """Measured best split count, or None (-> heuristic fallback)."""
        if batch is None:
            return None
        e = self.entries.get(_key(capacity, block_n, batch, layout, rescale))
        try:
            return int(e["best"]) if e else None
        except (TypeError, KeyError, ValueError):
            return None          # malformed entry -> heuristic fallback

    def lookup_nearest(self, capacity: int, block_n: int, batch: int | None,
                       layout: str = "contiguous",
                       rescale: str = "fma") -> int | None:
        """Exact hit, else nearest-neighbor batch interpolation: among the
        entries sharing (capacity, block_n, layout, rescale), the best of the
        batch nearest in log-space (ties go to the smaller batch — closer to
        the conservative fewer-splits regime). The split/combine trade-off
        moves with the batch *ratio*, not the difference, hence log distance.
        None if no comparable entry exists (-> heuristic fallback)."""
        exact = self.lookup(capacity, block_n, batch, layout, rescale)
        if exact is not None or batch is None:
            return exact
        candidates: list[tuple[float, int, int]] = []
        for key, entry in self.entries.items():
            parsed = _parse_key(key)
            if parsed is None or parsed[:2] != (capacity, block_n) \
                    or parsed[3] != layout or parsed[4] != rescale:
                continue
            b = parsed[2]
            try:
                best = int(entry["best"])
            except (TypeError, KeyError, ValueError):
                continue         # malformed neighbor -> skip it
            hi, lo = max(b, batch, 1), max(min(b, batch), 1)
            candidates.append((hi / lo, b, best))  # ratio == exp(log dist)
        if not candidates:
            return None
        return min(candidates)[2]

    def lookup_config(self, capacity: int, batch: int | None,
                      layout: str = "contiguous",
                      rescale: str = "fma") -> "SplitConfig | None":
        """Joint 2D plan: among ALL entries sharing (capacity, layout,
        rescale) — any block_n — pick the (num_splits, block_n) whose
        recorded best ran fastest. Exact-batch entries win; otherwise the
        nearest batch in log-space is used (same interpolation rule as
        ``lookup_nearest``), and only that batch's entries compete. Ties in
        measured time go to the smaller block_n. None when no comparable
        entry exists."""
        if batch is None:
            return None
        by_batch: dict[int, list[tuple[float, int, int]]] = {}
        for key, entry in self.entries.items():
            parsed = _parse_key(key)
            if parsed is None or parsed[0] != capacity or parsed[3] != layout \
                    or parsed[4] != rescale:
                continue
            us = _entry_best_us(entry)
            try:
                best = int(entry["best"])
            except (TypeError, KeyError, ValueError):
                continue
            if us is None:
                continue
            by_batch.setdefault(parsed[2], []).append((us, parsed[1], best))
        if not by_batch:
            return None
        if batch in by_batch:
            pool = by_batch[batch]
        else:
            def log_dist(b):
                hi, lo = max(b, batch, 1), max(min(b, batch), 1)
                return (hi / lo, b)
            pool = by_batch[min(by_batch, key=log_dist)]
        us, bn, best = min(pool)
        return SplitConfig(num_splits=best, block_n=bn)

    def record(self, capacity: int, block_n: int, batch: int,
               measured_us: dict[int, float],
               layout: str = "contiguous", rescale: str = "fma") -> int:
        """Store one sweep; best = fastest split count, with ties within
        WIN_MARGIN going to the smaller count. Returns the best."""
        if not measured_us:
            raise ValueError("empty sweep")
        best = _pick_best(measured_us)
        self.entries[_key(capacity, block_n, batch, layout, rescale)] = {
            "best": int(best),
            "best_us": float(measured_us[best]),
            "measured_us": {str(k): float(v) for k, v in measured_us.items()},
        }
        return int(best)

    # -- persistence ------------------------------------------------------
    def save(self, path: str | os.PathLike | None = None) -> pathlib.Path:
        p = pathlib.Path(path) if path else profile_path()
        p.write_text(json.dumps(
            {"version": PROFILE_VERSION, "entries": self.entries},
            indent=2, sort_keys=True) + "\n")
        return p

    @classmethod
    def load(cls, path: str | os.PathLike | None = None) -> "SplitProfile":
        p = pathlib.Path(path) if path else profile_path()
        try:
            payload = json.loads(p.read_text())
        except (OSError, ValueError):
            return cls()
        if payload.get("version") not in _LOADABLE_VERSIONS:
            return cls()
        entries = payload.get("entries", {})
        return cls(entries if isinstance(entries, dict) else {})


_PROFILE: SplitProfile | None = None


def get_profile() -> SplitProfile:
    """Lazily-loaded singleton backing ``ops.resolve_num_splits``."""
    global _PROFILE
    if _PROFILE is None:
        _PROFILE = SplitProfile.load()
    return _PROFILE


def reset(profile: SplitProfile | None = None) -> None:
    """Drop (or swap in) the singleton — tests and benchmark re-runs."""
    global _PROFILE
    _PROFILE = profile


def tuned_num_splits(capacity: int, block_n: int, batch: int | None,
                     layout: str = "contiguous",
                     rescale: str = "fma") -> int | None:
    """Measured best for the shape: exact (capacity, block_n, batch, layout,
    rescale) hit, else nearest-batch interpolation; None -> heuristic
    fallback. AMLA plans only come from AMLA-timed sweeps — its combine-free
    rescaling shifts the split/combine trade-off, so FMA timings never drive
    it (and an un-swept rescale simply falls back to the heuristic)."""
    return get_profile().lookup_nearest(capacity, block_n, batch, layout,
                                        rescale)


def tuned_split_config(capacity: int, batch: int | None,
                       layout: str = "contiguous",
                       rescale: str = "fma") -> SplitConfig | None:
    """Joint measured 2D plan (num_splits, block_n) for the shape — the
    fastest recorded best across every block_n the profile has measured at
    this (capacity, layout, rescale); None -> heuristic fallback."""
    return get_profile().lookup_config(capacity, batch, layout, rescale)


# ---------------------------------------------------------------------------
# Measured sweep (the benchmarks call this to populate the artifact)
# ---------------------------------------------------------------------------

def candidate_splits(capacity: int, block_n: int,
                     max_splits: int = 8) -> list[int]:
    """Powers of two up to min(max_splits, block count) — the shapes the split
    grid can actually take."""
    nblocks = max(1, capacity // block_n)
    out, s = [], 1
    while s <= min(max_splits, nblocks):
        out.append(s)
        s *= 2
    return out

def measure_split_sweep(capacity: int, block_n: int, batch: int,
                        *, d_c: int = 64, d_r: int = 16, heads: int = 8,
                        fmt: str = "fp8_e4m3", fill: float = 0.75,
                        iters: int = 3, profile: SplitProfile | None = None,
                        layout: str = "contiguous", interpret: bool = True,
                        rescale: str = "fma", timer=None) -> dict[int, float]:
    """Time the real split-KV kernel over the candidate split counts and
    record the winner into ``profile`` (default: the singleton) under
    ``layout`` ("contiguous" times ``snapmla_decode`` on an MLACache,
    "paged" times ``snapmla_decode_paged`` on a page pool — each layout's
    plan only ever comes from its own kernel's measurements).

    On CPU this times interpret-mode Pallas — relative ordering at small sizes
    is what seeds the cache; on TPU the same sweep measures compiled kernels.
    ``fill`` sets seq_lens = fill * capacity so early exit is in play exactly
    as it would be in serving.

    ``timer`` is the measurement seam: ``timer(num_splits, run) -> float``
    microseconds, where ``run()`` executes the kernel once at that split
    count. The default wall-clock timer compiles then averages ``iters``
    runs; tests inject fixed synthetic timings here so the recorded plan
    (and the WIN_MARGIN tie rule it feeds) is deterministic — wall-clock
    jitter on a shared CI runner must never flip a profile assertion."""
    if timer is None:
        timer = _wall_clock_timer(iters)
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.kvcache import (CacheConfig, init_mla_cache,
                                    init_paged_mla_cache, mla_prefill,
                                    paged_mla_prefill)
    from repro.kernels.mla_decode import ref as kref
    from repro.kernels.mla_decode.ops import (snapmla_decode,
                                              snapmla_decode_paged)

    key = jax.random.PRNGKey(0)
    cfg = CacheConfig(fmt=fmt, page_size=block_n)
    ks = jax.random.split(key, 4)
    ckv = jax.random.normal(ks[0], (batch, capacity, d_c))
    kr = jax.random.normal(ks[1], (batch, capacity, d_r))
    lens = jnp.asarray(
        np.full((batch,), max(1, int(capacity * fill)), np.int32))
    if layout == "paged":
        cache = paged_mla_prefill(
            init_paged_mla_cache(cfg, batch, capacity, d_c, d_r), cfg, ckv, kr)
    else:
        cache = mla_prefill(
            init_mla_cache(cfg, batch, capacity, d_c, d_r), cfg, ckv, kr)
    cache = cache._replace(seq_lens=lens)
    q_c8, q_r, sq = kref.prepare_q(
        jax.random.normal(ks[2], (batch, heads, d_c)),
        jax.random.normal(ks[3], (batch, heads, d_r)), fmt)
    scale = 1.0 / float(np.sqrt(d_c + d_r))

    def run(s):
        if layout == "paged":
            return snapmla_decode_paged(q_c8, q_r, sq, cache,
                                        softmax_scale=scale, fmt=fmt,
                                        num_splits=s, rescale=rescale,
                                        interpret=interpret)
        return snapmla_decode(q_c8, q_r, sq, cache, softmax_scale=scale,
                              block_n=block_n, fmt=fmt, num_splits=s,
                              rescale=rescale, interpret=interpret)

    measured: dict[int, float] = {}
    for s in candidate_splits(capacity, block_n):
        measured[s] = float(timer(s, lambda: run(s)))

    (profile if profile is not None else get_profile()).record(
        capacity, block_n, batch, measured, layout=layout, rescale=rescale)
    return measured


def _wall_clock_timer(iters: int):
    """Default ``measure_split_sweep`` timer: one warm-up (compile) run,
    then the mean wall-clock of ``iters`` synchronized runs, in us."""
    import jax

    def timer(_s, run):
        o, _ = run()                                        # compile
        jax.block_until_ready(o)
        t0 = time.perf_counter()
        for _ in range(iters):
            o, _ = run()
        jax.block_until_ready(o)
        return (time.perf_counter() - t0) / iters * 1e6
    return timer


def synthetic_timer(timings_us: dict[int, float]):
    """Deterministic ``timer`` for tests: fixed microseconds per split count,
    no kernel execution at all."""
    def timer(s, _run):
        return timings_us[s]
    return timer


# ---------------------------------------------------------------------------
# Joint (num_splits, block_n) sweep — the 2D autotuner
# ---------------------------------------------------------------------------

def candidate_block_ns(capacity: int,
                       block_ns: tuple[int, ...] = (32, 64, 128, 256)
                       ) -> list[int]:
    """Block sizes the contiguous kernel can take at this capacity: the
    standard candidates that divide it (paged layouts never sweep block_n —
    there it is structurally pinned to the physical page size)."""
    out = [bn for bn in block_ns if bn <= capacity and capacity % bn == 0]
    return out or [capacity]


def measure_config_sweep(capacity: int, batch: int,
                         *, block_ns: list[int] | None = None,
                         d_c: int = 64, d_r: int = 16, heads: int = 8,
                         fmt: str = "fp8_e4m3", fill: float = 0.75,
                         iters: int = 3,
                         profile: SplitProfile | None = None,
                         layout: str = "contiguous",
                         interpret: bool | None = None,
                         rescale: str = "fma",
                         timer=None) -> dict[tuple[int, int], float]:
    """Joint 2D sweep: run ``measure_split_sweep`` at every candidate
    ``block_n`` so the profile holds one entry per (capacity, block_n,
    batch, layout) and ``lookup_config`` can pick the joint winner.

    ``interpret=None`` resolves to COMPILED measurement on TPU (interpret
    elsewhere) — production shapes should be timed as the hardware runs
    them, not through the interpreter. ``timer`` here takes
    ``timer(block_n, num_splits, run)`` (tests inject a fixed 2D grid via
    ``synthetic_timer_2d``). Returns {(block_n, num_splits): us}."""
    if interpret is None:
        import jax
        interpret = jax.default_backend() != "tpu"
    if block_ns is None:
        block_ns = (candidate_block_ns(capacity) if layout == "contiguous"
                    else [block_ns_for_paged(capacity)])
    measured: dict[tuple[int, int], float] = {}
    for bn in block_ns:
        bn_timer = None if timer is None else \
            (lambda s, run, _bn=bn: timer(_bn, s, run))
        sweep = measure_split_sweep(
            capacity, bn, batch, d_c=d_c, d_r=d_r, heads=heads, fmt=fmt,
            fill=fill, iters=iters, profile=profile, layout=layout,
            interpret=interpret, rescale=rescale, timer=bn_timer)
        for s, us in sweep.items():
            measured[(bn, s)] = us
    return measured


def block_ns_for_paged(capacity: int, page_size: int = 128) -> int:
    """Paged layouts have no block_n freedom: the kernel's block axis IS the
    physical page. Kept as a function so call sites state the constraint."""
    return min(page_size, capacity)


def synthetic_timer_2d(timings_us: dict[tuple[int, int], float]):
    """Deterministic 2D ``timer`` for tests: fixed microseconds per
    (block_n, num_splits) cell, no kernel execution at all."""
    def timer(bn, s, _run):
        return timings_us[(bn, s)]
    return timer
