"""AMLA-style power-of-two rescaling helpers (PAPERS.md: arxiv 2509.25224).

The FlashAttention online-softmax rescale multiplies the accumulator by
``corr = exp(m_prev - m_new) * (sp_prev / sp_new)`` every KV block — an FMA
on the full [H, d_c] accumulator. AMLA's observation: if the running max and
the P-quantization scale are snapped onto the power-of-two grid
(``m = i * ln2``, ``sigma_p = 2^e`` with integer i, e), every rescale factor
becomes an exact power of two ``2^k`` that can be applied by ADDING
``k << 23`` to the int32 bit pattern of the f32 accumulator — a pure integer
add on the exponent field, no FMA, no exp.

Shared verbatim by the Pallas kernel (`kernel.py`) and the pure-jnp oracle
(`ref.py`) so the two AMLA paths are the *same arithmetic* (parity ~1e-5,
like the FMA mode). All helpers are plain jnp/lax and lower both inside a
Pallas kernel body and in interpret/CPU mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant

LN2 = 0.6931471805599453
LOG2E = 1.4426950408889634


def exp2_mul(x: jax.Array, k: jax.Array) -> jax.Array:
    """``x * 2**k`` for f32 ``x`` and int32 ``k`` via an integer exponent add.

    The hot path adds ``k << 23`` to the bit pattern of ``x`` (AMLA's
    MUL-by-ADD). The bit trick is only valid when both the input and the
    result are normal numbers; zeros, subnormals, and exponent over/underflow
    fall back to an exact multiply by ``exp2(k)`` (still a power of two, so
    both paths are bit-exact where they overlap).
    """
    k = k.astype(jnp.int32)
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    biased = (bits >> 23) & 0xFF
    shifted = biased + k
    fast = (biased > 0) & (shifted > 0) & (shifted < 255)
    y = jax.lax.bitcast_convert_type(bits + (k << 23), jnp.float32)
    return jnp.where(fast, y, x * jnp.exp2(k.astype(jnp.float32)))


def quantize_block_pow2(p_fused: jax.Array, fmt: str, qmax: float):
    """Block-wise dynamic P quantization with a POWER-OF-TWO scale.

    Like ``kernel._quantize_block`` but the scale is rounded UP to the next
    power of two (``sigma_p = 2^e``, e integer), so cross-block rescales stay
    on the 2^k grid. Rounding up keeps ``|p| / sigma_p <= qmax``. Returns
    ``(p8, e)`` with the scale EXPONENT ``e`` (f32-held integer), not the
    scale itself.
    """
    amax = jnp.max(jnp.abs(p_fused), axis=-1)
    e = jnp.ceil(jnp.log2(jnp.maximum(amax, quant.EPS) / qmax))
    inv = jnp.exp2(-e)                       # exact: power of two
    if fmt == "fp8_e4m3":
        p8 = jnp.clip(p_fused * inv[:, None], -quant.FP8_MAX, quant.FP8_MAX)
        p8 = p8.astype(jnp.float8_e4m3fn).astype(jnp.float32)
    elif fmt == "int8":
        p8 = jnp.clip(jnp.round(p_fused * inv[:, None]), -127, 127)
        p8 = p8.astype(jnp.int8).astype(jnp.float32)
    else:  # "none": scale-fused but unquantized (BF16-pipeline baseline)
        e = jnp.zeros_like(e)
        p8 = p_fused
    return p8, e
