"""shard_map-explicit SnapMLA decode attention — zero-collective attention.

EXPERIMENTS §Perf found the decode bottleneck on the production mesh is
GSPMD resharding the quantized latent cache (cache-sized all-gathers,
~150 ms/step on deepseek-v3-mla x decode_32k). The fix is to take the
partitioning decision away from the compiler for the attention region:

    shard_map over ('pod','data') x 'model' with
        q (batch over dp, heads over model)       — P(dp, 'model', None)
        cache (batch over dp, replicated on model) — P(dp, None, None)
        out (batch over dp, heads over model)      — P(dp, 'model', None)

Inside the mapped region every chip attends its batch shard x its head shard
against its full local cache shard — the computation is embarrassingly
parallel and the region contains NO collectives by construction. The paper's
scale-fused FP8 pipeline (the parallel-form oracle) runs verbatim inside.

Requires B % dp == 0 and H % model == 0 (true for the MLA archs:
deepseek-v3-mla H=128, mla-7b H=32 on the 16-way model axis).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# jax.shard_map only exists on newer jax; fall back to the experimental home
# (same callable) so this module works across the toolchain versions in use.
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:                       # pragma: no cover - version dep
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.core.kvcache import MLACache, sink_patched_content
from repro.kernels.mla_decode import ref as mla_ref


def shard_map_applicable(mesh, dp_axes, batch: int, n_heads: int) -> bool:
    if dp_axes is None:
        dp_size = 1
    else:
        axes = dp_axes if isinstance(dp_axes, tuple) else (dp_axes,)
        dp_size = 1
        for a in axes:
            dp_size *= mesh.shape[a]
    return (batch % dp_size == 0) and (n_heads % mesh.shape["model"] == 0)


def mla_decode_shard_map(
    mesh,
    dp_axes,
    q_c8: jax.Array,      # [B, H, d_c]
    q_r: jax.Array,       # [B, H, d_r]
    sigma_q: jax.Array,   # [B, H]
    cache: MLACache,
    *,
    softmax_scale: float,
    block_n: int,
    fmt: str,
    num_splits: int = 1,
) -> jax.Array:
    """Returns o_latent [B, H, d_c] f32; attention region is collective-free.

    ``num_splits > 1`` runs the split-KV (flash-decoding) pipeline *inside*
    the mapped region: the KV axis is replicated per chip, so splits cut a
    chip-local axis and compose with the zero-collective property — the
    combine is a per-chip reduction over that chip's own partials.
    """
    dpa = dp_axes

    def local_attn(q_c8, q_r, sq, content, rope, scale, seq_lens):
        # parallel (einsum) pipeline — while-loop-free inside the mapped
        # region, same rationale as the pjit serve path; the split-vs-single
        # branch lives in the shared helper, not here
        o, _lse = mla_ref.snapmla_decode_parallel_any(
            q_c8, q_r, sq, content, rope, scale, seq_lens,
            softmax_scale=softmax_scale, num_splits=num_splits,
            block_n=block_n, fmt=fmt)
        return o

    f = _shard_map(
        local_attn,
        mesh=mesh,
        in_specs=(P(dpa, "model", None), P(dpa, "model", None), P(dpa, "model"),
                  P(dpa, None, None), P(dpa, None, None), P(dpa, None), P(dpa)),
        out_specs=P(dpa, "model", None),
    )
    # sink guard substitution happens OUTSIDE the mapped region (batch-major
    # elementwise op — pjit shards it over dp with no collectives).
    return f(q_c8, q_r.astype(jnp.float32), sigma_q,
             sink_patched_content(cache), cache.rope.astype(jnp.float32),
             cache.scale, cache.seq_lens)


def mla_append_shard_map(mesh, dp_axes, cache: MLACache, cache_cfg,
                         c_kv: jax.Array, k_r: jax.Array,
                         active: jax.Array | None = None) -> MLACache:
    """Collective-free quantized cache append.

    The pjit-level append (vmap'd dynamic_update_slice with per-sequence
    indices) triggers XLA SPMD's "involuntary full rematerialization": the
    sharded cache is ALL-GATHERED, updated, and re-partitioned — the
    cache-sized collective identified in EXPERIMENTS §Perf (it scales with
    cache byte-width, explaining the fp8/int8/bf16 collective ratios).
    Under shard_map each chip scatters into its own batch shard locally.

    ``active`` [B] bool gates the append per row exactly like the pjit
    ``kvcache.mla_append``: it is a batch-dim mask, so it shards over dp
    with the cache — finished rows rewrite their slot with its old value
    and freeze their ``seq_lens`` inside the mapped region, with no
    collectives introduced.
    """
    from repro.core.kvcache import mla_append

    dpa = dp_axes
    # sink guard shadow (if armed) is batch-major like content, so it shards
    # over dp with the rest of the cache; None on unguarded caches.
    cache_specs = MLACache(P(dpa, None, None), P(dpa, None, None),
                           P(dpa, None), P(dpa),
                           sink=None if cache.sink is None
                           else P(dpa, None, None))

    if active is None:
        def local_append(cache, c_kv, k_r):
            return mla_append(cache, cache_cfg, c_kv, k_r)

        f = _shard_map(
            local_append, mesh=mesh,
            in_specs=(cache_specs, P(dpa, None), P(dpa, None)),
            out_specs=cache_specs)
        return f(cache, c_kv, k_r)

    def local_append_gated(cache, c_kv, k_r, act):
        return mla_append(cache, cache_cfg, c_kv, k_r, active=act)

    f = _shard_map(
        local_append_gated, mesh=mesh,
        in_specs=(cache_specs, P(dpa, None), P(dpa, None), P(dpa)),
        out_specs=cache_specs)
    return f(cache, c_kv, k_r, active)
