"""Quantization primitives for SnapMLA.

Implements the paper's quantization toolbox (Appendix C granularities) plus the
two SnapMLA-specific operations:

  * RoPE-aware per-token KV quantization (paper §3.1): quantize only the content
    part of an MLA KV entry, keep the RoPE part in high precision, and
    *pre-scale* the RoPE part by the inverse content scale (Key Step 1,
    Eq. 6) so downstream GEMMs can treat the concatenated vector uniformly.
  * Block-wise dynamic P quantization with scale fusion (paper §3.2): fuse the
    per-token V scale into the probability block before quantizing it.

Two storage formats are supported:
  * ``fp8_e4m3`` — the paper's format (max finite 448).
  * ``int8``     — beyond-paper TPU-native option (v5e MXU has 2x int8 peak);
    same per-token scale algebra with qmax 127.

All functions are pure jnp and shard_map/pjit friendly (no Python branching on
traced values).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal, Tuple

import jax
import jax.numpy as jnp

FP8_DTYPE = jnp.float8_e4m3fn
FP8_MAX = 448.0  # max finite magnitude of e4m3fn
INT8_MAX = 127.0
EPS = 1e-12  # lower bound for dynamic scales (paper App. D: "lower-bounded by a
# small eps before division to avoid zero-scale cases")

QuantFormat = Literal["fp8_e4m3", "int8", "none"]


def qmax_for(fmt: QuantFormat) -> float:
    if fmt == "fp8_e4m3":
        return FP8_MAX
    if fmt == "int8":
        return INT8_MAX
    raise ValueError(f"no qmax for format {fmt!r}")


def qdtype_for(fmt: QuantFormat):
    if fmt == "fp8_e4m3":
        return FP8_DTYPE
    if fmt == "int8":
        return jnp.int8
    raise ValueError(f"no dtype for format {fmt!r}")


def _cast(x: jax.Array, fmt: QuantFormat) -> jax.Array:
    """Cast a pre-scaled tensor into the storage format (with round/clip)."""
    if fmt == "fp8_e4m3":
        # fp8 cast saturates via clip first to avoid inf (e4m3fn has no inf but
        # overflow maps to nan on some backends).
        return jnp.clip(x, -FP8_MAX, FP8_MAX).astype(FP8_DTYPE)
    if fmt == "int8":
        return jnp.clip(jnp.round(x), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    raise ValueError(fmt)


@dataclasses.dataclass(frozen=True)
class Quantized:
    """A quantized tensor: ``real ≈ q.astype(f32) * scale`` (scale broadcast)."""

    q: jax.Array
    scale: jax.Array  # broadcastable against q along the scaled axes

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        return (self.q.astype(jnp.float32) * self.scale.astype(jnp.float32)).astype(dtype)

    @property
    def shape(self):
        return self.q.shape


jax.tree_util.register_pytree_node(
    Quantized,
    lambda t: ((t.q, t.scale), None),
    lambda _, c: Quantized(*c),
)


# ---------------------------------------------------------------------------
# Granularities (paper Appendix C)
# ---------------------------------------------------------------------------

def quantize_per_token(x: jax.Array, fmt: QuantFormat = "fp8_e4m3") -> Quantized:
    """Per-token (per-row, Eq. 8): one scale per leading-index row.

    The last axis is the channel axis; every other axis indexes tokens.
    scale shape == x.shape[:-1] + (1,).
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, EPS) / qmax_for(fmt)
    return Quantized(_cast(x.astype(jnp.float32) / scale, fmt), scale)


def quantize_per_channel(x: jax.Array, fmt: QuantFormat = "fp8_e4m3") -> Quantized:
    """Per-channel (per-column, Eq. 9): one scale per last-axis channel."""
    red_axes = tuple(range(x.ndim - 1))
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=red_axes, keepdims=True)
    scale = jnp.maximum(amax, EPS) / qmax_for(fmt)
    return Quantized(_cast(x.astype(jnp.float32) / scale, fmt), scale)


def quantize_per_tensor(
    x: jax.Array, fmt: QuantFormat = "fp8_e4m3", static_scale: float | None = None
) -> Quantized:
    """Per-tensor (Eq. 7). ``static_scale`` reproduces paper Config B (fixed 1.0)."""
    if static_scale is not None:
        scale = jnp.full((1,) * x.ndim, static_scale, jnp.float32)
    else:
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
        scale = (jnp.maximum(amax, EPS) / qmax_for(fmt)).reshape((1,) * x.ndim)
    return Quantized(_cast(x.astype(jnp.float32) / scale, fmt), scale)


def quantize_per_block(
    x: jax.Array, block: Tuple[int, int] = (64, 64), fmt: QuantFormat = "fp8_e4m3"
) -> Quantized:
    """Per-block (Eq. 10-11) over the last two axes; pads implicitly via reshape
    requirement: last-two dims must be divisible by ``block`` (callers pad)."""
    *lead, m, n = x.shape
    bm, bn = block
    assert m % bm == 0 and n % bn == 0, (x.shape, block)
    xb = x.astype(jnp.float32).reshape(*lead, m // bm, bm, n // bn, bn)
    amax = jnp.max(jnp.abs(xb), axis=(-3, -1), keepdims=True)
    scale = jnp.maximum(amax, EPS) / qmax_for(fmt)
    q = _cast(xb / scale, fmt).reshape(x.shape)
    # scale broadcastable to the blocked view; expose expanded to x's shape
    scale_full = jnp.broadcast_to(scale, xb.shape).reshape(x.shape)
    return Quantized(q, scale_full)


# ---------------------------------------------------------------------------
# SnapMLA Key Step 1: RoPE-aware per-token quantization with domain alignment
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RopeAwareQuantized:
    """An MLA KV entry (or Q row) split as [content | rope].

    real content ≈ q_content * scale      (per token)
    real rope    =  rope_scaled * scale   (rope stored PRE-DIVIDED by scale —
                                           paper Eq. 6 "domain alignment")

    so the concatenated vector satisfies
        real = concat(q_content, rope_scaled) * scale
    which is what lets the QK GEMM run uniformly over all groups and apply a
    single post-hoc rescale of sigma_q * sigma_k.
    """

    q_content: jax.Array      # [..., d_c] storage dtype
    rope_scaled: jax.Array    # [..., d_r] high precision, pre-divided by scale
    scale: jax.Array          # [..., 1] f32

    def dequant_content(self, dtype=jnp.float32) -> jax.Array:
        return (self.q_content.astype(jnp.float32) * self.scale).astype(dtype)

    def dequant_rope(self, dtype=jnp.float32) -> jax.Array:
        return (self.rope_scaled.astype(jnp.float32) * self.scale).astype(dtype)

    def dequant_concat(self, dtype=jnp.float32) -> jax.Array:
        return jnp.concatenate(
            [self.dequant_content(dtype), self.dequant_rope(dtype)], axis=-1
        )


jax.tree_util.register_pytree_node(
    RopeAwareQuantized,
    lambda t: ((t.q_content, t.rope_scaled, t.scale), None),
    lambda _, c: RopeAwareQuantized(*c),
)


def quantize_rope_aware(
    content: jax.Array,
    rope: jax.Array,
    fmt: QuantFormat = "fp8_e4m3",
    rope_dtype=jnp.bfloat16,
) -> RopeAwareQuantized:
    """Paper §3.1 + Eq. 6.

    Per-token scale from the *content* part only; rope part kept high precision
    but divided by the content scale so both live in one numerical domain.
    """
    qc = quantize_per_token(content, fmt)
    rope_scaled = (rope.astype(jnp.float32) / qc.scale).astype(rope_dtype)
    return RopeAwareQuantized(qc.q, rope_scaled, qc.scale)


def quantize_rope_unaware(
    content: jax.Array, rope: jax.Array, fmt: QuantFormat = "fp8_e4m3"
) -> RopeAwareQuantized:
    """Paper Config A ablation: quantize content AND rope per token (jointly).

    Returned in the same container: rope is quantized then re-expressed in the
    shared scale domain (stored as q_rope values; dequant gives the lossy rope).
    """
    full = jnp.concatenate([content.astype(jnp.float32), rope.astype(jnp.float32)], -1)
    qf = quantize_per_token(full, fmt)
    d_c = content.shape[-1]
    return RopeAwareQuantized(
        qf.q[..., :d_c],
        qf.q[..., d_c:].astype(jnp.float32),  # already in scale domain
        qf.scale,
    )


# ---------------------------------------------------------------------------
# SnapMLA Key Step 2 helper: scale fusion + block-wise dynamic P quantization
# ---------------------------------------------------------------------------

def fuse_and_quantize_p(
    p: jax.Array,
    v_scale: jax.Array,
    fmt: QuantFormat = "fp8_e4m3",
) -> tuple[jax.Array, jax.Array]:
    """Fuse the per-token V scale into a probability block and quantize it.

    p:       [..., block_n] unnormalized softmax numerators e_j for one KV block
    v_scale: [..., block_n] per-token V scales (broadcast from [block_n])

    Returns (p_q, sigma_p) with p_fused ≈ p_q * sigma_p, sigma_p per row
    ([..., 1]) — "block-wise dynamic quantization" where the block is the KV
    tile (paper sets block = the PV kernel's BlockN).
    """
    p_fused = p.astype(jnp.float32) * v_scale.astype(jnp.float32)
    amax = jnp.max(jnp.abs(p_fused), axis=-1, keepdims=True)
    sigma_p = jnp.maximum(amax, EPS) / qmax_for(fmt)
    return _cast(p_fused / sigma_p, fmt), sigma_p


# ---------------------------------------------------------------------------
# Analysis helpers (paper Fig. 3: value ranges + quantization MSE)
# ---------------------------------------------------------------------------

def quant_mse(x: jax.Array, fmt: QuantFormat = "fp8_e4m3", granularity: str = "per_token"):
    """Round-trip MSE of a tensor under a given quantization config."""
    fn = {
        "per_token": quantize_per_token,
        "per_channel": quantize_per_channel,
        "per_tensor": quantize_per_tensor,
        "per_block": lambda t, fmt: quantize_per_block(t, (64, 64), fmt),
    }[granularity]
    q = fn(x, fmt)
    err = q.dequant(jnp.float32) - x.astype(jnp.float32)
    return jnp.mean(err * err)


def dynamic_range(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    xf = jnp.abs(x.astype(jnp.float32))
    return jnp.min(xf), jnp.max(xf)
