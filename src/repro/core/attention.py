"""Reference decode attention over quantized caches (dequantize-first oracles).

These are the ground-truth implementations the Pallas kernels are validated
against: they dequantize the whole cache up front and run exact attention in
f32. The kernels (and their ref.py emulations of the *pipeline*) must match
these within FP8/INT8 round-trip tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kvcache import GQACache, MLACache


def mla_decode_dequant_ref(
    q_lat: jax.Array,       # [B, H, d_c] latent-space query (f32/bf16, UNQUANTIZED)
    q_rope: jax.Array,      # [B, H, d_r] rope query (RoPE applied, UNQUANTIZED)
    cache: MLACache,
    softmax_scale: float,
) -> jax.Array:
    """Exact absorbed-MLA decode over a (possibly quantized) latent cache."""
    c = cache.content.astype(jnp.float32) * cache.scale[..., None]   # dequant
    kr = cache.rope.astype(jnp.float32) * cache.scale[..., None]     # undo prescale
    logits = (
        jnp.einsum("bhc,bnc->bhn", q_lat.astype(jnp.float32), c)
        + jnp.einsum("bhr,bnr->bhn", q_rope.astype(jnp.float32), kr)
    ) * softmax_scale
    n = c.shape[1]
    mask = jnp.arange(n)[None, None, :] < cache.seq_lens[:, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhn,bnc->bhc", p, c)                           # [B,H,d_c]


def gqa_decode_dequant_ref(
    q: jax.Array,           # [B, H, dh] (RoPE applied)
    cache: GQACache,
    positions: jax.Array,   # [B] absolute position of the query token
    window: int = 0,
) -> jax.Array:
    """Exact GQA decode over a (possibly quantized, possibly ring) cache."""
    B, H, dh = q.shape
    Hkv = cache.k.shape[2]
    g = H // Hkv
    k = cache.k.astype(jnp.float32) * cache.k_scale[..., None]
    v = cache.v.astype(jnp.float32) * cache.v_scale[..., None]
    qg = q.reshape(B, Hkv, g, dh).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bnhd->bhgn", qg, k) / jnp.sqrt(dh)
    sp = cache.slot_pos                                   # [B, N]
    valid = (sp >= 0) & (sp <= positions[:, None])
    if window:
        valid &= sp > positions[:, None] - window
    logits = jnp.where(valid[:, None, None, :], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgn,bnhd->bhgd", p, v)
    return o.reshape(B, H, dh)
