"""Multi-head Latent Attention (MLA) — the paper's substrate architecture.

Implements the DeepSeek-V2/V3 MLA math (paper §2):

  * low-rank joint KV compression:  c_kv = W_DKV h           (Eq. 1)
  * decoupled RoPE:                 k_r  = RoPE(W_KR h)      (Eq. 2, shared
                                    across heads), per-head k_i = [k_c_i; k_r]
  * V from the latent only:         v_i  = W_UV_i c_kv       (Eq. 4)
  * absorbed decode form (Eq. 5):   q~_i = W_UK_i^T q_c_i  ∈ R^{d_c}
        logit_ij = q~_i . c_kv_j  +  q_r_i . k_r_j
        o_i = W_UV_i (sum_j p_ij c_kv_j)

Everything here is the high-precision reference path; the quantized decode
pipeline lives in core/snapmla.py + kernels/mla_decode.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm, apply_rope, rope_freqs


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    d_head: int          # per-head content dim (d_h)
    d_rope: int          # decoupled rope dim (d_r), shared K across heads
    d_c: int             # KV compression dim (latent)
    q_lora_rank: int = 0  # 0 => direct W_Q; >0 => DeepSeek-style Q LoRA
    rope_theta: float = 10000.0

    @property
    def qk_dim(self) -> int:
        return self.d_head + self.d_rope

    @property
    def softmax_scale(self) -> float:
        return 1.0 / (self.qk_dim ** 0.5)


class MLAParams(NamedTuple):
    """Weights for one MLA attention layer (absorbed-compatible layout)."""

    w_dq: jax.Array | None   # [d, q_lora] or None
    q_norm: jax.Array | None  # [q_lora] rmsnorm gain
    w_uq: jax.Array          # [q_lora or d, H, d_h + d_r]
    w_dkv: jax.Array         # [d, d_c]
    kv_norm: jax.Array       # [d_c] rmsnorm gain applied to c_kv before cache
    w_kr: jax.Array          # [d, d_r]
    w_uk: jax.Array          # [d_c, H, d_h]
    w_uv: jax.Array          # [d_c, H, d_h]
    w_o: jax.Array           # [H, d_h, d]


def init_mla_params(key: jax.Array, cfg: MLAConfig, dtype=jnp.float32) -> MLAParams:
    ks = jax.random.split(key, 8)
    d, H, dh, dr, dc = cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_rope, cfg.d_c

    def init(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(dtype)

    if cfg.q_lora_rank:
        w_dq = init(ks[0], (d, cfg.q_lora_rank), d)
        q_norm = jnp.ones((cfg.q_lora_rank,), dtype)
        w_uq = init(ks[1], (cfg.q_lora_rank, H, dh + dr), cfg.q_lora_rank)
    else:
        w_dq, q_norm = None, None
        w_uq = init(ks[1], (d, H, dh + dr), d)
    return MLAParams(
        w_dq=w_dq,
        q_norm=q_norm,
        w_uq=w_uq,
        w_dkv=init(ks[2], (d, dc), d),
        kv_norm=jnp.ones((dc,), dtype),
        w_kr=init(ks[3], (d, dr), d),
        w_uk=init(ks[4], (dc, H, dh), dc),
        w_uv=init(ks[5], (dc, H, dh), dc),
        w_o=init(ks[6], (H, dh, d), H * dh),
    )


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------

def project_q(params: MLAParams, cfg: MLAConfig, h: jax.Array, positions: jax.Array):
    """h: [..., S, d] -> (q_c [..., S, H, d_h], q_r [..., S, H, d_r] RoPE'd)."""
    if params.w_dq is not None:
        ql = h @ params.w_dq
        ql = rms_norm(ql, params.q_norm)
        q = jnp.einsum("...sk,khd->...shd", ql, params.w_uq)
    else:
        q = jnp.einsum("...sk,khd->...shd", h, params.w_uq)
    q_c, q_r = q[..., : cfg.d_head], q[..., cfg.d_head:]
    sin, cos = rope_freqs(positions, cfg.d_rope, cfg.rope_theta)
    q_r = apply_rope(q_r, sin[..., None, :], cos[..., None, :])
    return q_c, q_r


def project_kv(params: MLAParams, cfg: MLAConfig, h: jax.Array, positions: jax.Array):
    """h: [..., S, d] -> (c_kv [..., S, d_c] normed, k_r [..., S, d_r] RoPE'd)."""
    c_kv = rms_norm(h @ params.w_dkv, params.kv_norm)
    k_r = h @ params.w_kr
    sin, cos = rope_freqs(positions, cfg.d_rope, cfg.rope_theta)
    k_r = apply_rope(k_r, sin, cos)
    return c_kv, k_r


def absorb_q(params: MLAParams, q_c: jax.Array) -> jax.Array:
    """q_c [..., H, d_h] -> latent-space query q~ [..., H, d_c] (Eq. 5 LHS)."""
    return jnp.einsum("...hd,chd->...hc", q_c, params.w_uk)


def output_proj(params: MLAParams, o_latent: jax.Array) -> jax.Array:
    """o_latent [..., H, d_c] -> [..., d] via W_UV then W_O (absorbed pair)."""
    o_head = jnp.einsum("...hc,chd->...hd", o_latent, params.w_uv)
    return jnp.einsum("...hd,hdk->...k", o_head, params.w_o)


# ---------------------------------------------------------------------------
# Full-sequence (training / prefill) attention — naive "unabsorbed" oracle
# ---------------------------------------------------------------------------

def mla_attention(
    params: MLAParams,
    cfg: MLAConfig,
    h: jax.Array,                  # [B, S, d]
    positions: jax.Array,          # [S] or [B, S]
    causal: bool = True,
) -> jax.Array:
    q_c, q_r = project_q(params, cfg, h, positions)        # [B,S,H,dh],[B,S,H,dr]
    c_kv, k_r = project_kv(params, cfg, h, positions)      # [B,S,dc],[B,S,dr]
    k_c = jnp.einsum("...sc,chd->...shd", c_kv, params.w_uk)
    v = jnp.einsum("...sc,chd->...shd", c_kv, params.w_uv)

    logits = (
        jnp.einsum("...qhd,...khd->...hqk", q_c, k_c)
        + jnp.einsum("...qhd,...kd->...hqk", q_r, k_r)
    ) * cfg.softmax_scale
    S = h.shape[-2]
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(h.dtype)
    o = jnp.einsum("...hqk,...khd->...qhd", p, v)
    return jnp.einsum("...qhd,hdk->...qk", o, params.w_o)


# ---------------------------------------------------------------------------
# Absorbed decode (one new token against a latent cache) — BF16 baseline
# (our FlashMLA stand-in: same math as the quantized path, no quantization)
# ---------------------------------------------------------------------------

def mla_decode_absorbed(
    params: MLAParams,
    cfg: MLAConfig,
    h_t: jax.Array,            # [B, d] current hidden state
    cache_c: jax.Array,        # [B, N, d_c] latent cache (already normed)
    cache_kr: jax.Array,       # [B, N, d_r] rope key cache (RoPE applied)
    seq_lens: jax.Array,       # [B] valid lengths (including the new token slot
                               #     already appended by the caller)
    positions: jax.Array,      # [B] position of the current token
) -> jax.Array:
    q_c, q_r = project_q(params, cfg, h_t[:, None, :], positions[:, None])
    q_c, q_r = q_c[:, 0], q_r[:, 0]                        # [B,H,dh],[B,H,dr]
    q_lat = absorb_q(params, q_c)                          # [B,H,dc]

    logits = (
        jnp.einsum("bhc,bnc->bhn", q_lat.astype(jnp.float32), cache_c.astype(jnp.float32))
        + jnp.einsum("bhr,bnr->bhn", q_r.astype(jnp.float32), cache_kr.astype(jnp.float32))
    ) * cfg.softmax_scale
    n = cache_c.shape[1]
    mask = jnp.arange(n)[None, None, :] < seq_lens[:, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhn,bnc->bhc", p, cache_c.astype(jnp.float32))
    return output_proj(params, o_lat.astype(h_t.dtype))
