"""Quantized KV caches for decode.

Two families:

  * ``MLACache`` — the paper's object: per token a latent content vector
    (FP8/INT8, per-token scale) plus a decoupled-RoPE key kept in BF16 and
    *pre-scaled* by the inverse content scale (Key Step 1 domain alignment).
  * ``GQACache`` — generalization to GQA/MHA archs: K and V quantized per token
    per kv-head (post-RoPE). Supports sliding-window archs through a ring
    buffer with per-slot absolute positions.

Layout note (TPU adaptation): TPU serving stacks (JetStream/MaxText) use
*contiguous per-slot* caches ([B, N, ...]) rather than GPU-style paged pools —
contiguous caches shard cleanly over the ('pod','data') batch axes under pjit.
That is the default here. A paged-pool variant with scalar-prefetched page
tables (the PagedAttention analogue from the paper's Fused-K-Append) is
provided for the flagship Pallas kernel in kernels/mla_decode.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.quant import QuantFormat


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    fmt: str = "fp8_e4m3"        # "fp8_e4m3" | "int8" | "none" (bf16 baseline)
    page_size: int = 128          # kernel KV-block granularity (§3.3.2: 128)
    window: int = 0               # >0: ring buffer of this many tokens (SWA)
    # P-Cast sink guard (PAPERS.md: arxiv 2606.06521): attention sinks — the
    # first tokens, which soak up outsized probability mass at long context —
    # are exactly where FP8 E4M3 latent rows hurt most. >0 keeps the first
    # ``sink_tokens`` tokens' latent content in full precision alongside the
    # quantized rows (the decoupled-RoPE part already is), shadowed in
    # ``MLACache.sink`` and substituted at the decode boundary. Contiguous
    # MLA caches only; paged pools keep every page quantized (a sink page
    # would need a per-page precision tag through the allocator — follow-on).
    sink_tokens: int = 0

    @property
    def quantized(self) -> bool:
        return self.fmt != "none"

    def storage_dtype(self):
        return quant.qdtype_for(self.fmt) if self.quantized else jnp.bfloat16


def _round_up(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def page_aligned_capacity(n_tokens: int, page_size: int) -> int:
    """Exact cache capacity for ``n_tokens`` tokens: rounded up to the page
    size (the decode kernels' block granularity) and nothing more.

    The ONE sizing rule shared by both cache initializers and the serving
    driver — callers must not add their own page of slack on top (the old
    ``S + gen + page_size`` sizing over-allocated a full page whenever
    ``S + gen`` was already aligned)."""
    return _round_up(max(int(n_tokens), 1), page_size)


# ---------------------------------------------------------------------------
# MLA latent cache
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    content: jax.Array    # [B, N, d_c]  fp8/int8 (or bf16 when fmt == none)
    rope: jax.Array       # [B, N, d_r]  bf16, pre-divided by `scale` if quantized
    scale: jax.Array      # [B, N]       f32 per-token content scale (ones if none)
    seq_lens: jax.Array   # [B] int32 number of valid tokens
    # P-Cast sink guard shadow (CacheConfig.sink_tokens): [B, S_k, d_c] f32
    # holding the first S_k tokens' RAW latent c_kv. None (default) keeps the
    # pytree structure identical to the unguarded cache. The quantized rows
    # underneath stay written as usual — the guard substitutes at the decode
    # boundary (``sink_patched_content``), so every write path is unchanged.
    sink: jax.Array | None = None

    @property
    def capacity(self) -> int:
        return self.content.shape[1]

    @property
    def sink_tokens(self) -> int:
        return 0 if self.sink is None else self.sink.shape[1]


def sink_patched_content(cache: MLACache) -> jax.Array:
    """Content with the sink rows substituted in full precision.

    Returns ``cache.content`` untouched when no guard is armed. With a guard,
    returns an f32 copy whose first ``S_k`` rows are ``sink / scale`` — the
    decode pipeline multiplies content by ``scale`` downstream, so guarded
    rows reconstruct the exact latent c_kv while every other row keeps its
    FP8/INT8 value. Rows past ``seq_lens`` are masked by the kernels anyway,
    so unwritten sink slots (zeros) are never read."""
    if cache.sink is None:
        return cache.content
    S_k = cache.sink.shape[1]
    tiny = jnp.finfo(jnp.float32).tiny
    patched = cache.sink / jnp.maximum(cache.scale[:, :S_k, None], tiny)
    return cache.content.astype(jnp.float32).at[:, :S_k].set(patched)


def init_mla_cache(cfg: CacheConfig, batch: int, max_len: int, d_c: int, d_r: int) -> MLACache:
    """Allocate an MLA cache with capacity rounded up to the page size.

    The rounding is load-bearing: the decode kernels require block-aligned
    capacity (ops.snapmla_decode asserts it) — aligned allocation here is what
    lets every decode step skip re-padding the whole cache (an O(max_len) HBM
    copy per step in the old path).
    """
    n = page_aligned_capacity(max_len, cfg.page_size)
    S_k = min(cfg.sink_tokens, n)
    return MLACache(
        content=jnp.zeros((batch, n, d_c), cfg.storage_dtype()),
        rope=jnp.zeros((batch, n, d_r), jnp.bfloat16),
        scale=jnp.ones((batch, n), jnp.float32),
        seq_lens=jnp.zeros((batch,), jnp.int32),
        sink=(jnp.zeros((batch, S_k, d_c), jnp.float32) if S_k > 0 else None),
    )


def mla_quantize_entry(cfg: CacheConfig, c_kv: jax.Array, k_r: jax.Array):
    """Quantize one or more MLA KV entries (paper §3.1, Eq. 6).

    c_kv [..., d_c], k_r [..., d_r] -> (content_store, rope_store, scale[...]).
    """
    if not cfg.quantized:
        ones = jnp.ones(c_kv.shape[:-1], jnp.float32)
        return c_kv.astype(jnp.bfloat16), k_r.astype(jnp.bfloat16), ones
    raq = quant.quantize_rope_aware(c_kv, k_r, cfg.fmt)
    return raq.q_content, raq.rope_scaled, raq.scale[..., 0]


def mla_append(cache: MLACache, cfg: CacheConfig, c_kv: jax.Array, k_r: jax.Array,
               active: jax.Array | None = None) -> MLACache:
    """Append one token per sequence (instant per-token quantization).

    c_kv [B, d_c], k_r [B, d_r]. Pure-jnp reference for the Fused-K-Append
    kernel (kernels/quantize). ``active`` [B] bool gates the append per row:
    inactive rows rewrite their current slot with its old value and do NOT
    advance ``seq_lens`` — the fused scan uses this to stop growing the live
    region of EOS-finished rows (the split-KV early exit then skips their
    blocks). ``active=None`` is the ungated path, bit-identical to before.
    """
    content, rope, scale = mla_quantize_entry(cfg, c_kv, k_r)

    def upd(cache_b, val_b, idx):
        return jax.lax.dynamic_update_slice(cache_b, val_b[None], (idx,) + (0,) * (cache_b.ndim - 1))

    idx = cache.seq_lens
    if active is not None:
        def keep_old(cache_b, val_b, idx_b, act_b):
            old_b = jax.lax.dynamic_slice(
                cache_b, (idx_b,) + (0,) * (cache_b.ndim - 1),
                (1,) + cache_b.shape[1:])[0]
            return jnp.where(act_b, val_b, old_b)

        content = jax.vmap(keep_old)(cache.content,
                                     content.astype(cache.content.dtype),
                                     idx, active)
        rope = jax.vmap(keep_old)(cache.rope, rope.astype(jnp.bfloat16),
                                  idx, active)
        scale = jax.vmap(keep_old)(cache.scale, scale, idx, active)
    return MLACache(
        content=jax.vmap(upd)(cache.content, content.astype(cache.content.dtype), idx),
        rope=jax.vmap(upd)(cache.rope, rope.astype(jnp.bfloat16), idx),
        scale=jax.vmap(upd)(cache.scale, scale, idx),
        seq_lens=cache.seq_lens + (1 if active is None
                                   else active.astype(cache.seq_lens.dtype)),
        sink=_sink_append(cache, c_kv, idx, active),
    )


def _sink_append(cache: MLACache, c_kv: jax.Array, idx: jax.Array,
                 active: jax.Array | None) -> jax.Array | None:
    """Shadow-write the raw latent row into the sink guard when the append
    position lands inside the guarded prefix (idx < S_k). Shared by
    ``mla_append`` and the fused-append kernel wrapper so both write paths
    keep the guard coherent. No-op (None) on unguarded caches."""
    if cache.sink is None:
        return None
    S_k = cache.sink.shape[1]
    ok = idx < S_k
    if active is not None:
        ok = jnp.logical_and(ok, active)

    def upd(sink_b, val_b, idx_b, ok_b):
        i = jnp.minimum(idx_b, S_k - 1)
        old_b = jax.lax.dynamic_slice(sink_b, (i, 0), (1, sink_b.shape[1]))[0]
        new_b = jnp.where(ok_b, val_b, old_b)
        return jax.lax.dynamic_update_slice(sink_b, new_b[None], (i, 0))

    return jax.vmap(upd)(cache.sink, c_kv.astype(jnp.float32), idx, ok)


def mla_prefill(cache: MLACache, cfg: CacheConfig, c_kv: jax.Array, k_r: jax.Array) -> MLACache:
    """Bulk-write a prefix: c_kv [B, S, d_c], k_r [B, S, d_r] at positions [0, S)."""
    content, rope, scale = mla_quantize_entry(cfg, c_kv, k_r)
    S = c_kv.shape[1]
    sink = cache.sink
    if sink is not None:
        W = min(S, sink.shape[1])
        sink = sink.at[:, :W].set(c_kv[:, :W].astype(jnp.float32))
    return MLACache(
        content=cache.content.at[:, :S].set(content.astype(cache.content.dtype)),
        rope=cache.rope.at[:, :S].set(rope.astype(jnp.bfloat16)),
        scale=cache.scale.at[:, :S].set(scale),
        seq_lens=jnp.full_like(cache.seq_lens, S),
        sink=sink,
    )


# ---------------------------------------------------------------------------
# GQA cache (K and V per-token quantized, optional SWA ring buffer)
# ---------------------------------------------------------------------------

class GQACache(NamedTuple):
    k: jax.Array            # [B, N, Hkv, dh] storage dtype
    v: jax.Array            # [B, N, Hkv, dh]
    k_scale: jax.Array      # [B, N, Hkv] f32
    v_scale: jax.Array      # [B, N, Hkv]
    slot_pos: jax.Array     # [B, N] int32 absolute position in slot, -1 = empty
    seq_lens: jax.Array     # [B] int32 total tokens seen (not capped by window)

    @property
    def capacity(self) -> int:
        return self.k.shape[1]


def init_gqa_cache(cfg: CacheConfig, batch: int, max_len: int, n_kv: int, d_h: int) -> GQACache:
    cap = min(max_len, cfg.window) if cfg.window else max_len
    cap = _round_up(cap, cfg.page_size)
    return GQACache(
        k=jnp.zeros((batch, cap, n_kv, d_h), cfg.storage_dtype()),
        v=jnp.zeros((batch, cap, n_kv, d_h), cfg.storage_dtype()),
        k_scale=jnp.ones((batch, cap, n_kv), jnp.float32),
        v_scale=jnp.ones((batch, cap, n_kv), jnp.float32),
        slot_pos=jnp.full((batch, cap), -1, jnp.int32),
        seq_lens=jnp.zeros((batch,), jnp.int32),
    )


def gqa_quantize_entry(cfg: CacheConfig, k: jax.Array, v: jax.Array):
    """k, v [..., Hkv, dh] -> storage + per-(token, head) scales [..., Hkv]."""
    if not cfg.quantized:
        ones = jnp.ones(k.shape[:-1], jnp.float32)
        return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16), ones, ones
    qk = quant.quantize_per_token(k, cfg.fmt)
    qv = quant.quantize_per_token(v, cfg.fmt)
    return qk.q, qv.q, qk.scale[..., 0], qv.scale[..., 0]


def gqa_append(cache: GQACache, cfg: CacheConfig, k: jax.Array, v: jax.Array,
               active: jax.Array | None = None) -> GQACache:
    """Append one token per sequence. k, v [B, Hkv, dh] (RoPE already applied).
    ``active`` [B] bool gates the append per row (see ``mla_append``)."""
    kq, vq, ks, vs = gqa_quantize_entry(cfg, k, v)
    pos = cache.seq_lens                       # absolute position of the new token
    slot = pos % cache.capacity if cfg.window else pos

    def upd(cache_b, val_b, idx):
        return jax.lax.dynamic_update_slice(cache_b, val_b[None], (idx,) + (0,) * (cache_b.ndim - 1))

    sp = pos.astype(jnp.int32)
    if active is not None:
        def keep_old(cache_b, val_b, idx_b, act_b):
            old_b = jax.lax.dynamic_slice(
                cache_b, (idx_b,) + (0,) * (cache_b.ndim - 1),
                (1,) + cache_b.shape[1:])[0]
            return jnp.where(act_b, val_b, old_b)

        kq = jax.vmap(keep_old)(cache.k, kq.astype(cache.k.dtype), slot, active)
        vq = jax.vmap(keep_old)(cache.v, vq.astype(cache.v.dtype), slot, active)
        ks = jax.vmap(keep_old)(cache.k_scale, ks, slot, active)
        vs = jax.vmap(keep_old)(cache.v_scale, vs, slot, active)
        sp = jax.vmap(keep_old)(cache.slot_pos, sp, slot, active)

    return GQACache(
        k=jax.vmap(upd)(cache.k, kq.astype(cache.k.dtype), slot),
        v=jax.vmap(upd)(cache.v, vq.astype(cache.v.dtype), slot),
        k_scale=jax.vmap(upd)(cache.k_scale, ks, slot),
        v_scale=jax.vmap(upd)(cache.v_scale, vs, slot),
        slot_pos=jax.vmap(upd)(cache.slot_pos, sp, slot),
        seq_lens=cache.seq_lens + (1 if active is None
                                   else active.astype(cache.seq_lens.dtype)),
    )


def gqa_prefill(cache: GQACache, cfg: CacheConfig, k: jax.Array, v: jax.Array) -> GQACache:
    """Bulk-write a prefix. k, v [B, S, Hkv, dh]. With a window, only the last
    `capacity` tokens are retained (ring semantics preserved)."""
    B, S = k.shape[:2]
    cap = cache.capacity
    kq, vq, ks, vs = gqa_quantize_entry(cfg, k, v)
    positions = jnp.arange(S, dtype=jnp.int32)
    if cfg.window and S > cap:
        # keep last `cap` tokens, placed at slot = pos % cap
        keep = slice(S - cap, S)
        kq, vq, ks, vs = kq[:, keep], vq[:, keep], ks[:, keep], vs[:, keep]
        positions = positions[keep]
    slots = positions % cap if cfg.window else positions
    k_new = cache.k.at[:, slots].set(kq.astype(cache.k.dtype))
    v_new = cache.v.at[:, slots].set(vq.astype(cache.v.dtype))
    ks_new = cache.k_scale.at[:, slots].set(ks)
    vs_new = cache.v_scale.at[:, slots].set(vs)
    sp_new = cache.slot_pos.at[:, slots].set(jnp.broadcast_to(positions, (B, positions.shape[0])))
    return GQACache(k_new, v_new, ks_new, vs_new, sp_new, jnp.full_like(cache.seq_lens, S))


# ---------------------------------------------------------------------------
# Paged pool (PagedAttention analogue for the scalar-prefetch Pallas kernel)
# ---------------------------------------------------------------------------

class PagedMLAPool(NamedTuple):
    """Global page pool: pages are the unit of allocation AND the kernel's
    KV-block granularity (scalar-prefetched page table drives the BlockSpec
    index map — the TPU-native PagedAttention).

    The page table is *per-slot* state: each row maps one batch slot's
    logical pages to arbitrary physical pool pages. The batch-owned layout
    (``init_paged_mla_cache`` default) fills rows with private strided runs;
    the serving engine's multi-tenant layout has a free-list allocator
    (``serving.allocator.PageAllocator``) write rows as requests come and go,
    with refcounted prefix pages shared between rows and physical page 0
    reserved as a scratch page that idle slots park on (their writes land
    there and are never read back — entries past ``seq_lens`` are masked)."""

    content: jax.Array      # [n_pages, page_size, d_c]
    rope: jax.Array         # [n_pages, page_size, d_r]
    scale: jax.Array        # [n_pages, page_size]
    page_table: jax.Array   # [B, max_pages] int32 page ids (0 is a valid page;
                            #  unused entries point at page 0 and are masked)
    seq_lens: jax.Array     # [B]

    @property
    def page_size(self) -> int:
        return self.content.shape[1]

    @property
    def capacity(self) -> int:
        """Per-sequence token capacity (the page-table span), matching
        MLACache.capacity so the split resolution rule is cache-agnostic."""
        return self.page_table.shape[1] * self.page_size


def init_paged_mla_pool(
    cfg: CacheConfig, n_pages: int, max_pages_per_seq: int, batch: int, d_c: int, d_r: int
) -> PagedMLAPool:
    return PagedMLAPool(
        content=jnp.zeros((n_pages, cfg.page_size, d_c), cfg.storage_dtype()),
        rope=jnp.zeros((n_pages, cfg.page_size, d_r), jnp.bfloat16),
        scale=jnp.ones((n_pages, cfg.page_size), jnp.float32),
        page_table=jnp.zeros((batch, max_pages_per_seq), jnp.int32),
        seq_lens=jnp.zeros((batch,), jnp.int32),
    )


def paged_gather(pool: PagedMLAPool):
    """Gather a contiguous view [B, max_pages*page, ...] (reference only)."""
    c = pool.content[pool.page_table]   # [B, P, page, d_c]
    r = pool.rope[pool.page_table]
    s = pool.scale[pool.page_table]
    B, P, page, d_c = c.shape
    return (
        c.reshape(B, P * page, d_c),
        r.reshape(B, P * page, -1),
        s.reshape(B, P * page),
    )


def init_paged_mla_cache(cfg: CacheConfig, batch: int, max_len: int,
                         d_c: int, d_r: int, n_pages: int = 0) -> PagedMLAPool:
    """Allocate a paged pool behind the model-layer cache interface.

    ``n_pages == 0`` (default): batch-owned layout — each sequence gets a
    private strided run of pages (page table row b = [b*P, (b+1)*P)).

    ``n_pages > 0``: shared multi-tenant layout — ``n_pages`` physical pages
    with an all-zero page table (every entry parked on page 0, the reserved
    scratch page of the serving engine's free-list allocator) and zero
    seq_lens; page-table rows are written per request by the allocator as
    sequences are admitted, grown, and retired. The decode kernels only ever
    see the page table, so both layouts run the same code path."""
    n = page_aligned_capacity(max_len, cfg.page_size)
    pages_per_seq = n // cfg.page_size
    if n_pages:
        return init_paged_mla_pool(cfg, n_pages, pages_per_seq, batch,
                                   d_c, d_r)
    pool = init_paged_mla_pool(cfg, batch * pages_per_seq, pages_per_seq,
                               batch, d_c, d_r)
    table = jnp.arange(batch * pages_per_seq, dtype=jnp.int32).reshape(
        batch, pages_per_seq)
    return pool._replace(page_table=table)


def pool_with_tables(pool: PagedMLAPool, table, seq_lens) -> PagedMLAPool:
    """Swap a pool's page table + seq_lens for host-owned values — the
    free-list hook the serving engine uses to push its slot assignments into
    the jitted decode state each step. ``table`` [B, P] int32, ``seq_lens``
    [B] int32. Handles stacked pools (a leading superblock axis from the
    scanned-layer vmap in ``transformer.init_decode_state``) by broadcasting:
    every layer of a scanned tile shares the same slot→pages mapping."""
    table = jnp.asarray(table, jnp.int32)
    seq_lens = jnp.asarray(seq_lens, jnp.int32)
    if pool.page_table.ndim == table.ndim + 1:     # stacked superblock pools
        n_sb = pool.page_table.shape[0]
        table = jnp.broadcast_to(table[None], (n_sb,) + table.shape)
        seq_lens = jnp.broadcast_to(seq_lens[None], (n_sb,) + seq_lens.shape)
    return pool._replace(page_table=table, seq_lens=seq_lens)


def pool_read_page(pool: PagedMLAPool, page_id: int):
    """One physical page's payload ``(content, rope, scale)`` — the unit the
    serving engine's host-memory tier offloads. Handles stacked superblock
    pools (leading scanned-layer axis) transparently: the page axis is the
    third-from-last for content/rope and second-from-last for scale, so a
    stacked read returns every scanned layer's copy of the page at once."""
    if pool.content.ndim == 4:                     # stacked superblock pools
        return (pool.content[:, page_id], pool.rope[:, page_id],
                pool.scale[:, page_id])
    return pool.content[page_id], pool.rope[page_id], pool.scale[page_id]


def pool_write_page(pool: PagedMLAPool, page_id: int, payload) -> PagedMLAPool:
    """Write ``(content, rope, scale)`` (shapes from ``pool_read_page``)
    back into physical page ``page_id`` — the host-tier restore path. FP8
    quantization is deterministic, so a restored page is byte-identical to
    the page that was offloaded."""
    content, rope, scale = payload
    if pool.content.ndim == 4:
        return pool._replace(
            content=pool.content.at[:, page_id].set(
                jnp.asarray(content, pool.content.dtype)),
            rope=pool.rope.at[:, page_id].set(
                jnp.asarray(rope, pool.rope.dtype)),
            scale=pool.scale.at[:, page_id].set(
                jnp.asarray(scale, pool.scale.dtype)))
    return pool._replace(
        content=pool.content.at[page_id].set(
            jnp.asarray(content, pool.content.dtype)),
        rope=pool.rope.at[page_id].set(jnp.asarray(rope, pool.rope.dtype)),
        scale=pool.scale.at[page_id].set(
            jnp.asarray(scale, pool.scale.dtype)))


def paged_mla_prefill(pool: PagedMLAPool, cfg: CacheConfig,
                      c_kv: jax.Array, k_r: jax.Array) -> PagedMLAPool:
    """Bulk-write a prefix through the page table: c_kv [B, S, d_c],
    k_r [B, S, d_r] land in pages page_table[b, t // page] at slot t % page."""
    B, S = c_kv.shape[:2]
    page = pool.page_size
    content, rope, scale = mla_quantize_entry(cfg, c_kv, k_r)
    t = jnp.arange(S)
    pids = pool.page_table[:, t // page]                      # [B, S]
    offs = jnp.broadcast_to(t % page, (B, S))
    return pool._replace(
        content=pool.content.at[pids, offs].set(
            content.astype(pool.content.dtype)),
        rope=pool.rope.at[pids, offs].set(rope.astype(jnp.bfloat16)),
        scale=pool.scale.at[pids, offs].set(scale),
        seq_lens=jnp.full_like(pool.seq_lens, S),
    )


def paged_mla_append(pool: PagedMLAPool, cfg: CacheConfig,
                     c_kv: jax.Array, k_r: jax.Array,
                     active: jax.Array | None = None) -> PagedMLAPool:
    """Append one token per sequence into its current page (instant per-token
    quantization — the paged twin of ``mla_append``).

    Writes past capacity are clamped to the FINAL slot (matching the
    contiguous ``mla_append``'s degradation, where JAX clamps the update
    index to N-1): without the clamp, ``t // page`` would fall off the page
    table and JAX's scatter clamping would silently corrupt the *first* slot
    of the last page — a live mid-sequence entry.

    ``active`` [B] bool gates the append per row: inactive rows rewrite
    their current slot with its old value and keep ``seq_lens`` frozen, so
    the paged split-KV early exit stops paying for EOS-finished rows."""
    B = c_kv.shape[0]
    page = pool.page_size
    content, rope, scale = mla_quantize_entry(cfg, c_kv, k_r)
    t = jnp.minimum(pool.seq_lens, pool.capacity - 1)
    pid = pool.page_table[jnp.arange(B), t // page]           # [B]
    off = t % page
    if active is not None:
        content = jnp.where(active[:, None], content, pool.content[pid, off])
        rope = jnp.where(active[:, None], rope, pool.rope[pid, off])
        scale = jnp.where(active, scale, pool.scale[pid, off])
    return pool._replace(
        content=pool.content.at[pid, off].set(
            content.astype(pool.content.dtype)),
        rope=pool.rope.at[pid, off].set(rope.astype(jnp.bfloat16)),
        scale=pool.scale.at[pid, off].set(scale),
        seq_lens=pool.seq_lens + (1 if active is None
                                  else active.astype(pool.seq_lens.dtype)),
    )


def paged_mla_prefill_at(pool: PagedMLAPool, cfg: CacheConfig,
                         c_kv: jax.Array, k_r: jax.Array,
                         start: jax.Array, valid: jax.Array) -> PagedMLAPool:
    """Partial-length paged prefill append: bulk-write a CHUNK of the prompt
    through the page table at positions ``start + t`` (chunked prefill).

    c_kv [B, C, d_c], k_r [B, C, d_r]; ``start`` [B] int32 is the chunk's
    first absolute position (traced — one compiled program serves every
    chunk of a given width); ``valid`` [B, C] bool masks the padded tail of
    a bucketed final chunk — masked positions are routed to physical page 0
    (the engine's scratch page, never read back) so bucket padding can never
    clobber live entries or run off the page table. ``seq_lens`` advances to
    ``start + (number of valid chunk tokens)``."""
    B, C = c_kv.shape[:2]
    page = pool.page_size
    content, rope, scale = mla_quantize_entry(cfg, c_kv, k_r)
    t = start[:, None] + jnp.arange(C)[None, :]               # [B, C] absolute
    P = pool.page_table.shape[-1]
    logical = jnp.clip(t // page, 0, P - 1)
    pids = jnp.take_along_axis(pool.page_table, logical, axis=1)   # [B, C]
    # positions past the table span route to scratch instead of aliasing the
    # last mapped page (a speculative-verify block near the end of a full
    # span writes its rejected tail rows here; they are never read back)
    pids = jnp.where(valid & (t // page < P), pids, 0)
    offs = t % page
    return pool._replace(
        content=pool.content.at[pids, offs].set(
            content.astype(pool.content.dtype)),
        rope=pool.rope.at[pids, offs].set(rope.astype(jnp.bfloat16)),
        scale=pool.scale.at[pids, offs].set(scale),
        seq_lens=start + jnp.sum(valid, axis=1).astype(pool.seq_lens.dtype),
    )
