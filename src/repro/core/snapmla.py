"""SnapMLA — public API for the FP8 quantized MLA decoding pipeline.

Ties together the three paper components over one attention layer:

  prefill():      bulk-quantize the prompt's latent/rope entries into the cache
                  (RoPE-aware per-token quantization) and run exact attention
                  for the prompt itself.
  decode_step():  Fused-Q-Quant -> Fused-K-Append -> SnapMLA decode kernel
                  (scale-fused FP8 pipeline) -> absorbed output projection.

``pipeline="bf16"`` runs the same dataflow without quantization — the
FlashMLA-equivalent baseline used in all paper comparisons.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import mla as mla_lib
from repro.core.kvcache import (CacheConfig, MLACache, PagedMLAPool,
                                init_mla_cache, init_paged_mla_cache,
                                mla_prefill, paged_mla_append,
                                paged_mla_prefill)
from repro.kernels.mla_decode import backends as mla_backends
from repro.kernels.mla_decode import ref as mla_ref
from repro.kernels.quantize.ops import fused_k_append, fused_q_quant


@dataclasses.dataclass(frozen=True)
class SnapMLAConfig:
    mla: mla_lib.MLAConfig
    cache: CacheConfig = CacheConfig()
    # decode-attention backend (kernels/mla_decode/backends.py): True = the
    # Pallas split-KV kernels (interpret on CPU), False = the jnp ref twins
    use_kernel: bool = True
    interpret: bool = True
    # split-KV (flash-decoding) sequence parallelism for the decode kernel:
    # None or 0 = autotuner profile with the context-length heuristic as
    # fallback (ops.resolve_num_splits), 1 = always single-pass (bit-exact
    # seed path), >1 = fixed split count. Applies to BOTH cache layouts.
    num_splits: int | None = None
    # contiguous-cache decode block size: 0 = cache.page_size (seed
    # behavior); >0 = explicit override. Paged caches are structurally
    # pinned to the physical page size.
    block_n: int = 0
    # per-block accumulator rescale: "fma" (exact seed path) | "amla"
    # (exponent-add fast path, combine-free split-KV partials)
    rescale: str = "fma"
    # paged KV: the cache is a PagedMLAPool (page-table-driven kernels) rather
    # than a contiguous per-slot MLACache.
    paged: bool = False

    @property
    def fmt(self) -> str:
        return self.cache.fmt


def init_cache(cfg: SnapMLAConfig, batch: int, max_len: int):
    """MLACache, or a batch-owned PagedMLAPool when ``cfg.paged``."""
    init = init_paged_mla_cache if cfg.paged else init_mla_cache
    return init(cfg.cache, batch, max_len, cfg.mla.d_c, cfg.mla.d_rope)


def prefill(
    params: mla_lib.MLAParams,
    cfg: SnapMLAConfig,
    h: jax.Array,                 # [B, S, d] prompt hidden states
    cache,
) -> tuple[jax.Array, "MLACache | PagedMLAPool"]:
    """Run exact prompt attention and fill the quantized cache."""
    B, S, _ = h.shape
    positions = jnp.arange(S)
    out = mla_lib.mla_attention(params, cfg.mla, h, positions, causal=True)
    c_kv, k_r = mla_lib.project_kv(params, cfg.mla, h, positions)
    fill = paged_mla_prefill if isinstance(cache, PagedMLAPool) else mla_prefill
    cache = fill(cache, cfg.cache, c_kv, k_r)
    return out, cache


def decode_step(
    params: mla_lib.MLAParams,
    cfg: SnapMLAConfig,
    h_t: jax.Array,               # [B, d] current token hidden state
    cache,
) -> tuple[jax.Array, "MLACache | PagedMLAPool"]:
    """One decode step: returns (attention output [B, d], updated cache)."""
    B = h_t.shape[0]
    positions = cache.seq_lens                         # 0-based position of h_t
    paged = isinstance(cache, PagedMLAPool)

    # -- K side: project + Fused-K-Append (quantize + align + paged write) --
    c_kv, k_r = mla_lib.project_kv(params, cfg.mla, h_t[:, None, :], positions[:, None])
    if paged:
        cache = paged_mla_append(cache, cfg.cache, c_kv[:, 0], k_r[:, 0])
    elif cfg.cache.quantized:
        cache = fused_k_append(
            cache, c_kv[:, 0], k_r[:, 0], fmt=cfg.fmt, page=cfg.cache.page_size,
            use_kernel=cfg.use_kernel, interpret=cfg.interpret)
    else:
        from repro.core.kvcache import mla_append
        cache = mla_append(cache, cfg.cache, c_kv[:, 0], k_r[:, 0])

    # -- Q side: project + absorb + Fused-Q-Quant ---------------------------
    q_c, q_r = mla_lib.project_q(params, cfg.mla, h_t[:, None, :], positions[:, None])
    q_lat = mla_lib.absorb_q(params, q_c[:, 0])        # [B, H, d_c]
    q_rope = q_r[:, 0]                                 # [B, H, d_r]
    if cfg.cache.quantized:
        q_cat = jnp.concatenate([q_lat.astype(jnp.float32),
                                 q_rope.astype(jnp.float32)], axis=-1)
        q_c8, q_r_s, sigma_q = fused_q_quant(
            q_cat, cfg.mla.d_c, fmt=cfg.fmt,
            use_kernel=cfg.use_kernel, interpret=cfg.interpret)
    else:
        q_c8, q_r_s, sigma_q = mla_ref.prepare_q(q_lat, q_rope, "none")

    # -- SnapMLA decode attention: backend-registry dispatch ----------------
    backend = mla_backends.resolve_backend(
        "kernel" if cfg.use_kernel else "ref", paged=paged, batch=B,
        n_heads=cfg.mla.n_heads)
    bcfg = mla_backends.BackendConfig(
        softmax_scale=cfg.mla.softmax_scale,
        block_n=cfg.block_n or cfg.cache.page_size,
        fmt=cfg.fmt if cfg.cache.quantized else "none",
        num_splits=cfg.num_splits, interpret=cfg.interpret,
        rescale=cfg.rescale)
    o_lat = backend.decode(
        mla_backends.DecodeQuery(q_c8, q_r_s, sigma_q), cache, bcfg)

    out = mla_lib.output_proj(params, o_lat.astype(h_t.dtype))
    return out, cache
