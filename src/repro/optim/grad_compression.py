"""INT8 error-feedback gradient compression for the DP all-reduce.

Distributed-optimization trick for multi-pod training: gradients crossing the
slow inter-pod links are per-tensor int8-quantized before the reduction, with
the quantization residual fed back into the next step (error feedback keeps
the compression unbiased over time — Karimireddy et al., 2019).

Used via shard_map around the gradient reduction in launch/train.py when
``compress_grads=True``; this module provides the (de)compression math, which
is mesh-agnostic and unit-tested for the error-feedback contraction property.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any      # pytree like grads, f32


def init_ef_state(grads_like) -> EFState:
    return EFState(jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads_like))


def compress(g: jax.Array, residual: jax.Array):
    """g + residual -> (int8 payload, f32 scale, new residual)."""
    corrected = g.astype(jnp.float32) + residual
    amax = jnp.max(jnp.abs(corrected))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    new_residual = corrected - q.astype(jnp.float32) * scale
    return q, scale, new_residual


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, state: EFState):
    """Pytree version. Returns (payload tree of (q, scale), new EFState)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(state.residual)
    qs, new_r = [], []
    for g, r in zip(flat_g, flat_r):
        q, s, nr = compress(g, r)
        qs.append((q, s))
        new_r.append(nr)
    return treedef.unflatten(qs), EFState(treedef.unflatten(new_r))


def decompress_tree(payload):
    return jax.tree.map(lambda qs: decompress(*qs), payload,
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                        and isinstance(x[0], jax.Array))
