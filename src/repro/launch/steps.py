"""Step functions (train / prefill / decode) + ShapeDtypeStruct input specs.

These are the units the dry-run lowers and the launchers run. All are pure
functions of (params, state, batch) so they jit/pjit cleanly; input_specs
builds allocation-free stand-ins for every (architecture x input-shape) cell.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw
from repro.optim.schedule import warmup_cosine

# (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    seq, gb, kind = SHAPES[shape]
    if kind == "decode" and not cfg.has_decoder:
        return False, "encoder-only arch has no decode step"
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch; long_500k needs sub-quadratic attention (DESIGN.md §5)"
    return True, ""


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                    warmup_steps: int = 100, total_steps: int = 10000,
                    remat: bool = True):
    def train_step(params, opt_state, batch, step):
        def lfn(p):
            return T.loss_fn(p, cfg, batch["tokens"], batch["labels"],
                             batch.get("aux_embed"), remat=remat)

        (loss, metrics), grads = jax.value_and_grad(lfn, has_aux=True)(params)
        lr_scale = warmup_cosine(step, warmup_steps=warmup_steps,
                                 total_steps=total_steps)
        new_params, new_opt, om = adamw_update(opt_cfg, grads, opt_state,
                                               params, lr_scale)
        return new_params, new_opt, {**metrics, **om, "loss": loss}

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, tokens, state, aux_embed=None):
        return T.prefill(params, cfg, tokens, state, aux_embed)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, token, state, pos):
        return T.decode_step(params, cfg, token, state, pos)

    return decode_step


def make_ref_decode_step(cfg: ModelConfig):
    """Decode step pinned to the jnp reference attention backend (the pjit
    twin; paged configs resolve to ``jnp_paged_ref``). The serving engine's
    graceful-degradation twin: quarantined rows are retried on it and a
    raising primary dispatch falls back to it, so a kernel fault degrades to
    reference numerics instead of killing the process."""
    ref_cfg = dataclasses.replace(cfg, decode_backend="ref",
                                  use_kernels=False)

    def ref_decode_step(params, token, state, pos):
        return T.decode_step(params, ref_cfg, token, state, pos)

    return ref_decode_step


def make_verify_step(cfg: ModelConfig, ref: bool = False):
    """Speculative-verify step: K candidate tokens through the stack in ONE
    dispatch.

    verify_step(params, tokens [B, K], state, start [B]) -> (logits
    [B, K, V] for every position, state with the block's quantized entries
    landed in the pool). ``start`` is traced, so ONE compiled program serves
    every engine step at a given draft width K. ``ref=True`` pins the jnp
    reference backend (the graceful-degradation twin, mirroring
    ``make_ref_decode_step``)."""
    vcfg = dataclasses.replace(cfg, decode_backend="ref",
                               use_kernels=False) if ref else cfg

    def verify_step(params, tokens, state, start):
        return T.verify_step(params, vcfg, tokens, state, start)

    return verify_step


def make_chunked_prefill_step(cfg: ModelConfig):
    """Chunked-prefill step: one (bucketed) prompt chunk through the stack.

    chunk_prefill_step(params, tokens [B, C], state, chunk_start [B],
    last_idx [B]) -> (logits [B, V] at the last real token, updated state).
    ``chunk_start``/``last_idx`` are traced, so compiles are per chunk WIDTH
    (one per bucket), never per prompt length or cursor position."""
    def chunk_prefill_step(params, tokens, state, chunk_start, last_idx):
        return T.chunked_prefill(params, cfg, tokens, state, chunk_start,
                                 last_idx)

    return chunk_prefill_step


def chunk_buckets(prefill_chunk: int) -> list[int]:
    """Chunk-shape buckets: powers of two up to ``prefill_chunk`` (plus
    ``prefill_chunk`` itself when it is not a power of two). The engine pads
    every chunk up to its bucket, so it compiles at most ``len(buckets)``
    prefill variants across ANY mix of prompt lengths."""
    if prefill_chunk < 1:
        raise ValueError("prefill_chunk must be >= 1 to bucket")
    buckets = []
    b = 1
    while b < prefill_chunk:
        buckets.append(b)
        b *= 2
    buckets.append(prefill_chunk)
    return buckets


def bucket_for(n_tokens: int, prefill_chunk: int) -> int:
    """Smallest bucket covering ``n_tokens`` (the padded chunk width)."""
    for b in chunk_buckets(prefill_chunk):
        if b >= n_tokens:
            return b
    raise ValueError(f"{n_tokens} tokens exceed prefill_chunk "
                     f"{prefill_chunk}")


def sample_logits(logits: jax.Array, key, temperature: float = 0.0,
                  top_k: int = 0, top_p: float = 0.0) -> jax.Array:
    """Next-token selection from [B, V] logits (shared by the step loop, the
    fused scan, and the serving engine): ``temperature <= 0`` is greedy
    argmax (key unused), otherwise temperature scaling with optional top-k
    truncation, nucleus (top-p) truncation, and a categorical draw.

    ``top_p`` in (0, 1) keeps the smallest set of tokens whose cumulative
    probability reaches ``top_p`` (the nucleus; the most-probable token is
    always kept) and renormalizes over it. 0 or >= 1 disables the filter.
    Applied after top-k, so ``top_k`` + ``top_p`` compose (vLLM-style)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    if top_k > 0 and top_k < scaled.shape[-1]:
        kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    if 0.0 < top_p < 1.0:
        desc = -jnp.sort(-scaled, axis=-1)               # descending logits
        probs = jax.nn.softmax(desc, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep while the mass BEFORE a token is < top_p: the first token is
        # always kept, and the token that crosses the threshold is included
        keep = (cum - probs) < top_p
        cutoff = jnp.min(jnp.where(keep, desc, jnp.inf), axis=-1,
                         keepdims=True)
        scaled = jnp.where(scaled < cutoff, -jnp.inf, scaled)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


def apply_eos(tok: jax.Array, done: jax.Array, eos_id: int | None):
    """EOS bookkeeping shared by the step loop and the fused scan: pin
    sequences that already finished to ``eos_id``, then fold this step's
    emissions into the done mask. No-op when ``eos_id`` is None."""
    if eos_id is None:
        return tok, done
    tok = jnp.where(done, eos_id, tok)
    return tok, jnp.logical_or(done, tok == eos_id)


def make_fused_decode(cfg: ModelConfig, n_steps: int, *,
                      temperature: float = 0.0, top_k: int = 0,
                      top_p: float = 0.0, eos_id: int | None = None,
                      gate_finished: bool = True):
    """Multi-token decode as ONE dispatch: a lax.scan over decode steps.

    Replaces the per-step Python loop (one jit dispatch + host round-trip per
    token) with a single compiled scan whose carry is (token, decode state,
    ok, PRNG key, done mask) — sampling happens inside the scan. Jit with
    ``donate_argnums=(2,)`` so the cache buffers are updated in place across
    the whole generation.

    ``temperature > 0`` enables temperature/top-k/top-p sampling: the returned
    function then takes a PRNG key as its 5th argument, split once per step
    inside the carry (one key in, n_steps independent draws out — no host
    round-trips). ``temperature <= 0`` keeps the greedy 4-argument signature.

    ``eos_id`` enables EOS early-stop semantics inside the scan: once a
    sequence emits ``eos_id`` every later slot is pinned to ``eos_id`` (the
    scan itself runs n_steps — a compiled scan has a static trip count — but
    finished sequences stop influencing the output).

    Returns fused(params, token [B], state, start_pos [B][, key])
        -> (tokens [B, n_steps] int32, final state, logits_finite [] bool).
    ``logits_finite`` is the AND of an all-finite check over EVERY step's
    logits, folded into the scan carry — one boolean rides along so callers
    (serve, CI smoke) can gate on a NaN at any step, not just the last,
    without a second dispatch or materializing [n_steps, B, V] logits.

    ``gate_finished`` (with an ``eos_id``): rows that already emitted EOS
    run the per-layer bodies gated on ``~done`` — zero-width work is not
    possible under jit, so their queries are masked to zero and every cache
    append / recurrent update is skipped (``decode_step``'s ``active``
    mask). Their ``seq_lens`` freeze, which is what lets the split-KV
    early-exit kernels stop streaming KV blocks for finished rows. Output
    tokens are unchanged (finished rows are pinned to ``eos_id`` either
    way); ``gate_finished=False`` keeps the old always-append behavior for
    the benchmark twin.
    """
    sampled = temperature > 0.0
    gated = gate_finished and eos_id is not None

    def fused_decode(params, token, state, start_pos, key=None):
        if sampled and key is None:
            raise ValueError("temperature > 0 needs a PRNG key argument")

        def body(carry, i):
            tok, st, ok, k, done = carry
            logits, st = T.decode_step(params, cfg, tok, st, start_pos + i,
                                       active=~done if gated else None)
            ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(logits)))
            if sampled:
                k, sub = jax.random.split(k)
                nxt = sample_logits(logits, sub, temperature, top_k, top_p)
            else:
                nxt = sample_logits(logits, None)
            nxt, done = apply_eos(nxt, done, eos_id)
            return (nxt, st, ok, k, done), nxt

        # a sequence whose incoming token is already EOS is born finished
        done0 = (token == eos_id) if eos_id is not None \
            else jnp.zeros(token.shape, bool)
        carry0 = (token, state, jnp.array(True), key if sampled else None,
                  done0)
        (_, state_out, ok, _, _), toks = jax.lax.scan(
            body, carry0, jnp.arange(n_steps, dtype=jnp.int32))
        return jnp.moveaxis(toks, 0, 1), state_out, ok

    return fused_decode


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input specs (no allocation — dry-run stand-ins)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def params_spec(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: T.init_model(jax.random.PRNGKey(0), cfg, dtype=dtype))


def state_spec(cfg: ModelConfig, batch: int, max_len: int):
    s = jax.eval_shape(lambda: T.init_decode_state(cfg, batch, max_len))
    # aux embeddings live in the state after prefill
    if cfg.n_aux_tokens:
        s = dict(s)
        s["aux"] = _sds((batch, cfg.n_aux_tokens, cfg.d_model), jnp.float32)
    return s


def input_specs(cfg: ModelConfig, shape: str, param_dtype=jnp.bfloat16):
    """Returns (step_kind, args tuple of ShapeDtypeStructs)."""
    seq, gb, kind = SHAPES[shape]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape}: {why}")
    params = params_spec(cfg, param_dtype)

    if kind == "train":
        opt = jax.eval_shape(init_adamw, params)
        batch = {"tokens": _sds((gb, seq), jnp.int32),
                 "labels": _sds((gb, seq), jnp.int32)}
        if cfg.n_aux_tokens:
            batch["aux_embed"] = _sds((gb, cfg.n_aux_tokens, cfg.d_model), jnp.float32)
        return "train", (params, opt, batch, _sds((), jnp.int32))

    if kind == "prefill":
        state = jax.eval_shape(lambda: T.init_decode_state(cfg, gb, seq))
        args = (params, _sds((gb, seq), jnp.int32), state)
        if cfg.n_aux_tokens:
            args = args + (_sds((gb, cfg.n_aux_tokens, cfg.d_model), jnp.float32),)
        return "prefill", args

    # decode: one new token against a cache of `seq`
    state = state_spec(cfg, gb, seq)
    return "decode", (params, _sds((gb,), jnp.int32), state, _sds((gb,), jnp.int32))


def step_fn_for(cfg: ModelConfig, kind: str, remat: bool = True):
    if kind == "train":
        return make_train_step(cfg, remat=remat)
    if kind == "prefill":
        return make_prefill_step(cfg)
    return make_decode_step(cfg)
