import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch mixtral-8x7b --shape decode_32k --mesh multipod --out out.json

Proves the distribution config is coherent without hardware: builds the
production mesh from placeholder host devices, lowers the appropriate step
function against ShapeDtypeStruct inputs (zero allocation), compiles it, and
reports memory analysis, cost analysis, and the per-collective byte counts
parsed from the partitioned HLO — the inputs to the §Roofline terms.
"""
import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _bytes_of_shape(text: str) -> int:
    """Sum byte sizes of all typed shapes in an HLO result type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind output bytes from partitioned HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s*(all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)", line)
        if not m:
            continue
        kind = m.group(2)
        # ignore -start/-done duplicates by counting only '-start' or plain
        if re.search(rf"{kind}-done", line):
            continue
        out[kind] += _bytes_of_shape(m.group(1))
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def _lower_and_compile(cfg, shape, mesh, remat, variant="baseline"):
    """One lowering pass. Returns (compiled, kind, timings)."""
    from repro.models import transformer as _T
    kind, args = ST.input_specs(cfg, shape)
    step = ST.step_fn_for(cfg, kind, remat=remat)
    ws = variant.startswith("serve_ws") and kind in ("decode", "prefill")
    if variant.endswith(("_local", "_smap")) and kind == "decode":
        _T.SHARD_CTX = {"mesh": mesh,
                        "dp": SH.dp_axes_for(args[1].shape[0], mesh),
                        "use_shard_map": variant.endswith("_smap")}
    else:
        _T.SHARD_CTX = None

    # --- shardings -----------------------------------------------------
    if kind == "train":
        params, opt, batch, stepc = args
        in_specs = (SH.param_pspecs(params, mesh), SH.param_pspecs(opt, mesh),
                    SH.batch_pspecs(batch, mesh), P())
        metrics_spec = jax.tree.map(
            lambda _: P(), jax.eval_shape(step, *args)[2])
        out_specs = (in_specs[0], in_specs[1], metrics_spec)
    elif kind == "prefill":
        params, tokens, state = args[:3]
        dpa = SH.dp_axes_for(tokens.shape[0], mesh)
        # prefill is flash-attention-heavy: replicate fallback (like train)
        in_specs = (SH.param_pspecs(params, mesh, weight_stationary=ws),
                    SH.batch_pspecs({"t": tokens}, mesh)["t"],
                    SH.state_pspecs(state, mesh, cfg))
        out_state = jax.eval_shape(step, *args)[1]
        out_specs = (P(dpa, None), SH.state_pspecs(out_state, mesh, cfg))
        if cfg.n_aux_tokens:
            in_specs = in_specs + (SH.batch_pspecs({"a": args[3]}, mesh)["a"],)
    else:  # decode
        params, token, state, pos = args
        dpa = SH.dp_axes_for(token.shape[0], mesh)
        in_specs = (SH.param_pspecs(params, mesh, weight_stationary=ws,
                                    attn_fallback="shard_dh"), P(dpa),
                    SH.state_pspecs(state, mesh, cfg), P(dpa))
        out_state = jax.eval_shape(step, *args)[1]
        out_specs = (P(dpa, None), SH.state_pspecs(out_state, mesh, cfg))

    in_named = SH.to_named(in_specs, mesh)
    out_named = SH.to_named(out_specs, mesh)
    t0 = time.time()
    try:
        with mesh:
            jitted = jax.jit(step, in_shardings=in_named, out_shardings=out_named)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
    finally:
        _T.SHARD_CTX = None
    return compiled, kind, {"lower_s": round(t_lower, 1),
                            "compile_s": round(t_compile, 1)}


def run_cell(arch: str, shape: str, mesh_kind: str, remat: bool = True,
             extra: dict | None = None, cost_pass: bool = True,
             variant: str = "baseline") -> dict:
    """variant: 'baseline' (FSDP x TP everywhere) or 'serve_ws'
    (weight-stationary DP x TP for serving kinds — §Perf hillclimb)."""
    cfg = get_config(arch)
    if extra:
        cfg = cfg.scaled(**extra)
    ok, why = ST.shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))

    # Pass 1 — production lowering (scan-over-layers): the compile proof and
    # the memory analysis. cost_analysis here UNDERCOUNTS while-loop bodies
    # (counted once), so FLOP/byte/collective totals come from pass 2.
    compiled, kind, times = _lower_and_compile(cfg, shape, mesh, remat, variant)
    mem = compiled.memory_analysis()

    result = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "kind": kind,
        "variant": variant,
        "status": "ok",
        "n_chips": int(mesh.devices.size),
        **times,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "kv_fmt": cfg.kv_fmt,
    }

    # Pass 2 — cost-exact lowering (unrolled layer/flash scans), GLOBAL logical
    # FLOPs/bytes via lowered.cost_analysis() — no compile, no sharding, exact
    # (validated against 6ND analytics in EXPERIMENTS.md §Dry-run).
    if cost_pass:
        cfg_exact = cfg.scaled(cost_exact=True)
        kind2, args2 = ST.input_specs(cfg_exact, shape)
        step2 = ST.step_fn_for(cfg_exact, kind2, remat=remat)
        lowered2 = jax.jit(step2).lower(*args2)
        cost = lowered2.cost_analysis() or {}
        result.update({
            "flops_global": cost.get("flops", 0.0),
            "bytes_global_unfused": cost.get("bytes accessed", 0.0),
            "flops": cost.get("flops", 0.0) / result["n_chips"],
            "cost_pass": {"exact": True, "method": "lowered-global/chips",
                          "caveat": "slstm sequential scans still counted once"},
        })

        # Pass 3 — collective bytes: compile cost-exact at two reduced depths
        # and extrapolate linearly in superblock count (collectives are
        # per-layer homogeneous; scan-free so nothing is undercounted).
        try:
            result["collectives"] = _extrapolated_collectives(
                cfg, shape, mesh, remat, variant)
        except Exception as e:     # pragma: no cover - diagnostic path
            result["collectives"] = {"error": f"{type(e).__name__}: {e}",
                                     "total_bytes": 0}
    else:
        cost = compiled.cost_analysis() or {}
        coll = collective_bytes(compiled.as_text())
        result.update({
            "flops": cost.get("flops", 0.0),
            "collectives": coll,
            "cost_pass": {"exact": False,
                          "caveat": "scan bodies counted once; use pod-mesh "
                                    "cost-exact numbers for roofline"},
        })
    return result


def _reduced_cfg(cfg, k: int):
    """Same family at k superblocks (+ original remainder)."""
    r = len(cfg.remainder_kinds)
    extra = {}
    if cfg.encoder_layers:
        extra["encoder_layers"] = max(1, round(
            cfg.encoder_layers * k / max(cfg.n_superblocks, 1)))
    return cfg.scaled(n_layers=k * cfg.pattern_len + r, cost_exact=True, **extra)


def _extrapolated_collectives(cfg, shape, mesh, remat, variant="baseline") -> dict:
    """Fit coll(k) = c0 + c1*k over k in {1, 2} and evaluate at full depth."""
    k_full = cfg.n_superblocks
    if k_full <= 2:
        compiled, _, _ = _lower_and_compile(cfg.scaled(cost_exact=True),
                                            shape, mesh, remat, variant)
        out = collective_bytes(compiled.as_text())
        out["method"] = "direct-cost-exact-compile"
        return out
    samples = {}
    for k in (1, 2):
        compiled, _, _ = _lower_and_compile(_reduced_cfg(cfg, k), shape, mesh,
                                            remat, variant)
        samples[k] = collective_bytes(compiled.as_text())
    bytes_full, counts_full = {}, {}
    for key in _COLLECTIVES:
        c1 = samples[2]["bytes"][key] - samples[1]["bytes"][key]
        c0 = samples[1]["bytes"][key] - c1
        bytes_full[key] = max(0, int(c0 + c1 * k_full))
        n1 = samples[2]["counts"][key] - samples[1]["counts"][key]
        n0 = samples[1]["counts"][key] - n1
        counts_full[key] = max(0, int(n0 + n1 * k_full))
    return {"bytes": bytes_full, "counts": counts_full,
            "total_bytes": sum(bytes_full.values()),
            "method": "linear-extrapolation-k1-k2",
            "samples": {str(k): v["total_bytes"] for k, v in samples.items()}}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(ST.SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-cost-pass", action="store_true",
                    help="skip the unrolled cost-exact second lowering")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    res = run_cell(args.arch, args.shape, args.mesh, remat=not args.no_remat,
                   cost_pass=not args.no_cost_pass)
    print(json.dumps(res, indent=1, default=str))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1, default=str)
    return 0 if res["status"] in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
