"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state. The dry-run entry point is the ONLY place
that forces 512 host platform devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel), ("data", "model"))


def data_axis_names(mesh) -> tuple[str, ...]:
    """Axes carrying the batch/FSDP dimension ('pod' folds into data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1
