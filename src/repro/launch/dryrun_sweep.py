import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).

"""Dry-run sweep driver: every (architecture x shape x mesh) cell.

Each cell runs in a fresh subprocess (compile memory isolation + parallelism)
via ``python -m repro.launch.dryrun``; results land as JSON in --out-dir and
are aggregated into sweep.json, which benchmarks/roofline.py consumes.

    PYTHONPATH=src python -m repro.launch.dryrun_sweep \
        --out-dir results/dryrun --jobs 4 [--mesh pod multipod]
"""
import argparse
import json
import pathlib
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor

ARCHS_DEFAULT = [
    "llama-3.2-vision-90b", "llama3.2-3b", "gemma3-27b", "qwen2.5-3b",
    "granite-3-2b", "qwen3-moe-30b-a3b", "mixtral-8x7b", "recurrentgemma-9b",
    "whisper-base", "xlstm-1.3b", "deepseek-v3-mla", "mla-7b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def run_one(arch, shape, mesh, out_dir, timeout, cost=False):
    out = pathlib.Path(out_dir) / f"{arch}__{shape}__{mesh}.json"
    if out.exists():
        try:
            r = json.loads(out.read_text())
            done = r.get("status") in ("ok", "skipped")
            if done and cost and r.get("status") == "ok":
                done = bool(r.get("cost_pass", {}).get("exact"))
            if done:
                return arch, shape, mesh, r.get("status"), "cached"
        except json.JSONDecodeError:
            pass
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", str(out)]
    if not cost:
        # wave 1: compile proof only; cost-exact numbers for the roofline
        # table come from the single-pod wave 2.
        cmd.append("--no-cost-pass")
    t0 = time.time()
    try:
        p = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                           env={**os.environ, "PYTHONPATH": "src"})
        if p.returncode != 0:
            out.write_text(json.dumps({
                "arch": arch, "shape": shape, "mesh": mesh, "status": "error",
                "stderr": p.stderr[-3000:]}))
            return arch, shape, mesh, "error", p.stderr.strip().splitlines()[-1][:120] if p.stderr.strip() else "?"
        return arch, shape, mesh, "ok", f"{time.time()-t0:.0f}s"
    except subprocess.TimeoutExpired:
        out.write_text(json.dumps({"arch": arch, "shape": shape, "mesh": mesh,
                                   "status": "timeout"}))
        return arch, shape, mesh, "timeout", f">{timeout}s"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--mesh", nargs="+", default=["pod", "multipod"])
    ap.add_argument("--archs", nargs="+", default=ARCHS_DEFAULT)
    ap.add_argument("--shapes", nargs="+", default=SHAPES)
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    cells = [(a, s, m) for a in args.archs for s in args.shapes for m in args.mesh]

    with ThreadPoolExecutor(max_workers=args.jobs) as ex:
        # wave 1: compile proof for every cell (the dry-run deliverable)
        futs = [ex.submit(run_one, a, s, m, out_dir, args.timeout, False)
                for a, s, m in cells]
        for f in futs:
            a, s, m, st, msg = f.result()
            print(f"wave1 {a:24s} {s:12s} {m:8s} {st:8s} {msg}", flush=True)
        # wave 2: cost-exact roofline numbers, single-pod cells only
        futs = [ex.submit(run_one, a, s, m, out_dir, args.timeout, True)
                for a, s, m in cells if m == "pod"]
        for f in futs:
            a, s, m, st, msg = f.result()
            print(f"wave2 {a:24s} {s:12s} {m:8s} {st:8s} {msg}", flush=True)

    # aggregate
    agg = []
    for p in sorted(out_dir.glob("*.json")):
        if p.name == "sweep.json":
            continue
        try:
            agg.append(json.loads(p.read_text()))
        except json.JSONDecodeError:
            pass
    (out_dir / "sweep.json").write_text(json.dumps(agg, indent=1))
    n_ok = sum(1 for r in agg if r.get("status") == "ok")
    n_skip = sum(1 for r in agg if r.get("status") == "skipped")
    n_bad = len(agg) - n_ok - n_skip
    print(f"\nsweep: {n_ok} ok, {n_skip} skipped, {n_bad} failed "
          f"-> {out_dir/'sweep.json'}")


if __name__ == "__main__":
    main()
