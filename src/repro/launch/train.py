"""Production training launcher: sharded train loop with checkpoint/restart,
preemption handling, straggler detection, and optional gradient compression.

CPU-scale usage (runs a real multi-step training on the host mesh):

    PYTHONPATH=src python -m repro.launch.train \
        --arch mla-7b --smoke --steps 20 --ckpt-dir /tmp/ckpt --ckpt-every 10

On a real cluster the same loop runs under the production mesh (mesh.py); the
data pipeline, checkpoint format, and step functions are mesh-independent.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.checkpoint.checkpoint import (latest_checkpoint, load_checkpoint,
                                         save_checkpoint)
from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, synth_batch
from repro.launch import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, init_adamw
from repro.runtime.fault_tolerance import PreemptionHandler
from repro.runtime.straggler import StragglerConfig, StragglerDetector


def train_loop(cfg, *, steps: int, batch: int, seq: int, ckpt_dir: str | None,
               ckpt_every: int = 50, mesh=None, preemption: PreemptionHandler | None = None,
               seed: int = 0, log_every: int = 5, lr: float = 3e-4) -> dict:
    mesh = mesh or make_host_mesh(1)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                          global_batch=batch, seed=seed,
                          n_aux_tokens=cfg.n_aux_tokens, d_model=cfg.d_model)
    step_fn = ST.make_train_step(cfg, AdamWConfig(lr=lr),
                                 warmup_steps=max(2, steps // 10),
                                 total_steps=steps)

    params = T.init_model(jax.random.PRNGKey(seed), cfg)
    opt = init_adamw(params)
    start_step = 0
    if ckpt_dir:
        latest = latest_checkpoint(ckpt_dir)
        if latest:
            (params, opt), manifest = load_checkpoint(
                latest, (params, opt),
                (SH.to_named(SH.param_pspecs(params, mesh), mesh),
                 SH.to_named(SH.param_pspecs(opt, mesh), mesh)))
            start_step = manifest["step"]
            print(f"[train] resumed from {latest} at step {start_step}")

    in_specs = (SH.param_pspecs(params, mesh), SH.param_pspecs(opt, mesh),
                SH.batch_pspecs(jax.eval_shape(lambda: synth_batch(data_cfg, 0)), mesh),
                P())
    metrics_shape = jax.eval_shape(step_fn, params, opt,
                                   synth_batch(data_cfg, 0), jnp.int32(0))[2]
    out_specs = (in_specs[0], in_specs[1], jax.tree.map(lambda _: P(), metrics_shape))

    with mesh:
        jitted = jax.jit(step_fn, in_shardings=SH.to_named(in_specs, mesh),
                         out_shardings=SH.to_named(out_specs, mesh),
                         donate_argnums=(0, 1))
        detector = StragglerDetector(StragglerConfig(), n_hosts=1)
        losses = []
        status = "done"
        for step in range(start_step, steps):
            t0 = time.time()
            batch_data = synth_batch(data_cfg, step)
            params, opt, metrics = jitted(params, opt, batch_data, jnp.int32(step))
            loss = float(metrics["loss"])
            losses.append(loss)
            detector.update(np.array([time.time() - t0]))
            if step % log_every == 0:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({time.time()-t0:.2f}s)")
            should_ckpt = ckpt_dir and ((step + 1) % ckpt_every == 0)
            if preemption and preemption.requested:
                status = "preempted"
                should_ckpt = bool(ckpt_dir)
            if should_ckpt:
                path = save_checkpoint(ckpt_dir, step + 1, (params, opt),
                                       {"arch": cfg.name, "seed": seed,
                                        "data_cursor": step + 1})
                print(f"[train] checkpointed -> {path}")
            if status == "preempted":
                break
    return {"status": status, "losses": losses, "final_step": step + 1,
            "params": params, "flagged_stragglers": detector.flagged}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mla-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    handler = PreemptionHandler()
    out = train_loop(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     preemption=handler, lr=args.lr)
    print(f"[train] {out['status']} at step {out['final_step']}; "
          f"loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
