"""Serving launcher: batched prefill + decode with the SnapMLA FP8 KV cache.

CPU-scale usage (real generation on the host mesh, greedy sampling):

    PYTHONPATH=src python -m repro.launch.serve \
        --arch mla-7b --smoke --batch 4 --prompt-len 32 --gen 16 --fmt fp8_e4m3

This is deliverable (b)'s end-to-end serving driver: it exercises prefill
(bulk RoPE-aware per-token quantization into the cache), then the quantized
decode pipeline per step, and reports decode throughput + agreement with the
BF16 baseline.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.core.kvcache import page_aligned_capacity
from repro.launch import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T


def _check_finite(ok, where: str) -> None:
    """Loud NaN gate: serving must never emit non-finite logits — a NaN here
    means the quantized decode pipeline (or a kernel change behind it) broke,
    so fail the process rather than generate garbage tokens. ``ok`` is either
    raw logits or an already-reduced boolean (the fused scan's every-step
    flag); both generation paths cover every decode step."""
    if not bool(jnp.all(jnp.isfinite(ok) if ok.ndim else ok)):
        raise SystemExit(f"[serve] FATAL: non-finite logits at {where}")


def _decode_capacity(cfg, prompt_len: int, gen_steps: int) -> int:
    """Exact page-aligned cache capacity for prompt + generation.

    Prefill writes ``prompt_len`` entries and each decode step appends one;
    the last decode step (gen_steps-1 appends after the prefill token) needs
    ``prompt_len + gen_steps - 1`` slots, so ``prompt_len + gen_steps``
    rounded to the page is always enough — the former
    ``S + gen + page_size`` sizing over-allocated a whole page whenever the
    sum was already aligned."""
    return page_aligned_capacity(prompt_len + gen_steps, cfg.page_size)


def generate(cfg, params, prompts: jax.Array, gen_steps: int, mesh=None,
             aux_embed=None, temperature: float = 0.0, top_k: int = 0,
             top_p: float = 0.0, eos_id: int | None = None, seed: int = 0):
    """prompts [B, S] -> (generated tokens [B, gen_steps], decode tok/s).

    Per-step decode loop. ``temperature``/``top_k``/``top_p`` switch greedy
    argmax to sampling (one fold_in per step of a single PRNG key, nucleus
    truncation after top-k); ``eos_id`` stops
    the loop early once EVERY sequence has emitted it (finished sequences
    are padded with ``eos_id``). Note the early-stop check is a per-step
    host sync — the price of actually ending the Python loop; the fused
    path handles EOS sync-free inside the scan."""
    mesh = mesh or make_host_mesh(1)
    B, S = prompts.shape
    max_len = _decode_capacity(cfg, S, gen_steps)
    prefill_fn = jax.jit(ST.make_prefill_step(cfg))
    decode_fn = jax.jit(ST.make_decode_step(cfg))
    key = jax.random.PRNGKey(seed)

    def pick(logits, i):
        # greedy (temperature <= 0) ignores the key inside sample_logits
        return ST.sample_logits(logits, jax.random.fold_in(key, i),
                                temperature, top_k, top_p)

    state = T.init_decode_state(cfg, B, max_len)
    logits, state = prefill_fn(params, prompts, state, *(
        (aux_embed,) if aux_embed is not None else ()))
    _check_finite(logits, "prefill")
    tok = pick(logits, 0)
    done = (tok == eos_id) if eos_id is not None \
        else jnp.zeros((B,), bool)

    outs = [tok]
    if gen_steps <= 1:
        return jnp.stack(outs, axis=1)[:, :gen_steps], 0.0
    # warm up decode compile before timing
    pos = jnp.full((B,), S, jnp.int32)
    logits, state = decode_fn(params, tok, state, pos)
    # every-step NaN gate, accumulated on device (no per-step host sync
    # unless EOS early stop is requested)
    ok = jnp.all(jnp.isfinite(logits))
    tok, done = ST.apply_eos(pick(logits, 1), done, eos_id)
    outs.append(tok)
    jax.block_until_ready(tok)

    steps_run = 0
    t0 = time.time()
    for i in range(1, gen_steps - 1):
        if eos_id is not None and bool(jnp.all(done)):
            break               # EOS early stop: every sequence finished
        pos = jnp.full((B,), S + i, jnp.int32)
        logits, state = decode_fn(params, tok, state, pos)
        ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(logits)))
        tok, done = ST.apply_eos(pick(logits, i + 1), done, eos_id)
        outs.append(tok)
        steps_run += 1
    jax.block_until_ready(tok)
    dt = time.time() - t0
    _check_finite(ok, "decode (any step)")
    while len(outs) < gen_steps:    # EOS-stopped early: pad to [B, gen_steps]
        outs.append(jnp.full((B,), eos_id, jnp.int32))
    # 0.0, not an absurd number, when EOS ended generation before the loop
    toks_per_s = B * steps_run / max(dt, 1e-9) if steps_run else 0.0
    return jnp.stack(outs, axis=1), toks_per_s


def generate_fused(cfg, params, prompts: jax.Array, gen_steps: int, mesh=None,
                   aux_embed=None, temperature: float = 0.0, top_k: int = 0,
                   top_p: float = 0.0, eos_id: int | None = None,
                   seed: int = 0):
    """Scan-based generation: prefill + ONE fused decode dispatch.

    Token-exact with ``generate`` under greedy decoding (same decode_step
    inside a lax.scan) but the whole multi-token decode is a single compiled
    program — no per-step dispatch/host round-trip — with the decode state
    (quantized KV caches) donated so XLA updates the cache buffers in place.
    ``temperature``/``top_k``/``top_p`` sample inside the scan (PRNG key
    threaded through the carry); ``eos_id`` pins finished sequences to
    ``eos_id``.

    Returns (generated tokens [B, gen_steps], decode tok/s).
    """
    mesh = mesh or make_host_mesh(1)
    B, S = prompts.shape
    max_len = _decode_capacity(cfg, S, gen_steps)
    sampled = temperature > 0.0
    key = jax.random.PRNGKey(seed)
    prefill_fn = jax.jit(ST.make_prefill_step(cfg))
    fused_fn = jax.jit(
        ST.make_fused_decode(cfg, max(gen_steps - 1, 0),
                             temperature=temperature, top_k=top_k,
                             top_p=top_p, eos_id=eos_id),
        donate_argnums=(2,))

    state = T.init_decode_state(cfg, B, max_len)
    logits, state = prefill_fn(params, prompts, state, *(
        (aux_embed,) if aux_embed is not None else ()))
    _check_finite(logits, "prefill")
    tok = ST.sample_logits(logits, jax.random.fold_in(key, 0),
                           temperature, top_k, top_p)
    if gen_steps <= 1:
        return tok[:, None][:, :gen_steps], 0.0

    start_pos = jnp.full((B,), S, jnp.int32)
    args = (params, tok, state, start_pos) + (
        (jax.random.fold_in(key, 1),) if sampled else ())
    # AOT-compile before timing (donation happens at execution, not lowering)
    compiled = fused_fn.lower(*args).compile()
    jax.block_until_ready((tok, state))
    t0 = time.time()
    toks, _state, ok = compiled(*args)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    _check_finite(ok, "fused decode (any step)")
    toks_per_s = B * (gen_steps - 1) / max(dt, 1e-9)
    return jnp.concatenate([tok[:, None], toks], axis=1), toks_per_s


def _engine_prompts(cfg, key, args) -> list[np.ndarray]:
    """Per-request prompts for ``serve --engine``: ``--prompt-lens`` (comma
    list, cycled over ``--batch`` requests) yields a MIXED long+short
    workload — the regime chunked prefill exists for; otherwise every
    request gets a ``--prompt-len`` prompt. ``--shared-prefix N`` makes the
    first N tokens identical across requests (the shared-system-prompt
    traffic shape the radix prefix cache exists for)."""
    if args.prompt_lens:
        lens = [int(s) for s in args.prompt_lens.split(",")]
        lens = [lens[i % len(lens)] for i in range(args.batch)]
    else:
        lens = [args.prompt_len] * args.batch
    shared = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 2**31 - 1), (max(args.shared_prefix, 0),), 0,
        cfg.vocab_size, jnp.int32))
    prompts = []
    for i, n in enumerate(lens):
        p = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (n,), 0, cfg.vocab_size, jnp.int32))
        k = min(len(shared), n)
        if k:
            p = p.copy()
            p[:k] = shared[:k]
        prompts.append(p)
    return prompts


def _make_logger(log_json: bool):
    """Engine-mode event logging: the default is the human-readable
    ``[serve]`` lines; ``--log-json`` swaps every one for a single-line JSON
    object (``{"event": ..., ...}``) a log pipeline can parse without
    regexes. ``text`` is the legacy rendering, ``fields`` the structured
    payload."""
    def log(event: str, text: str, **fields) -> None:
        if log_json:
            print(json.dumps({"event": event, **fields}, sort_keys=True,
                             default=float))
        else:
            print(text)
    return log


def run_engine(cfg, params, args) -> None:
    """``serve --engine``: the continuous-batching engine over the shared
    paged pool, with the static-batch ``generate`` path as the greedy parity
    oracle (per prompt-length group when ``--prompt-lens`` mixes lengths).
    Arrivals are staggered every ``--arrival-gap`` engine steps so the run
    exercises admission/retirement churn; ``--prefill-chunk`` switches
    admission to budgeted chunked prefill. Exits non-zero on token mismatch
    (greedy) or leaked pages, so CI can gate on it.

    Fault drills: ``--inject kind:step[:slot][:sticky]`` threads a
    deterministic ``FaultPlan`` through the engine (NaN quarantine + jnp_ref
    retry, forced pool exhaustion, backend raise, preemption);
    ``--restartable`` wraps the run in ``run_with_restarts`` + a
    ``PreemptionHandler`` with periodic snapshots to ``--ckpt-dir``, so an
    (injected or real SIGTERM) preemption restarts and restores from the
    latest checkpoint — CI gates that the survivors complete, match the
    greedy oracle, and drain every page."""
    from repro.checkpoint import checkpoint as CK
    from repro.obs import SpanTracer, validate_chrome_trace
    from repro.runtime.fault_tolerance import (PreemptionHandler,
                                               RestartPolicy,
                                               run_with_restarts)
    from repro.serving import (EngineConfig, FaultPlan, Request,
                               ServingEngine)

    log = _make_logger(args.log_json)
    tracer = SpanTracer(clock=args.trace_clock) if args.trace_out else None
    key = jax.random.PRNGKey(args.seed)
    prompts = _engine_prompts(cfg, key, args)
    span_pages = page_aligned_capacity(
        max(len(p) for p in prompts) + args.gen, cfg.page_size) \
        // cfg.page_size
    cfg = dataclasses.replace(cfg, prefill_chunk=args.prefill_chunk)
    ecfg = EngineConfig(
        max_batch=args.max_batch or len(prompts),
        max_pages_per_seq=span_pages,
        n_pages=args.pool_pages,
        prefix_sharing=not args.no_prefix_share,
        prefix_cache_pages=args.prefix_cache_pages,
        host_tier_pages=args.host_tier_pages,
        prefill_budget=args.prefill_budget,
        max_queue=args.max_queue,
        temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
        eos_id=args.eos_id, seed=args.seed,
        quant_health_every=args.quant_health_every,
        spec_draft_len=args.spec_draft)
    plan = FaultPlan.parse(args.inject) if args.inject else None
    reqs = [Request(rid=i, prompt=p, max_new=args.gen,
                    arrival=float(i * args.arrival_gap),
                    ttft_deadline=args.ttft_deadline or None,
                    deadline=args.deadline or None)
            for i, p in enumerate(prompts)]

    if args.restartable:
        import tempfile
        ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="serve_ckpt_")
        handler = PreemptionHandler(install=not args.inject)
        out: dict = {}

        def attempt() -> str:
            # every attempt starts from the LATEST snapshot (none on the
            # first): the engine skips requests it has already seen, so
            # resubmitting the whole workload is idempotent
            handler.reset()
            engine = ServingEngine(cfg, params, ecfg, fault_plan=plan,
                                   preemption=handler, tracer=tracer)
            latest = CK.latest_checkpoint(ckpt_dir)
            if latest:
                engine.restore(latest)
            out["engine"] = engine
            out["results"] = engine.run(reqs, ckpt_dir=ckpt_dir,
                                        ckpt_every=args.ckpt_every)
            return "done"

        run_with_restarts(
            attempt, RestartPolicy(max_restarts=3),
            on_restart=lambda n: log(
                "engine_restart",
                f"[serve] engine restart #{n} (restoring from {ckpt_dir})",
                restart=n, ckpt_dir=ckpt_dir))
        handler.restore()
        engine, results = out["engine"], out["results"]
    else:
        engine = ServingEngine(cfg, params, ecfg, fault_plan=plan,
                               preemption=None, tracer=tracer)
        results = engine.run(reqs)
    m = engine.metrics()
    n_done = sum(1 for r in results if r.status == "done")
    log("engine_summary",
        f"[serve] engine: {len(results)} requests over "
        f"{ecfg.max_batch} slots, {m['steps']} steps, "
        f"{m['wall']['decode_tok_per_s']:.1f} tok/s (decode), "
        f"prefill {m['prefill']['mode']} "
        f"(chunk={m['prefill']['chunk']}, "
        f"traces={m['prefill']['traces']}), "
        f"pages peak {m['pages']['peak_in_use']}/{m['pages']['capacity']} "
        f"(saved by sharing: {m['pages']['saved_by_sharing']}), "
        f"evictions: {m['evictions']} "
        f"(requeued: {m['requeues']})",
        requests=len(results), slots=ecfg.max_batch, steps=m["steps"],
        decode_tok_per_s=m["wall"]["decode_tok_per_s"],
        prefill_mode=m["prefill"]["mode"], chunk=m["prefill"]["chunk"],
        prefill_traces=m["prefill"]["traces"],
        pages_peak=m["pages"]["peak_in_use"],
        pages_capacity=m["pages"]["capacity"],
        saved_by_sharing=m["pages"]["saved_by_sharing"],
        evictions=m["evictions"], requeues=m["requeues"],
        roofline=m["roofline"])
    f = m["faults"]
    if plan or args.restartable or f["rejected"] or f["deadline_cancelled"]:
        log("engine_faults",
            f"[serve] faults: injected={len(f['injected'])} "
            f"quarantined={f['nonfinite_rows']} "
            f"(recovered via jnp_ref: {f['recovered_ref']}, "
            f"failed: {f['failed_nonfinite']}), "
            f"backend faults={f['backend_faults']}, "
            f"deadline cancels={f['deadline_cancelled']}, "
            f"rejected={f['rejected']}, "
            f"preemptions={f['preemptions']}, "
            f"restores={f['restores']} -> "
            f"{n_done}/{len(results)} completed",
            completed=n_done, total=len(results),
            **{k: v for k, v in f.items() if k != "injected"},
            injected=len(f["injected"]))
    sp = m["speculative"]
    if sp["enabled"]:
        log("spec_decode",
            f"[serve] speculative: draft_len={sp['draft_len']}, "
            f"{sp['verify_steps']} verify steps, "
            f"drafted {sp['drafted_tokens']} / accepted "
            f"{sp['accepted_tokens']} "
            f"(accept rate {sp['accept_rate']:.3f}), "
            f"{sp['accepted_tokens_per_step']:.3f} tokens/slot-step",
            **{k: v for k, v in sp.items()})
    pc = m["prefix_cache"]
    if pc["budget_pages"] or pc["host_tier_pages"]:
        log("prefix_cache",
            f"[serve] prefix cache: {pc['cached']} pages retained "
            f"(budget {pc['budget_pages']}), reused {pc['reused_cached']}, "
            f"restored from host {pc['restored_host']} "
            f"(offloads {pc['offloads']}, tier "
            f"{pc['host_used']}/{pc['host_tier_pages']}), "
            f"prefill tokens skipped {pc['prefill_skipped_tokens']}, "
            f"HBM high-water {pc['peak_resident']} pages",
            **{k: v for k, v in pc.items()})
    if engine.quant_probe is not None and engine.quant_probe.samples:
        last = engine.quant_probe.samples[-1]
        log("quant_health",
            f"[serve] quant health ({cfg.kv_fmt}, every "
            f"{args.quant_health_every} steps, "
            f"{len(engine.quant_probe.samples)} samples): scale "
            f"[{last['scale_min']:.3g}, {last['scale_max']:.3g}], "
            f"clip rate max {last['clip_rate_max']:.3g}, sink err bound "
            f"{last['sink_err_bound_max']:.3g}",
            fmt=cfg.kv_fmt, every=args.quant_health_every,
            samples=len(engine.quant_probe.samples), **last)
    if tracer is not None:
        tracer.write(args.trace_out)
        stats = validate_chrome_trace(
            json.load(open(args.trace_out)), expect_requests=len(reqs))
        log("trace_written",
            f"[serve] trace: {args.trace_out} ({stats['events']} events, "
            f"{stats['requests']} request tracks, {stats['spans']} spans; "
            f"clock={tracer.clock})",
            path=args.trace_out, clock=tracer.clock, **stats)
    # drained means every page is FREE or a retained (refcount-0) cache page
    if m["pages"]["free"] + m["pages"]["cached"] != m["pages"]["capacity"]:
        raise SystemExit("[serve] FATAL: engine drained but pages leaked "
                         f"({m['pages']['free']} free + "
                         f"{m['pages']['cached']} cached != "
                         f"{m['pages']['capacity']} capacity)")
    if (plan or args.restartable) and n_done == 0:
        raise SystemExit("[serve] FATAL: fault drill left zero completed "
                         "requests")
    if args.prefill_chunk > 0:
        n_buckets = len(ST.chunk_buckets(args.prefill_chunk))
        if m["prefill"]["traces"] > n_buckets:
            raise SystemExit(
                "[serve] FATAL: chunked prefill compiled "
                f"{m['prefill']['traces']} variants > {n_buckets} buckets")
    if args.temperature <= 0 and m["requeues"] == 0:
        # greedy parity oracle: completed requests must be token-identical
        # to the static-batch generate path for the same prompts/gen
        # lengths — run per prompt-length group so mixed-length workloads
        # are covered. FAILED/REJECTED results are excluded (a recovered
        # quarantine still matches: the jnp_ref retry is the oracle's own
        # numerics), so this doubles as the isolation gate: survivors of a
        # fault drill must be unaffected by the poisoned slot.
        by_len: dict[int, list[int]] = {}
        for i, p in enumerate(prompts):
            by_len.setdefault(len(p), []).append(i)
        ref: dict[int, list[int]] = {}
        for rids in by_len.values():
            batch = jnp.asarray(np.stack([prompts[i] for i in rids]))
            toks_ref, _ = generate(cfg, params, batch, args.gen,
                                   eos_id=args.eos_id, seed=args.seed)
            for row, rid in zip(np.asarray(toks_ref), rids):
                ref[rid] = list(row)
        # EOS-stopped requests are a prefix of the (eos-padded) oracle row
        bad = [r.rid for r in results if r.status == "done"
               and r.tokens != ref[r.rid][:len(r.tokens)]]
        if bad:
            raise SystemExit("[serve] FATAL: engine tokens diverge from the "
                             f"static-batch generate oracle for {bad}")
        log("engine_parity",
            f"[serve] engine parity vs static-batch generate: exact "
            f"({n_done} completed requests)",
            parity="exact", completed=n_done)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mla-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--fmt", default="fp8_e4m3",
                    choices=["fp8_e4m3", "int8", "none"])
    ap.add_argument("--fused", action="store_true",
                    help="scan-based generate_fused (one dispatch) instead of "
                         "the per-step decode loop")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "ref", "kernel", "shard-map"],
                    help="decode-attention backend "
                         "(kernels/mla_decode/backends.py): 'ref' = pure-jnp "
                         "einsum twins (pjit-friendly), 'kernel' = the Pallas "
                         "split-KV kernels inside the jitted decode step "
                         "(interpret on CPU, compiled on TPU; paged caches "
                         "use the scalar-prefetched page-table kernel), "
                         "'shard-map' = collective-free shard_map region "
                         "over the host (data, model) mesh (contiguous "
                         "caches; batch must divide the data axis), 'auto' = "
                         "ref unless a mesh/kernels flag says otherwise")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (> 0 switches greedy argmax "
                         "to temperature/top-k sampling, PRNG key threaded "
                         "through the fused scan carry)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation for sampling (0 = full softmax)")
    ap.add_argument("--top-p", type=float, default=0.0,
                    help="nucleus sampling: keep the smallest token set with "
                         "cumulative probability >= top-p, applied after "
                         "top-k (0 or >= 1 disables; needs --temperature)")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="EOS token id: generation early-stops (step loop) / "
                         "pins finished sequences (fused scan) once emitted")
    ap.add_argument("--kv-splits", type=int, default=0,
                    help="split-KV (flash-decoding) splits for decode "
                         "attention, contiguous AND paged caches "
                         "(0 = auto: measured split profile if present, else "
                         "the context-length heuristic; 1 = single-pass)")
    ap.add_argument("--block-n", type=int, default=0,
                    help="decode-attention KV block size (0 = page size). "
                         "Contiguous caches take any divisor of the cache "
                         "capacity; with --paged the block size is "
                         "structurally the physical page, so this sets the "
                         "page size itself")
    ap.add_argument("--sink-tokens", type=int, default=0,
                    help="P-Cast sink guard: keep the first k tokens' latent "
                         "KV rows in full precision (attention sinks are the "
                         "most quantization-sensitive rows). Contiguous MLA "
                         "caches only; 0 disables")
    ap.add_argument("--rescale", default="fma", choices=["fma", "amla"],
                    help="per-block accumulator rescale in the decode "
                         "kernels: fma = exact max-shift FMA (default), "
                         "amla = AMLA exponent-add fast path (power-of-two "
                         "sigma_p grid, combine-free split-KV partials; "
                         "differs from fma only at quantization-rounding "
                         "level)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache for MLA layers: latent entries live "
                         "in a page pool addressed through per-sequence page "
                         "tables (multi-tenant pool layout) instead of a "
                         "contiguous per-slot cache")
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching serving engine (serving/): "
                         "multi-tenant free-list page allocator with "
                         "prefix sharing over one shared paged pool, FCFS "
                         "slot scheduler, and the jitted decode step over "
                         "staggered arrivals — greedy runs are gated "
                         "against the static-batch generate oracle")
    ap.add_argument("--max-batch", type=int, default=0,
                    help="engine decode slots (0 = one per request)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="engine chunked prefill: split prompt admission "
                         "into chunks of this many tokens, run alongside "
                         "the slot-batched decode each engine step (later "
                         "chunks attend the FP8-quantized prefix pages "
                         "through the fused fetch-dequant path); chunk "
                         "shapes are bucketed to powers of two so compiles "
                         "stay O(log chunk). 0 = monolithic one-shot "
                         "prefill")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="max prefill tokens per engine step under "
                         "--prefill-chunk (granted one chunk per PREFILLING "
                         "request per FCFS round-robin pass; the head "
                         "always gets one chunk). 0 = one chunk per "
                         "prefilling request per step")
    ap.add_argument("--prompt-lens", default="",
                    help="engine-only: comma list of prompt lengths cycled "
                         "across --batch requests (mixed long+short "
                         "workload), overriding --prompt-len")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="engine pool size in physical pages (0 = auto: "
                         "max_batch full-span sequences + the scratch page)")
    ap.add_argument("--arrival-gap", type=int, default=1,
                    help="engine virtual steps between request arrivals")
    ap.add_argument("--no-prefix-share", action="store_true",
                    help="disable the engine's refcounted prefix sharing")
    ap.add_argument("--prefix-cache-pages", type=int, default=0,
                    help="engine radix prefix cache: retain up to this many "
                         "refcount-0 prefix pages in HBM for reuse across "
                         "requests (LRU-evicted under pressure; 0 = off, "
                         "pages are recycled at refcount-0 exactly as "
                         "before)")
    ap.add_argument("--host-tier-pages", type=int, default=0,
                    help="host-memory KV tier: LRU-evicted prefix-cache "
                         "pages offload to this many host slots instead of "
                         "being dropped, and re-admit via async device_put "
                         "restore (requires --prefix-cache-pages > 0; "
                         "0 = off)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="engine workload shaping: first N tokens identical "
                         "across every request (the shared-system-prompt "
                         "traffic the prefix cache serves; 0 = fully random "
                         "prompts)")
    ap.add_argument("--spec-draft", type=int, default=0,
                    help="engine-only: self-speculative decoding — host-side "
                         "n-gram proposer drafts up to this many tokens per "
                         "slot per step, verified in ONE q_len>1 split-KV "
                         "dispatch; the longest accepted prefix commits and "
                         "rejected tail positions are rolled back by rewind "
                         "(seq_lens never advance past committed tokens — "
                         "pages never move). Greedy output is token-identical "
                         "to non-speculative decoding (the parity oracle "
                         "still gates it). 0 = off")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="engine admission-queue bound: a submit that finds "
                         "this many requests already queued is load-shed "
                         "with a typed REJECTED result (0 = unbounded)")
    ap.add_argument("--ttft-deadline", type=int, default=0,
                    help="engine TTFT deadline in virtual steps from "
                         "arrival: requests still waiting for their first "
                         "token past it are cancelled FAILED('deadline') "
                         "(0 = none)")
    ap.add_argument("--deadline", type=int, default=0,
                    help="engine total-latency deadline in virtual steps "
                         "from arrival; blown requests become the preferred "
                         "eviction victim and are cancelled, freeing pages "
                         "mid-decode (0 = none)")
    ap.add_argument("--inject", action="append", default=[],
                    metavar="KIND:STEP[:SLOT][:sticky]",
                    help="engine fault injection (repeatable): "
                         "nan_logits:step:slot[:sticky] poisons a slot's "
                         "decode logits (sticky also poisons the jnp_ref "
                         "retry), alloc_fail:step[:count] forces pool "
                         "exhaustion, backend_raise:step raises from the "
                         "decode dispatch, preempt:step triggers the "
                         "preemption handler (needs --restartable)")
    ap.add_argument("--restartable", action="store_true",
                    help="engine checkpoint/restart drill: run under "
                         "run_with_restarts + PreemptionHandler with "
                         "periodic snapshots to --ckpt-dir; a preemption "
                         "(SIGTERM/SIGINT or --inject preempt:k) snapshots, "
                         "exits the attempt, and the restart restores from "
                         "the latest checkpoint token-identically")
    ap.add_argument("--ckpt-dir", default="",
                    help="engine snapshot directory for --restartable "
                         "(default: a fresh temp dir)")
    ap.add_argument("--ckpt-every", type=int, default=4,
                    help="snapshot cadence in engine steps under "
                         "--restartable (a preemption always snapshots)")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for params, prompts, and sampling — "
                         "smokes, the engine, and the serving sim are "
                         "reproducible run-to-run for a fixed seed")
    ap.add_argument("--trace-out", default="",
                    help="engine-only: write a Chrome trace-event JSON of "
                         "the run (per-request lifecycle spans, engine step "
                         "phases, pool counters) to this path — loadable in "
                         "chrome://tracing or ui.perfetto.dev. Validated on "
                         "write (all spans closed, one terminal instant per "
                         "request)")
    ap.add_argument("--trace-clock", default="virtual",
                    choices=["virtual", "wall"],
                    help="trace timestamp source: 'virtual' stamps "
                         "step*1000+offset ticks (byte-identical across "
                         "same-seed runs; ts//1000 recovers the engine "
                         "step), 'wall' stamps real microseconds (readable, "
                         "not reproducible)")
    ap.add_argument("--log-json", action="store_true",
                    help="engine-only: emit every [serve] status line as a "
                         "single-line JSON event object instead of prose")
    ap.add_argument("--quant-health-every", type=int, default=0,
                    help="engine-only: sample FP8 quantization health "
                         "(per-layer KV scale min/max + exponent histogram, "
                         "clip rate, sink-row error bound) from the live "
                         "pool every N engine steps. Host-read cost per "
                         "sample; 0 = off (the default — the hot path never "
                         "pays it)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, kv_fmt=args.fmt, kv_splits=args.kv_splits,
                              kv_paged=args.paged,
                              kv_rescale=args.rescale,
                              kv_sink_tokens=args.sink_tokens,
                              decode_backend=args.backend,
                              use_kernels=args.backend == "kernel")
    if args.block_n:
        # paged caches have no block_n freedom — the kernel block axis IS the
        # physical page — so --block-n repages the pool there; contiguous
        # caches keep their page size and override only the decode block
        cfg = dataclasses.replace(
            cfg, page_size=args.block_n) if args.paged else \
            dataclasses.replace(cfg, kv_block_n=args.block_n)
    if args.backend == "shard-map":
        # the shard_map backend needs a mesh context (dryrun sets SHARD_CTX
        # for the production mesh; here: the host mesh, data = all devices)
        T.SHARD_CTX = {"mesh": make_host_mesh(1), "dp": "data",
                       "use_shard_map": True}
    key = jax.random.PRNGKey(args.seed)
    params = T.init_model(key, cfg)

    if args.engine:
        if args.fused:
            ap.error("--engine has no fused mode (it steps the decode loop "
                     "per engine tick); drop --fused or --engine")
        run_engine(cfg, params, args)
        return

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    aux = (jax.random.normal(key, (args.batch, cfg.n_aux_tokens, cfg.d_model))
           if cfg.n_aux_tokens else None)

    gen_fn = generate_fused if args.fused else generate
    sample_kw = dict(temperature=args.temperature, top_k=args.top_k,
                     top_p=args.top_p, eos_id=args.eos_id, seed=args.seed)
    toks, tps = gen_fn(cfg, params, prompts, args.gen, aux_embed=aux,
                       **sample_kw)
    mode = "fused-scan" if args.fused else "step-loop"
    cache_kind = "paged" if args.paged else "contiguous"
    print(f"[serve] {cfg.name} fmt={args.fmt} backend={args.backend} "
          f"({mode}, {cache_kind} cache): generated {toks.shape} at "
          f"{tps:.1f} tok/s (decode)")

    if args.fmt != "none":
        cfg_b = dataclasses.replace(cfg, kv_fmt="none")
        toks_b, _ = gen_fn(cfg_b, params, prompts, args.gen, aux_embed=aux,
                           **sample_kw)
        agree = float(jnp.mean((toks == toks_b).astype(jnp.float32)))
        print(f"[serve] token agreement vs BF16 pipeline: {agree * 100:.1f}%")


if __name__ == "__main__":
    main()
