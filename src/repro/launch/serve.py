"""Serving launcher: batched prefill + decode with the SnapMLA FP8 KV cache.

CPU-scale usage (real generation on the host mesh, greedy sampling):

    PYTHONPATH=src python -m repro.launch.serve \
        --arch mla-7b --smoke --batch 4 --prompt-len 32 --gen 16 --fmt fp8_e4m3

This is deliverable (b)'s end-to-end serving driver: it exercises prefill
(bulk RoPE-aware per-token quantization into the cache), then the quantized
decode pipeline per step, and reports decode throughput + agreement with the
BF16 baseline.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.launch import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T


def _check_finite(ok, where: str) -> None:
    """Loud NaN gate: serving must never emit non-finite logits — a NaN here
    means the quantized decode pipeline (or a kernel change behind it) broke,
    so fail the process rather than generate garbage tokens. ``ok`` is either
    raw logits or an already-reduced boolean (the fused scan's every-step
    flag); both generation paths cover every decode step."""
    if not bool(jnp.all(jnp.isfinite(ok) if ok.ndim else ok)):
        raise SystemExit(f"[serve] FATAL: non-finite logits at {where}")


def generate(cfg, params, prompts: jax.Array, gen_steps: int, mesh=None,
             aux_embed=None, greedy: bool = True):
    """prompts [B, S] -> (generated tokens [B, gen_steps], decode tok/s)."""
    mesh = mesh or make_host_mesh(1)
    B, S = prompts.shape
    max_len = S + gen_steps + cfg.page_size
    prefill_fn = jax.jit(ST.make_prefill_step(cfg))
    decode_fn = jax.jit(ST.make_decode_step(cfg))

    state = T.init_decode_state(cfg, B, max_len)
    logits, state = prefill_fn(params, prompts, state, *(
        (aux_embed,) if aux_embed is not None else ()))
    _check_finite(logits, "prefill")
    tok = jnp.argmax(logits, -1).astype(jnp.int32)

    outs = [tok]
    # warm up decode compile before timing
    pos = jnp.full((B,), S, jnp.int32)
    logits, state = decode_fn(params, tok, state, pos)
    # every-step NaN gate, accumulated on device (no per-step host sync)
    ok = jnp.all(jnp.isfinite(logits))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs.append(tok)
    jax.block_until_ready(tok)

    t0 = time.time()
    for i in range(1, gen_steps - 1):
        pos = jnp.full((B,), S + i, jnp.int32)
        logits, state = decode_fn(params, tok, state, pos)
        ok = jnp.logical_and(ok, jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    _check_finite(ok, "decode (any step)")
    toks_per_s = B * max(gen_steps - 2, 1) / max(dt, 1e-9)
    return jnp.stack(outs, axis=1), toks_per_s


def generate_fused(cfg, params, prompts: jax.Array, gen_steps: int, mesh=None,
                   aux_embed=None):
    """Scan-based generation: prefill + ONE fused decode dispatch.

    Token-exact with ``generate`` (same greedy decode_step inside a lax.scan)
    but the whole multi-token decode is a single compiled program — no
    per-step dispatch/host round-trip — with the decode state (quantized KV
    caches) donated so XLA updates the cache buffers in place.

    Returns (generated tokens [B, gen_steps], decode tok/s).
    """
    mesh = mesh or make_host_mesh(1)
    B, S = prompts.shape
    max_len = S + gen_steps + cfg.page_size
    prefill_fn = jax.jit(ST.make_prefill_step(cfg))
    fused_fn = jax.jit(ST.make_fused_decode(cfg, max(gen_steps - 1, 0)),
                       donate_argnums=(2,))

    state = T.init_decode_state(cfg, B, max_len)
    logits, state = prefill_fn(params, prompts, state, *(
        (aux_embed,) if aux_embed is not None else ()))
    _check_finite(logits, "prefill")
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    if gen_steps <= 1:
        return tok[:, None][:, :gen_steps], 0.0

    start_pos = jnp.full((B,), S, jnp.int32)
    # AOT-compile before timing (donation happens at execution, not lowering)
    compiled = fused_fn.lower(params, tok, state, start_pos).compile()
    jax.block_until_ready((tok, state))
    t0 = time.time()
    toks, _state, ok = compiled(params, tok, state, start_pos)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    _check_finite(ok, "fused decode (any step)")
    toks_per_s = B * (gen_steps - 1) / max(dt, 1e-9)
    return jnp.concatenate([tok[:, None], toks], axis=1), toks_per_s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mla-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--fmt", default="fp8_e4m3",
                    choices=["fp8_e4m3", "int8", "none"])
    ap.add_argument("--fused", action="store_true",
                    help="scan-based generate_fused (one dispatch) instead of "
                         "the per-step decode loop")
    ap.add_argument("--kv-splits", type=int, default=0,
                    help="split-KV (flash-decoding) splits for decode "
                         "attention, contiguous AND paged caches "
                         "(0 = auto: measured split profile if present, else "
                         "the context-length heuristic; 1 = single-pass)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache for MLA layers: latent entries live "
                         "in a page pool addressed through per-sequence page "
                         "tables (multi-tenant pool layout) instead of a "
                         "contiguous per-slot cache")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, kv_fmt=args.fmt, kv_splits=args.kv_splits,
                              kv_paged=args.paged)
    key = jax.random.PRNGKey(args.seed)
    params = T.init_model(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, jnp.int32)
    aux = (jax.random.normal(key, (args.batch, cfg.n_aux_tokens, cfg.d_model))
           if cfg.n_aux_tokens else None)

    gen_fn = generate_fused if args.fused else generate
    toks, tps = gen_fn(cfg, params, prompts, args.gen, aux_embed=aux)
    mode = "fused-scan" if args.fused else "step-loop"
    cache_kind = "paged" if args.paged else "contiguous"
    print(f"[serve] {cfg.name} fmt={args.fmt} ({mode}, {cache_kind} cache): "
          f"generated {toks.shape} at {tps:.1f} tok/s (decode)")

    if args.fmt != "none":
        cfg_b = dataclasses.replace(cfg, kv_fmt="none")
        toks_b, _ = gen_fn(cfg_b, params, prompts, args.gen, aux_embed=aux)
        agree = float(jnp.mean((toks == toks_b).astype(jnp.float32)))
        print(f"[serve] token agreement vs BF16 pipeline: {agree * 100:.1f}%")


if __name__ == "__main__":
    main()
