"""Sharding rules: parameter / optimizer / decode-state / batch PartitionSpecs.

Policy (DESIGN.md §6): 2-D sharding — FSDP over the ('pod','data') axes,
tensor/expert parallelism over 'model'. Rules are keyed on parameter *names*
(the finite set emitted by models/*.py); scanned parameters get a leading
unsharded superblock axis. Uneven dimensions are allowed (GSPMD pads), but
KV-head axes smaller than the model axis are deliberately swapped for a
head-dim sharding to avoid padding waste on caches.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axis_names, model_axis_size


def _rules(dp, model, model_size, attn_fallback="replicate"):
    """name -> function(shape) -> PartitionSpec (without scan prefix).

    attn_fallback: what to do when a head count does not divide the model
    axis. "replicate" (train default): keep attention weights replicated over
    'model' — head-dim sharding would turn every QK/PV matmul into a
    logits-sized partial-sum all-reduce (measured 1.3 TB/chip/step on
    llama3.2-3b train_4k — EXPERIMENTS §Perf). "shard_dh" (decode default):
    shard the head_dim — at one-token decode the induced all-reduce is only
    [B, H, N]-sized and it keeps the big KV cache sharded 16-way.
    """
    def attn_qkv(shape):     # [d, H, dh]
        h = shape[-2]
        if h % model_size == 0:
            return P(dp, model, None)
        if attn_fallback == "shard_dh" and shape[-1] % model_size == 0:
            return P(dp, None, model)
        return P(dp, None, None)
    def attn_bias(shape):    # [H, dh]
        h = shape[-2]
        if h % model_size == 0:
            return P(model, None)
        if attn_fallback == "shard_dh" and shape[-1] % model_size == 0:
            return P(None, model)
        return P(None, None)
    def attn_wo(shape):      # [H, dh, d]
        h = shape[-3]
        if h % model_size == 0:
            return P(model, None, dp)
        if attn_fallback == "shard_dh" and shape[-2] % model_size == 0:
            return P(None, model, dp)
        return P(None, None, dp)

    return {
        # embeddings
        "embed": lambda s: P(model, dp),
        "unembed": lambda s: P(model, dp),
        # attention
        "wq": attn_qkv, "wk": attn_qkv, "wv": attn_qkv,
        "bq": attn_bias, "bk": attn_bias, "bv": attn_bias,
        "wo": attn_wo,
        # dense MLP (2-D) and MoE expert-stacked (3-D). EP shards the expert
        # axis when divisible; otherwise fall back to TP on the ffn axis
        # (standard when tp > n_experts, e.g. mixtral's 8 experts on 16-way).
        "w_gate": lambda s: (
            (P(model, dp, None) if s[0] % model_size == 0 else P(None, dp, model))
            if len(s) == 3 else P(dp, model)),
        "w_up": lambda s: (
            (P(model, dp, None) if s[0] % model_size == 0 else P(None, dp, model))
            if len(s) == 3 else P(dp, model)),
        "w_down": lambda s: (
            (P(model, None, dp) if s[0] % model_size == 0 else P(None, model, dp))
            if len(s) == 3 else P(model, dp)),
        # MoE (3-D expert-stacked variants handled above via len(s) == 3: EP on E)
        "w_router": lambda s: P(dp, None),
        "shared_gate": lambda s: P(dp, model),
        "shared_up": lambda s: P(dp, model),
        "shared_down": lambda s: P(model, dp),
        # MLA
        "w_dq": lambda s: P(dp, None),
        "q_norm": lambda s: P(None),
        "w_uq": lambda s: P(dp, "model", None) if s[-2] % model_size == 0 else P(dp, None, None),
        "w_dkv": lambda s: P(dp, None),
        "kv_norm": lambda s: P(None),
        "w_kr": lambda s: P(dp, None),
        "w_uk": lambda s: P(None, model, None) if s[-2] % model_size == 0 else P(None, None, None),
        "w_uv": lambda s: P(None, model, None) if s[-2] % model_size == 0 else P(None, None, None),
        "w_o": attn_wo,
        # RG-LRU
        "w_gate_branch": lambda s: P(dp, model),
        "w_in": lambda s: P(dp, model),
        "conv_w": lambda s: P(None, model),
        "conv_b": lambda s: P(model),
        "w_a": lambda s: P(None, model),
        "b_a": lambda s: P(model),
        "w_x": lambda s: P(None, model),
        "b_x": lambda s: P(model),
        "log_lambda": lambda s: P(model),
        "w_out": lambda s: P(model, dp) if len(s) == 2 else P(None, model, dp),
        # xLSTM — w_q/w_k feed the dhk contraction (q.k and C.q): sharding
        # them over 'model' would all-reduce the [B,T,S,H] score tensor every
        # layer. Keep dhk replicated; shard the value dim (dhv) instead.
        "w_q": lambda s: P(dp, None, None),
        "w_k": lambda s: P(dp, None, None),
        "w_v": lambda s: P(dp, None, model),
        "w_i": lambda s: P(dp, None),
        "w_f": lambda s: P(dp, None),
        "b_i": lambda s: P(None),
        "b_f": lambda s: P(None),
        "w_o_gate": lambda s: P(dp, None, model),
        "gn_gain": lambda s: P(None, None),
        "w": lambda s: P(None, dp, None, model),       # slstm input proj [4,d,H,dh]
        "r": lambda s: P(None),                        # slstm recurrent (small)
        "b": lambda s: P(None),
        # norms / scalars
        "ln1": lambda s: P(None), "ln2": lambda s: P(None),
        "ln_cross": lambda s: P(None), "ln_f": lambda s: P(None),
        "enc_ln_f": lambda s: P(None), "xgate": lambda s: P(None),
    }


def dp_size(mesh) -> int:
    out = 1
    for a in data_axis_names(mesh):
        out *= mesh.shape[a]
    return out


def dp_axes_for(batch_size: int, mesh):
    """Batch axes spec: shard over ('pod','data') only when divisible —
    tiny batches (long_500k's global_batch=1) are replicated instead, with
    the model axis still sharding heads/head-dim (DESIGN.md §6)."""
    if batch_size % dp_size(mesh) != 0:
        return None
    dp = data_axis_names(mesh)
    return dp[0] if len(dp) == 1 else dp


def _axes_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, (tuple, list)):
        out = 1
        for a in axes:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axes]


def sanitize_pspec(ps, shape, mesh):
    """pjit *argument* shardings require exact divisibility — drop any axis
    whose mesh size does not divide the dimension (falls back to replication
    on that dim; e.g. granite's 49155 vocab on a 16-way model axis)."""
    parts = list(ps) + [None] * (len(shape) - len(ps))
    out = []
    for dim, axes in zip(shape, parts):
        out.append(axes if axes is None or dim % _axes_size(mesh, axes) == 0
                   else None)
    return P(*out)


def _leaf_name(path) -> str:
    last = path[-1]
    if hasattr(last, "name"):        # GetAttrKey (NamedTuple field)
        return last.name
    if hasattr(last, "key"):         # DictKey
        return str(last.key)
    return ""


def _is_scanned(path) -> bool:
    for p in path:
        if hasattr(p, "key") and str(getattr(p, "key", "")) in ("scanned", "encoder"):
            return True
    return False


def param_pspecs(params, mesh, weight_stationary: bool = False,
                 attn_fallback: str = "replicate"):
    """PartitionSpec pytree for a model/optimizer parameter tree.

    weight_stationary=True replicates weights over the data axes and keeps
    only the 'model' (TP) sharding — the paper's DP x TP *serving* layout
    (no per-step FSDP weight all-gathers). Default (False) is the 2-D
    FSDP x TP training layout.
    """
    if weight_stationary:
        dp = None
    else:
        dp = data_axis_names(mesh)
        dp = dp[0] if len(dp) == 1 else dp
    msize = model_axis_size(mesh)
    rules = _rules(dp, "model", msize, attn_fallback)

    def spec(path, leaf):
        name = _leaf_name(path)
        scanned = _is_scanned(path)
        shape = leaf.shape
        core_shape = shape[1:] if scanned else shape
        if name in rules and len(core_shape) > 0:
            ps = rules[name](core_shape)
        else:
            ps = P()
        if scanned:
            ps = P(None, *ps)
        parts = list(ps)[: len(shape)]
        parts += [None] * (len(shape) - len(parts))
        return sanitize_pspec(P(*parts), shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, params)


def state_pspecs(state, mesh, cfg):
    """Decode-state PartitionSpecs: batch over dp; heads (or head-dim) over model."""
    msize = model_axis_size(mesh)

    def spec(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        scanned = _is_scanned(path)
        core = shape[1:] if scanned else shape
        dp = dp_axes_for(core[0], mesh) if len(core) else None
        # GQA cache leaves
        if name in ("k", "v") and len(core) == 4:          # [B,N,Hkv,dh]
            ps = P(dp, None, "model", None) if core[2] % msize == 0 \
                else P(dp, None, None, "model")
        elif name in ("k_scale", "v_scale") and len(core) == 3:
            ps = P(dp, None, "model") if core[2] % msize == 0 else P(dp, None, None)
        elif name == "slot_pos":
            ps = P(dp, None)
        elif name in ("seq_lens",):
            ps = P(dp)
        # MLA cache leaves (latent dim replicated over model; DESIGN §6)
        elif name == "content" and len(core) == 3:         # [B,N,d_c]
            ps = P(dp, None, None)
        elif name == "rope" and len(core) == 3:
            ps = P(dp, None, None)
        elif name == "scale" and len(core) == 2:
            ps = P(dp, None)
        # recurrent states
        elif name == "h" and len(core) == 2:               # rglru [B, d_rnn]
            ps = P(dp, "model")
        elif name == "conv":                               # [B, W-1, d_rnn]
            ps = P(dp, None, "model")
        elif name == "c" and len(core) == 4:               # mlstm [B,H,dhk,dhv]
            ps = P(dp, "model", None, None) if core[1] % msize == 0 \
                else P(dp, None, "model", None)
        elif name in ("c", "n", "h") and len(core) == 3:   # [B,H,dh]
            ps = P(dp, None, "model")
        elif name == "m" and len(core) == 2:               # [B,H]
            ps = P(dp, None)
        elif len(core) >= 1:
            ps = P(dp, *([None] * (len(core) - 1)))
        else:
            ps = P()
        if scanned:
            ps = P(None, *ps)
        parts = list(ps)[: len(shape)]
        parts += [None] * (len(shape) - len(parts))
        return sanitize_pspec(P(*parts), shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, state)


def batch_pspecs(batch, mesh):
    def spec(path, leaf):
        dp = dp_axes_for(leaf.shape[0], mesh) if leaf.ndim else None
        return P(dp, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(spec, batch)


def to_named(pspecs, mesh):
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
