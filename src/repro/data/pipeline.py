"""Deterministic synthetic LM data pipeline, host-shardable and resumable.

Production shape: each host materializes only its own shard of the global
batch (``host_slice``), batches are a pure function of (seed, step) so any
host can reproduce any step — which is what makes checkpoint/restart and
elastic rescaling trivial (the pipeline cursor is just the step counter in
the checkpoint manifest; no data-state files).

Token stream: a mixture of Zipf-distributed unigrams and shifted-window
repeats (gives non-trivial next-token structure so training losses move),
generated with counter-based randomness (jax.random.fold_in) — O(1) state.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    n_aux_tokens: int = 0        # emit stub modality embeddings if > 0
    d_model: int = 0


def _zipf_logits(vocab: int) -> jax.Array:
    return -jnp.log(jnp.arange(1, vocab + 1, dtype=jnp.float32))


def synth_batch(cfg: DataConfig, step: int | jax.Array):
    """Global batch for ``step``: dict(tokens, labels[, aux_embed])."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    logits = _zipf_logits(cfg.vocab_size)
    base = jax.random.categorical(
        k1, logits, shape=(cfg.global_batch, cfg.seq_len + 1))
    # inject copy structure: second half repeats the first half with offset 1
    half = (cfg.seq_len + 1) // 2
    rep = jnp.concatenate([base[:, :half], base[:, : cfg.seq_len + 1 - half]], axis=1)
    use_rep = jax.random.bernoulli(k2, 0.5, (cfg.global_batch, 1))
    seq = jnp.where(use_rep, rep, base)
    out = {"tokens": seq[:, :-1].astype(jnp.int32),
           "labels": seq[:, 1:].astype(jnp.int32)}
    if cfg.n_aux_tokens:
        out["aux_embed"] = jax.random.normal(
            k3, (cfg.global_batch, cfg.n_aux_tokens, cfg.d_model), jnp.float32)
    return out


def host_slice(cfg: DataConfig, step: int, host_id: int, n_hosts: int):
    """The shard of the global batch this host must materialize."""
    assert cfg.global_batch % n_hosts == 0
    per = cfg.global_batch // n_hosts
    full = synth_batch(cfg, step)
    return jax.tree.map(lambda x: x[host_id * per : (host_id + 1) * per], full)


def batch_iterator(cfg: DataConfig, start_step: int = 0, host_id: int = 0,
                   n_hosts: int = 1):
    """Resumable iterator: (step, batch) pairs from ``start_step``."""
    step = start_step
    while True:
        yield step, host_slice(cfg, step, host_id, n_hosts)
        step += 1
