"""Opt-in FP8 quantization health probes over the live paged KV pool.

SnapMLA stores the content half of every KV entry quantized per token
(``core/quant.py``: scale = amax / qmax), and P-Cast's observation is that
quantization damage is not uniform — attention-sink rows (token 0) carry
outsized scales and outsized error. ``benchmarks/numerics.py`` measures
this offline on synthetic grids; this module measures it on the RUNNING
engine's pool, so a serving workload whose scale distribution drifts (or
whose clip rate climbs) is visible before tokens degrade.

Sampling is **opt-in and periodic** (``serve --quant-health-every N``,
default off): each sample does host reads of the resident pages' scale /
content planes — a real transfer cost, which is why the hot path never
pays it implicitly. The probe only READS pool state, so greedy tokens are
bit-identical with probes on or off (pinned by tests/test_obs.py).

Per pool layer, over WRITTEN rows only (unwritten rows keep their init
scale of 0 and are masked out):

  * ``scale_min`` / ``scale_max`` and a log2-exponent histogram of the
    per-token scales — drift here means the activation distribution moved;
  * ``clip_rate`` — fraction of stored content elements saturated at the
    format's qmax (|code| >= qmax): persistent clipping means per-token
    scaling is no longer absorbing the dynamic range;
  * ``sink_err_bound_max`` — an analytic max-quantization-error bound for
    the sink rows (token 0 of each live sequence): ``scale * qmax *
    rel_step / 2``, the worst-case grid spacing of the storage format at
    full magnitude. fp8_e4m3 has a 3-bit mantissa (rel_step 2^-3); int8 has
    rel_step 1/qmax (uniform grid). The paper's sink guard exists exactly
    because this bound is largest on those rows.
"""
from __future__ import annotations

from typing import Any

import numpy as np

from repro.core.quant import qmax_for

# log2(scale) exponent histogram range (clamped): 2^-24 .. 2^8
_EXP_LO, _EXP_HI = -24, 8


def _rel_step(fmt: str) -> float:
    """Worst-case relative grid spacing of the storage format."""
    if fmt == "fp8_e4m3":
        return 2.0 ** -3          # e4m3: 3 mantissa bits
    return 1.0 / qmax_for(fmt)    # int8: uniform grid


def _layer_stats(content: np.ndarray, scale: np.ndarray, qmax: float,
                 rel_step: float, pages: np.ndarray,
                 sink_pages: np.ndarray) -> dict[str, Any]:
    """Health stats for ONE pool layer. ``content`` [n_pages, page, d_c]
    (already float32 host copies), ``scale`` [n_pages, page]; ``pages`` are
    the resident page ids, ``sink_pages`` the first page of each live
    sequence (their row 0 is the sequence's attention sink)."""
    s = scale[pages]                                   # [P, page]
    written = s > 0.0
    n_written = int(written.sum())
    out: dict[str, Any] = {"written_rows": n_written}
    if n_written == 0:
        out.update(scale_min=0.0, scale_max=0.0, clip_rate=0.0,
                   scale_exp_hist={}, sink_rows=0, sink_scale_max=0.0,
                   sink_err_bound_max=0.0)
        return out
    sw = s[written]
    out["scale_min"] = float(sw.min())
    out["scale_max"] = float(sw.max())
    exps = np.clip(np.floor(np.log2(sw)).astype(np.int64), _EXP_LO, _EXP_HI)
    uniq, counts = np.unique(exps, return_counts=True)
    out["scale_exp_hist"] = {str(int(e)): int(n)
                             for e, n in zip(uniq, counts)}
    c = np.abs(content[pages])                         # [P, page, d_c]
    clipped = int((c[written] >= qmax).sum())
    out["clip_rate"] = clipped / float(c[written].size)
    # sink rows: token 0 of each live sequence
    if sink_pages.size:
        sink_s = scale[sink_pages, 0]
        sink_live = sink_s > 0.0
        out["sink_rows"] = int(sink_live.sum())
        smax = float(sink_s[sink_live].max()) if sink_live.any() else 0.0
        out["sink_scale_max"] = smax
        out["sink_err_bound_max"] = smax * qmax * rel_step / 2.0
    else:
        out.update(sink_rows=0, sink_scale_max=0.0, sink_err_bound_max=0.0)
    return out


def probe_pools(map_pools, state, *, fmt: str, resident_pages,
                sink_pages) -> dict[str, Any]:
    """Sample every pool leaf of ``state`` (via the engine's ``map_pools``
    traversal) and return the per-layer health report plus an aggregate.

    Scanned superblock leaves carry leading stacked layer axes; each
    stacked index is reported as its own layer (``layers`` is keyed by
    ``pool{leaf}.{stack}``)."""
    qmax = qmax_for(fmt)
    rel = _rel_step(fmt)
    pages = np.asarray(sorted(resident_pages), np.int64)
    sinks = np.asarray(sorted(sink_pages), np.int64)
    layers: dict[str, dict] = {}
    leaf_idx = [0]

    def visit(pool):
        content = np.asarray(pool.content, np.float32)
        scale = np.asarray(pool.scale, np.float32)
        # flatten leading stacked axes down to [L, n_pages, page, ...]
        lead = content.shape[:-3]
        content = content.reshape((-1,) + content.shape[len(lead):])
        scale = scale.reshape((-1,) + scale.shape[len(lead):])
        for layer in range(content.shape[0]):
            key = f"pool{leaf_idx[0]}.{layer}"
            layers[key] = _layer_stats(content[layer], scale[layer], qmax,
                                       rel, pages, sinks)
        leaf_idx[0] += 1
        return pool

    map_pools(visit, state)
    agg = {
        "resident_pages": int(pages.size),
        "scale_min": min((v["scale_min"] for v in layers.values()
                          if v["written_rows"]), default=0.0),
        "scale_max": max((v["scale_max"] for v in layers.values()), default=0.0),
        "clip_rate_max": max((v["clip_rate"] for v in layers.values()),
                             default=0.0),
        "sink_err_bound_max": max((v["sink_err_bound_max"]
                                   for v in layers.values()), default=0.0),
    }
    return {"fmt": fmt, "layers": layers, "aggregate": agg}


class QuantHealthProbe:
    """Periodic sampler bound to a registry: every ``every`` engine steps,
    probe the pool and push the aggregate into gauges. Reports accumulate
    in ``self.samples`` for the JSON event log."""

    def __init__(self, registry, *, fmt: str, every: int):
        if every <= 0:
            raise ValueError("quant-health sampling period must be > 0")
        self.fmt = fmt
        self.every = int(every)
        self.samples: list[dict] = []
        self._scale_min = registry.gauge(
            "snapmla_quant_scale_min", "min per-token KV scale (written rows)")
        self._scale_max = registry.gauge(
            "snapmla_quant_scale_max", "max per-token KV scale (written rows)")
        self._clip_rate = registry.gauge(
            "snapmla_quant_clip_rate_max",
            "max per-layer fraction of content elements saturated at qmax")
        self._sink_err = registry.gauge(
            "snapmla_quant_sink_err_bound_max",
            "analytic max quantization error bound over sink rows")
        self._samples = registry.counter(
            "snapmla_quant_samples_total", "quant-health probes taken")

    def due(self, step: int) -> bool:
        return step % self.every == 0

    def sample(self, step: int, map_pools, state, *, resident_pages,
               sink_pages) -> dict[str, Any]:
        report = probe_pools(map_pools, state, fmt=self.fmt,
                             resident_pages=resident_pages,
                             sink_pages=sink_pages)
        agg = report["aggregate"]
        self._scale_min.set(agg["scale_min"])
        self._scale_max.set(agg["scale_max"])
        self._clip_rate.set(agg["clip_rate_max"])
        self._sink_err.set(agg["sink_err_bound_max"])
        self._samples.inc()
        self.samples.append({"step": step, **agg})
        return report
