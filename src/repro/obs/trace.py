"""Per-request span tracing with Chrome trace-event export.

The engine emits three kinds of events into a :class:`SpanTracer`:

  * **request lifecycle spans** — one track (thread) per request id:
    ``QUEUED -> PREFILL -> DECODE`` duration spans, ``PREFILL(chunk i)``
    sub-spans for chunked admission, and ``FIRST_TOKEN`` / ``DONE`` /
    ``FAILED(reason)`` / ``REJECTED`` / ``EVICTED`` instants;
  * **engine step-phase spans** — admit / tier_drain / prefill / decode /
    postprocess / retire windows on the engine track, one per step in which
    the phase did work;
  * **counter samples** — per-step pool occupancy (Chrome ``C`` events, so
    Perfetto draws the page-utilization area chart directly).

CLOCKS.  The default clock is **virtual**: one engine step is
``TICKS_PER_STEP`` (1000) microsecond-ticks, and each step phase owns a
fixed sub-window (``PHASE_WINDOWS``). Timestamps are therefore pure
functions of the engine's step counter — a seeded run exports a
byte-identical trace on any machine, and integer-dividing any request
event's ts by ``TICKS_PER_STEP`` recovers the exact engine step, so the
trace REPRODUCES the engine's reported TTFT / latency (in steps) rather
than approximating them. ``clock="wall"`` stamps real microseconds instead
(readable, not reproducible; never used by CI).

The exporter (:meth:`SpanTracer.chrome_payload`) emits the Chrome
trace-event JSON format (``traceEvents`` array of ``X``/``i``/``C``/``M``
events) that chrome://tracing and https://ui.perfetto.dev load directly.
All spans must be closed at export; an open span at export time is a
lifecycle-accounting bug and raises.

Tracer state (events, open spans, the span-id cursor) rides
``export_state``/``restore_state`` through engine checkpoints, so a
preempted-and-restored run continues the SAME trace: span ids stay unique
and the resumed steps append exactly where the snapshot stopped.
"""
from __future__ import annotations

import json
import time
from typing import Any

TICKS_PER_STEP = 1000
# fixed per-step sub-windows (virtual clock): [begin, end) tick offsets
PHASE_WINDOWS: dict[str, tuple[int, int]] = {
    "admit": (0, 100),
    "tier_drain": (100, 150),
    "prefill": (150, 450),
    "decode": (450, 750),
    "postprocess": (750, 850),
    "retire": (850, 1000),
}
# point offsets for request lifecycle edges (all < TICKS_PER_STEP, so
# ts // TICKS_PER_STEP is always the emitting step)
OFF_ADMIT = 50            # QUEUED -> PREFILL transition
OFF_DECODE = 445          # PREFILL -> DECODE transition (prefill window end)
OFF_FIRST_TOKEN = 780     # FIRST_TOKEN instant (postprocess window)
OFF_RETIRE = 860          # span close + DONE instant
OFF_FAIL = 870            # span close + FAILED/REJECTED instant
OFF_EVICT = 855           # span close + EVICTED instant, QUEUED reopens
# chunk sub-spans tile the prefill window: 6 ticks per chunk, clamped so
# the last tile still closes before the PREFILL span's DECODE transition
# at offset 445
_CHUNK_W = 6
_CHUNK_MAX = (PHASE_WINDOWS["prefill"][1]
              - PHASE_WINDOWS["prefill"][0]) // _CHUNK_W - 2

ENGINE_PID = 1
REQUEST_PID = 2


class SpanTracer:
    """Collects engine/request events; exports Chrome trace JSON."""

    def __init__(self, clock: str = "virtual"):
        if clock not in ("virtual", "wall"):
            raise ValueError(f"clock must be 'virtual' or 'wall': {clock!r}")
        self.clock = clock
        self._t0 = time.time()
        self._next_sid = 1
        self._events: list[dict] = []
        # rid -> open lifecycle span {sid, name, ts, args}
        self._open: dict[int, dict] = {}
        # rid -> chunks traced so far (names the PREFILL(chunk i) sub-spans)
        self._chunks: dict[int, int] = {}
        # per-step cursor slotting chunk sub-spans side by side
        self._step_chunk_cursor: tuple[int, int] = (-1, 0)

    # ------------------------------------------------------------------
    # clocks
    # ------------------------------------------------------------------

    def ts(self, step: int, offset: int = 0) -> int:
        """Virtual: ``step * TICKS_PER_STEP + offset`` ticks. Wall: real
        microseconds since tracer creation (offset ignored)."""
        if self.clock == "virtual":
            return step * TICKS_PER_STEP + offset
        return int((time.time() - self._t0) * 1e6)

    def _sid(self) -> int:
        sid = self._next_sid
        self._next_sid += 1
        return sid

    # ------------------------------------------------------------------
    # engine track
    # ------------------------------------------------------------------

    def step_phase(self, step: int, phase: str,
                   args: dict[str, Any] | None = None) -> None:
        """One step-phase window as a complete span on the engine track."""
        begin, end = PHASE_WINDOWS[phase]
        if self.clock == "virtual":
            ts, dur = self.ts(step, begin), end - begin
        else:
            ts, dur = self.ts(step), 0
        self._events.append({
            "name": phase, "ph": "X", "ts": ts, "dur": dur,
            "pid": ENGINE_PID, "tid": 0, "cat": "phase",
            "args": {"step": step, **(args or {})}, "sid": self._sid()})

    def counter(self, step: int, name: str,
                values: dict[str, int | float]) -> None:
        """Chrome 'C' sample (Perfetto renders a stacked area chart)."""
        self._events.append({
            "name": name, "ph": "C",
            "ts": self.ts(step, TICKS_PER_STEP - 1),
            "pid": ENGINE_PID, "tid": 0, "args": dict(values),
            "sid": self._sid()})

    def engine_instant(self, step: int, offset: int, name: str,
                       args: dict[str, Any] | None = None) -> None:
        self._events.append({
            "name": name, "ph": "i", "ts": self.ts(step, offset), "s": "g",
            "pid": ENGINE_PID, "tid": 0, "cat": "fault",
            "args": {"step": step, **(args or {})}, "sid": self._sid()})

    # ------------------------------------------------------------------
    # request track
    # ------------------------------------------------------------------

    def req_begin(self, rid: int, name: str, ts: int,
                  args: dict[str, Any] | None = None) -> None:
        """Open the request's next lifecycle span (QUEUED/PREFILL/DECODE).
        A request has at most one open span; opening over an open span is a
        lifecycle bug and raises."""
        if rid in self._open:
            raise RuntimeError(
                f"request {rid}: span {self._open[rid]['name']!r} still "
                f"open while beginning {name!r}")
        self._open[rid] = {"sid": self._sid(), "name": name, "ts": ts,
                           "args": dict(args or {})}

    def req_end(self, rid: int, ts: int,
                args: dict[str, Any] | None = None) -> None:
        span = self._open.pop(rid, None)
        if span is None:
            return
        self._events.append({
            "name": span["name"], "ph": "X", "ts": span["ts"],
            "dur": max(ts - span["ts"], 0), "pid": REQUEST_PID, "tid": rid,
            "cat": "request", "args": {**span["args"], **(args or {})},
            "sid": span["sid"]})

    def req_transition(self, rid: int, name: str, ts: int,
                       args: dict[str, Any] | None = None) -> None:
        self.req_end(rid, ts)
        self.req_begin(rid, name, ts, args)

    def req_instant(self, rid: int, name: str, ts: int,
                    args: dict[str, Any] | None = None) -> None:
        self._events.append({
            "name": name, "ph": "i", "ts": ts, "s": "t",
            "pid": REQUEST_PID, "tid": rid, "cat": "request",
            "args": dict(args or {}), "sid": self._sid()})

    def req_chunk(self, rid: int, step: int,
                  args: dict[str, Any] | None = None) -> None:
        """One PREFILL(chunk i) sub-span, tiled inside the step's prefill
        window in execution order."""
        cur_step, k = self._step_chunk_cursor
        if cur_step != step:
            k = 0
        self._step_chunk_cursor = (step, k + 1)
        i = self._chunks.get(rid, 0)
        self._chunks[rid] = i + 1
        off = PHASE_WINDOWS["prefill"][0] + _CHUNK_W * min(k, _CHUNK_MAX)
        if self.clock == "virtual":
            ts, dur = self.ts(step, off), _CHUNK_W
        else:
            ts, dur = self.ts(step), 0
        self._events.append({
            "name": f"PREFILL(chunk {i})", "ph": "X", "ts": ts, "dur": dur,
            "pid": REQUEST_PID, "tid": rid, "cat": "request",
            "args": {"step": step, **(args or {})}, "sid": self._sid()})

    def reset_chunks(self, rid: int) -> None:
        """A requeued request replays prefill: chunk numbering restarts."""
        self._chunks.pop(rid, None)

    # ------------------------------------------------------------------
    # export
    # ------------------------------------------------------------------

    def open_spans(self) -> dict[int, str]:
        return {rid: span["name"] for rid, span in self._open.items()}

    def chrome_payload(self) -> dict[str, Any]:
        """The Chrome trace-event JSON payload. Raises if any lifecycle
        span is still open — a drained engine must have closed them all."""
        if self._open:
            leaked = {rid: s["name"] for rid, s in sorted(self._open.items())}
            raise RuntimeError(f"open spans at export: {leaked}")
        meta: list[dict] = [
            {"name": "process_name", "ph": "M", "pid": ENGINE_PID, "tid": 0,
             "args": {"name": "engine"}},
            {"name": "thread_name", "ph": "M", "pid": ENGINE_PID, "tid": 0,
             "args": {"name": "step phases"}},
            {"name": "process_name", "ph": "M", "pid": REQUEST_PID, "tid": 0,
             "args": {"name": "requests"}},
        ]
        rids = sorted({e["tid"] for e in self._events
                       if e["pid"] == REQUEST_PID})
        for rid in rids:
            meta.append({"name": "thread_name", "ph": "M",
                         "pid": REQUEST_PID, "tid": rid,
                         "args": {"name": f"request {rid}"}})
        events = sorted(self._events, key=lambda e: (e["ts"], e["sid"]))
        # sid is tracer-internal (checkpoint continuity); strip from export
        body = [{k: v for k, v in e.items() if k != "sid"} for e in events]
        return {"traceEvents": meta + body,
                "displayTimeUnit": "ms",
                "metadata": {"clock": self.clock,
                             "ticks_per_step": TICKS_PER_STEP}}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_payload(), f, indent=1, sort_keys=True)
            f.write("\n")

    # ------------------------------------------------------------------
    # checkpoint round-trip
    # ------------------------------------------------------------------

    def export_state(self) -> dict[str, Any]:
        return {
            "clock": self.clock,
            "next_sid": self._next_sid,
            "events": [dict(e) for e in self._events],
            "open": {str(rid): dict(s) for rid, s in self._open.items()},
            "chunks": {str(rid): n for rid, n in self._chunks.items()},
        }

    def restore_state(self, state: dict[str, Any]) -> None:
        self.clock = state["clock"]
        self._next_sid = int(state["next_sid"])
        self._events = [dict(e) for e in state["events"]]
        self._open = {int(rid): dict(s)
                      for rid, s in state["open"].items()}
        self._chunks = {int(rid): int(n)
                        for rid, n in state["chunks"].items()}
        self._step_chunk_cursor = (-1, 0)


# ---------------------------------------------------------------------------
# validation (CI smoke + trace_report)
# ---------------------------------------------------------------------------

_VALID_PH = {"X", "i", "C", "M"}
_TERMINAL = ("DONE", "FAILED", "REJECTED")


def validate_chrome_trace(payload: dict, *,
                          expect_requests: int | None = None) -> dict:
    """Structural validation of an exported trace. Raises ``ValueError``
    with every violation found; returns summary stats on success:
    ``{"events", "requests", "spans", "terminal"}``.

    Checks: Chrome-schema fields on every event, non-negative integer
    ts/dur on every ``X`` span (all spans closed — duration spans can only
    be emitted closed, so presence == closure), exactly one terminal
    instant (DONE/FAILED/REJECTED) per request track, and — when
    ``expect_requests`` is given — that the number of request tracks
    matches the submitted-request count with zero leaked (non-terminated)
    tracks."""
    problems: list[str] = []
    events = payload.get("traceEvents")
    if not isinstance(events, list) or not events:
        raise ValueError("trace: missing/empty traceEvents array")
    req_tracks: set[int] = set()
    terminal: dict[int, int] = {}
    spans = 0
    for i, e in enumerate(events):
        ph = e.get("ph")
        if ph not in _VALID_PH:
            problems.append(f"event {i}: bad ph {ph!r}")
            continue
        if not isinstance(e.get("name"), str):
            problems.append(f"event {i}: missing name")
        if ph == "M":
            continue
        ts = e.get("ts")
        if not isinstance(ts, int) or ts < 0:
            problems.append(f"event {i} ({e.get('name')}): bad ts {ts!r}")
        if not isinstance(e.get("pid"), int) \
                or not isinstance(e.get("tid"), int):
            problems.append(f"event {i} ({e.get('name')}): bad pid/tid")
        if ph == "X":
            spans += 1
            dur = e.get("dur")
            if not isinstance(dur, int) or dur < 0:
                problems.append(
                    f"event {i} ({e.get('name')}): bad dur {dur!r}")
        if e.get("pid") == REQUEST_PID:
            rid = e.get("tid")
            req_tracks.add(rid)
            if ph == "i" and any(e.get("name", "").startswith(t)
                                 for t in _TERMINAL):
                terminal[rid] = terminal.get(rid, 0) + 1
    for rid in sorted(req_tracks):
        n = terminal.get(rid, 0)
        if n != 1:
            problems.append(f"request {rid}: {n} terminal instants "
                            "(expected exactly 1 DONE/FAILED/REJECTED)")
    if expect_requests is not None and len(req_tracks) != expect_requests:
        problems.append(f"{len(req_tracks)} request tracks != "
                        f"{expect_requests} submitted requests")
    if problems:
        raise ValueError("invalid trace:\n  " + "\n  ".join(problems))
    return {"events": len(events), "requests": len(req_tracks),
            "spans": spans, "terminal": len(terminal)}
