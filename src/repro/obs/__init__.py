"""Deterministic-first observability: metrics registry, span tracing,
quantization health probes. See README "Observability"."""
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry)
from repro.obs.quant_health import QuantHealthProbe, probe_pools  # noqa: F401
from repro.obs.trace import (SpanTracer, TICKS_PER_STEP,  # noqa: F401
                             validate_chrome_trace)
