"""Typed metrics registry — the deterministic-first telemetry core.

One :class:`MetricsRegistry` holds every counter the serving stack exposes,
under the naming convention ``snapmla_<area>_<name>`` (enforced at
registration). The design splits metrics into two strictly separated
families:

  * **work metrics** (the default) — deterministic work units: tokens,
    pages, blocks, requests, fault counts. Same seed + workload ⇒ same
    values on any machine, so ``scripts/bench_gate.py`` can pin them as
    regression floors.
  * **wall metrics** (``wall=True``) — wall-clock seconds / throughput.
    They live in a separate namespace in every exported view and are NEVER
    eligible for gating (bench_gate asserts no gated path touches them).

Three metric types, Prometheus-shaped but in-process:

  * :class:`Counter` — monotonic ``inc(n)``; negative increments raise.
  * :class:`Gauge` — ``set``/``inc``/``dec``; also used to mirror counters
    owned by subsystems whose values can legally move down (e.g. the
    allocator's un-evict fast path decrements ``host_offloads``).
  * :class:`Histogram` — ``observe(v)`` into fixed buckets plus sum/count.

Labels are supported (``labels("kind")`` then ``metric.labels(kind=...)``);
label sets materialize children on first use and snapshots sort them, so
the exported view is byte-stable for a deterministic run.

``snapshot()`` returns a nested plain dict (JSON-safe, sorted keys);
``export_state``/``restore_state`` round-trip the registry through the
engine checkpoint manifest so a restored run resumes its series exactly.

Subsystems that keep counters as internal state (allocator free lists,
tier slots) are absorbed via **collectors**: ``register_collector(fn)``
callbacks run at snapshot time and push the current values into registry
gauges — one registry view over every module without rewriting
invariant-carrying internals.
"""
from __future__ import annotations

import re
from typing import Any, Callable, Iterable

_NAME_RE = re.compile(r"^snapmla_[a-z0-9]+(_[a-z0-9]+)+$")

# default histogram buckets: powers of two — token widths, page counts and
# scale magnitudes all live naturally on this grid
DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name):
        raise ValueError(
            f"metric name {name!r} violates the snapmla_<area>_<name> "
            "convention (lowercase, underscore-separated, >= 3 segments)")
    return name


class _Metric:
    """Shared base: identity, wall/work family, label plumbing."""

    kind = "metric"

    def __init__(self, name: str, help: str, labels: Iterable[str] = (),
                 *, wall: bool = False):
        self.name = _check_name(name)
        self.help = help
        self.label_names = tuple(labels)
        self.wall = bool(wall)
        # label-values tuple -> child payload (created on first use)
        self._children: dict[tuple[str, ...], Any] = {}

    # -- labels --------------------------------------------------------
    def _key(self, kv: dict[str, str]) -> tuple[str, ...]:
        if set(kv) != set(self.label_names):
            raise ValueError(
                f"{self.name}: labels {sorted(kv)} != declared "
                f"{sorted(self.label_names)}")
        return tuple(str(kv[k]) for k in self.label_names)

    def labels(self, **kv: str):
        """Child accessor for a labeled metric (unlabeled metrics ARE their
        own child)."""
        if not self.label_names:
            raise ValueError(f"{self.name} declares no labels")
        key = self._key(kv)
        child = self._children.get(key)
        if child is None:
            child = self._make_child()
            self._children[key] = child
        return child

    def _make_child(self):
        raise NotImplementedError

    def _self_child(self):
        """The implicit child of an unlabeled metric."""
        if self.label_names:
            raise ValueError(f"{self.name} is labeled; use .labels(...)")
        child = self._children.get(())
        if child is None:
            child = self._make_child()
            self._children[()] = child
        return child

    # -- snapshot / state ---------------------------------------------
    def _child_value(self, child) -> Any:
        raise NotImplementedError

    def _child_restore(self, child, value) -> None:
        raise NotImplementedError

    def value_dict(self) -> dict[str, Any]:
        """{label-values-joined-by-comma: value}; '' for unlabeled."""
        return {",".join(k): self._child_value(c)
                for k, c in sorted(self._children.items())}

    def restore_values(self, values: dict[str, Any]) -> None:
        self._children.clear()
        for joined, value in values.items():
            key = tuple(joined.split(",")) if joined else ()
            if len(key) != len(self.label_names):
                raise ValueError(
                    f"{self.name}: restored label arity {key} != declared "
                    f"{self.label_names}")
            child = self._make_child()
            self._child_restore(child, value)
            self._children[key] = child


class _CounterChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter increment must be >= 0, got {n}")
        self.value += n


class Counter(_Metric):
    """Monotonic counter (work units by default; seconds when wall=True)."""

    kind = "counter"

    def _make_child(self):
        return _CounterChild()

    def _child_value(self, child):
        return child.value

    def _child_restore(self, child, value):
        child.value = value

    def inc(self, n: int | float = 1) -> None:
        self._self_child().inc(n)

    @property
    def value(self):
        return self._self_child().value


class _GaugeChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def set(self, v) -> None:
        self.value = v

    def inc(self, n=1) -> None:
        self.value += n

    def dec(self, n=1) -> None:
        self.value -= n


class Gauge(_Metric):
    """Point-in-time value (can move both ways)."""

    kind = "gauge"

    def _make_child(self):
        return _GaugeChild()

    def _child_value(self, child):
        return child.value

    def _child_restore(self, child, value):
        child.value = value

    def set(self, v) -> None:
        self._self_child().set(v)

    def inc(self, n=1) -> None:
        self._self_child().inc(n)

    def dec(self, n=1) -> None:
        self._self_child().dec(n)

    @property
    def value(self):
        return self._self_child().value


class _HistogramChild:
    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, buckets: tuple[float, ...]):
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)   # +inf overflow bucket
        self.sum = 0
        self.count = 0

    def observe(self, v) -> None:
        self.sum += v
        self.count += 1
        for i, le in enumerate(self.buckets):
            if v <= le:
                self.counts[i] += 1
                return
        self.counts[-1] += 1


class Histogram(_Metric):
    """Fixed-bucket histogram (cumulative-free: per-bucket counts)."""

    kind = "histogram"

    def __init__(self, name, help, labels=(), *, wall=False,
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labels, wall=wall)
        self.buckets = tuple(buckets)
        if list(self.buckets) != sorted(self.buckets):
            raise ValueError(f"{name}: histogram buckets must be sorted")

    def _make_child(self):
        return _HistogramChild(self.buckets)

    def _child_value(self, child):
        return {"count": child.count, "sum": child.sum,
                "buckets": {str(le): n for le, n
                            in zip(child.buckets, child.counts)},
                "overflow": child.counts[-1]}

    def _child_restore(self, child, value):
        child.count = value["count"]
        child.sum = value["sum"]
        child.counts = [value["buckets"].get(str(le), 0)
                        for le in child.buckets] + [value.get("overflow", 0)]

    def observe(self, v) -> None:
        self._self_child().observe(v)

    @property
    def count(self):
        return self._self_child().count

    @property
    def sum(self):
        return self._self_child().sum


class MetricsRegistry:
    """The one place every telemetry scalar registers.

    Registration is idempotent for an identical spec (same type / labels /
    wall family) and raises on a conflicting re-registration, so modules can
    declare their metrics independently against a shared registry.
    """

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}
        self._collectors: list[Callable[[], None]] = []

    # -- registration --------------------------------------------------
    def _register(self, cls, name, help, labels, wall, **kw) -> Any:
        existing = self._metrics.get(name)
        if existing is not None:
            if (type(existing) is not cls
                    or existing.label_names != tuple(labels)
                    or existing.wall != bool(wall)):
                raise ValueError(
                    f"metric {name!r} re-registered with a different spec")
            return existing
        metric = cls(name, help, labels, wall=wall, **kw)
        self._metrics[name] = metric
        return metric

    def counter(self, name: str, help: str = "", labels: Iterable[str] = (),
                *, wall: bool = False) -> Counter:
        return self._register(Counter, name, help, labels, wall)

    def gauge(self, name: str, help: str = "", labels: Iterable[str] = (),
              *, wall: bool = False) -> Gauge:
        return self._register(Gauge, name, help, labels, wall)

    def histogram(self, name: str, help: str = "", labels: Iterable[str] = (),
                  *, wall: bool = False,
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, labels, wall,
                              buckets=buckets)

    def get(self, name: str) -> _Metric:
        return self._metrics[name]

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> list[str]:
        return sorted(self._metrics)

    # -- collectors ----------------------------------------------------
    def register_collector(self, fn: Callable[[], None]) -> None:
        """``fn`` runs before every snapshot/export and pushes subsystem
        state (allocator stats, tier slots, tree size) into gauges."""
        self._collectors.append(fn)

    def collect(self) -> None:
        for fn in self._collectors:
            fn()

    # -- views ---------------------------------------------------------
    def snapshot(self, *, include_wall: bool = False) -> dict[str, Any]:
        """Deterministic nested view: ``{"work": {...}, "wall": {...}}``.

        ``work`` is always byte-stable for a seeded run; ``wall`` is only
        present when requested (it never is for gating/baseline paths)."""
        self.collect()
        work: dict[str, Any] = {}
        wall: dict[str, Any] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            (wall if m.wall else work)[name] = {
                "type": m.kind, "values": m.value_dict()}
        out: dict[str, Any] = {"work": work}
        if include_wall:
            out["wall"] = wall
        return out

    # -- checkpoint round-trip ----------------------------------------
    def export_state(self) -> dict[str, Any]:
        """JSON-safe values-only state (specs live in code, like
        bench_gate's METRICS table)."""
        self.collect()
        return {name: m.value_dict()
                for name, m in sorted(self._metrics.items())}

    def restore_state(self, state: dict[str, Any]) -> None:
        """Restore values into already-registered metrics. Unknown names in
        ``state`` are ignored (forward compat); registered metrics missing
        from ``state`` keep their zeros."""
        for name, values in state.items():
            m = self._metrics.get(name)
            if m is not None:
                m.restore_values(values)
