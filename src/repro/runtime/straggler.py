"""Straggler detection for synchronous SPMD training.

In a synchronous pjit step, one slow host drags the whole mesh (every
collective waits). Detection: each host tracks an EWMA of its own step wall
time; a host whose time exceeds ``threshold``x the fleet median (exchanged
through the same allgather that carries metrics) is flagged. The production
action — documented in DESIGN.md — is hot-spare swap + elastic restart from
the latest checkpoint; here the detector and its policy hooks are implemented
and unit-tested with injected timings.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    ewma_alpha: float = 0.2
    threshold: float = 1.5       # x fleet median
    warmup_steps: int = 5        # ignore compile/first steps


class StragglerDetector:
    def __init__(self, cfg: StragglerConfig, n_hosts: int):
        self.cfg = cfg
        self.n_hosts = n_hosts
        self.ewma = np.zeros(n_hosts)
        self.steps = 0
        self.flagged: list[tuple[int, int]] = []   # (step, host)

    def update(self, per_host_times: np.ndarray) -> list[int]:
        """per_host_times [n_hosts] seconds for this step -> flagged hosts."""
        self.steps += 1
        a = self.cfg.ewma_alpha
        if self.steps == 1:
            self.ewma = per_host_times.astype(float).copy()
        else:
            self.ewma = (1 - a) * self.ewma + a * per_host_times
        if self.steps <= self.cfg.warmup_steps:
            return []
        med = float(np.median(self.ewma))
        slow = [h for h in range(self.n_hosts)
                if self.ewma[h] > self.cfg.threshold * med]
        for h in slow:
            self.flagged.append((self.steps, h))
        return slow
