"""Fault-tolerance runtime: preemption handling + checkpoint/restart loop.

Production semantics implemented here and exercised in tests:

* ``PreemptionHandler`` — installs a SIGTERM/SIGINT handler that sets a flag;
  the train loop checkpoints at the next step boundary and exits cleanly
  (the pattern for Borg/K8s preemption notices and TPU maintenance events).
* ``run_with_restarts`` — supervisor that restarts the step loop from the
  latest checkpoint after a (simulated or real) failure, up to a retry
  budget. Because checkpoints are mesh-independent (see checkpoint.py), a
  restart may come back on fewer hosts (elastic shrink after node loss).
* Failure-domain notes for >1k nodes live in DESIGN.md §10.
"""
from __future__ import annotations

import dataclasses
import random
import signal
import time
from typing import Callable


class PreemptionHandler:
    def __init__(self, install: bool = True):
        self.requested = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handle)
                except ValueError:   # not main thread (tests)
                    pass

    def _handle(self, signum, frame):
        self.requested = True

    def trigger(self):               # for tests / manual drills
        self.requested = True

    def reset(self):
        """Clear the flag for the next attempt of a restart loop (the
        handler stays installed). Without this, a restored attempt would
        observe the PREVIOUS preemption and immediately re-exit."""
        self.requested = False

    def restore(self):
        """Reinstall the signal handlers that were active before this
        handler was installed. A previous disposition captured as ``None``
        (handler set outside Python) cannot be reinstalled from Python —
        fall back to SIG_DFL rather than raising mid-teardown; likewise a
        non-main-thread teardown is a no-op, mirroring install."""
        for sig, prev in self._prev.items():
            try:
                signal.signal(sig, prev if prev is not None else
                              signal.SIG_DFL)
            except ValueError:       # not main thread (tests)
                pass
        self._prev = {}


@dataclasses.dataclass
class RestartPolicy:
    """Retry budget + backoff schedule for ``run_with_restarts``.

    ``delay(attempt)`` is exponential with a cap and optional full jitter:
    ``min(backoff_s * backoff_factor**(attempt-1), max_backoff_s)`` scaled
    by U[1-jitter, 1] (thundering-herd spreading for co-preempted workers;
    ``seed`` pins the draw for deterministic tests)."""
    max_restarts: int = 3
    backoff_s: float = 0.0
    backoff_factor: float = 2.0
    max_backoff_s: float = 30.0
    jitter: float = 0.0              # in [0, 1): fraction of spread
    seed: int | None = None

    def __post_init__(self):
        self._rng = random.Random(self.seed)

    def delay(self, attempt: int) -> float:
        if self.backoff_s <= 0.0:
            return 0.0
        d = min(self.backoff_s * self.backoff_factor ** max(attempt - 1, 0),
                self.max_backoff_s)
        if self.jitter > 0.0:
            d *= 1.0 - self.jitter * self._rng.random()
        return d


def run_with_restarts(step_loop: Callable[[], str], policy: RestartPolicy,
                      on_restart: Callable[[int], None] | None = None) -> str:
    """Run ``step_loop`` (returns "done"/"preempted") restarting on exceptions.

    ``step_loop`` is expected to resume from the latest checkpoint itself
    (see launch/train.py, launch/serve.run_engine --restartable); this
    supervisor only bounds the retry budget and paces the restarts.
    """
    attempts = 0
    while True:
        try:
            return step_loop()
        except Exception:
            attempts += 1
            if attempts > policy.max_restarts:
                raise
            if on_restart:
                on_restart(attempts)
            delay = policy.delay(attempts)
            if delay:
                time.sleep(delay)
