"""Fault-tolerance runtime: preemption handling + checkpoint/restart loop.

Production semantics implemented here and exercised in tests:

* ``PreemptionHandler`` — installs a SIGTERM/SIGINT handler that sets a flag;
  the train loop checkpoints at the next step boundary and exits cleanly
  (the pattern for Borg/K8s preemption notices and TPU maintenance events).
* ``run_with_restarts`` — supervisor that restarts the step loop from the
  latest checkpoint after a (simulated or real) failure, up to a retry
  budget. Because checkpoints are mesh-independent (see checkpoint.py), a
  restart may come back on fewer hosts (elastic shrink after node loss).
* Failure-domain notes for >1k nodes live in DESIGN.md §10.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable


class PreemptionHandler:
    def __init__(self, install: bool = True):
        self.requested = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handle)
                except ValueError:   # not main thread (tests)
                    pass

    def _handle(self, signum, frame):
        self.requested = True

    def trigger(self):               # for tests / manual drills
        self.requested = True

    def restore(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 3
    backoff_s: float = 0.0


def run_with_restarts(step_loop: Callable[[], str], policy: RestartPolicy,
                      on_restart: Callable[[int], None] | None = None) -> str:
    """Run ``step_loop`` (returns "done"/"preempted") restarting on exceptions.

    ``step_loop`` is expected to resume from the latest checkpoint itself
    (see launch/train.py); this supervisor only bounds the retry budget.
    """
    attempts = 0
    while True:
        try:
            return step_loop()
        except Exception:
            attempts += 1
            if attempts > policy.max_restarts:
                raise
            if on_restart:
                on_restart(attempts)
            if policy.backoff_s:
                time.sleep(policy.backoff_s)
