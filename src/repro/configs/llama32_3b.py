"""llama3.2-3b [dense]: 28L GQA. [hf:meta-llama/Llama-3.2-1B; unverified]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab_size=128256,
    layer_pattern=("attn",), rope_theta=500000.0, act="silu",
    subquadratic=False,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, page_size=16, max_seq_len=128)
