"""whisper-base [audio]: 6L enc + 6L dec; conv frontend is a STUB
(input_specs provides precomputed frame embeddings [B, 1500, d]).
[arXiv:2212.04356; unverified]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_head=64,
    d_ff=2048, vocab_size=51865,
    layer_pattern=("dec",), act="gelu",
    encoder_layers=6, n_aux_tokens=1500,
    subquadratic=False, tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=256, encoder_layers=2, n_aux_tokens=24,
        page_size=16, max_seq_len=128)
