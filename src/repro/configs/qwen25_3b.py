"""qwen2.5-3b [dense]: 36L GQA kv=2, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, d_head=128,
    d_ff=11008, vocab_size=151936,
    layer_pattern=("attn",), qkv_bias=True, rope_theta=1000000.0, act="silu",
    subquadratic=False,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, page_size=16, max_seq_len=128)
