"""granite-3-2b [dense]: 40L GQA. [hf:ibm-granite/granite-3.0-2b-base; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, d_head=64,
    d_ff=8192, vocab_size=49155,
    layer_pattern=("attn",), rope_theta=10000.0, act="silu",
    subquadratic=False,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, page_size=16, max_seq_len=128)
