"""recurrentgemma-9b [hybrid]: 38L, RG-LRU + local attn at 2:1 (window 2048).
38 = 12 (rglru, rglru, swa) superblocks + 2 remainder rglru layers.
[arXiv:2402.19427; unverified]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, d_head=256,
    d_ff=12288, vocab_size=256000,
    layer_pattern=("rglru", "rglru", "swa"), window=2048,
    rope_theta=10000.0, act="gelu",
    subquadratic=True, max_seq_len=524288,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, d_head=16,
        d_ff=128, vocab_size=256, window=16, page_size=16, max_seq_len=128)
