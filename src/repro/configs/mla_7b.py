"""mla-7b [mla-dense] — mid-size dense MLA model (DeepSeek-V2-Lite-like,
scaled) used for SnapMLA end-to-end throughput benchmarks."""
import dataclasses
from repro.configs.base import MLADims, ModelConfig

CONFIG = ModelConfig(
    name="mla-7b", family="mla",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, d_head=128,
    d_ff=11008, vocab_size=102400,
    layer_pattern=("mla",), rope_theta=10000.0, act="silu",
    mla=MLADims(d_c=512, d_rope=64, q_lora_rank=0),
    subquadratic=False, max_seq_len=131072,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=256, mla=MLADims(d_c=32, d_rope=16),
        page_size=16, max_seq_len=128)
