"""Architecture registry: the 10 assigned configs + the paper's MLA models.

Every config module defines ``CONFIG`` (full-size, exercised only via the
dry-run) and ``smoke()`` (reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib

from repro.configs.base import MLADims, ModelConfig  # noqa: F401

ARCH_IDS = [
    # 10 assigned architectures
    "llama-3.2-vision-90b",
    "llama3.2-3b",
    "gemma3-27b",
    "qwen2.5-3b",
    "granite-3-2b",
    "qwen3-moe-30b-a3b",
    "mixtral-8x7b",
    "recurrentgemma-9b",
    "whisper-base",
    "xlstm-1.3b",
    # the paper's own family (extra): DeepSeek-V3-style MLA MoE + a dense MLA
    "deepseek-v3-mla",
    "mla-7b",
]

_MODULES = {
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "llama3.2-3b": "llama32_3b",
    "gemma3-27b": "gemma3_27b",
    "qwen2.5-3b": "qwen25_3b",
    "granite-3-2b": "granite3_2b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b",
    "mixtral-8x7b": "mixtral_8x7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-base": "whisper_base",
    "xlstm-1.3b": "xlstm_1_3b",
    "deepseek-v3-mla": "deepseek_v3_mla",
    "mla-7b": "mla_7b",
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.smoke()
