"""gemma3-27b [dense]: 62L, 5:1 local:global (window 1024), 128k context.

62 = 10 full (5 swa + 1 attn) superblocks + 2 remainder swa layers.
[hf:google/gemma-3-1b-pt; unverified]
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_head=128,
    d_ff=21504, vocab_size=262144,
    layer_pattern=("swa", "swa", "swa", "swa", "swa", "attn"),
    window=1024, rope_theta=1000000.0, act="gelu",
    subquadratic=True,                      # dominantly local; global layers are
                                            # linear per decode step (DESIGN §5)
    max_seq_len=524288,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, window=16, page_size=16, max_seq_len=128)
