"""deepseek-v3-mla [mla-moe] — the paper's primary evaluation family.

DeepSeek-V3-style: 61L MLA (d_c=512, d_rope=64, q_lora=1536), MoE with 256
routed experts top-8 + 1 shared expert. (All layers MoE here; the real model's
first-3-dense detail is noted in DESIGN.md.) [arXiv:2412.19437]
"""
import dataclasses
from repro.configs.base import MLADims, ModelConfig
from repro.models.moe import MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-mla", family="mla",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_head=128,
    d_ff=0, vocab_size=129280,
    layer_pattern=("mla",), rope_theta=10000.0, act="silu",
    mla=MLADims(d_c=512, d_rope=64, q_lora_rank=1536),
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  capacity_factor=1.25, n_shared_experts=1),
    subquadratic=False, max_seq_len=131072,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        vocab_size=256, mla=MLADims(d_c=32, d_rope=16, q_lora_rank=48),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                      capacity_factor=1.5, n_shared_experts=1),
        page_size=16, max_seq_len=128)
