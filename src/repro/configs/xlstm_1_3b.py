"""xlstm-1.3b [ssm]: 48L mLSTM/sLSTM at 7:1, d_ff=0 (self-contained blocks).
[arXiv:2405.04517; unverified]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_head=512,
    d_ff=0, vocab_size=50304,
    layer_pattern=("mlstm",) * 7 + ("slstm",),
    act="gelu",
    subquadratic=True, max_seq_len=524288,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=8, d_model=64, n_heads=2, n_kv_heads=2, d_head=32,
        vocab_size=256, page_size=16, max_seq_len=128)
