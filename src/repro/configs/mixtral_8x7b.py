"""mixtral-8x7b [moe]: 32L, 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
import dataclasses
from repro.configs.base import ModelConfig
from repro.models.moe import MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=0, vocab_size=32000,
    layer_pattern=("swa",), window=4096, rope_theta=1000000.0, act="silu",
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336, capacity_factor=1.25),
    subquadratic=True,                      # SWA bounds every layer's cache
    max_seq_len=524288,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        vocab_size=256, window=16,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, capacity_factor=1.5),
        page_size=16, max_seq_len=128)
