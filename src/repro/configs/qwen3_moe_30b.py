"""qwen3-moe-30b-a3b [moe]: 48L, 128 experts top-8 (d_ff_expert=768).
[hf:Qwen/Qwen3-30B-A3B; hf]"""
import dataclasses
from repro.configs.base import ModelConfig
from repro.models.moe import MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=0, vocab_size=151936,
    layer_pattern=("attn",), rope_theta=1000000.0, act="silu",
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768, capacity_factor=1.25),
    subquadratic=False,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        vocab_size=256,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, capacity_factor=1.5),
        page_size=16, max_seq_len=128)
