"""ModelConfig — the single config dataclass every architecture instantiates.

Layer heterogeneity is expressed through ``layer_pattern``: a tuple of layer
kinds that is tiled across ``n_layers``. Full tiles are scanned (stacked
params, lax.scan over superblocks — MaxText-style, keeps HLO size flat in
depth); a remainder of ``n_layers % len(pattern)`` layers is applied unscanned.

Layer kinds:
  attn    full causal self-attention (GQA)
  swa     sliding-window self-attention (ring-buffer cache at decode)
  mla     Multi-head Latent Attention (the paper's family; SnapMLA decode)
  cross   cross-attention block (llama-vision style gated cross + MLP)
  dec     enc-dec decoder block: self-attn + cross-attn + MLP (whisper)
  rglru   Griffin RG-LRU recurrent block (no MLP pairing if d_ff == 0)
  mlstm   xLSTM matrix-memory block (self-contained, no MLP)
  slstm   xLSTM scalar-memory block (self-contained, no MLP)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models.moe import MoEConfig


@dataclasses.dataclass(frozen=True)
class MLADims:
    d_c: int = 512
    d_rope: int = 64
    q_lora_rank: int = 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | mla | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    layer_pattern: Tuple[str, ...] = ("attn",)
    window: int = 0                  # for 'swa' layers
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    act: str = "silu"
    moe: Optional[MoEConfig] = None  # if set, MLPs are MoE
    first_k_dense: int = 0           # deepseek: first k layers use dense MLP
    mla: Optional[MLADims] = None
    # enc-dec / multimodal stub (precomputed frame/patch embeddings)
    encoder_layers: int = 0          # whisper transformer encoder depth
    n_aux_tokens: int = 0            # encoder frames (whisper) / image patches (vlm)
    # serving / quantized KV cache (the paper's technique)
    kv_fmt: str = "fp8_e4m3"         # fp8_e4m3 | int8 | none (bf16 baseline)
    page_size: int = 128
    # split-KV (flash-decoding) sequence parallelism in decode attention:
    # 0 = auto (measured split profile, else context-length heuristic),
    # 1 = single-pass, >1 = fixed splits. Applies to both cache layouts.
    kv_splits: int = 0
    # decode-attention KV block size for CONTIGUOUS caches: 0 = page_size
    # (the seed behavior), >0 = explicit override (must divide the cache
    # capacity; `serve --block-n`). Paged caches ignore it — their block
    # size is structurally the physical page (set page_size instead).
    kv_block_n: int = 0
    # per-block accumulator rescale in the decode kernels: "fma" = the exact
    # max-shift FMA (seed), "amla" = the AMLA exponent-add fast path with
    # combine-free split-KV partials (power-of-two sigma_p grid; differs
    # from fma only at P-quantization rounding level)
    kv_rescale: str = "fma"
    # P-Cast sink guard: keep the first k tokens' latent content rows in full
    # precision (attention sinks concentrate probability mass and are the
    # most quantization-sensitive rows in the cache; the decoupled-RoPE part
    # is already high-precision). Contiguous MLA caches only — paged pools
    # keep every page quantized. 0 disables (the seed behavior).
    kv_sink_tokens: int = 0
    # paged KV cache for 'mla' layers at decode: the latent cache lives in a
    # page pool addressed through a per-sequence page table (multi-tenant
    # pool layout) instead of a contiguous per-slot [B, N, ...] cache
    kv_paged: bool = False
    # >0: size the paged pool as a SHARED multi-tenant pool with this many
    # physical pages and an initially-empty page table (all entries parked on
    # the page-0 scratch page) — the layout the serving engine's free-list
    # allocator (serving.allocator.PageAllocator) hands pages out of. 0 keeps
    # the batch-owned layout (each slot owns a private strided run of pages).
    kv_pool_pages: int = 0
    # >0: the serving engine splits prompt admission into fixed-size chunks of
    # this many tokens and runs at most a token-budgeted amount of prefill
    # work per engine step alongside the ongoing slot-batched decode (later
    # chunks attend to earlier chunks' already-quantized FP8 pages through the
    # fused fetch-dequant path — no bf16 re-materialization of the prefix).
    # Chunk shapes are bucketed to powers of two up to this value so the
    # engine compiles O(log chunk) prefill variants instead of one per prompt
    # length. 0 keeps the monolithic one-shot prefill.
    prefill_chunk: int = 0
    # run the Pallas decode kernels inside the jitted model decode (interpret
    # mode on CPU, compiled on TPU) instead of the pure-jnp einsum twins;
    # consulted by decode_backend == "auto"
    use_kernels: bool = False
    # decode-attention backend request, resolved per step by
    # kernels.mla_decode.backends.resolve_backend: "auto" (shard_map when the
    # mesh context asks for it, Pallas kernels when use_kernels, else the
    # pjit ref twin), "ref", "kernel", "shard-map", or an exact registry name
    decode_backend: str = "auto"
    # capability flags for the shape grid
    subquadratic: bool = False       # can run long_500k decode
    has_decoder: bool = True         # encoder-only archs would be False
    max_seq_len: int = 131072
    tie_embeddings: bool = True
    # cost-accounting mode: unroll layer/flash scans so HLO cost analysis is
    # exact (while-loop bodies are otherwise counted once). Lowering-only.
    cost_exact: bool = False

    # ---------------------------------------------------------------
    @property
    def pattern_len(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_superblocks(self) -> int:
        return self.n_layers // self.pattern_len

    @property
    def remainder_kinds(self) -> Tuple[str, ...]:
        r = self.n_layers % self.pattern_len
        return self.layer_pattern[:r]

    @property
    def has_mlp(self) -> bool:
        return self.d_ff > 0 or self.moe is not None

    def param_count(self) -> int:
        """Approximate parameter count (for 6ND model-FLOPs accounting)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d
        per_layer = 0
        n_attn = sum(1 for i in range(L) if self._kind(i) in ("attn", "swa", "dec"))
        n_cross = sum(1 for i in range(L) if self._kind(i) in ("cross", "dec"))
        n_mla = sum(1 for i in range(L) if self._kind(i) == "mla")
        n_rglru = sum(1 for i in range(L) if self._kind(i) == "rglru")
        n_xlstm = sum(1 for i in range(L) if self._kind(i) in ("mlstm", "slstm"))
        attn_p = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head \
            + self.n_heads * self.d_head * d
        total = emb + n_attn * attn_p + n_cross * attn_p
        if self.mla:
            m = self.mla
            q_in = m.q_lora_rank or d
            mla_p = (d * m.q_lora_rank if m.q_lora_rank else 0) \
                + q_in * self.n_heads * (self.d_head + m.d_rope) \
                + d * (m.d_c + m.d_rope) \
                + 2 * m.d_c * self.n_heads * self.d_head \
                + self.n_heads * self.d_head * d
            total += n_mla * mla_p
        total += n_rglru * (3 * d * d + 2 * d * d)          # approx (d_rnn = d)
        total += n_xlstm * (4 * d * self.n_heads * self.d_head * 2)
        # MLPs
        n_mlp = sum(1 for i in range(L) if self._kind(i) in
                    ("attn", "swa", "mla", "cross", "dec", "rglru")) if self.has_mlp else 0
        if self.moe is not None:
            dense_layers = min(self.first_k_dense, n_mlp)
            moe_layers = n_mlp - dense_layers
            total += dense_layers * 3 * d * self.d_ff
            total += moe_layers * (d * self.moe.n_experts
                                   + 3 * d * self.moe.d_ff_expert * self.moe.n_experts
                                   + 3 * d * self.moe.d_ff_expert * self.moe.n_shared_experts)
        elif self.d_ff:
            total += n_mlp * 3 * d * self.d_ff
        if self.encoder_layers:
            total += self.encoder_layers * (attn_p + 3 * d * self.d_ff)
        if not self.tie_embeddings:
            total += emb
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE-aware) for 6·N_active·D."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        L = self.n_layers
        n_mlp = sum(1 for i in range(L) if self._kind(i) in
                    ("attn", "swa", "mla", "cross", "dec", "rglru"))
        moe_layers = n_mlp - min(self.first_k_dense, n_mlp)
        all_expert = moe_layers * 3 * self.d_model * self.moe.d_ff_expert * self.moe.n_experts
        act_expert = moe_layers * 3 * self.d_model * self.moe.d_ff_expert * self.moe.top_k
        return int(full - all_expert + act_expert)

    def _kind(self, i: int) -> str:
        return self.layer_pattern[i % self.pattern_len]

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)
