"""llama-3.2-vision-90b [vlm]: 100L, cross-attn image layers every 5th layer.

Backbone only; the vision frontend is a stub — input_specs provides
precomputed patch embeddings (4 tiles x 1601 patches).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=28672, vocab_size=128256,
    layer_pattern=("attn", "attn", "attn", "attn", "cross"),
    rope_theta=500000.0, act="silu",
    n_aux_tokens=6404,                      # 4 tiles x 1601 patch embeddings
    subquadratic=False, tie_embeddings=False,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, n_aux_tokens=24, page_size=16, max_seq_len=128)
