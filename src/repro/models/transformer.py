"""Unified decoder stack: init / train-forward / prefill / decode for every
assigned architecture family.

Structure (MaxText-style): layers are grouped into superblocks of
``cfg.layer_pattern``; full tiles are applied under ``jax.lax.scan`` with
parameters stacked along a leading superblock axis (keeps HLO size flat in
depth — essential for 100-layer dry-run compiles), plus an unscanned
remainder. Decode threads per-layer states (quantized KV caches / recurrent
states) through the same scan.

Decode attention dispatches through the backend registry
(``kernels/mla_decode/backends.py``): by default the pure-jnp einsum twins
(pjit/cost-analysis friendly), with ``cfg.use_kernels=True`` (or
``cfg.decode_backend="kernel"``, ``serve --backend kernel``) the actual
Pallas split-KV kernels run inside the jitted decode step — interpret mode
on CPU, compiled on TPU.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import mla as mla_lib
from repro.core.kvcache import (CacheConfig, GQACache, MLACache, gqa_append,
                                gqa_prefill, init_gqa_cache, init_mla_cache,
                                init_paged_mla_cache, mla_append, mla_prefill,
                                paged_mla_append, paged_mla_prefill,
                                paged_mla_prefill_at)
from repro.core.attention import gqa_decode_dequant_ref, mla_decode_dequant_ref
from repro.kernels.gqa_decode import ref as gqa_ref
from repro.kernels.mla_decode import backends as BK
from repro.kernels.mla_decode import ref as mla_kref
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import xlstm as xlstm_lib


# ---------------------------------------------------------------------------
# Config plumbing
# ---------------------------------------------------------------------------

def _attn_cfg(cfg: ModelConfig, kind: str) -> L.AttnConfig:
    return L.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        d_head=cfg.d_head, rope_theta=cfg.rope_theta, qkv_bias=cfg.qkv_bias,
        window=cfg.window if kind == "swa" else 0,
        use_rope=True)


def _mla_cfg(cfg: ModelConfig) -> mla_lib.MLAConfig:
    m = cfg.mla
    return mla_lib.MLAConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, d_head=cfg.d_head,
        d_rope=m.d_rope, d_c=m.d_c, q_lora_rank=m.q_lora_rank,
        rope_theta=cfg.rope_theta)


def _cache_cfg(cfg: ModelConfig, kind: str) -> CacheConfig:
    # kv_sink_tokens only arms the guard on contiguous MLA caches — GQA
    # caches and paged pools ignore it (init_gqa_cache / init_paged_mla_*
    # never allocate a sink shadow).
    return CacheConfig(fmt=cfg.kv_fmt, page_size=cfg.page_size,
                       window=cfg.window if kind == "swa" else 0,
                       sink_tokens=0 if kind != "mla" or cfg.kv_paged
                       else cfg.kv_sink_tokens)


# ---------------------------------------------------------------------------
# Per-layer parameter init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, kind: str, layer_idx_hint: int, dtype):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), dtype)}
    if kind in ("attn", "swa"):
        p["mixer"] = L.init_attn_params(ks[0], _attn_cfg(cfg, kind), dtype)
    elif kind == "mla":
        p["mixer"] = mla_lib.init_mla_params(ks[0], _mla_cfg(cfg), dtype)
    elif kind == "cross":
        p["mixer"] = L.init_attn_params(ks[0], _attn_cfg(cfg, kind), dtype)
        p["xgate"] = jnp.zeros((1,), dtype)          # tanh-gated (llama-vision)
    elif kind == "dec":
        p["mixer"] = L.init_attn_params(ks[0], _attn_cfg(cfg, kind), dtype)
        p["ln_cross"] = jnp.ones((cfg.d_model,), dtype)
        p["cross"] = L.init_attn_params(ks[1], _attn_cfg(cfg, kind), dtype)
    elif kind == "rglru":
        p["mixer"] = rglru_lib.init_rglru_params(ks[0], cfg.d_model, cfg.d_model, dtype)
    elif kind == "mlstm":
        p["mixer"] = xlstm_lib.init_mlstm_params(ks[0], cfg.d_model, cfg.n_heads,
                                                 cfg.d_head, dtype)
    elif kind == "slstm":
        p["mixer"] = xlstm_lib.init_slstm_params(ks[0], cfg.d_model, cfg.n_heads,
                                                 cfg.d_head, dtype)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")

    if cfg.has_mlp and kind not in ("mlstm", "slstm"):
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        if cfg.moe is not None and layer_idx_hint >= cfg.first_k_dense:
            p["mlp"] = moe_lib.init_moe_params(ks[2], cfg.d_model, cfg.moe, dtype)
        elif cfg.d_ff:
            p["mlp"] = L.init_mlp_params(ks[2], cfg.d_model, cfg.d_ff, True, dtype)
    return p


def init_model(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": L.init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.init_embedding(ks[1], cfg.vocab_size, cfg.d_model, dtype)

    # scanned superblocks: stack params along a leading axis per pattern slot
    if cfg.n_superblocks > 0:
        def init_block(bkey):
            bks = jax.random.split(bkey, cfg.pattern_len)
            return [
                _init_layer(bks[i], cfg, kind, cfg.first_k_dense, dtype)
                for i, kind in enumerate(cfg.layer_pattern)
            ]
        params["scanned"] = jax.vmap(init_block)(
            jax.random.split(ks[2], cfg.n_superblocks))
    # remainder layers (unscanned)
    params["tail"] = [
        _init_layer(k, cfg, kind, cfg.first_k_dense, dtype)
        for k, kind in zip(jax.random.split(ks[3], max(1, len(cfg.remainder_kinds))),
                           cfg.remainder_kinds)
    ]
    # deepseek-style first-k-dense layers are materialized inside the scan with
    # MoE params; for simplicity first_k_dense>0 swaps those layers into tail.
    if cfg.encoder_layers:
        def init_enc(bkey):
            return _init_layer(bkey, dataclasses.replace(cfg, moe=None), "attn", 0, dtype)
        params["encoder"] = jax.vmap(init_enc)(
            jax.random.split(ks[4], cfg.encoder_layers))
        params["enc_ln_f"] = jnp.ones((cfg.d_model,), dtype)
    return params


# ---------------------------------------------------------------------------
# Train / prefill forward
# ---------------------------------------------------------------------------

def _apply_mlp(p, cfg: ModelConfig, x):
    if "mlp" not in p:
        return x, 0.0
    h = L.rms_norm(x, p["ln2"])
    if cfg.moe is not None and isinstance(p["mlp"], moe_lib.MoEParams):
        out, dropped = moe_lib.moe_layer(p["mlp"], cfg.moe, h,
                                         act={"silu": jax.nn.silu,
                                              "gelu": jax.nn.gelu}[cfg.act])
        return x + out, dropped
    return x + L.mlp(p["mlp"], h, cfg.act), 0.0


def _apply_block_train(p, cfg: ModelConfig, kind: str, x, positions, aux):
    h = L.rms_norm(x, p["ln1"])
    if kind in ("attn", "swa"):
        x = x + L.attention_block(p["mixer"], _attn_cfg(cfg, kind), h, positions,
                                  unroll=cfg.cost_exact)
    elif kind == "mla":
        x = x + mla_lib.mla_attention(p["mixer"], _mla_cfg(cfg), h, positions)
    elif kind == "cross":
        g = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x.dtype)
        x = x + g * L.cross_attention_block(p["mixer"], _attn_cfg(cfg, kind), h, aux)
    elif kind == "dec":
        x = x + L.attention_block(p["mixer"], _attn_cfg(cfg, kind), h, positions)
        hc = L.rms_norm(x, p["ln_cross"])
        x = x + L.cross_attention_block(p["cross"], _attn_cfg(cfg, kind), hc, aux)
    elif kind == "rglru":
        y, _ = rglru_lib.rglru_block(p["mixer"], h)
        x = x + y
    elif kind == "mlstm":
        y, _ = xlstm_lib.mlstm_block(p["mixer"], h)
        return x + y, 0.0                              # self-contained, no MLP
    elif kind == "slstm":
        y, _ = xlstm_lib.slstm_block(p["mixer"], h)
        return x + y, 0.0
    return _apply_mlp(p, cfg, x)


def _run_encoder(params, cfg: ModelConfig, aux_embed):
    """Whisper-style bidirectional transformer encoder over frame embeddings."""
    if cfg.encoder_layers == 0 or aux_embed is None:
        return aux_embed
    positions = jnp.arange(aux_embed.shape[1])
    enc_cfg = dataclasses.replace(cfg, moe=None)

    def body(x, p):
        h = L.rms_norm(x, p["ln1"])
        x = x + L.attention_block(p["mixer"], _attn_cfg(enc_cfg, "attn"), h,
                                  positions, causal=False)
        x, _ = _apply_mlp(p, enc_cfg, x)
        return x, None

    x, _ = jax.lax.scan(body, aux_embed, params["encoder"])
    return L.rms_norm(x, params["enc_ln_f"])


def forward(params, cfg: ModelConfig, tokens: jax.Array,
            aux_embed: jax.Array | None = None, remat: bool = True):
    """Training forward: tokens [B, S] -> logits [B, S, V] (f32)."""
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens)
    positions = jnp.arange(S)
    aux = _run_encoder(params, cfg, aux_embed)

    aux_losses = 0.0
    if cfg.n_superblocks > 0:
        def superblock(x, block_params):
            dropped = 0.0
            for i, kind in enumerate(cfg.layer_pattern):
                x, d = _apply_block_train(block_params[i], cfg, kind, x, positions, aux)
                dropped = dropped + d
            return x, dropped

        sb = jax.checkpoint(superblock) if remat else superblock
        if cfg.cost_exact:
            # unrolled (no while loop): exact under HLO cost analysis
            for i in range(cfg.n_superblocks):
                bp = jax.tree.map(lambda a: a[i], params["scanned"])
                x, d = sb(x, bp)
                aux_losses = aux_losses + d
        else:
            x, droppeds = jax.lax.scan(sb, x, params["scanned"])
            aux_losses = jnp.sum(droppeds)
    for p, kind in zip(params["tail"], cfg.remainder_kinds):
        x, d = _apply_block_train(p, cfg, kind, x, positions, aux)
        aux_losses = aux_losses + d

    x = L.rms_norm(x, params["ln_f"])
    table = params.get("unembed", params["embed"])
    return L.unembed(table, x), aux_losses


def loss_fn(params, cfg: ModelConfig, tokens, labels, aux_embed=None, remat=True):
    """Next-token cross entropy; labels == -1 are masked."""
    logits, aux = forward(params, cfg, tokens, aux_embed, remat)
    V = logits.shape[-1]
    mask = labels >= 0
    lab = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss, {"ce": loss, "moe_dropped": aux}


# ---------------------------------------------------------------------------
# Decode state
# ---------------------------------------------------------------------------

def _init_layer_state(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind in ("attn", "swa"):
        return init_gqa_cache(_cache_cfg(cfg, kind), batch, max_len,
                              cfg.n_kv_heads, cfg.d_head)
    if kind == "mla":
        if cfg.kv_paged:
            # kv_pool_pages > 0 switches to the shared multi-tenant pool
            # (empty tables; the serving engine's allocator owns the rows)
            return init_paged_mla_cache(_cache_cfg(cfg, kind), batch, max_len,
                                        cfg.mla.d_c, cfg.mla.d_rope,
                                        n_pages=cfg.kv_pool_pages)
        return init_mla_cache(_cache_cfg(cfg, kind), batch, max_len,
                              cfg.mla.d_c, cfg.mla.d_rope)
    if kind == "cross":
        return init_gqa_cache(_cache_cfg(cfg, "attn"), batch,
                              max(cfg.n_aux_tokens, 1), cfg.n_kv_heads, cfg.d_head)
    if kind == "dec":
        return {
            "self": init_gqa_cache(_cache_cfg(cfg, "attn"), batch, max_len,
                                   cfg.n_kv_heads, cfg.d_head),
            "cross": init_gqa_cache(_cache_cfg(cfg, "attn"), batch,
                                    max(cfg.n_aux_tokens, 1), cfg.n_kv_heads,
                                    cfg.d_head),
        }
    if kind == "rglru":
        return rglru_lib.init_rglru_state(batch, cfg.d_model)
    if kind == "mlstm":
        return xlstm_lib.init_mlstm_state(batch, cfg.n_heads, cfg.d_head)
    if kind == "slstm":
        return xlstm_lib.init_slstm_state(batch, cfg.n_heads, cfg.d_head)
    raise ValueError(kind)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    state: dict[str, Any] = {}
    if cfg.n_superblocks > 0:
        def one(_):
            return [
                _init_layer_state(cfg, kind, batch, max_len)
                for kind in cfg.layer_pattern
            ]
        state["scanned"] = jax.vmap(lambda i: one(i))(jnp.arange(cfg.n_superblocks))
    state["tail"] = [
        _init_layer_state(cfg, kind, batch, max_len)
        for kind in cfg.remainder_kinds
    ]
    state["aux"] = None       # encoder output / image embeddings, set at prefill
    return state


# ---------------------------------------------------------------------------
# Decode step (quantized SnapMLA pipeline semantics)
# ---------------------------------------------------------------------------

# Optional sharding-constraint context for the distributed decode path
# (set by launch/dryrun.py; see EXPERIMENTS §Perf "attention locality"):
# {"mesh": Mesh, "dp": axis-or-tuple-or-None}. Constrains per-head decode
# tensors to stay 'model'-sharded on heads, preventing GSPMD from resharding
# the (huge) KV cache through all-gathers.
SHARD_CTX = None


def _wsc(x, *spec):
    if SHARD_CTX is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec
    mesh = SHARD_CTX["mesh"]
    parts = []
    for p_, dim in zip(spec, x.shape):
        if p_ == "model" and dim % mesh.shape["model"] != 0:
            p_ = None
        elif p_ == "dp":
            p_ = SHARD_CTX["dp"]
        parts.append(p_)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*parts)))

def _attn_decode(p, cfg: ModelConfig, kind: str, x_t, cache: GQACache, pos,
                 active=None):
    """One-token GQA/SWA decode against a quantized cache. ``active`` [B]
    bool gates the cache append per row (finished-row skipping in the fused
    scan); inactive rows keep a frozen cache and produce garbage (finite,
    never-read) outputs."""
    acfg = _attn_cfg(cfg, kind)
    ccfg = _cache_cfg(cfg, kind)
    q, k, v = L.project_qkv(p, acfg, x_t[:, None, :], pos[:, None])
    if active is not None:
        q = jnp.where(active[:, None, None, None], q, 0.0)
    cache = gqa_append(cache, ccfg, k[:, 0], v[:, 0], active=active)
    window = cfg.window if kind == "swa" else 0
    qd = _wsc(q[:, 0].astype(jnp.float32), "dp", "model", None)
    o = gqa_ref.gqa_decode_parallel_ref(
        qd, cache.k, cache.v, cache.k_scale,
        cache.v_scale, cache.slot_pos, pos, window=window,
        block_n=ccfg.page_size, fmt=ccfg.fmt if ccfg.quantized else "none")
    o = _wsc(o, "dp", "model", None)
    o = jnp.einsum("bhk,hkd->bd", o.astype(x_t.dtype), p.wo)
    return o, cache


def _cross_decode(p, cfg: ModelConfig, x_t, cache: GQACache):
    """One-token cross-attention against the static (quantized) aux cache."""
    q = jnp.einsum("bd,dhk->bhk", x_t, p.wq)
    if p.bq is not None:
        q = q + p.bq
    pos = jnp.full((x_t.shape[0],), jnp.iinfo(jnp.int32).max - 1, jnp.int32)
    ccfg = _cache_cfg(cfg, "attn")
    o = gqa_ref.gqa_decode_parallel_ref(
        q.astype(jnp.float32), cache.k, cache.v, cache.k_scale,
        cache.v_scale, cache.slot_pos, pos, window=0,
        block_n=ccfg.page_size, fmt=ccfg.fmt if ccfg.quantized else "none")
    return jnp.einsum("bhk,hkd->bd", o.astype(x_t.dtype), p.wo)


def _mla_decode(p, cfg: ModelConfig, x_t, cache, pos, active=None):
    """SnapMLA decode: Fused-Q-Quant + Fused-K-Append + backend attention.

    The attention itself is dispatched through the decode-attention backend
    registry (``kernels.mla_decode.backends.resolve_backend``) — the single
    decision point shared with ``core.snapmla.decode_step`` and
    ``serve --backend``. ``cfg.decode_backend`` / ``cfg.use_kernels`` select
    between the pjit einsum twins (``jnp_ref`` / ``jnp_paged_ref``), the
    Pallas split-KV kernels (``pallas_splitkv`` / ``pallas_paged_splitkv``,
    interpret mode on CPU, compiled on TPU — the paged kernel reads pages
    through scalar-prefetched index maps, so HBM traffic follows seq_lens,
    not pool capacity), and the collective-free ``shard_map`` region (set by
    launch/dryrun.py via SHARD_CTX; contiguous caches, shapes permitting).
    """
    mcfg = _mla_cfg(cfg)
    ccfg = _cache_cfg(cfg, "mla")
    paged = cfg.kv_paged
    ctx = SHARD_CTX
    backend = BK.resolve_backend(
        cfg.decode_backend, paged=paged, batch=x_t.shape[0],
        n_heads=cfg.n_heads,
        mesh=ctx["mesh"] if ctx else None, dp=ctx["dp"] if ctx else None,
        use_kernels=cfg.use_kernels,
        prefer_shard_map=bool(ctx and ctx.get("use_shard_map")))
    c_kv, k_r = mla_lib.project_kv(p, mcfg, x_t[:, None, :], pos[:, None])
    if paged:
        cache = paged_mla_append(cache, ccfg, c_kv[:, 0], k_r[:, 0],
                                 active=active)
    elif backend.name == "shard_map":
        # gated like the pjit append: ``active`` is a batch-dim mask, so it
        # shards over dp into the collective-free region — finished rows
        # freeze their seq_lens here too, and the split-KV early exit's
        # saving applies on every backend
        from repro.core.distributed_decode import mla_append_shard_map
        cache = mla_append_shard_map(ctx["mesh"], ctx["dp"], cache, ccfg,
                                     c_kv[:, 0], k_r[:, 0], active=active)
    else:
        cache = mla_append(cache, ccfg, c_kv[:, 0], k_r[:, 0], active=active)
    q_c, q_r = mla_lib.project_q(p, mcfg, x_t[:, None, :], pos[:, None])
    if active is not None:
        # finished rows: zero the query (quantize_per_token's EPS floor keeps
        # the scale finite, so the masked row's attention is a uniform — and
        # finite — average over its frozen live region, never read again)
        q_c = jnp.where(active[:, None, None, None], q_c, 0.0)
        q_r = jnp.where(active[:, None, None, None], q_r, 0.0)
    q_lat = _wsc(mla_lib.absorb_q(p, q_c[:, 0]), "dp", "model", None)
    fmt = ccfg.fmt if ccfg.quantized else "none"
    q_c8, q_r_s, sigma_q = mla_kref.prepare_q(q_lat, q_r[:, 0], fmt)
    q_c8 = _wsc(q_c8, "dp", "model", None)
    bcfg = BK.BackendConfig(softmax_scale=mcfg.softmax_scale,
                            block_n=cfg.kv_block_n or ccfg.page_size, fmt=fmt,
                            num_splits=cfg.kv_splits,
                            rescale=cfg.kv_rescale)
    o_lat = backend.decode(
        BK.DecodeQuery(q_c8, q_r_s, sigma_q), cache, bcfg,
        {"mesh": ctx["mesh"], "dp": ctx["dp"]} if ctx else None)
    o_lat = _wsc(o_lat, "dp", "model", None)
    return mla_lib.output_proj(p, o_lat.astype(x_t.dtype)), cache


def _freeze_inactive(active, new_state, old_state):
    """Per-row recurrent-state freeze: keep old rows where ``active`` is
    False (leaves are [B, ...], tiny next to KV caches)."""
    def sel(new, old):
        mask = active.reshape(active.shape + (1,) * (new.ndim - 1))
        return jnp.where(mask, new, old)
    return jax.tree.map(sel, new_state, old_state)


def _apply_block_decode(p, cfg: ModelConfig, kind: str, x_t, state, pos,
                        active=None):
    h = L.rms_norm(x_t, p["ln1"])
    if kind in ("attn", "swa"):
        y, state = _attn_decode(p["mixer"], cfg, kind, h, state, pos, active)
        x_t = x_t + y
    elif kind == "mla":
        y, state = _mla_decode(p["mixer"], cfg, h, state, pos, active)
        x_t = x_t + y
    elif kind == "cross":
        g = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x_t.dtype)
        x_t = x_t + g * _cross_decode(p["mixer"], cfg, h, state)
    elif kind == "dec":
        y, self_c = _attn_decode(p["mixer"], cfg, "attn", h, state["self"],
                                 pos, active)
        x_t = x_t + y
        hc = L.rms_norm(x_t, p["ln_cross"])
        x_t = x_t + _cross_decode(p["cross"], cfg, hc, state["cross"])
        state = {"self": self_c, "cross": state["cross"]}
    elif kind == "rglru":
        old = state
        y, state = rglru_lib.rglru_step(p["mixer"], h, state)
        if active is not None:
            state = _freeze_inactive(active, state, old)
        x_t = x_t + y
    elif kind == "mlstm":
        old = state
        y, state = xlstm_lib.mlstm_step(p["mixer"], h, state)
        if active is not None:
            state = _freeze_inactive(active, state, old)
        return x_t + y, state
    elif kind == "slstm":
        old = state
        y, state = xlstm_lib.slstm_step(p["mixer"], h, state)
        if active is not None:
            state = _freeze_inactive(active, state, old)
        return x_t + y, state
    x_t, _ = _apply_mlp(p, cfg, x_t)
    return x_t, state


def decode_step(params, cfg: ModelConfig, token: jax.Array, state,
                pos: jax.Array, active: jax.Array | None = None):
    """token [B] int32, pos [B] int32 -> (logits [B, V], new state).

    ``active`` [B] bool (optional) marks rows still generating: inactive
    (EOS-finished) rows skip every cache append / recurrent-state update
    (their ``seq_lens`` freeze, so length-driven early exits stop paying for
    them) and run with zeroed queries. ``active=None`` is bit-identical to
    the ungated step."""
    x_t = L.embed(params["embed"], token)
    aux = state.get("aux")

    new_state = dict(state)
    if cfg.n_superblocks > 0:
        def step(x_t, inputs):
            block_params, block_state = inputs
            new_states = []
            for i, kind in enumerate(cfg.layer_pattern):
                x_t, s = _apply_block_decode(block_params[i], cfg, kind, x_t,
                                             block_state[i], pos, active)
                new_states.append(s)
            return x_t, new_states

        if cfg.cost_exact:
            outs = []
            for i in range(cfg.n_superblocks):
                bp = jax.tree.map(lambda a: a[i], params["scanned"])
                bs = jax.tree.map(lambda a: a[i], state["scanned"])
                x_t, ns = step(x_t, (bp, bs))
                outs.append(ns)
            new_state["scanned"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *outs)
        else:
            x_t, scanned_states = jax.lax.scan(
                step, x_t, (params["scanned"], state["scanned"]))
            new_state["scanned"] = scanned_states
    tail_states = []
    for p, kind, s in zip(params["tail"], cfg.remainder_kinds, state["tail"]):
        x_t, s = _apply_block_decode(p, cfg, kind, x_t, s, pos, active)
        tail_states.append(s)
    new_state["tail"] = tail_states

    x_t = L.rms_norm(x_t, params["ln_f"])
    table = params.get("unembed", params["embed"])
    logits = jnp.einsum("bd,vd->bv", x_t.astype(jnp.float32),
                        table.astype(jnp.float32))
    return logits, new_state


# ---------------------------------------------------------------------------
# Prefill (prompt -> cache states + last-token logits)
# ---------------------------------------------------------------------------

def _prefill_layer_state(p, cfg: ModelConfig, kind: str, x, state, aux):
    """Compute the post-prompt state for one layer while producing its output."""
    positions = jnp.arange(x.shape[1])
    h = L.rms_norm(x, p["ln1"])
    if kind in ("attn", "swa"):
        acfg = _attn_cfg(cfg, kind)
        q, k, v = L.project_qkv(p["mixer"], acfg, h, positions)
        o = L.flash_sdpa(q, k, v, causal=True, window=acfg.window,
                         unroll=cfg.cost_exact)
        state = gqa_prefill(state, _cache_cfg(cfg, kind), k, v)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["mixer"].wo)
    elif kind == "mla":
        mcfg = _mla_cfg(cfg)
        x = x + mla_lib.mla_attention(p["mixer"], mcfg, h, positions)
        c_kv, k_r = mla_lib.project_kv(p["mixer"], mcfg, h, positions)
        fill = paged_mla_prefill if cfg.kv_paged else mla_prefill
        state = fill(state, _cache_cfg(cfg, "mla"), c_kv, k_r)
    elif kind == "cross":
        g = jnp.tanh(p["xgate"].astype(jnp.float32)).astype(x.dtype)
        x = x + g * L.cross_attention_block(p["mixer"], _attn_cfg(cfg, kind), h, aux)
        state = _fill_cross_cache(p["mixer"], cfg, aux, state)
    elif kind == "dec":
        acfg = _attn_cfg(cfg, kind)
        q, k, v = L.project_qkv(p["mixer"], acfg, h, positions)
        o = L.flash_sdpa(q, k, v, causal=True, unroll=cfg.cost_exact)
        self_c = gqa_prefill(state["self"], _cache_cfg(cfg, "attn"), k, v)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["mixer"].wo)
        hc = L.rms_norm(x, p["ln_cross"])
        x = x + L.cross_attention_block(p["cross"], acfg, hc, aux)
        state = {"self": self_c,
                 "cross": _fill_cross_cache(p["cross"], cfg, aux, state["cross"])}
    elif kind == "rglru":
        y, state = rglru_lib.rglru_block(p["mixer"], h)
        x = x + y
    elif kind == "mlstm":
        y, state = xlstm_lib.mlstm_block(p["mixer"], h)
        return x + y, state
    elif kind == "slstm":
        y, state = xlstm_lib.slstm_block(p["mixer"], h)
        return x + y, state
    x, _ = _apply_mlp(p, cfg, x)
    return x, state


def _fill_cross_cache(attn_p, cfg: ModelConfig, aux, cache: GQACache) -> GQACache:
    k = jnp.einsum("bsd,dhk->bshk", aux, attn_p.wk)
    v = jnp.einsum("bsd,dhk->bshk", aux, attn_p.wv)
    if attn_p.bk is not None:
        k, v = k + attn_p.bk, v + attn_p.bv
    return gqa_prefill(cache, _cache_cfg(cfg, "attn"), k, v)


def prefill(params, cfg: ModelConfig, tokens: jax.Array, state,
            aux_embed: jax.Array | None = None):
    """tokens [B, S] -> (last-token logits [B, V], filled decode state)."""
    x = L.embed(params["embed"], tokens)
    aux = _run_encoder(params, cfg, aux_embed)
    new_state = dict(state)
    new_state["aux"] = aux

    if cfg.n_superblocks > 0:
        def step(x, inputs):
            block_params, block_state = inputs
            new_states = []
            for i, kind in enumerate(cfg.layer_pattern):
                x, s = _prefill_layer_state(block_params[i], cfg, kind, x,
                                            block_state[i], aux)
                new_states.append(s)
            return x, new_states

        if cfg.cost_exact:
            outs = []
            for i in range(cfg.n_superblocks):
                bp = jax.tree.map(lambda a: a[i], params["scanned"])
                bs = jax.tree.map(lambda a: a[i], state["scanned"])
                x, ns = step(x, (bp, bs))
                outs.append(ns)
            new_state["scanned"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *outs)
        else:
            x, scanned_states = jax.lax.scan(
                step, x, (params["scanned"], state["scanned"]))
            new_state["scanned"] = scanned_states
    tail_states = []
    for p, kind, s in zip(params["tail"], cfg.remainder_kinds, state["tail"]):
        x, s = _prefill_layer_state(p, cfg, kind, x, s, aux)
        tail_states.append(s)
    new_state["tail"] = tail_states

    x_last = L.rms_norm(x[:, -1], params["ln_f"])
    table = params.get("unembed", params["embed"])
    logits = jnp.einsum("bd,vd->bv", x_last.astype(jnp.float32),
                        table.astype(jnp.float32))
    return logits, new_state


# ---------------------------------------------------------------------------
# Chunked prefill (one bucketed prompt chunk -> paged cache writes + logits)
# ---------------------------------------------------------------------------

def _chunked_prefill_mla_layer(p, cfg: ModelConfig, x, pool, chunk_start,
                               valid):
    """One MLA layer over one prompt chunk: project the chunk's KV, land it
    in the FP8 pool pages at ``chunk_start + t``, then attend the chunk's
    queries against [quantized prefix pages] + [the chunk itself] (causal)
    through the fused fetch-dequant path."""
    from repro.kernels.quantize import fetch_dequant as FD
    mcfg = _mla_cfg(cfg)
    ccfg = _cache_cfg(cfg, "mla")
    C = x.shape[1]
    positions = chunk_start[:, None] + jnp.arange(C)[None, :]
    h = L.rms_norm(x, p["ln1"])
    c_kv, k_r = mla_lib.project_kv(p["mixer"], mcfg, h, positions)
    pool = paged_mla_prefill_at(pool, ccfg, c_kv, k_r, chunk_start, valid)
    q_c, q_r = mla_lib.project_q(p["mixer"], mcfg, h, positions)
    q_lat = mla_lib.absorb_q(p["mixer"], q_c)          # [B, C, H, d_c]
    o_lat = FD.paged_chunked_prefill_attention(
        q_lat, q_r, pool, c_kv, k_r, chunk_start, valid,
        softmax_scale=mcfg.softmax_scale, use_kernel=cfg.use_kernels,
        interpret=jax.default_backend() != "tpu")
    x = x + mla_lib.output_proj(p["mixer"], o_lat.astype(x.dtype))
    x, _ = _apply_mlp(p, cfg, x)
    return x, pool


def chunked_prefill(params, cfg: ModelConfig, tokens: jax.Array, state,
                    chunk_start: jax.Array, last_idx: jax.Array):
    """One prompt CHUNK through the stack: tokens [B, C] at absolute
    positions ``chunk_start + t`` -> (logits [B, V] for the chunk's last real
    token, state with the chunk's quantized entries landed in the pool).

    The serving engine's chunked-prefill step: called once per (bucketed)
    chunk, with ``chunk_start`` / ``last_idx`` traced so ONE compiled
    program serves every chunk of a given width — prefill compiles are
    bounded by the bucket count, not the number of distinct prompt lengths.
    ``last_idx`` [B] is the index of the chunk's last REAL token (positions
    past it are bucket padding: their cache writes are routed to the scratch
    page and their keys masked out of the attention). Only the final chunk's
    logits are meaningful (the engine samples the first token from them).

    Pure-MLA + paged caches only — the same constraint as the engine."""
    bad = [k for k in cfg.layer_pattern if k != "mla"]
    if bad or not cfg.kv_paged:
        raise ValueError(
            "chunked_prefill drives the paged MLA pipeline; layer pattern "
            f"{cfg.layer_pattern} (kv_paged={cfg.kv_paged}) is unsupported")
    B, C = tokens.shape
    valid = jnp.arange(C)[None, :] <= last_idx[:, None]          # [B, C]
    x = L.embed(params["embed"], tokens)
    new_state = dict(state)

    if cfg.n_superblocks > 0:
        def step(x, inputs):
            block_params, block_state = inputs
            new_states = []
            for i in range(cfg.pattern_len):
                x, s = _chunked_prefill_mla_layer(
                    block_params[i], cfg, x, block_state[i], chunk_start,
                    valid)
                new_states.append(s)
            return x, new_states

        if cfg.cost_exact:
            outs = []
            for i in range(cfg.n_superblocks):
                bp = jax.tree.map(lambda a: a[i], params["scanned"])
                bs = jax.tree.map(lambda a: a[i], state["scanned"])
                x, ns = step(x, (bp, bs))
                outs.append(ns)
            new_state["scanned"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *outs)
        else:
            x, scanned_states = jax.lax.scan(
                step, x, (params["scanned"], state["scanned"]))
            new_state["scanned"] = scanned_states
    tail_states = []
    for p, s in zip(params["tail"], state["tail"]):
        x, s = _chunked_prefill_mla_layer(p, cfg, x, s, chunk_start, valid)
        tail_states.append(s)
    new_state["tail"] = tail_states

    x_last = jnp.take_along_axis(
        x, last_idx[:, None, None].astype(jnp.int32),
        axis=1)[:, 0]                                             # [B, d]
    x_last = L.rms_norm(x_last, params["ln_f"])
    table = params.get("unembed", params["embed"])
    logits = jnp.einsum("bd,vd->bv", x_last.astype(jnp.float32),
                        table.astype(jnp.float32))
    return logits, new_state


# ---------------------------------------------------------------------------
# Speculative verify (K drafted tokens -> all-position logits, one dispatch)
# ---------------------------------------------------------------------------

def _verify_mla_layer(p, cfg: ModelConfig, x, pool, start):
    """One MLA layer over a K-token verify block: land the block's quantized
    KV entries in the pool at positions ``start + t`` (exactly the bytes a
    sequential decode would have appended — ``mla_quantize_entry`` is
    deterministic, so accepted entries never need rewriting), then attend all
    K queries against [FP8 prefix pages + the block itself] through the
    q_len>1 split-KV decode backend (causal across the block via the kernel's
    per-row limits)."""
    mcfg = _mla_cfg(cfg)
    ccfg = _cache_cfg(cfg, "mla")
    B, K = x.shape[:2]
    positions = start[:, None] + jnp.arange(K)[None, :]
    h = L.rms_norm(x, p["ln1"])
    c_kv, k_r = mla_lib.project_kv(p["mixer"], mcfg, h, positions)
    # valid=ones: pool seq_lens become start + K, so every verify row's
    # kernel limit is >= 1 (idle slots attend their own first row — finite
    # garbage, discarded by the engine's acceptance rule). Entries past the
    # slot's allocated pages clip to the scratch page inside prefill_at.
    valid = jnp.ones((B, K), bool)
    pool = paged_mla_prefill_at(pool, ccfg, c_kv, k_r, start, valid)
    q_c, q_r = mla_lib.project_q(p["mixer"], mcfg, h, positions)
    q_lat = mla_lib.absorb_q(p["mixer"], q_c)           # [B, K, H, d_c]
    fmt = ccfg.fmt if ccfg.quantized else "none"
    H = q_lat.shape[2]
    q8, qr_s, sq = mla_kref.prepare_q(
        q_lat.reshape(B, K * H, -1), q_r.reshape(B, K * H, -1), fmt)
    query = BK.DecodeQuery(q8.reshape(B, K, H, -1),
                           qr_s.reshape(B, K, H, -1),
                           sq.reshape(B, K, H))
    backend = BK.resolve_backend(
        cfg.decode_backend, paged=True, batch=B, n_heads=cfg.n_heads,
        use_kernels=cfg.use_kernels, q_len=K)
    bcfg = BK.BackendConfig(softmax_scale=mcfg.softmax_scale,
                            block_n=cfg.kv_block_n or ccfg.page_size, fmt=fmt,
                            num_splits=cfg.kv_splits, rescale=cfg.kv_rescale)
    o_lat = backend.decode(query, pool, bcfg, None)     # [B, K, H, d_c]
    x = x + mla_lib.output_proj(p["mixer"], o_lat.astype(x.dtype))
    x, _ = _apply_mlp(p, cfg, x)
    return x, pool


def verify_step(params, cfg: ModelConfig, tokens: jax.Array, state,
                start: jax.Array):
    """Self-speculative verify: tokens [B, K] (row 0 = the slot's last
    committed token, rows 1..K-1 = drafted continuation) at absolute
    positions ``start + t`` -> (logits [B, K, V] for EVERY position, state
    with the block's quantized entries landed in the pool).

    One compiled program verifies all slots' drafts per engine step; the
    engine's acceptance rule decides how many of the K candidate samples to
    commit, and rejected tail entries are masked by the NEXT step's pushed
    ``seq_lens`` (rollback-by-rewind — pages never move). With K=1 this is
    semantically the ordinary decode step (append one entry, one query row).

    Pure-MLA + paged caches only — the same constraint as chunked_prefill."""
    bad = [k for k in cfg.layer_pattern if k != "mla"]
    if bad or not cfg.kv_paged:
        raise ValueError(
            "verify_step drives the paged MLA pipeline; layer pattern "
            f"{cfg.layer_pattern} (kv_paged={cfg.kv_paged}) is unsupported")
    x = L.embed(params["embed"], tokens)
    new_state = dict(state)

    if cfg.n_superblocks > 0:
        def step(x, inputs):
            block_params, block_state = inputs
            new_states = []
            for i in range(cfg.pattern_len):
                x, s = _verify_mla_layer(block_params[i], cfg, x,
                                         block_state[i], start)
                new_states.append(s)
            return x, new_states

        if cfg.cost_exact:
            outs = []
            for i in range(cfg.n_superblocks):
                bp = jax.tree.map(lambda a: a[i], params["scanned"])
                bs = jax.tree.map(lambda a: a[i], state["scanned"])
                x, ns = step(x, (bp, bs))
                outs.append(ns)
            new_state["scanned"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *outs)
        else:
            x, scanned_states = jax.lax.scan(
                step, x, (params["scanned"], state["scanned"]))
            new_state["scanned"] = scanned_states
    tail_states = []
    for p, s in zip(params["tail"], state["tail"]):
        x, s = _verify_mla_layer(p, cfg, x, s, start)
        tail_states.append(s)
    new_state["tail"] = tail_states

    x = L.rms_norm(x, params["ln_f"])
    table = params.get("unembed", params["embed"])
    logits = jnp.einsum("bkd,vd->bkv", x.astype(jnp.float32),
                        table.astype(jnp.float32))
    return logits, new_state
