"""Token-choice top-k Mixture-of-Experts with capacity-based sort dispatch.

XLA/pjit-friendly dropless-ish MoE: tokens are routed to their top-k experts,
packed into an [E, C, d] buffer via argsort (no [T, E, C] one-hot tensors),
processed by stacked expert MLPs, and combined with router weights. Tokens
beyond an expert's capacity are dropped (standard capacity-factor semantics;
the dropped fraction is returned as an observable metric).

Sharding: the expert axis (leading axis of expert weights and of the [E, C, d]
buffer) carries the 'model' mesh axis (EP); GSPMD inserts the dispatch
all-to-alls. See launch/sharding.py.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    n_shared_experts: int = 0      # deepseek-style always-on shared expert(s)
    renorm_topk: bool = True       # renormalize top-k router weights to sum 1


class MoEParams(NamedTuple):
    w_router: jax.Array            # [d, E]
    w_gate: jax.Array              # [E, d, f]
    w_up: jax.Array                # [E, d, f]
    w_down: jax.Array              # [E, f, d]
    shared_gate: jax.Array | None  # [d, f_shared]
    shared_up: jax.Array | None
    shared_down: jax.Array | None


def init_moe_params(key, d: int, cfg: MoEConfig, dtype=jnp.float32) -> MoEParams:
    ks = jax.random.split(key, 7)
    E, f = cfg.n_experts, cfg.d_ff_expert

    def init(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(dtype)

    fs = f * cfg.n_shared_experts
    return MoEParams(
        w_router=init(ks[0], (d, E), d),
        w_gate=init(ks[1], (E, d, f), d),
        w_up=init(ks[2], (E, d, f), d),
        w_down=init(ks[3], (E, f, d), f),
        shared_gate=init(ks[4], (d, fs), d) if fs else None,
        shared_up=init(ks[5], (d, fs), d) if fs else None,
        shared_down=init(ks[6], (fs, d), fs) if fs else None,
    )


def moe_layer(params: MoEParams, cfg: MoEConfig, x: jax.Array,
              act=jax.nn.silu) -> tuple[jax.Array, jax.Array]:
    """x [..., T, d] -> (out [..., T, d], dropped_fraction scalar)."""
    orig_shape = x.shape
    d = x.shape[-1]
    xt = x.reshape(-1, d)                                   # [T, d]
    T = xt.shape[0]
    E, k = cfg.n_experts, cfg.top_k

    # --- routing ----------------------------------------------------------
    router_logits = (xt.astype(jnp.float32) @ params.w_router.astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)          # [T, E]
    weights, ids = jax.lax.top_k(probs, k)                  # [T, k]
    if cfg.renorm_topk:
        weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # --- sort-based dispatch ------------------------------------------------
    flat_ids = ids.reshape(-1)                              # [T*k]
    sort_idx = jnp.argsort(flat_ids, stable=True)           # [T*k]
    sorted_ids = flat_ids[sort_idx]
    # rank of each routed pair within its expert
    first_of_expert = jnp.searchsorted(sorted_ids, sorted_ids, side="left")
    rank = jnp.arange(T * k) - first_of_expert
    C = max(1, int(T * k * cfg.capacity_factor / E))
    keep = rank < C
    dest = jnp.where(keep, sorted_ids * C + rank, E * C)    # overflow row
    token_of = sort_idx // k

    buf = jnp.zeros((E * C + 1, d), xt.dtype)
    buf = buf.at[dest].set(xt[token_of] * keep[:, None].astype(xt.dtype))
    buf = buf[: E * C].reshape(E, C, d)

    # --- expert MLPs (stacked einsums; E axis is EP-sharded) ---------------
    h = act(jnp.einsum("ecd,edf->ecf", buf, params.w_gate)) * jnp.einsum(
        "ecd,edf->ecf", buf, params.w_up)
    eout = jnp.einsum("ecf,efd->ecd", h, params.w_down)     # [E, C, d]

    # --- combine ------------------------------------------------------------
    flat_out = jnp.concatenate([eout.reshape(E * C, d), jnp.zeros((1, d), eout.dtype)])
    pair_out = flat_out[dest] * keep[:, None].astype(eout.dtype)   # sorted order
    unsorted = jnp.zeros((T * k, d), eout.dtype).at[sort_idx].set(pair_out)
    out = jnp.einsum("tkd,tk->td", unsorted.reshape(T, k, d),
                     weights.astype(eout.dtype))

    # --- shared experts (always-on path) -----------------------------------
    if params.shared_gate is not None:
        hs = act(xt @ params.shared_gate) * (xt @ params.shared_up)
        out = out + hs @ params.shared_down

    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return out.reshape(orig_shape), dropped


def moe_ref_dense(params: MoEParams, cfg: MoEConfig, x: jax.Array, act=jax.nn.silu):
    """O(T*E) dense oracle (computes every expert for every token) — tests only."""
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    probs = jax.nn.softmax(xt.astype(jnp.float32) @ params.w_router.astype(jnp.float32), -1)
    weights, ids = jax.lax.top_k(probs, cfg.top_k)
    if cfg.renorm_topk:
        weights = weights / jnp.sum(weights, -1, keepdims=True)
    h = act(jnp.einsum("td,edf->tef", xt, params.w_gate)) * jnp.einsum(
        "td,edf->tef", xt, params.w_up)
    every = jnp.einsum("tef,efd->ted", h, params.w_down)     # [T, E, d]
    mask = jax.nn.one_hot(ids, cfg.n_experts, dtype=every.dtype)  # [T,k,E]
    out = jnp.einsum("tke,ted,tk->td", mask, every, weights.astype(every.dtype))
    if params.shared_gate is not None:
        hs = act(xt @ params.shared_gate) * (xt @ params.shared_up)
        out = out + hs @ params.shared_down
    return out.reshape(x.shape)
