"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory), arXiv:2405.04517.

mLSTM — parallelizable matrix-memory cell with exponential gating:
    train: quadratic masked form (like attention with a log-gate decay matrix)
    decode: C_t = f' C_{t-1} + i' k_t v_t^T ;  n_t = f' n_{t-1} + i' k_t
            h_t = C_t^T q_t / max(|n_t . q_t|, exp(-m_t))
    with the max-stabilizer m_t = max(log f + m_{t-1}, log i).

sLSTM — scalar-memory cell with recurrent (per-head block-diagonal) weights;
    inherently sequential -> lax.scan over time for training.

Neither block has a KV cache, so SnapMLA quantization is N/A (documented in
DESIGN.md); decode state is O(1) in sequence length which is what makes the
``long_500k`` shape runnable for this family. States kept in f32.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

class MLSTMParams(NamedTuple):
    w_q: jax.Array        # [d, H, dh]
    w_k: jax.Array        # [d, H, dh]
    w_v: jax.Array        # [d, H, dh]
    w_i: jax.Array        # [d, H]  input-gate logit
    w_f: jax.Array        # [d, H]  forget-gate logit
    b_i: jax.Array        # [H]
    b_f: jax.Array        # [H]
    w_o_gate: jax.Array   # [d, H, dh] output gate (sigmoid)
    w_out: jax.Array      # [H, dh, d]
    gn_gain: jax.Array    # [H, dh] per-head group-norm gain


class MLSTMState(NamedTuple):
    c: jax.Array          # [B, H, dh, dh] matrix memory
    n: jax.Array          # [B, H, dh] normalizer
    m: jax.Array          # [B, H] stabilizer


def init_mlstm_params(key, d: int, n_heads: int, d_head: int, dtype=jnp.float32):
    ks = jax.random.split(key, 7)

    def init(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(dtype)

    return MLSTMParams(
        w_q=init(ks[0], (d, n_heads, d_head), d),
        w_k=init(ks[1], (d, n_heads, d_head), d),
        w_v=init(ks[2], (d, n_heads, d_head), d),
        w_i=init(ks[3], (d, n_heads), d),
        w_f=init(ks[4], (d, n_heads), d),
        b_i=jnp.zeros((n_heads,), dtype),
        b_f=jnp.full((n_heads,), 3.0, dtype),   # bias toward remembering
        w_o_gate=init(ks[5], (d, n_heads, d_head), d),
        w_out=init(ks[6], (n_heads, d_head, d), n_heads * d_head),
        gn_gain=jnp.ones((n_heads, d_head), dtype),
    )


def init_mlstm_state(batch: int, n_heads: int, d_head: int) -> MLSTMState:
    return MLSTMState(
        c=jnp.zeros((batch, n_heads, d_head, d_head), jnp.float32),
        n=jnp.zeros((batch, n_heads, d_head), jnp.float32),
        m=jnp.full((batch, n_heads), -jnp.inf, jnp.float32),
    )


def _head_norm(h: jax.Array, gain: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS norm over dh: h [..., H, dh]."""
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    return h * jax.lax.rsqrt(var + eps) * gain


def mlstm_block(params: MLSTMParams, x: jax.Array):
    """Training/prefill (fresh state): x [B,S,d] -> (y [B,S,d], final state).

    Quadratic parallel form (xLSTM paper eq. 'parallel mLSTM').
    """
    B, S, d = x.shape
    H, dh = params.w_q.shape[1], params.w_q.shape[2]
    q = jnp.einsum("bsd,dhk->bshk", x, params.w_q) / jnp.sqrt(dh)
    k = jnp.einsum("bsd,dhk->bshk", x, params.w_k)
    v = jnp.einsum("bsd,dhk->bshk", x, params.w_v)
    i_log = (jnp.einsum("bsd,dh->bsh", x, params.w_i) + params.b_i).astype(jnp.float32)
    f_log = jax.nn.log_sigmoid(
        (jnp.einsum("bsd,dh->bsh", x, params.w_f) + params.b_f).astype(jnp.float32))

    f_cum = jnp.cumsum(f_log, axis=1)                       # [B,S,H]
    # D[t,s] = f_cum[t] - f_cum[s] + i_log[s]   for s <= t
    dmat = f_cum[:, :, None, :] - f_cum[:, None, :, :] + i_log[:, None, :, :]
    mask = jnp.tril(jnp.ones((S, S), bool))[None, :, :, None]
    dmat = jnp.where(mask, dmat, -jnp.inf)                  # [B,T,S,H]
    m = jnp.max(dmat, axis=2, keepdims=True)                # [B,T,1,H]
    dexp = jnp.exp(dmat - m)
    scores = jnp.einsum("bthk,bshk->btsh", q.astype(jnp.float32), k.astype(jnp.float32))
    ct = scores * dexp
    norm = jnp.maximum(jnp.abs(jnp.sum(ct, axis=2)), jnp.exp(-m[:, :, 0]))  # [B,T,H]
    h = jnp.einsum("btsh,bshk->bthk", ct, v.astype(jnp.float32)) / norm[..., None]

    o_gate = jax.nn.sigmoid(jnp.einsum("bsd,dhk->bshk", x, params.w_o_gate))
    y = _head_norm(h.astype(x.dtype), params.gn_gain) * o_gate
    y = jnp.einsum("bshk,hkd->bsd", y, params.w_out)

    # final recurrent state (for prefill -> decode handoff)
    m_fin = f_cum[:, -1:, :] - f_cum + i_log                # decay to last step
    w = jnp.exp(m_fin - jnp.max(m_fin, axis=1, keepdims=True))
    c_fin = jnp.einsum("bsh,bshk,bshl->bhkl", w, k.astype(jnp.float32), v.astype(jnp.float32))
    n_fin = jnp.einsum("bsh,bshk->bhk", w, k.astype(jnp.float32))
    state = MLSTMState(c=c_fin, n=n_fin, m=jnp.max(m_fin, axis=1))
    return y, state


def mlstm_step(params: MLSTMParams, x_t: jax.Array, state: MLSTMState):
    """Decode: x_t [B,d] -> (y [B,d], new state). O(dh^2) per token."""
    H, dh = params.w_q.shape[1], params.w_q.shape[2]
    q = jnp.einsum("bd,dhk->bhk", x_t, params.w_q).astype(jnp.float32) / jnp.sqrt(dh)
    k = jnp.einsum("bd,dhk->bhk", x_t, params.w_k).astype(jnp.float32)
    v = jnp.einsum("bd,dhk->bhk", x_t, params.w_v).astype(jnp.float32)
    i_log = (jnp.einsum("bd,dh->bh", x_t, params.w_i) + params.b_i).astype(jnp.float32)
    f_log = jax.nn.log_sigmoid(
        (jnp.einsum("bd,dh->bh", x_t, params.w_f) + params.b_f).astype(jnp.float32))

    m_new = jnp.maximum(f_log + state.m, i_log)
    f_p = jnp.exp(f_log + state.m - m_new)[..., None]
    i_p = jnp.exp(i_log - m_new)[..., None]
    c = f_p[..., None] * state.c + i_p[..., None] * k[..., :, None] * v[..., None, :]
    n = f_p * state.n + i_p * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), jnp.exp(-m_new))
    h = jnp.einsum("bhkl,bhk->bhl", c, q) / denom[..., None]

    o_gate = jax.nn.sigmoid(jnp.einsum("bd,dhk->bhk", x_t, params.w_o_gate))
    y = _head_norm(h.astype(x_t.dtype), params.gn_gain) * o_gate
    return jnp.einsum("bhk,hkd->bd", y, params.w_out), MLSTMState(c, n, m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

class SLSTMParams(NamedTuple):
    w: jax.Array          # [4, d, H, dh]  (z, i, f, o input projections)
    r: jax.Array          # [4, H, dh, dh] recurrent block-diagonal per head
    b: jax.Array          # [4, H, dh]
    w_out: jax.Array      # [H, dh, d]
    gn_gain: jax.Array    # [H, dh]


class SLSTMState(NamedTuple):
    c: jax.Array          # [B, H, dh]
    n: jax.Array          # [B, H, dh]
    h: jax.Array          # [B, H, dh]
    m: jax.Array          # [B, H, dh]


def init_slstm_params(key, d: int, n_heads: int, d_head: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    w = (jax.random.normal(ks[0], (4, d, n_heads, d_head), jnp.float32) / jnp.sqrt(d)).astype(dtype)
    r = (jax.random.normal(ks[1], (4, n_heads, d_head, d_head), jnp.float32) / jnp.sqrt(d_head)).astype(dtype)
    b = jnp.zeros((4, n_heads, d_head), dtype).at[2].set(3.0)  # forget bias
    w_out = (jax.random.normal(ks[2], (n_heads, d_head, d), jnp.float32)
             / jnp.sqrt(n_heads * d_head)).astype(dtype)
    return SLSTMParams(w, r, b, w_out, jnp.ones((n_heads, d_head), dtype))


def init_slstm_state(batch: int, n_heads: int, d_head: int) -> SLSTMState:
    z = jnp.zeros((batch, n_heads, d_head), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full_like(z, -jnp.inf))


def slstm_step(params: SLSTMParams, x_t: jax.Array, state: SLSTMState):
    """x_t [B, d] -> (y [B, d], new state)."""
    pre = jnp.einsum("bd,gdhk->gbhk", x_t, params.w).astype(jnp.float32)
    rec = jnp.einsum("bhk,ghkl->gbhl", state.h, params.r.astype(jnp.float32))
    z_, i_, f_, o_ = pre + rec + params.b.astype(jnp.float32)[:, None]

    z = jnp.tanh(z_)
    o = jax.nn.sigmoid(o_)
    f_log = jax.nn.log_sigmoid(f_)
    m_new = jnp.maximum(f_log + state.m, i_)
    i_p = jnp.exp(i_ - m_new)
    f_p = jnp.exp(f_log + state.m - m_new)
    c = f_p * state.c + i_p * z
    n = jnp.maximum(f_p * state.n + i_p, jnp.exp(-m_new))
    h = o * (c / n)
    y = _head_norm(h.astype(x_t.dtype), params.gn_gain)
    return jnp.einsum("bhk,hkd->bd", y, params.w_out), SLSTMState(c, n, h, m_new)


def slstm_block(params: SLSTMParams, x: jax.Array, state: SLSTMState | None = None):
    """Training/prefill: sequential lax.scan over time. x [B,S,d]."""
    B, S, d = x.shape
    H, dh = params.w.shape[2], params.w.shape[3]
    st = state if state is not None else init_slstm_state(B, H, dh)

    def body(carry, x_t):
        y, new = slstm_step(params, x_t, carry)
        return new, y

    final, ys = jax.lax.scan(body, st, jnp.swapaxes(x, 0, 1))
    return jnp.swapaxes(ys, 0, 1), final
