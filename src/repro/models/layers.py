"""Common transformer layers: norms, RoPE, GQA attention, MLPs, embeddings.

Pure-functional JAX (params are pytrees of arrays); every op is pjit-friendly.
Weight layouts are chosen so TP sharding rules in launch/sharding.py can key on
axis position (heads / ffn axes are always the sharded 'model' axes).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, gain: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * gain.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, gain: jax.Array, bias: jax.Array, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * gain.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(positions: jax.Array, dim: int, theta: float = 10000.0):
    """positions [...,] -> (sin, cos) each [..., dim] (half-split convention)."""
    half = dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv  # [..., half]
    ang = jnp.concatenate([ang, ang], axis=-1)            # [..., dim]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x [..., dim]; sin/cos broadcastable to x. Half-split rotate."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    rotated = jnp.concatenate([-x2, x1], axis=-1)
    return (x.astype(jnp.float32) * cos + rotated.astype(jnp.float32) * sin).astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (training / prefill path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    rope_theta: float = 10000.0
    qkv_bias: bool = False            # qwen2.5 style
    window: int = 0                   # 0 = full causal; >0 = sliding window
    use_rope: bool = True


class AttnParams(NamedTuple):
    wq: jax.Array            # [d, H, dh]
    wk: jax.Array            # [d, Hkv, dh]
    wv: jax.Array            # [d, Hkv, dh]
    wo: jax.Array            # [H, dh, d]
    bq: jax.Array | None     # [H, dh]
    bk: jax.Array | None
    bv: jax.Array | None


def init_attn_params(key, cfg: AttnConfig, dtype=jnp.float32) -> AttnParams:
    ks = jax.random.split(key, 4)
    d, H, Hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    def init(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(dtype)

    bias = (lambda s: jnp.zeros(s, dtype)) if cfg.qkv_bias else (lambda s: None)
    return AttnParams(
        wq=init(ks[0], (d, H, dh), d),
        wk=init(ks[1], (d, Hk, dh), d),
        wv=init(ks[2], (d, Hk, dh), d),
        wo=init(ks[3], (H, dh, d), H * dh),
        bq=bias((H, dh)), bk=bias((Hk, dh)), bv=bias((Hk, dh)),
    )


def project_qkv(params: AttnParams, cfg: AttnConfig, x: jax.Array, positions: jax.Array):
    """x [B,S,d] -> q [B,S,H,dh], k,v [B,S,Hkv,dh] (RoPE applied to q,k)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params.wq)
    k = jnp.einsum("bsd,dhk->bshk", x, params.wk)
    v = jnp.einsum("bsd,dhk->bshk", x, params.wv)
    if params.bq is not None:
        q, k, v = q + params.bq, k + params.bk, v + params.bv
    if cfg.use_rope:
        sin, cos = rope_freqs(positions, cfg.d_head, cfg.rope_theta)
        sin, cos = sin[..., None, :], cos[..., None, :]
        q, k = apply_rope(q, sin, cos), apply_rope(k, sin, cos)
    return q, k, v


def sdpa(
    q: jax.Array,             # [B, Sq, H, dh]
    k: jax.Array,             # [B, Sk, Hkv, dh]
    v: jax.Array,             # [B, Sk, Hkv, dh]
    causal: bool = True,
    window: int = 0,
    q_offset: int | jax.Array = 0,  # absolute position of q[0] (for chunked use)
    logit_dtype=jnp.float32,
) -> jax.Array:
    """Reference scaled-dot-product attention with GQA head sharing + SWA."""
    B, Sq, H, dh = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    qg = q.reshape(B, Sq, Hkv, g, dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(logit_dtype), k.astype(logit_dtype))
    logits = logits / jnp.sqrt(dh).astype(logit_dtype)
    Sk = k.shape[1]
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(logit_dtype))
    return o.reshape(B, Sq, H, dh).astype(q.dtype)


def flash_sdpa(
    q: jax.Array,             # [B, Sq, H, dh]
    k: jax.Array,             # [B, Sk, Hkv, dh]
    v: jax.Array,             # [B, Sk, Hkv, dh]
    causal: bool = True,
    window: int = 0,
    q_offset: int | jax.Array = 0,
    block_k: int = 512,
    unroll: bool = False,
) -> jax.Array:
    """Memory-efficient attention: lax.scan over KV blocks with online softmax.

    Peak intermediate is O(Sq * block_k) instead of O(Sq * Sk) — this is the
    training/prefill attention used by the full-model forward (the HLO the
    dry-run rooflines is flash-structured, like a production framework).
    """
    B, Sq, H, dh = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    if Sk % block_k:
        pad = block_k - Sk % block_k
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblocks = k.shape[1] // block_k
    g = H // Hkv
    qg = (q.reshape(B, Sq, Hkv, g, dh).astype(jnp.float32) / jnp.sqrt(dh))
    qpos = jnp.arange(Sq) + q_offset

    kb = k.reshape(B, nblocks, block_k, Hkv, dh)
    vb = v.reshape(B, nblocks, block_k, Hkv, dh)

    def body(carry, inputs):
        m, l, acc = carry
        j, k_j, v_j = inputs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_j.astype(jnp.float32))
        kpos = j * block_k + jnp.arange(block_k)
        valid = kpos[None, :] < Sk
        if causal:
            valid &= kpos[None, :] <= qpos[:, None]
        if window:
            valid &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(valid[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        e = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(e, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", e, v_j.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((B, Hkv, g, Sq), -jnp.inf, jnp.float32),
        jnp.zeros((B, Hkv, g, Sq), jnp.float32),
        jnp.zeros((B, Hkv, g, Sq, dh), jnp.float32),
    )
    if unroll:
        carry = init
        for j in range(nblocks):
            carry, _ = body(carry, (jnp.int32(j), kb[:, j], vb[:, j]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            body, init,
            (jnp.arange(nblocks), jnp.swapaxes(kb, 0, 1), jnp.swapaxes(vb, 0, 1)))
    o = acc / jnp.maximum(l, 1e-30)[..., None]              # [B,Hkv,g,Sq,dh]
    o = jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(B, Sq, H, dh)
    return o.astype(q.dtype)


def attention_block(
    params: AttnParams, cfg: AttnConfig, x: jax.Array, positions: jax.Array,
    causal: bool = True, use_flash: bool = True, unroll: bool = False,
) -> jax.Array:
    q, k, v = project_qkv(params, cfg, x, positions)
    if use_flash:
        o = flash_sdpa(q, k, v, causal=causal, window=cfg.window, unroll=unroll)
    else:
        o = sdpa(q, k, v, causal=causal, window=cfg.window)
    return jnp.einsum("bshk,hkd->bsd", o, params.wo)


def cross_attention_block(
    params: AttnParams, cfg: AttnConfig, x: jax.Array, kv_src: jax.Array,
) -> jax.Array:
    """Cross attention: queries from x [B,Sq,d], keys/values from kv_src [B,Sk,d].

    No RoPE, no causal mask (llama-vision / whisper style).
    """
    q = jnp.einsum("bsd,dhk->bshk", x, params.wq)
    k = jnp.einsum("bsd,dhk->bshk", kv_src, params.wk)
    v = jnp.einsum("bsd,dhk->bshk", kv_src, params.wv)
    if params.bq is not None:
        q, k, v = q + params.bq, k + params.bk, v + params.bv
    o = sdpa(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", o, params.wo)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

class MLPParams(NamedTuple):
    w_gate: jax.Array | None  # [d, f] (None for plain GELU MLP)
    w_up: jax.Array           # [d, f]
    w_down: jax.Array         # [f, d]


def init_mlp_params(key, d: int, f: int, gated: bool = True, dtype=jnp.float32):
    ks = jax.random.split(key, 3)

    def init(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(dtype)

    return MLPParams(
        w_gate=init(ks[0], (d, f), d) if gated else None,
        w_up=init(ks[1], (d, f), d),
        w_down=init(ks[2], (f, d), f),
    )


def mlp(params: MLPParams, x: jax.Array, activation: str = "silu") -> jax.Array:
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "gelu_tanh": lambda t: jax.nn.gelu(t, approximate=True)}[activation]
    if params.w_gate is not None:
        h = act(x @ params.w_gate) * (x @ params.w_up)
    else:
        h = act(x @ params.w_up)
    return h @ params.w_down


# ---------------------------------------------------------------------------
# Embeddings / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(table: jax.Array, x: jax.Array) -> jax.Array:
    """Tied unembedding: x [B,S,d] @ table.T -> [B,S,V] (f32 logits)."""
    return jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32), table.astype(jnp.float32))
