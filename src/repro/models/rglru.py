"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block =  x -> [linear -> GELU]  ⊙  [linear -> causal conv1d(w=4) -> RG-LRU] -> linear

RG-LRU cell (per channel):
    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    a_t = exp(-c * softplus(Λ) * r_t),  c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses an associative scan over the sequence (parallel depth log S);
decoding is the O(1)-per-token recurrence. The state is NOT a KV cache, so
the paper's quantization technique is N/A here (DESIGN.md §Arch-applicability)
— state is kept in f32.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

RGLRU_C = 8.0
CONV_W = 4


class RGLRUParams(NamedTuple):
    w_gate_branch: jax.Array   # [d, d_rnn] (GELU branch)
    w_in: jax.Array            # [d, d_rnn] (recurrent branch input)
    conv_w: jax.Array          # [CONV_W, d_rnn] depthwise causal conv
    conv_b: jax.Array          # [d_rnn]
    w_a: jax.Array             # [d_rnn, d_rnn] recurrence-gate proj
    b_a: jax.Array             # [d_rnn]
    w_x: jax.Array             # [d_rnn, d_rnn] input-gate proj
    b_x: jax.Array             # [d_rnn]
    log_lambda: jax.Array      # [d_rnn] Λ parameter (softplus'd)
    w_out: jax.Array           # [d_rnn, d]


class RGLRUState(NamedTuple):
    h: jax.Array               # [B, d_rnn] recurrent state (f32)
    conv: jax.Array            # [B, CONV_W - 1, d_rnn] conv tail buffer


def init_rglru_params(key, d: int, d_rnn: int, dtype=jnp.float32) -> RGLRUParams:
    ks = jax.random.split(key, 7)

    def init(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)).astype(dtype)

    # Λ init so that a ~ U(0.9, 0.999)^c as in the Griffin paper
    u = jax.random.uniform(ks[6], (d_rnn,), jnp.float32, 0.9, 0.999)
    log_lambda = jnp.log(jnp.expm1(-jnp.log(u)))  # softplus^{-1}(-log u)
    return RGLRUParams(
        w_gate_branch=init(ks[0], (d, d_rnn), d),
        w_in=init(ks[1], (d, d_rnn), d),
        conv_w=init(ks[2], (CONV_W, d_rnn), CONV_W),
        conv_b=jnp.zeros((d_rnn,), dtype),
        w_a=init(ks[3], (d_rnn, d_rnn), d_rnn),
        b_a=jnp.zeros((d_rnn,), dtype),
        w_x=init(ks[4], (d_rnn, d_rnn), d_rnn),
        b_x=jnp.zeros((d_rnn,), dtype),
        log_lambda=log_lambda.astype(dtype),
        w_out=init(ks[5], (d_rnn, d), d_rnn),
    )


def init_rglru_state(batch: int, d_rnn: int) -> RGLRUState:
    return RGLRUState(
        h=jnp.zeros((batch, d_rnn), jnp.float32),
        conv=jnp.zeros((batch, CONV_W - 1, d_rnn), jnp.float32),
    )


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, tail: jax.Array | None):
    """x [B,S,dr]; depthwise causal conv width CONV_W. tail: [B,CONV_W-1,dr]."""
    pad = jnp.zeros((x.shape[0], CONV_W - 1, x.shape[2]), x.dtype) if tail is None else tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                  # [B, S+3, dr]
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(CONV_W))
    return out + b, xp[:, -(CONV_W - 1):]                    # (y, new tail)


def _gates(params: RGLRUParams, u: jax.Array):
    r = jax.nn.sigmoid(u @ params.w_a + params.b_a)
    i = jax.nn.sigmoid(u @ params.w_x + params.b_x)
    log_a = -RGLRU_C * jax.nn.softplus(params.log_lambda.astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u)
    return a, gated


def rglru_block(params: RGLRUParams, x: jax.Array,
                state: RGLRUState | None = None):
    """Training/prefill: x [B,S,d] -> (y [B,S,d], final RGLRUState)."""
    gate = jax.nn.gelu(x @ params.w_gate_branch)
    u = x @ params.w_in
    u, conv_tail = _causal_conv(u, params.conv_w, params.conv_b,
                                None if state is None else state.conv)
    a, gated = _gates(params, u.astype(jnp.float32))

    h0 = jnp.zeros((x.shape[0], u.shape[-1]), jnp.float32) if state is None else state.h
    # include h0 by folding it into the first step's additive term
    gated = gated.at[:, 0].add(a[:, 0] * h0) if state is not None else gated

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, b1 * a2 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    y = (h.astype(x.dtype) * gate) @ params.w_out
    return y, RGLRUState(h=h[:, -1], conv=conv_tail)


def rglru_step(params: RGLRUParams, x_t: jax.Array, state: RGLRUState):
    """Decode: x_t [B, d] -> (y [B, d], new state). O(1) per token."""
    gate = jax.nn.gelu(x_t @ params.w_gate_branch)
    u = x_t @ params.w_in                                   # [B, dr]
    conv_in = jnp.concatenate([state.conv, u[:, None]], axis=1)  # [B, W, dr]
    u_c = jnp.einsum("bwd,wd->bd", conv_in, params.conv_w) + params.conv_b
    a, gated = _gates(params, u_c.astype(jnp.float32))
    h = a * state.h + gated
    y = (h.astype(x_t.dtype) * gate) @ params.w_out
    return y, RGLRUState(h=h, conv=conv_in[:, 1:])
