"""Atomic, mesh-independent checkpointing with elastic reshard-on-load.

Layout per checkpoint:

    <dir>/step_000123.tmp/...      (written first)
    <dir>/step_000123/
        manifest.json              step, config name/hash, mesh shape, rng,
                                   data cursor, leaf index
        arrays.npz                 all leaves as logical (unsharded) arrays

Writes are atomic (tmp dir + os.rename), so a preemption mid-write never
corrupts the latest checkpoint. Arrays are stored *logically*: loading
re-device_puts onto whatever sharding the restart supplies — a job restarted
on a different chip count (elastic scaling / shrunk-by-failure cluster)
resumes without any resharding tooling. On multi-host, each host writes its
addressable shards and host 0 writes the manifest; here (single-host) the
full arrays are written directly.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(p) for p in path)
        out.append((name, leaf))
    return out


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra_manifest: dict | None = None,
                    keep: int | None = None) -> str:
    """Write ``<directory>/step_<step>`` atomically. ``keep`` (when set)
    prunes the directory down to the newest ``keep`` published checkpoints
    AFTER the new one lands — bounded disk for periodic snapshotting (the
    serving engine's ``ckpt_every``) without ever deleting the checkpoint
    a concurrent restore would pick (``latest_checkpoint`` order is the
    same lexicographic step order pruning uses)."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    named = _flatten_with_names(tree)
    arrays = {}
    index = []
    for i, (name, leaf) in enumerate(named):
        key = f"leaf_{i:05d}"
        arrays[key] = np.asarray(jax.device_get(leaf))
        index.append({"key": key, "path": name,
                      "dtype": str(arrays[key].dtype),
                      "shape": list(arrays[key].shape)})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"step": step, "leaves": index}
    manifest.update(extra_manifest or {})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)       # atomic publish
    if keep is not None and keep >= 1:
        published = sorted(d for d in os.listdir(directory)
                           if d.startswith("step_")
                           and not d.endswith(".tmp"))
        for stale in published[:-keep]:
            shutil.rmtree(os.path.join(directory, stale))
    return final


def latest_checkpoint(directory: str) -> str | None:
    if not os.path.isdir(directory):
        return None
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    return os.path.join(directory, steps[-1]) if steps else None


def load_checkpoint(path: str, tree_like: Any, shardings: Any | None = None):
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional pytree (same structure) of jax.sharding.Sharding —
    leaves are device_put with them (elastic reshard happens here).
    Returns (tree, manifest).
    """
    import ml_dtypes  # registers bfloat16/fp8 numpy dtypes  # noqa: F401

    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves = []
    for e in manifest["leaves"]:
        l = data[e["key"]]
        if l.dtype.kind == "V":      # npz stores ml_dtypes as raw void bytes
            l = l.view(np.dtype(e["dtype"]))
        leaves.append(l)
    flat_like, treedef = jax.tree_util.tree_flatten(tree_like)
    assert len(flat_like) == len(leaves), \
        f"checkpoint has {len(leaves)} leaves, structure wants {len(flat_like)}"
    # ml_dtypes (bfloat16/fp8) need jnp for the cast; numpy lacks cast kernels
    cast = [np.asarray(l).astype(like.dtype) if l.dtype != like.dtype else l
            for l, like in zip(leaves, flat_like)]
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        cast = [jax.device_put(l, s) for l, s in zip(cast, flat_sh)]
    else:
        cast = [jax.numpy.asarray(l) for l in cast]
    return treedef.unflatten(cast), manifest
