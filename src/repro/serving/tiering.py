"""Host-memory second tier for evicted-but-hot FP8 prefix pages.

When the allocator's device-side prefix-cache budget overflows, the LRU
cached page is not simply dropped: its FP8 page data (content + rope + scale,
one tuple per pool leaf of the engine state) is copied into a slot of this
host-memory store. A later prompt that matches the offloaded prefix restores
the slot into a fresh device page — one ``jax.device_put`` per array instead
of recomputing the page's prefill — which is exactly the trade the paper's
memory-bound analysis says to make: MLA decode starves on HBM capacity, not
on PCIe transfers of cold prefixes.

Division of labor:

  * the ALLOCATOR owns slot placement (``alloc_slot``/``drop`` and which
    node maps to which slot, recorded in the prefix tree);
  * the ENGINE owns data movement: it drains the allocator's pending-op
    queue, calling ``store`` (device page -> host copy) and ``take``
    (host copy -> device arrays, freeing the slot). ``prefetch`` issues the
    ``device_put`` transfers asynchronously ahead of the consuming write so
    readmission overlaps the upload with the remaining host work.

The payload is opaque to this class — a list (one entry per pool leaf) of
``(content, rope, scale)`` arrays — so allocator-level tests can exercise
slot accounting with dummy payloads. ``export_state`` snapshots the payloads
base64-encoded (FP8/bf16 dtypes ride as ml_dtypes names), so an engine
checkpoint restores the tier byte-identically.
"""
from __future__ import annotations

import base64
from typing import Any

import jax
import numpy as np


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes                    # registered by jax's own dep
        return np.dtype(getattr(ml_dtypes, name))


def _encode(a: np.ndarray) -> dict:
    a = np.ascontiguousarray(a)
    return {"dtype": a.dtype.name, "shape": list(a.shape),
            "data": base64.b64encode(a.tobytes()).decode("ascii")}


def _decode(rec: dict) -> np.ndarray:
    raw = base64.b64decode(rec["data"])
    return np.frombuffer(raw, dtype=_np_dtype(rec["dtype"])).reshape(
        rec["shape"]).copy()


class HostTier:
    """Slot-addressed host store of offloaded FP8 KV pages."""

    def __init__(self, n_slots: int):
        self.n_slots = int(n_slots)
        self._free: list[int] = list(range(self.n_slots - 1, -1, -1))
        # slot -> list[(content, rope, scale)] host copies (one per pool leaf)
        self._data: dict[int, list[tuple]] = {}
        # slot -> list[(content, rope, scale)] in-flight device_put results
        self._staged: dict[int, list[tuple]] = {}
        self.offloads = 0
        self.restores = 0
        self.prefetches = 0

    # -- slot accounting (allocator side) -----------------------------------

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.n_slots - len(self._free)

    def alloc_slot(self) -> int | None:
        """Reserve a slot for a pending offload (data arrives via ``store``
        when the engine drains). None when the tier is full — the allocator
        then LRU-evicts a host-resident node or drops the page."""
        if not self._free:
            return None
        return self._free.pop()

    def drop(self, slot: int) -> None:
        """Release a slot (host LRU eviction / subtree drop); any stored or
        staged payload is discarded."""
        if slot in self._free or not (0 <= slot < self.n_slots):
            raise ValueError(f"bad host-tier slot {slot}")
        self._data.pop(slot, None)
        self._staged.pop(slot, None)
        self._free.append(slot)

    # -- data movement (engine side) ----------------------------------------

    def store(self, slot: int, page_data: list[tuple]) -> None:
        """Land a device page's host copy in a previously reserved slot."""
        if slot in self._free or not (0 <= slot < self.n_slots):
            raise ValueError(f"store into unreserved host-tier slot {slot}")
        self._data[slot] = page_data
        self.offloads += 1

    def has_data(self, slot: int) -> bool:
        return slot in self._data

    def prefetch(self, slot: int) -> None:
        """Begin the host -> device upload for ``slot`` without blocking:
        ``jax.device_put`` returns immediately with in-flight arrays that
        the consuming ``take``/pool-write then uses directly."""
        if slot in self._staged or slot not in self._data:
            return
        self._staged[slot] = [tuple(jax.device_put(a) for a in leaf)
                              for leaf in self._data[slot]]
        self.prefetches += 1

    def take(self, slot: int) -> list[tuple]:
        """Consume a slot for restore: returns the (prefetched, if
        ``prefetch`` ran) page payload and frees the slot."""
        if slot not in self._data:
            raise ValueError(f"take from empty host-tier slot {slot}")
        payload = self._staged.pop(slot, None)
        if payload is None:
            payload = self._data[slot]
        del self._data[slot]
        self._free.append(slot)
        self.restores += 1
        return payload

    # -- invariants ---------------------------------------------------------

    def check(self, referenced: set[int], pending: set[int]) -> None:
        """``referenced``: slots held by prefix-tree nodes. ``pending``:
        slots owned by not-yet-drained restore ops. Together they must
        account for every non-free slot exactly once."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate free host slot"
        assert free <= set(range(self.n_slots)), "host slot out of range"
        used = set(range(self.n_slots)) - free
        assert not (referenced & pending), \
            "host slot both node-referenced and restore-pending"
        assert referenced | pending == used, \
            f"host-tier slot leak: used={used} referenced={referenced} " \
            f"pending={pending}"
        assert set(self._data) <= used, "payload in a free slot"
        assert set(self._staged) <= set(self._data), "staged without data"

    # -- checkpoint ---------------------------------------------------------

    def export_state(self) -> dict:
        """JSON-safe snapshot including payload bytes (host copies are part
        of engine state: a restore must be able to serve them without the
        original device pages)."""
        data: dict[str, Any] = {}
        for slot, leaves in self._data.items():
            data[str(slot)] = [[_encode(np.asarray(a)) for a in leaf]
                               for leaf in leaves]
        return {
            "n_slots": self.n_slots,
            "free": list(self._free),
            "data": data,
            "offloads": self.offloads,
            "restores": self.restores,
            "prefetches": self.prefetches,
        }

    def restore_state(self, state: dict) -> None:
        if int(state["n_slots"]) != self.n_slots:
            raise ValueError(
                f"checkpointed host tier geometry ({state['n_slots']} "
                f"slots) does not match this engine ({self.n_slots})")
        self._free = [int(s) for s in state["free"]]
        self._staged = {}
        self._data = {
            int(slot): [tuple(_decode(rec) for rec in leaf)
                        for leaf in leaves]
            for slot, leaves in state["data"].items()}
        self.offloads = int(state["offloads"])
        self.restores = int(state["restores"])
        self.prefetches = int(state["prefetches"])
