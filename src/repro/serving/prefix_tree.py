"""Radix/trie index over page-granular KV-prefix content hashes.

PR 4's prefix sharing kept a flat ``hash(prefix) -> page`` dict whose entries
died with their last referencing request. This tree is the replacement
*index* for a prefix cache that SURVIVES request completion:

  * one node per full prompt page, keyed by the content hash of the whole
    token prefix ending at that page (the same chained ``_prefix_key``
    scheme the allocator has always used, so textual prefix equality — not
    request identity — is what matches);
  * explicit parent/child structure (node at depth ``d`` covers tokens
    ``[0, d * page_size)``; its parent covers one page less), which is what
    lets the allocator retain refcount-0 prefixes, evict them leaf-first
    under an LRU budget, and drop whole stale subtrees at once;
  * per-node *placement*: ``page_id`` (device-resident FP8 pool page) or
    ``host_id`` (slot in the host-memory second tier) — never both. A node
    with neither is removed on the spot; ``by_page`` inverts the
    device-resident mapping for O(1) "is this page a cached prefix?" checks.

The tree is a pure host-side index: it never touches array data and holds no
refcounts (the allocator owns both). ``last_use`` ticks off the tree's own
logical clock so LRU decisions are deterministic and checkpointable.
"""
from __future__ import annotations

from typing import Iterator


class PrefixNode:
    """One full prompt page's worth of cached KV prefix."""

    __slots__ = ("key", "parent", "children", "depth", "page_id", "host_id",
                 "last_use", "ready")

    def __init__(self, key: bytes, parent: "PrefixNode | None", depth: int,
                 page_id: int | None = None, host_id: int | None = None):
        self.key = key
        self.parent = parent
        self.children: dict[bytes, PrefixNode] = {}
        self.depth = depth                 # pages from the root (root = 0)
        self.page_id = page_id             # device pool page, if resident
        self.host_id = host_id             # host-tier slot, if offloaded
        self.last_use = 0
        # registration happens at ALLOC time but the page's bytes land
        # chunk-by-chunk: only a page whose prefill actually completed
        # (engine-confirmed via mark_ready) may satisfy a cache hit or be
        # retained — matching a just-allocated, still-unwritten page must
        # fall back to live sharing + byte-identical rewrite
        self.ready = False

    def __repr__(self) -> str:            # pragma: no cover - debugging aid
        where = (f"page={self.page_id}" if self.page_id is not None
                 else f"host={self.host_id}")
        return f"PrefixNode(depth={self.depth}, {where})"


class PrefixTree:
    """Prefix-page index: chained-hash lookup + parent/child structure."""

    def __init__(self) -> None:
        self.root = PrefixNode(b"", None, 0)
        self.nodes: dict[bytes, PrefixNode] = {}
        self.by_page: dict[int, PrefixNode] = {}
        self.clock = 0

    def __len__(self) -> int:
        return len(self.nodes)

    def tick(self) -> int:
        self.clock += 1
        return self.clock

    # -- lookup / structure -------------------------------------------------

    def get(self, key: bytes) -> PrefixNode | None:
        return self.nodes.get(key)

    def insert(self, key: bytes, parent: PrefixNode,
               page_id: int) -> PrefixNode:
        """Register a fresh device-resident prefix page under ``parent``."""
        if key in self.nodes:
            raise ValueError("prefix node already registered")
        node = PrefixNode(key, parent, parent.depth + 1, page_id=page_id)
        node.last_use = self.tick()
        parent.children[key] = node
        self.nodes[key] = node
        self.by_page[page_id] = node
        return node

    def remove(self, node: PrefixNode) -> None:
        """Detach a childless node (placement must already be cleared by the
        allocator or be device-resident-and-released)."""
        if node.children:
            raise ValueError("cannot remove a prefix node with children")
        if node.host_id is not None:
            raise ValueError("cannot remove a node still holding a host slot")
        if node.page_id is not None:
            del self.by_page[node.page_id]
            node.page_id = None
        assert node.parent is not None, "cannot remove the root"
        del node.parent.children[node.key]
        del self.nodes[node.key]
        node.parent = None

    def subtree_postorder(self, node: PrefixNode) -> list[PrefixNode]:
        """Descendants-first (safe removal order), ``node`` last."""
        out: list[PrefixNode] = []

        def walk(n: PrefixNode) -> None:
            for child in list(n.children.values()):
                walk(child)
            out.append(n)

        walk(node)
        return out

    def iter_nodes(self) -> Iterator[PrefixNode]:
        return iter(self.nodes.values())

    # -- placement ----------------------------------------------------------

    def set_device(self, node: PrefixNode, page_id: int) -> None:
        if node.page_id is not None:
            raise ValueError("node already device-resident")
        node.page_id = page_id
        self.by_page[page_id] = node

    def clear_device(self, node: PrefixNode) -> None:
        if node.page_id is None:
            raise ValueError("node not device-resident")
        del self.by_page[node.page_id]
        node.page_id = None

    def set_host(self, node: PrefixNode, host_id: int) -> None:
        if node.host_id is not None:
            raise ValueError("node already host-resident")
        node.host_id = host_id

    def clear_host(self, node: PrefixNode) -> int:
        if node.host_id is None:
            raise ValueError("node not host-resident")
        slot, node.host_id = node.host_id, None
        return slot

    # -- invariants ---------------------------------------------------------

    def check(self) -> None:
        """Structural invariants (the allocator layers the page-state and
        refcount invariants on top): parent links consistent, depths chain,
        every node resident somewhere, by_page exactly inverts page_id."""
        seen_pages: dict[int, bytes] = {}
        for key, node in self.nodes.items():
            assert node.key == key, "node key skew"
            parent = node.parent
            assert parent is not None, "detached node still indexed"
            assert parent is self.root or parent.key in self.nodes, \
                "parent not indexed"
            assert parent.children.get(key) is node, "parent link skew"
            assert node.depth == parent.depth + 1, "depth chain broken"
            assert node.page_id is not None or node.host_id is not None, \
                "node resident nowhere"
            assert node.page_id is None or node.host_id is None, \
                "node resident on BOTH tiers"
            if node.page_id is not None:
                assert node.page_id not in seen_pages, "page mapped twice"
                seen_pages[node.page_id] = key
            assert 0 <= node.last_use <= self.clock, "clock skew"
        assert seen_pages == {p: n.key for p, n in self.by_page.items()}, \
            "by_page index skew"
        assert self.root.page_id is None and self.root.host_id is None

    # -- checkpoint ---------------------------------------------------------

    def export_state(self) -> dict:
        """JSON-safe node list, parents before children (depth order)."""
        records = []
        for node in sorted(self.nodes.values(),
                           key=lambda n: (n.depth, n.key)):
            records.append({
                "key": node.key.hex(),
                "parent": node.parent.key.hex(),
                "depth": node.depth,
                "page": -1 if node.page_id is None else int(node.page_id),
                "host": -1 if node.host_id is None else int(node.host_id),
                "last_use": int(node.last_use),
                "ready": bool(node.ready),
            })
        return {"clock": int(self.clock), "nodes": records}

    def restore_state(self, state: dict) -> None:
        self.root = PrefixNode(b"", None, 0)
        self.nodes, self.by_page = {}, {}
        self.clock = int(state["clock"])
        for rec in state["nodes"]:
            key = bytes.fromhex(rec["key"])
            parent_key = bytes.fromhex(rec["parent"])
            parent = self.root if not parent_key else self.nodes[parent_key]
            node = PrefixNode(key, parent, int(rec["depth"]))
            node.last_use = int(rec["last_use"])
            node.ready = bool(rec.get("ready", True))
            if rec["page"] >= 0:
                node.page_id = int(rec["page"])
                self.by_page[node.page_id] = node
            if rec["host"] >= 0:
                node.host_id = int(rec["host"])
            parent.children[key] = node
            self.nodes[key] = node
        self.check()
