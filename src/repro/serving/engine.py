"""Continuous-batching serving engine over the shared FP8 paged pool.

The engine drives the EXISTING jitted steps (``steps.make_prefill_step`` /
``steps.make_decode_step`` — the same ``transformer.decode_step`` the
static-batch ``serve.generate`` paths run, dispatching attention through the
decode-backend registry) over a *dynamic* request population:

  * the decode step is compiled ONCE for a fixed ``max_batch`` slot array and
    a fixed shared pool; requests flow through slots with no *decode*
    recompiles — idle slots are parked on the allocator's scratch page and
    masked by ``seq_lens`` (the same pinning idea the fused scan uses for
    EOS rows). Prefill still retraces per distinct (group, prompt-length)
    shape; bucketing that is a ROADMAP follow-on;
  * admission/retirement and the page tables are host-side bookkeeping
    (``allocator.PageAllocator`` free list + refcounted prefix sharing,
    ``scheduler.Scheduler`` FCFS lifecycle); each step the engine pushes its
    slot→pages mapping into the jitted state via ``kvcache.pool_with_tables``;
  * prefill is batched per admission group (same prompt length → one bulk
    RoPE-aware quantized write into the allocated pages). Shared prefix pages
    are rewritten with bit-identical values (same tokens, same positions,
    deterministic quantization), which is what makes prefix sharing exact:
    the savings are pool pages, not changed numerics.

Greedy engine output is token-identical to the static-batch ``generate``
oracle for the same prompts/gen lengths (pinned by tests/test_serving.py);
MLA decode is memory-bound, so keeping many concurrent requests on one
weight pass is where the paper's pipeline pays off at serving time.

Virtual time = engine steps (arrival times are given in steps; no wall-clock
in traced code — wall-clock is only sampled host-side for throughput/TTFT
reporting), so a seeded workload schedules identically run-to-run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.kvcache import (PagedMLAPool, page_aligned_capacity,
                                pool_with_tables)
from repro.launch import steps as ST
from repro.models import transformer as T
from repro.serving.allocator import PageAllocator
from repro.serving.scheduler import Request, Scheduler, Status


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Host-side engine knobs (the model itself comes from ModelConfig)."""

    max_batch: int = 4             # decode slot count (static jit batch)
    n_pages: int = 0               # physical pool pages (0 = auto-size:
    #                                max_batch sequences at full span + scratch)
    max_pages_per_seq: int = 8     # page-table width (max context in pages)
    prefix_sharing: bool = True
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 0.0
    eos_id: int | None = None
    seed: int = 0

    def resolved_n_pages(self) -> int:
        if self.n_pages:
            return self.n_pages
        return self.max_batch * self.max_pages_per_seq + 1   # + scratch page


@dataclasses.dataclass
class RequestResult:
    rid: int
    status: str
    tokens: list[int]
    prompt_len: int
    ttft_steps: int                # first token step - arrival (virtual)
    latency_steps: int             # finish step - arrival (virtual)
    ttft_s: float                  # wall-clock first-token latency
    latency_s: float               # wall-clock total latency


class ServingEngine:
    """Admit → prefill → decode → retire over one shared paged pool."""

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        bad = [k for k in cfg.layer_pattern if k != "mla"]
        if bad or cfg.n_aux_tokens:
            raise ValueError(
                "the serving engine drives the paged MLA decode path; "
                f"layer pattern {cfg.layer_pattern} / aux tokens "
                f"{cfg.n_aux_tokens} are not pure-MLA")
        self.ecfg = ecfg
        self.page = cfg.page_size
        self.span_pages = ecfg.max_pages_per_seq
        self.n_pages = ecfg.resolved_n_pages()
        self.cfg = dataclasses.replace(cfg, kv_paged=True,
                                       kv_pool_pages=self.n_pages)
        self.params = params
        span_tokens = self.span_pages * self.page
        self.state = T.init_decode_state(self.cfg, ecfg.max_batch, span_tokens)
        self._prefill_fn = jax.jit(ST.make_prefill_step(self.cfg))
        self._decode_fn = jax.jit(ST.make_decode_step(self.cfg))

        self.allocator = PageAllocator(self.n_pages, self.page,
                                       prefix_sharing=ecfg.prefix_sharing)
        self.scheduler = Scheduler(ecfg.max_batch)
        self.table = np.zeros((ecfg.max_batch, self.span_pages), np.int32)
        self.last_tok = np.zeros((ecfg.max_batch,), np.int32)
        self.key = jax.random.PRNGKey(ecfg.seed)

        # warm the decode jit cache on the all-idle state (every slot parked
        # on the scratch page) so the first REAL decode step — and the
        # decode_tok_per_s window — never pays trace/compile; the returned
        # state is discarded, so the warm-up's scratch writes never land
        self._decode_fn(
            self.params, jnp.zeros((ecfg.max_batch,), jnp.int32),
            self._state_with_tables(self.table,
                                    np.zeros((ecfg.max_batch,), np.int32)),
            jnp.zeros((ecfg.max_batch,), jnp.int32))[0].block_until_ready()

        self.step_idx = 0
        self.decode_tokens = 0          # tokens produced by decode steps
        self.decode_seconds = 0.0
        self.evictions = 0
        self.util_series: list[float] = []
        self._wall: dict[int, dict[str, float]] = {}   # rid -> wall marks

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------

    def required_pages(self, prompt_len: int, max_new: int) -> int:
        """Worst-case private pages a request can hold: every resident token
        (prompt + all appended generations; the final sampled token is never
        appended) page-aligned — through the ONE sizing rule
        (``kvcache.page_aligned_capacity``) serve and the cache initializers
        share."""
        return page_aligned_capacity(prompt_len + max_new - 1,
                                     self.page) // self.page

    def submit(self, req: Request) -> None:
        if req.max_new < 1:
            raise ValueError("max_new must be >= 1")
        need = self.required_pages(req.prompt_len, req.max_new)
        if need > self.span_pages:
            raise ValueError(
                f"request {req.rid}: {need} pages exceed the page-table "
                f"width {self.span_pages} (prompt {req.prompt_len} + "
                f"{req.max_new} new tokens)")
        if need > self.allocator.capacity:
            raise ValueError(
                f"request {req.rid}: {need} pages exceed pool capacity "
                f"{self.allocator.capacity}")
        self._wall[req.rid] = {"arrival": time.time()}
        self.scheduler.submit(req)

    # ------------------------------------------------------------------
    # state plumbing (host tables -> jitted pytree)
    # ------------------------------------------------------------------

    def _map_pools(self, fn, *trees):
        return jax.tree.map(
            lambda leaf, *rest: fn(leaf, *rest)
            if isinstance(leaf, PagedMLAPool) else leaf,
            *trees, is_leaf=lambda x: isinstance(x, PagedMLAPool))

    def _state_with_tables(self, table: np.ndarray, seq_lens: np.ndarray):
        return self._map_pools(
            lambda pool: pool_with_tables(pool, table, seq_lens), self.state)

    def _adopt_pool_data(self, new_state) -> None:
        """Take the (functionally updated) pool page data from a prefill
        call back into the engine state; tables/seq_lens stay host-owned."""
        self.state = self._map_pools(
            lambda old, new: old._replace(content=new.content, rope=new.rope,
                                          scale=new.scale),
            self.state, new_state)

    def _seq_lens(self) -> np.ndarray:
        lens = np.zeros((self.ecfg.max_batch,), np.int32)
        for r in self.scheduler.active:
            lens[r.slot] = r.seq_len
        return lens

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------

    def _pick_tokens(self, rows: jax.Array, reqs: list[Request]) -> np.ndarray:
        """Next token for each request (``rows`` [len(reqs), V] aligned with
        ``reqs``), ONE dispatch + host transfer for the whole set. Sampled
        draws use per-request keys folded by token index, so a request's
        continuation is independent of what it happens to be co-batched
        with — reproducible run-to-run for a fixed seed regardless of
        arrival interleaving."""
        e = self.ecfg
        if e.temperature <= 0.0:
            return np.asarray(jnp.argmax(rows, -1))
        keys = jnp.stack([
            jax.random.fold_in(jax.random.fold_in(self.key, r.rid),
                               len(r.out_tokens)) for r in reqs])
        draw = jax.vmap(lambda row, k: ST.sample_logits(
            row[None], k, e.temperature, e.top_k, e.top_p)[0])
        return np.asarray(draw(rows, keys))

    def _emit(self, req: Request, tok: int) -> None:
        req.out_tokens.append(tok)
        self.last_tok[req.slot] = tok
        if len(req.out_tokens) == 1:
            req.first_token_step = self.step_idx
            self._wall[req.rid]["first"] = time.time()
        eos_hit = self.ecfg.eos_id is not None and tok == self.ecfg.eos_id
        if len(req.out_tokens) >= req.max_new or eos_hit:
            self._retire(req, Status.DONE)

    def _retire(self, req: Request, status: Status) -> None:
        slot = req.slot
        self.scheduler.retire(req, status, self.allocator, self.step_idx)
        self._wall[req.rid]["finish"] = time.time()
        if slot >= 0:
            self.table[slot] = 0          # park the slot on the scratch page
            self.last_tok[slot] = 0

    # ------------------------------------------------------------------
    # prefill
    # ------------------------------------------------------------------

    def _prefill_group(self, group: list[Request]) -> None:
        """Batched prefill of same-length admitted requests: one bulk
        quantized write through each request's freshly-written table row."""
        for r in group:
            row = np.zeros((self.span_pages,), np.int32)
            row[:len(r.pages)] = r.pages
            self.table[r.slot] = row
        rows = np.stack([self.table[r.slot] for r in group])
        prompts = jnp.asarray(np.stack([r.prompt for r in group]), jnp.int32)
        view = self._map_pools(
            lambda pool: pool_with_tables(
                pool, rows, np.zeros((len(group),), np.int32)), self.state)
        logits, new_state = self._prefill_fn(self.params, prompts, view)
        finite = np.asarray(jnp.all(jnp.isfinite(logits), axis=-1))
        if not finite.all():
            raise FloatingPointError(
                f"non-finite prefill logits for request(s) "
                f"{[r.rid for r, ok in zip(group, finite) if not ok]}")
        self._adopt_pool_data(new_state)
        toks = self._pick_tokens(logits, group)
        for r, tok in zip(group, toks):
            r.status = Status.DECODE
            self._emit(r, int(tok))

    def _admit_and_prefill(self) -> None:
        admitted = self.scheduler.admit(self.allocator, self.step_idx)
        by_len: dict[int, list[Request]] = {}
        for r in admitted:
            by_len.setdefault(r.prompt_len, []).append(r)
        for group in by_len.values():
            self._prefill_group(group)

    # ------------------------------------------------------------------
    # growth / eviction
    # ------------------------------------------------------------------

    def _ensure_capacity(self) -> None:
        """Before a decode step, every active request must have a page slot
        for the token the step will append (position ``seq_len``). Grow by
        one page on demand; when the pool is exhausted, evict the youngest
        active request (FCFS fairness) and retry."""
        for req in list(self.scheduler.active):
            if req.done:
                continue
            while req.seq_len >= len(req.pages) * self.page:
                assert len(req.pages) < self.span_pages, \
                    "submit() validation bounds the page run"
                grown = self.allocator.grow(1)
                if grown is not None:
                    req.pages.extend(grown)
                    self.table[req.slot, len(req.pages) - 1] = grown[0]
                    continue
                victim = self.scheduler.eviction_victim()
                self.evictions += 1
                self._retire(victim, Status.EVICTED)
                if victim is req:
                    break

    # ------------------------------------------------------------------
    # the step loop
    # ------------------------------------------------------------------

    def step(self) -> None:
        """One engine iteration: admit + prefill, grow, one decode step for
        every active slot, retire finished requests. Advances virtual time
        even when idle (so future arrivals are reached)."""
        self._admit_and_prefill()
        self._ensure_capacity()
        active = [r for r in self.scheduler.active
                  if r.status == Status.DECODE]
        if active:
            seq_lens = self._seq_lens()
            state = self._state_with_tables(self.table, seq_lens)
            t0 = time.time()
            logits, self.state = self._decode_fn(
                self.params, jnp.asarray(self.last_tok), state,
                jnp.asarray(seq_lens))
            logits.block_until_ready()
            self.decode_seconds += time.time() - t0
            finite = np.asarray(jnp.all(jnp.isfinite(logits), axis=-1))
            bad = [r.rid for r in active if not finite[r.slot]]
            if bad:
                raise FloatingPointError(
                    f"non-finite decode logits at step {self.step_idx} for "
                    f"request(s) {bad}")
            slots = np.array([r.slot for r in active], np.int32)
            toks = self._pick_tokens(logits[slots], active)
            for r, tok in zip(active, toks):
                self.decode_tokens += 1
                self._emit(r, int(tok))
        live = sum(r.seq_len for r in self.scheduler.active)
        self.util_series.append(self.allocator.stats(live).utilization)
        self.step_idx += 1

    def run(self, requests: list[Request]) -> list[RequestResult]:
        """Run a workload to drain. ``requests`` carry virtual arrival times
        (in engine steps); a request is enqueued once the engine clock
        reaches it — deterministic for a fixed workload + seed."""
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        i = 0
        while i < len(pending) or not self.scheduler.drained:
            while i < len(pending) and pending[i].arrival <= self.step_idx:
                self.submit(pending[i])
                i += 1
            self.step()
        out = []
        for r in sorted(self.scheduler.finished, key=lambda r: r.rid):
            w = self._wall[r.rid]
            out.append(RequestResult(
                rid=r.rid, status=r.status.value,
                tokens=[int(t) for t in r.out_tokens],
                prompt_len=r.prompt_len,
                ttft_steps=(r.first_token_step - int(r.arrival)
                            if r.first_token_step >= 0 else -1),
                latency_steps=r.finish_step - int(r.arrival),
                ttft_s=w.get("first", w["finish"]) - w["arrival"],
                latency_s=w["finish"] - w["arrival"]))
        return out

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------

    def metrics(self) -> dict[str, Any]:
        stats = self.allocator.stats()
        tps = self.decode_tokens / self.decode_seconds \
            if self.decode_seconds else 0.0
        return {
            "steps": self.step_idx,
            "decode_tokens": self.decode_tokens,
            "decode_tok_per_s": tps,
            "evictions": self.evictions,
            "pages": {
                "capacity": stats.capacity,
                "free": stats.free,
                "in_use": stats.in_use,
                "peak_in_use": stats.peak_in_use,
                "total_allocs": stats.total_allocs,
                "saved_by_sharing": stats.pages_saved_by_sharing,
            },
            "utilization_series": self.util_series,
        }
